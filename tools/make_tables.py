"""Render EXPERIMENTS.md tables from results/*.jsonl."""
import json, sys, pathlib

R = pathlib.Path("results")

def load(name):
    p = R / name
    if not p.exists(): return []
    return [json.loads(l) for l in p.read_text().splitlines()]

def fmt_dryrun(rows, title):
    out = [f"### {title}", "",
           "| arch | shape | ok | bytes/dev (GB) | HLO GFLOP/dev | coll GB/dev | collectives | note |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ok = "yes" if r.get("ok") else ("skip" if "SKIP" in r.get("note","") else "FAIL")
        colls = " ".join(f"{k}:{v}" for k,v in r.get("colls",{}).items())
        note = r.get("note","").replace("SKIP: ","")
        out.append(f"| {r['arch']} | {r['shape']} | {ok} | "
                   f"{r.get('temp_gb_dev','-')} | {r.get('hlo_gflops_dev','-')} | "
                   f"{r.get('coll_gb_dev','-')} | {colls} | {note[:70]} |")
    return "\n".join(out)

def fmt_roofline(rows):
    out = ["| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | dominant | MODEL_GFLOPs | useful ratio | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    FIX = {
      ("compute"): "larger per-chip batch or fewer remat replays (raise MXU occupancy)",
      ("memory"): "bigger fusion regions / larger attention KV chunks (fewer HBM round-trips)",
      ("collective"): "fewer param re-gathers (lower microbatch count) or HSDP to cap group size",
    }
    for r in rows:
        if not r.get("ok"): continue
        out.append(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']} | {r['t_memory_s']} | "
                   f"{r['t_collective_s']} | **{r['dominant']}** | {r['model_gflops']} | "
                   f"{r['useful_ratio']} | {FIX[r['dominant']]} |")
    return "\n".join(out)

single = load("dryrun.jsonl")
multi = load("dryrun_multipod.jsonl")
mode = sys.argv[1] if len(sys.argv) > 1 else "all"
if mode in ("all","dryrun"):
    print(fmt_dryrun(single, "Single-pod mesh 16x16 (256 chips)"))
    print()
    print(fmt_dryrun(multi, "Multi-pod mesh 2x16x16 (512 chips) — compile/sharding proof (uncalibrated costs)"))
if mode in ("all","roofline"):
    print(fmt_roofline(single))
