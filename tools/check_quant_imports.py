#!/usr/bin/env python
"""Back-compat shim: the quant.blockwise import guard now lives in the
lint framework (``repro.analysis.lint``, rule ``quant-blockwise``) --
run ``python tools/lint.py`` for the full rule set."""
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--select", "quant-blockwise", "--root", str(_ROOT)]))
