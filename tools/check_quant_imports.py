#!/usr/bin/env python
"""CI guard: hot paths must go through the kernels dispatch layer.

``repro.quant.blockwise`` is the REFERENCE implementation and parity
oracle; the execution engine for every quant hot path is
``repro.kernels.ops`` (Pallas on TPU, interpret elsewhere).  This check
fails if anything outside the allowed homes imports quant.blockwise
directly:

  * src/repro/kernels/   -- the dispatch layer and its oracles (ref.py)
    are BUILT on the reference; that is the point.
  * src/repro/quant/     -- the module itself.
  * tests/               -- parity suites compare against the reference.

Everything else (core/, models/, optim/, serve/, launch/, benchmarks/)
must import ``repro.kernels.ops`` (or ``repro.kernels.ref`` when a
benchmark deliberately models the unfused ablation).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# an import of the reference module, any spelling:
#   from repro.quant.blockwise import ... / import repro.quant.blockwise
#   from ..quant.blockwise import ...    / from .blockwise import ...
PAT = re.compile(
    r"^\s*(?:from\s+(?:repro\.|\.+)?quant\.blockwise\s+import"
    r"|import\s+repro\.quant\.blockwise"
    r"|from\s+(?:repro\.|\.+)?quant\s+import)",
    re.MULTILINE)

ALLOWED = ("src/repro/kernels/", "src/repro/quant/", "tests/")
SCAN = ("src", "benchmarks", "tools")


def main() -> int:
    bad = []
    for top in SCAN:
        for py in sorted((ROOT / top).rglob("*.py")):
            rel = py.relative_to(ROOT).as_posix()
            if rel == "tools/check_quant_imports.py":
                continue
            if any(rel.startswith(a) for a in ALLOWED):
                continue
            for m in PAT.finditer(py.read_text()):
                line = py.read_text()[:m.start()].count("\n") + 1
                bad.append(f"{rel}:{line}: {m.group(0).strip()}")
    if bad:
        print("hot paths must import repro.kernels.ops, not quant.blockwise:")
        for b in bad:
            print("  " + b)
        return 1
    print(f"ok: no direct quant.blockwise imports outside {ALLOWED}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
