#!/usr/bin/env python
"""Offline checkpoint resharding: rewrite a v2 checkpoint for a new plan.

    PYTHONPATH=src python tools/reshard.py SRC DST \
        --arch gemma2-2b --reduced --data 4 --model 1 [--tp N] \
        [--planner ragged] [--policies auto] [--drop-opt]

The destination layout is a fresh ``ShardingPlan`` resolved host-side
(``make_plan`` needs no devices), so an 8-way checkpoint reshard to 4-way —
or to a different TP degree, plan mode, or store format — runs anywhere,
e.g. on a single CPU node after a preemption resized the job.

Both sides are per-shard ``.npy`` files addressed through the per-tensor
shard index (``repro.core.reshard``), memmapped on both ends: peak host
memory is ONE tensor (plus a shard row), never a layer stack or a full
group buffer (``benchmarks/bench_reshard.py`` pins this).  Groups whose
layout and store are unchanged are copied bytewise; changed groups stream
masters tensor-by-tensor, then derive the destination store's leaves
shard-row by shard-row (bf16 rounding / ``ops.quantize`` requantization —
bitwise-identical to what a save-under-the-new-plan would write, because
the planner aligns tensor starts and S to the quant block; EF residuals
restart at zero).

Optimizer state rides along: moment-buffer families follow their
parameter's extents (8-bit codes/scales move on the aligned path and
refuse an outer-layout change), Shampoo/Muon per-layer factors are stored
unpadded (plan-independent) and follow their tensor's owning group across
a TP regrouping, dense leaves copy verbatim.  ``--drop-opt`` omits
optimizer state instead (the resumed job reinitializes it).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import shutil
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.checkpoint.ckpt import (group_meta, opt_shard_file,  # noqa: E402
                                   param_shard_file, shard_file_reader)
from repro.core.ragged import checkpoint_index  # noqa: E402
from repro.core.reshard import GroupIndex, copy_tensor  # noqa: E402
from repro.core.store import EF_KEY  # noqa: E402


def _entry_meta(entry) -> dict:
    """The dst meta.json group entry for a plan entry (mirror of
    ``ckpt.group_meta``, derived from the plan instead of a runtime)."""
    return {
        "index": checkpoint_index(entry.plan),
        "shard_size": entry.plan.shard_size,
        "num_shards": entry.plan.num_shards,
        "outer_size": entry.outer_size,
        "outer_dims": {k: int(v) for k, v in entry.outer_dims.items()},
        "n_layers": entry.n_layers,
        "mode": entry.plan.mode,
        "store": entry.store.fmt,
        "quant_block": entry.store.block,
        "ef_m": entry.store.ef_m,
    }


def _same_group(saved: dict, want: dict) -> bool:
    """Bytewise-copy eligibility: every layout AND store field matches."""
    keys = ("index", "shard_size", "num_shards", "outer_size", "outer_dims",
            "n_layers", "mode", "store", "ef_m")
    if any(saved.get(k) != want[k] for k in keys):
        return False
    if want["store"] == "q8_block" or want["ef_m"]:
        return saved.get("quant_block") == want["quant_block"]
    return True


def _open_rows(path, n_layers: int, row_len: int, dtype):
    """A zero-initialized dst ``.npy`` memmap shaped like one shard file."""
    shape = (n_layers, row_len) if n_layers else (row_len,)
    return np.lib.format.open_memmap(path, mode="w+", dtype=dtype,
                                     shape=shape)


def _rows_writer(mmaps):
    def write(j: int, layer):
        return mmaps[j] if layer is None else mmaps[j][layer]

    return write


def _reshard_group_params(gname, entry, sgroups, src_shards, dst_shards,
                          tensor_src, src_idx):
    """Stream one changed group's master, then derive its store leaves."""
    import jax.numpy as jnp

    from repro.kernels import ops

    dst = GroupIndex.from_entry(entry)
    store = entry.store
    S, L = entry.plan.shard_size, entry.n_layers or 0
    masters = {j: _open_rows(dst_shards / param_shard_file(gname, "master", j),
                             L, S, np.float32)
               for j in range(dst.num_rows)}
    write = _rows_writer(masters)
    for name in entry.plan.names:
        g_old = tensor_src.get(name)
        if g_old is None:
            raise ValueError(
                f"tensor {name!r} (group {gname!r}) not in source "
                f"checkpoint")
        s_idx = src_idx[g_old]
        if (s_idx.n_layers or 0) != L:
            raise ValueError(
                f"{name}: layer count changed ({s_idx.n_layers} -> {L})")
        read = shard_file_reader(
            src_shards, lambda j, g=g_old: param_shard_file(g, "master", j))
        for li in (range(L) if L else [None]):
            copy_tensor(s_idx, dst, name, read, write, layer=li)
    # derive the rest of the store's leaves shard-row by shard-row (S is a
    # block multiple, so per-row quantization == whole-buffer quantization)
    extra = {}
    if store.fmt == "bf16":
        for j, mm in masters.items():
            rows = mm if L else mm[None, :]
            for li in range(rows.shape[0]):
                rows[li] = np.asarray(
                    jnp.asarray(rows[li]).astype(jnp.bfloat16)
                    .astype(jnp.float32))
    elif store.quantized:
        for j in range(dst.num_rows):
            extra[("codes", j)] = _open_rows(
                dst_shards / param_shard_file(gname, "codes", j),
                L, S, np.int8)
            extra[("scales", j)] = _open_rows(
                dst_shards / param_shard_file(gname, "scales", j),
                L, S // store.block, np.float32)
        for j, mm in masters.items():
            rows = mm if L else mm[None, :]
            for li in range(rows.shape[0]):
                codes, scales = ops.quantize(jnp.asarray(rows[li]),
                                             store.block)
                dst_c = extra[("codes", j)]
                dst_s = extra[("scales", j)]
                (dst_c[li] if L else dst_c)[...] = np.asarray(codes)
                (dst_s[li] if L else dst_s)[...] = np.asarray(scales)
    if store.has_ef:
        for j in range(dst.num_rows):
            # freshly created memmaps are zero-filled == a reset EF history
            _open_rows(dst_shards / param_shard_file(gname, EF_KEY, j),
                       L, S * store.ef_m, np.float32)
    for mm in list(masters.values()) + list(extra.values()):
        mm.flush()


def _tensor_group_map(plan) -> dict:
    return {t: g for g, e in plan.groups.items() for t in e.plan.names}


def reshard(src, dst, new_plan, *, drop_opt: bool = False,
            verbose: bool = True) -> dict:
    """Rewrite checkpoint ``src`` into ``dst`` under ``new_plan``.

    Returns a summary dict: which groups were copied bitwise vs streamed,
    and how optimizer leaves moved.
    """
    src, dst = pathlib.Path(src), pathlib.Path(dst)
    meta_src = json.loads((src / "meta.json").read_text())
    if int(meta_src.get("version", 1)) < 2:
        raise ValueError(
            f"{src}: legacy (v1) checkpoint; load + re-save it under the "
            f"current format first (ckpt.load/save), then reshard")
    src_shards, dst_shards = src / "shards", dst / "shards"
    dst_shards.mkdir(parents=True, exist_ok=True)

    sgroups = meta_src["groups"]
    src_idx = {g: GroupIndex.from_meta(sg) for g, sg in sgroups.items()}
    tensor_src = {t: g for g, sg in sgroups.items() for t in sg["index"]}

    summary = {"copied": [], "streamed": [], "opt": "dropped" if drop_opt
               else "resharded"}
    dst_groups = {}
    for gname, entry in new_plan.groups.items():
        want = _entry_meta(entry)
        dst_groups[gname] = want
        saved = sgroups.get(gname)
        if saved is not None and _same_group(saved, want):
            store = entry.store
            rows = entry.outer_size * entry.plan.num_shards
            for leaf in (store.state_keys() or ("master",)):
                for j in range(rows):
                    f = param_shard_file(gname, leaf, j)
                    shutil.copyfile(src_shards / f, dst_shards / f)
            summary["copied"].append(gname)
        else:
            _reshard_group_params(gname, entry, sgroups, src_shards,
                                  dst_shards, tensor_src, src_idx)
            summary["streamed"].append(gname)
        if verbose:
            how = "copy" if gname in summary["copied"] else "stream"
            print(f"[reshard] params {gname}: {how}")

    manifest = []
    if not drop_opt:
        manifest = _reshard_opt(meta_src, new_plan, src_shards, dst_shards,
                                tensor_src, src_idx, verbose)

    meta = {"version": 2, "step": int(meta_src["step"]),
            "groups": dst_groups, "opt": manifest}
    (dst / "meta.json").write_text(json.dumps(meta, indent=1))
    (dst / "plan.json").write_text(
        json.dumps(new_plan.to_json(), sort_keys=True, indent=1))
    return summary


def _reshard_opt(meta_src, new_plan, src_shards, dst_shards, tensor_src,
                 src_idx, verbose):
    """Move the optimizer manifest: buffer families re-follow their
    parameters under the new plan; factors/dense copy (factors follow a
    migrated tensor's new owning group)."""
    families: dict[tuple, dict] = {}
    others = []
    for ent in meta_src.get("opt", []):
        if ent["kind"] == "buffer":
            families.setdefault(tuple(ent["path"][:-1]), {})[
                ent["group"]] = ent
        else:
            others.append(ent)

    new_tensor_group = _tensor_group_map(new_plan)
    sgroups = meta_src["groups"]
    manifest = []
    fid = 0
    for prefix, group_ents in sorted(families.items()):
        for gname, entry in new_plan.groups.items():
            dst = GroupIndex.from_entry(entry)
            file = f"o__{fid:03d}"
            fid += 1
            src_ent = group_ents.get(gname)
            want = _entry_meta(entry)
            div = src_ent["div"] if src_ent is not None else next(
                e["div"] for e in group_ents.values())
            same = (src_ent is not None
                    and _same_layout_fields(sgroups[gname], want))
            if same:
                for j in range(dst.num_rows):
                    shutil.copyfile(
                        src_shards / opt_shard_file(src_ent["file"], j),
                        dst_shards / opt_shard_file(file, j))
                dtype = src_ent["dtype"]
            else:
                dtype = _remap_opt_family(prefix, gname, entry, dst, div,
                                          group_ents, src_shards, dst_shards,
                                          file, tensor_src, src_idx)
            manifest.append({"path": list(prefix) + [gname],
                             "kind": "buffer", "group": gname, "div": div,
                             "dtype": dtype, "file": file})
            if verbose:
                print(f"[reshard] opt {'/'.join(prefix)}/{gname}: "
                      f"{'copy' if same else 'stream'}")
    for ent in others:
        file = f"o__{fid:03d}"
        fid += 1
        new_ent = dict(ent, file=file)
        if ent["kind"] == "factor":
            key = ent["path"][-1]
            g_old, rest = key.split("/", 1)
            tname = rest.rsplit("/", 1)[0]
            g_new = new_tensor_group.get(tname)
            if g_new is None:
                raise ValueError(
                    f"optimizer factor {key!r}: tensor {tname!r} not in "
                    f"the new plan")
            if g_new != g_old:
                # the tensor migrated groups (TP regrouping): the factor
                # follows it — rewrite the key; local dims are validated
                # shape-wise at load
                new_ent["path"] = ent["path"][:-1] + [f"{g_new}/{rest}"]
                new_ent["group"] = g_new
        shutil.copyfile(src_shards / f"{ent['file']}.npy",
                        dst_shards / f"{file}.npy")
        manifest.append(new_ent)
    return manifest


def _same_layout_fields(saved: dict, want: dict) -> bool:
    keys = ("index", "shard_size", "num_shards", "outer_size", "outer_dims",
            "n_layers", "mode")
    return all(saved.get(k) == want[k] for k in keys)


def _remap_opt_family(prefix, gname, entry, dst, div, group_ents, src_shards,
                      dst_shards, file, tensor_src, src_idx):
    L = entry.n_layers or 0
    sl = entry.plan.shard_size // div
    mmaps = None
    dtype = None
    for name in entry.plan.names:
        g_old = tensor_src.get(name)
        src_ent = group_ents.get(g_old) if g_old is not None else None
        if src_ent is None:
            raise ValueError(
                f"optimizer state {'/'.join(prefix)}: no source buffer for "
                f"tensor {name!r} (old group {g_old!r})")
        if src_ent["div"] != div:
            raise ValueError(
                f"optimizer state {'/'.join(prefix)}: block granularity "
                f"changed ({src_ent['div']} -> {div}); 8-bit optimizer "
                f"state cannot cross it — use --drop-opt")
        read = shard_file_reader(
            src_shards, lambda j, f=src_ent["file"]: opt_shard_file(f, j))
        if mmaps is None:
            probe = np.asarray(read(0, 0 if L else None))
            dtype = probe.dtype
            mmaps = {j: _open_rows(
                dst_shards / opt_shard_file(file, j), L, sl, dtype)
                for j in range(dst.num_rows)}
        write = _rows_writer(mmaps)
        s_idx = src_idx[g_old]
        if (s_idx.n_layers or 0) != L:
            raise ValueError(
                f"optimizer state {'/'.join(prefix)}: layer count changed "
                f"for {name!r} ({s_idx.n_layers} -> {L})")
        aligned = div > 1 or np.dtype(dtype).kind in "iu"
        for li in (range(L) if L else [None]):
            copy_tensor(s_idx, dst, name, read, write,
                        layer=li, div=div, aligned=aligned)
    for mm in (mmaps or {}).values():
        mm.flush()
    return str(dtype) if dtype is not None else "float32"


def build_new_plan(args):
    """Resolve the destination ShardingPlan from CLI args — pure host-side
    metadata (no jax devices touched)."""
    from repro.configs import build_model, get_config
    from repro.core.policy import make_plan

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.optimizer:
        cfg = dataclasses.replace(cfg, optimizer=args.optimizer)
    if args.tp:
        par = cfg.parallel
        if args.tp > 1:
            par = dataclasses.replace(
                par, tp=args.tp,
                fsdp_axes=tuple(a for a in par.fsdp_axes if a != "model")
                or ("data",))
        else:
            par = dataclasses.replace(par, tp=1)
        cfg = dataclasses.replace(cfg, parallel=par)
    model = build_model(cfg)
    return make_plan(model, {"data": args.data, "model": args.model},
                     args.policies, planner=args.planner)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="reshard a v2 checkpoint to a new mesh/TP/plan")
    ap.add_argument("src")
    ap.add_argument("dst")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--data", type=int, default=1, help="new data axis size")
    ap.add_argument("--model", type=int, default=1,
                    help="new model axis size")
    ap.add_argument("--tp", type=int, default=0,
                    help="override the arch config's TP degree")
    ap.add_argument("--planner", default="ragged")
    ap.add_argument("--policies", default=None)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--drop-opt", action="store_true",
                    help="omit optimizer state from the output")
    args = ap.parse_args(argv)

    new_plan = build_new_plan(args)
    summary = reshard(args.src, args.dst, new_plan, drop_opt=args.drop_opt)
    print(f"[reshard] done: {len(summary['copied'])} group(s) copied "
          f"bitwise, {len(summary['streamed'])} streamed; "
          f"opt {summary['opt']}")


if __name__ == "__main__":
    main()
