#!/usr/bin/env python
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Verify shipped configs' plans on the 8-shard host mesh.

For each arch (reduced CPU-smoke geometry, same family/structure) and
each schedule variant, build the runtime, trace one train step (pure
abstract eval -- nothing compiles), and prove every invariant the plan
declares (``repro.analysis``): wire legs + byte totals, wire dtypes,
ring-chunk snapping, gathered-buffer peak, fused dequant, EF threading.
Exit nonzero on any Violation -- the ``static-analysis`` CI job runs
``--all``.

``--break ring-chunk|wire-bytes`` demonstrates the negative path: the
runtime is real, but the plan it is verified against is tampered (a
ring chunk forced past ``_snap_chunk`` off the quant-block grid / a
codec whose bytes diverge from the declared ``gather_wire_mb``), and
the tool must exit nonzero naming group, invariant, and
expected-vs-found.
"""
import argparse
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))


def build_runtime(arch: str, variant: str):
    import jax.numpy as jnp

    from repro.configs import build_model, get_config
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import VARIANTS
    from repro.launch.mesh import make_local_mesh

    cfg = get_config(arch).reduced()
    tp = max(1, cfg.parallel.tp, cfg.parallel.ep)
    mesh = make_local_mesh(8 // tp, tp)
    sched = None
    if variant == "q8":
        sched = dataclasses.replace(
            VARIANTS["overlap_all"], param_store="q8_block",
            reduce_wire="q8_block", reduce_dtype=None,
            reduce_mode="ring_acc", gather_mode="ring")
    elif variant != "default":
        sched = VARIANTS[variant]
    return FSDPRuntime(build_model(cfg), mesh, schedule=sched,
                       compute_dtype=jnp.bfloat16)


def tamper(plan, mode: str):
    """A deliberately broken copy of ``plan`` for the negative demo."""
    gname = max(plan.groups, key=lambda n: plan.groups[n].plan.total)
    e = plan.groups[gname]
    if mode == "ring-chunk":
        # not a multiple of the quant block: _snap_chunk's unit-1 wire
        # snap and the block-aligned snap disagree -> blocks straddle
        # ring messages
        pol = dataclasses.replace(e.policy,
                                  ring_chunk_elems=e.quant_block + 1)
    elif mode == "wire-bytes":
        # plan claims a bf16 cast wire; the runtime's traced program
        # ships int8 codes + fp32 scales -> comm legs missing, dtypes
        # illegal, byte totals diverge from gather_wire_mb
        pol = dataclasses.replace(e.policy, store="bf16", reduce_wire=None)
    else:
        raise SystemExit(f"unknown --break mode {mode!r}")
    e2 = dataclasses.replace(e, policy=pol)
    return dataclasses.replace(plan, groups={**dict(plan.groups), gname: e2})


def main(argv=None) -> int:
    from repro.analysis import verify_runtime
    from repro.configs import ASSIGNED_ARCH_IDS

    ap = argparse.ArgumentParser(
        description="verify shipped configs' plans on the host mesh")
    ap.add_argument("--config", action="append", default=None,
                    help="arch id (repeatable); see repro.configs")
    ap.add_argument("--all", action="store_true",
                    help="verify every assigned arch")
    ap.add_argument("--variant", action="append", default=None,
                    choices=["default", "q8"],
                    help="schedule variants per arch (default: both)")
    ap.add_argument("--break", dest="break_mode", default=None,
                    choices=["ring-chunk", "wire-bytes"],
                    help="tamper the plan and demand a Violation "
                         "(negative-path demo; single --config, q8 variant)")
    ap.add_argument("--profile", default=None,
                    help="comm profile path for the freshness check")
    args = ap.parse_args(argv)

    archs = list(ASSIGNED_ARCH_IDS) if args.all else (args.config or [])
    if not archs:
        ap.error("pass --config <arch> or --all")

    if args.break_mode:
        rt = build_runtime(archs[0], "q8")
        report = verify_runtime(rt, plan=tamper(rt.plan, args.break_mode))
        print(report.summary())
        if report.ok:
            print(f"--break {args.break_mode}: tampered plan verified "
                  f"clean -- the verifier has no teeth", file=sys.stderr)
            return 1
        print(f"--break {args.break_mode}: violation detected as expected")
        return 0

    failed = 0
    for arch in archs:
        for variant in args.variant or ["default", "q8"]:
            rt = build_runtime(arch, variant)
            report = verify_runtime(rt, profile_path=args.profile)
            status = "ok" if report.ok else "FAIL"
            print(f"[{status}] {arch} variant={variant}: "
                  f"{len(report.checked)} invariants, "
                  f"{len(report.errors)} violations, "
                  f"{len(report.warnings)} warnings")
            for v in report.violations:
                print(f"  {v}")
            failed += not report.ok
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
