#!/usr/bin/env python
"""Single lint entry point: runs ``repro.analysis.lint`` over the repo.

Bootstraps ``src/`` onto sys.path so CI jobs (and humans) can run it as
plain ``python tools/lint.py`` with no PYTHONPATH setup.  Rule docs and
the registry live in ``src/repro/analysis/lint.py``; select a subset
with ``--select RULE`` (repeatable).
"""
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] + ["--root", str(_ROOT)]
                  if "--root" not in sys.argv else sys.argv[1:]))
