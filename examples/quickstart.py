"""Quickstart: shard a model with veScale-FSDP-style RaggedShard planning
and train it for a few steps on synthetic data.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer


def main():
    # 1. pick an architecture (any of the 12 registered configs) and reduce
    #    it to CPU scale; the full config is identical code at mesh scale.
    cfg = get_config("gemma2-2b").reduced()

    # 2. build the model and wrap it for the mesh -- this runs the paper's
    #    planner (Algorithm 1) per communication group and backs every group
    #    with a flat DBuffer sharded over the FSDP axes.
    mesh = make_local_mesh(data=1, model=1)
    model = build_model(cfg)
    runtime = FSDPRuntime(model, mesh)
    for name, lo in runtime.layouts.items():
        print(f"group {name:12s} shard={lo.plan.shard_size:>10,} elems  "
              f"padding={lo.plan.padding_ratio:.4%}  "
              f"tensors={len(lo.plan.placements)}")

    # 3. init + train
    params = runtime.init_params(seed=0)
    optimizer = make_optimizer(cfg)
    opt_state = optimizer.init(runtime)
    train_step = runtime.make_train_step(optimizer)

    stream = SyntheticStream(DataConfig(cfg.vocab, 64, 8), cfg)
    step = jnp.int32(0)
    for i in range(20):
        batch = stream.shard(stream.batch(i), runtime)
        params, opt_state, step, m = train_step(params, opt_state, step,
                                                batch)
        if i % 5 == 0 or i == 19:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"grad_norm {float(m['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
