"""Continuous-batching serving: requests of different lengths stream through
a fixed slot pool; finished requests retire and queued ones are admitted
without stalling the batch.  One compiled decode shape for the whole run.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import numpy as np

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.launch.mesh import make_local_mesh
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("qwen2.5-14b").reduced()
    mesh = make_local_mesh(1, 1)
    model = build_model(cfg)
    rt = FSDPRuntime(model, mesh)
    params = rt.init_params(0)

    eng = ServeEngine(rt, model, params, pool=3, max_len=96)
    rng = np.random.default_rng(0)
    n_req = 7
    for i in range(n_req):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab, (4 + 3 * i,)).astype(np.int32),
            max_new=6 + (i % 3),
        ))

    t0 = time.time()
    steps = 0
    while eng.queue or any(s.req for s in eng.slots):
        n = eng.step()
        steps += 1
        if steps % 10 == 0:
            done = len(eng.finished)
            print(f"step {steps:3d}: {n} active rows, {done}/{n_req} done")
    dt = time.time() - t0
    print(f"\nserved {n_req} requests in {steps} engine steps ({dt:.1f}s)")
    for r in sorted(eng.finished, key=lambda r: r.uid):
        print(f"  req[{r.uid}] prompt_len={len(r.prompt):2d} -> {r.out}")


if __name__ == "__main__":
    main()
