"""Batched MoE serving example: prefill + decode with ZeRO-3 parameter
gathering and top-k expert routing.

    PYTHONPATH=src python examples/serve_moe.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.launch.mesh import make_local_mesh


def main():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    mesh = make_local_mesh(1, 1)
    model = build_model(cfg)
    rt = FSDPRuntime(model, mesh)
    params = rt.init_params(0)
    prefill = rt.make_prefill_step()
    decode = rt.make_decode_step()

    B, P, GEN = 4, 24, 12
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, P)), jnp.int32)}
    cache = model.init_cache(B, P + GEN)

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    print(f"prefill {B} prompts x {P} tokens "
          f"({cfg.n_experts} experts, top-{cfg.top_k}) in {time.time()-t0:.2f}s")

    seqs = [np.asarray(nxt)]
    for i in range(GEN - 1):
        db = {"tokens": nxt[:, None]}
        logits, cache = decode(params, db, cache, jnp.int32(P + i))
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        seqs.append(np.asarray(nxt))
    gen = np.stack(seqs, 1)
    for b in range(B):
        print(f"request[{b}] -> {gen[b].tolist()}")


if __name__ == "__main__":
    main()
