"""Figure 10(b) reproduction: distributed Muon vs AdamW loss curves.

Muon's Newton-Schulz step needs whole 2-D matrices; RaggedShard's
redistribute (here: layer-resharding across the FSDP group, DESIGN.md)
gives each device a load-balanced set of full matrices to precondition.

    PYTHONPATH=src python examples/muon_demo.py
"""
import dataclasses

import jax.numpy as jnp

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

STEPS = 120


def run(optname: str, lr: float):
    cfg = dataclasses.replace(
        get_config("qwen2.5-14b").reduced(), optimizer=optname,
        learning_rate=lr)
    mesh = make_local_mesh(1, 1)
    model = build_model(cfg)
    rt = FSDPRuntime(model, mesh)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    stream = SyntheticStream(DataConfig(cfg.vocab, 64, 8, seed=2), cfg)
    step = jnp.int32(0)
    losses = []
    for i in range(STEPS):
        b = stream.shard(stream.batch(i), rt)
        params, state, step, m = fn(params, state, step, b)
        losses.append(float(m["loss"]))
    return losses


def main():
    adamw = run("adamw", 1e-3)
    muon = run("muon", 3e-3)
    print(f"{'step':>5s} {'adamw':>8s} {'muon':>8s}")
    for i in range(0, STEPS, 10):
        print(f"{i:5d} {adamw[i]:8.4f} {muon[i]:8.4f}")
    print(f"final {adamw[-1]:8.4f} {muon[-1]:8.4f}")
    print("\npaper Fig.10b: Muon converges faster, stabilizing ~0.01 lower. "
          "At this 2-layer/256-d smoke scale the advantage is within noise; "
          "we check Muon trains comparably (gap < 0.25) -- the distributed "
          "redistribute machinery itself is verified exactly in "
          "tests/test_multidevice.py and tests/test_optim.py")
    assert muon[-1] <= adamw[-1] + 0.25, (muon[-1], adamw[-1])


if __name__ == "__main__":
    main()
