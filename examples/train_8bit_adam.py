"""Figure 10(a) reproduction: 8-bit Adam (block-wise quantized moments)
loss curve vs fp32 AdamW on the same model/data.

RaggedShard makes this communication-free: the planner aligns every tensor
start and the shard size to the quant block, so each device quantizes its
local shard independently (no metadata exchange -- the paper's point).

    PYTHONPATH=src python examples/train_8bit_adam.py
"""
import dataclasses

import jax.numpy as jnp

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

STEPS = 120


def run(optname: str):
    cfg = dataclasses.replace(
        get_config("gpt-oss-120b").reduced(), optimizer=optname,
        quant_block=64, learning_rate=1e-3)
    mesh = make_local_mesh(1, 1)
    model = build_model(cfg)
    rt = FSDPRuntime(model, mesh)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    stream = SyntheticStream(DataConfig(cfg.vocab, 64, 8, seed=1), cfg)
    step = jnp.int32(0)
    losses = []
    for i in range(STEPS):
        b = stream.shard(stream.batch(i), rt)
        params, state, step, m = fn(params, state, step, b)
        losses.append(float(m["loss"]))
    return losses


def main():
    l32 = run("adamw")
    l8 = run("adam8bit")
    print(f"{'step':>5s} {'adamw':>8s} {'adam8bit':>9s}")
    for i in range(0, STEPS, 10):
        print(f"{i:5d} {l32[i]:8.4f} {l8[i]:9.4f}")
    print(f"final {l32[-1]:8.4f} {l8[-1]:9.4f}")
    gap = abs(l8[-1] - l32[-1])
    print(f"\nfinal-loss gap = {gap:.3f} "
          f"(paper Fig.10a: curves track closely; occasional spikes are "
          f"characteristic of reduced-precision states)")
    assert gap < 0.3, "8-bit Adam diverged from fp32 AdamW"


if __name__ == "__main__":
    main()
