"""Figure 8 reproduction (CPU-scale): end-to-end train-step throughput and
peak memory across planner modes == the systems the paper compares.

  ragged   = veScale-FSDP        (planned layout, zero-copy unpack)
  fsdp2    = PyTorch fully_shard (per-param even shard, interleaved copies)
  megatron = Megatron-FSDP       (concat + row/device padding)
  naive    = unplanned concat    (Fig. 6(a); blocks straddle shards)

Wall time on one CPU device captures the copy/padding overheads (the
collective terms come from the dry-run roofline instead).  Memory = XLA
temp allocation from compiled memory_analysis.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

from .common import emit, timeit

MODES = ["ragged", "fsdp2", "megatron", "naive"]


def run(quick: bool = False, arch: str = "gpt-oss-120b"):
    cfg = get_config(arch).reduced()
    # a bit larger than smoke scale so copies matter
    if not quick:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=512, d_ff=1024,
                                  head_dim=128)
    mesh = make_local_mesh(1, 1)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (8, 128)), jnp.int32)}

    out = {}
    base = None
    for mode in MODES:
        model = build_model(cfg)
        rt = FSDPRuntime(model, mesh, planner=mode, donate=False)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)

        def step(params=params, state=state, st=st, fn=fn):
            return fn(params, state, st, batch)

        us = timeit(step, iters=5 if quick else 10, warmup=2)
        # memory: compile the step and read temp bytes
        lowered = fn.lower(params, state, st, batch)
        mem = lowered.compile().memory_analysis()
        temp = getattr(mem, "temp_size_in_bytes", 0)
        pad = {n: lo.plan.padding_ratio for n, lo in rt.layouts.items()}
        tok_s = 8 * 128 / (us / 1e6)
        if base is None:
            base = us
        out[mode] = (us, temp)
        emit(f"fig8/{arch}/{mode}/step", us,
             f"tokens_per_s={tok_s:.0f};temp_mb={temp/1e6:.1f};"
             f"speedup_vs_mode={base/us:.3f};pad_layers={pad.get('layers', 0):.4f}")
    return out


if __name__ == "__main__":
    run()
