"""Figure 8 reproduction (CPU-scale): end-to-end train-step throughput and
peak memory across planner modes == the systems the paper compares.

  ragged   = veScale-FSDP        (planned layout, zero-copy unpack)
  fsdp2    = PyTorch fully_shard (per-param even shard, interleaved copies)
  megatron = Megatron-FSDP       (concat + row/device padding)
  naive    = unplanned concat    (Fig. 6(a); blocks straddle shards)

Wall time on one CPU device captures the copy/padding overheads (the
collective terms come from the dry-run roofline instead).  Memory = XLA
temp allocation from compiled memory_analysis.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.schedule import APPROX_VARIANTS, VARIANTS
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

from .common import emit, timeit

MODES = ["ragged", "fsdp2", "megatron", "naive"]

# persisted --schedule artifact (repo root, next to BENCH_kernels.json):
# per-CommSchedule step time + memory/wire accounting, the end-to-end
# counterpart of the BENCH_comm.json micro-profile
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_e2e.json")


def _bench_cfg(arch: str, quick: bool):
    cfg = get_config(arch).reduced()
    # a bit larger than smoke scale so copies matter
    if not quick:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=512, d_ff=1024,
                                  head_dim=128)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (8, 128)), jnp.int32)}
    return cfg, batch


def _measure_step(cfg, rt, batch, quick: bool):
    """Median train-step wall time (us) + compiled temp bytes."""
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)

    def step(params=params, state=state, st=st, fn=fn):
        return fn(params, state, st, batch)

    us = timeit(step, iters=5 if quick else 10, warmup=2)
    mem = fn.lower(params, state, st, batch).compile().memory_analysis()
    return us, getattr(mem, "temp_size_in_bytes", 0)


def run(quick: bool = False, arch: str = "gpt-oss-120b"):
    cfg, batch = _bench_cfg(arch, quick)
    mesh = make_local_mesh(1, 1)
    out = {}
    base = None
    for mode in MODES:
        rt = FSDPRuntime(build_model(cfg), mesh, planner=mode, donate=False)
        us, temp = _measure_step(cfg, rt, batch, quick)
        pad = {n: lo.plan.padding_ratio for n, lo in rt.layouts.items()}
        tok_s = 8 * 128 / (us / 1e6)
        if base is None:
            base = us
        out[mode] = (us, temp)
        emit(f"fig8/{arch}/{mode}/step", us,
             f"tokens_per_s={tok_s:.0f};temp_mb={temp/1e6:.1f};"
             f"speedup_vs_mode={base/us:.3f};pad_layers={pad.get('layers', 0):.4f}")
    return out


def run_schedules(quick: bool = False, arch: str = "gpt-oss-120b"):
    """Per-CommSchedule step time + temp memory on the ragged planner: the
    cost/benefit of prefetch double-buffering, ring vs xla gathers,
    skipping reshard, wire/reduce dtype choices (all numerically identical
    on one device), plus the approx variants (ring_acc reduce, q8_block
    stores, the q8_block gradient reduce wire).  ``gathered_peak_mb`` is
    the analytic peak of live gathered layer buffers -- the quantity the
    two-slot prefetch bounds at 2 per depth (the retention bug made it
    n_layers).  ``gather_wire_mb`` is the bytes one forward pass's
    parameter all-gathers put on the wire: compare the fp32_wire row
    (4 B/element) against the q8 rows (1 B/element codes + per-block
    scales) for the ~4x quantized-store drop.  ``reduce_wire_mb`` is the
    mirror for the gradient direction: compare fp32_reduce (4 B/element)
    against the q8_reduce rows for the same >=3x QSDP gradient-wire
    drop."""
    cfg, batch = _bench_cfg(arch, quick)
    mesh = make_local_mesh(1, 1)
    out = {}
    base = None
    # measure "default" first so the speedup ratio really is vs. default,
    # whatever order VARIANTS declares
    order = ["default"] + [k for k in VARIANTS if k != "default"]
    order += list(APPROX_VARIANTS)
    persisted = {}
    for name in order:
        sched = VARIANTS.get(name) or APPROX_VARIANTS[name]
        rt = FSDPRuntime(build_model(cfg), mesh, schedule=sched,
                         donate=False)
        # the resolved ShardingPlan: per-group policy, shard size S,
        # padding, predicted gather wire -- auditable without running a step
        print(f"-- {name} --")
        print(rt.plan.describe())
        us, temp = _measure_step(cfg, rt, batch, quick)
        if base is None:
            base = us
        out[name] = (us, temp)
        persisted[name] = {
            "step_us": us, "temp_mb": temp / 1e6,
            "gathered_peak_mb": rt.gathered_peak_bytes() / 1e6,
            "gather_wire_mb": rt.gather_wire_bytes() / 1e6,
            "reduce_wire_mb": rt.reduce_wire_bytes() / 1e6,
            "speedup_vs_default": base / us,
            "schedule": sched.describe()}
        emit(f"sched/{arch}/{name}/step", us,
             f"temp_mb={temp/1e6:.1f};"
             f"gathered_peak_mb={rt.gathered_peak_bytes()/1e6:.2f};"
             f"gather_wire_mb={rt.gather_wire_bytes()/1e6:.2f};"
             f"reduce_wire_mb={rt.reduce_wire_bytes()/1e6:.2f};"
             f"speedup_vs_default={base/us:.3f};"
             f"{sched.describe().replace(' ', ';')}")
    with open(BENCH_JSON, "w") as f:
        json.dump({"backend": jax.default_backend(), "quick": quick,
                   "arch": arch, "schedules": persisted},
                  f, indent=2, sort_keys=True)
        f.write("\n")
    emit(f"sched/{arch}/bench_json", 0.0, f"wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", action="store_true",
                    help="benchmark CommSchedule variants instead of planners")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="gpt-oss-120b")
    a = ap.parse_args()
    (run_schedules if a.schedule else run)(quick=a.quick, arch=a.arch)
