"""Kernel microbenchmarks: fused Pallas (interpret on CPU) vs unfused jnp
reference.  On CPU the interpret-mode kernel is *slower* (it's a Python
interpreter of the kernel body) -- the number that matters here is the
oracle agreement + the HBM-stream count derived from the kernel structure;
wall-time wins appear on real TPU hardware.  We therefore report the jnp
reference timing and the analytic bytes-moved ratio, and persist the fused
entries to BENCH_kernels.json at the repo root (the CI artifact).

BENCH_kernels.json holds a HISTORY: each run appends one entry
(``{"history": [{"backend", "quick", "fused_kernels": {...}}, ...]}``)
instead of overwriting, so regressions across commits stay visible in the
artifact.  A pre-history flat file migrates in place as the first entry."""
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.compat import float8_dtypes
from repro.kernels import ops, ref

from .common import emit, timeit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")


_INT_VIEW = {1: np.int8, 2: np.int16, 4: np.int32}


def _ulp_agree(got, want, max_ulp=4):
    """Integer-representation distance <= max_ulp per leaf -- the
    adam8bit parity class (ops.py PARITY tags): the log-space v decode's
    exp drifts by a last ulp between the pallas interpreter and the
    fused reference graph."""
    import jax

    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        iv = _INT_VIEW[np.dtype(np.asarray(a).dtype).itemsize]
        d = np.abs(np.asarray(a).view(iv).astype(np.int64)
                   - np.asarray(b).view(iv).astype(np.int64))
        if d.max(initial=0) > max_ulp:
            return False
    return True


def _append_history(entry: dict) -> dict:
    """Append ``entry`` to the BENCH_kernels.json history (migrating a
    pre-history flat dict into the first history slot) and return the
    full document written."""
    doc = {"history": []}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                old = json.load(f)
        except (OSError, json.JSONDecodeError):
            old = None
        if isinstance(old, dict):
            if isinstance(old.get("history"), list):
                doc["history"] = old["history"]
            elif "fused_kernels" in old:      # pre-history flat schema
                doc["history"] = [old]
    doc["history"].append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def run(quick: bool = False):
    n = 2**16 if quick else 2**20
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    g = jnp.asarray(rng.normal(size=n).astype(np.float32))
    m = jnp.asarray(rng.normal(size=n).astype(np.float32)) * 0.1
    v = jnp.abs(jnp.asarray(rng.normal(size=n).astype(np.float32))) * 0.01
    mask = jnp.ones((n,), jnp.float32)
    import jax

    f32 = jax.jit(lambda *a: ref.adamw_update_ref(
        *a, 1e-3, 0.9, 0.95, 1e-8, 0.1, 0.5, 0.25))
    us = timeit(f32, w, g, m, v, mask, iters=5 if quick else 20)
    # unfused jnp chain touches w,g,m,v,mask reads + m,v,upd,w writes with
    # intermediate spills ~ 12 streams; fused kernel: 5 in + 3 out
    emit("kernel/adamw_ref_jnp", us,
         f"n={n};fused_hbm_streams=8;unfused_streams~12;expected_tpu_gain="
         f"{12/8:.2f}x")

    m8, ms = ref.quantize_ref(m, 1024)
    v8, vs = ref.quantize_ref(v, 1024)
    f8 = jax.jit(lambda *a: ref.adam8bit_update_ref(
        *a, 1e-3, 0.9, 0.95, 1e-8, 0.1, 0.5, 0.25, 1024))
    us8 = timeit(f8, w, g, m8, v8, ms, vs, mask, iters=5 if quick else 20)
    emit("kernel/adam8bit_ref_jnp", us8,
         f"n={n};state_bytes_vs_fp32={(2*1+8/1024)/(8):.3f}")

    q = jax.jit(lambda x: ref.quantize_ref(x, 1024))
    usq = timeit(q, w, iters=5 if quick else 20)
    emit("kernel/blockwise_quant_ref", usq, f"n={n}")

    # ------------------------------------------------------------------ #
    # fused quant hot-path kernels (PR: kernels as the execution engine)
    # ------------------------------------------------------------------ #
    fused = {}
    iters = 5 if quick else 20
    block = 1024

    # gather path: fused dequant-into-compute-dtype.  Unfused moves the
    # f32 dequant buffer to HBM and back (codes in + f32 out + f32 in +
    # bf16 out = 1+4+4+2 bytes/elt); fused streams codes in, bf16 out.
    codes, scales = ref.quantize_ref(w, block)
    d_ref = jax.jit(lambda c, s: ref.dequantize_into_ref(
        c, s, block, jnp.bfloat16))
    us_d = timeit(d_ref, codes, scales, iters=iters)
    match = bool(np.array_equal(
        np.asarray(ops.dequantize_into(codes, scales, block,
                                       out_dtype=jnp.bfloat16)),
        np.asarray(d_ref(codes, scales))))
    emit("kernel/dequantize_into_ref_jnp", us_d,
         f"n={n};bytes_unfused={11};bytes_fused={3};expected_tpu_gain="
         f"{11/3:.2f}x;fused_matches_jitted_ref={match}")
    fused["dequantize_into"] = {
        "ref_us": us_d, "n": n, "block": block, "parity": "BITWISE",
        "fused_matches_jitted_ref": match,
        "bytes_per_elt_unfused": 11, "bytes_per_elt_fused": 3}

    # reduce path: fused encode + error feedback.  Unfused: ct+ef reads,
    # comp write/read, codes+scales write, dequant write/read, new_ef
    # write (~26 B/elt with a bf16 ct); fused: ct+ef in, codes+scales+
    # new_ef out (~11 B/elt).
    ct = w.astype(jnp.bfloat16)
    ef = g * 1e-3
    e_ref = jax.jit(lambda c, e: ref.encode_ef_ref(c, e, block))
    us_e = timeit(e_ref, ct, ef, iters=iters)
    got = ops.encode_ef(ct, ef, block)
    want = e_ref(ct, ef)
    match = bool(all(np.array_equal(np.asarray(a), np.asarray(b))
                     for a, b in zip(got, want)))
    emit("kernel/encode_ef_ref_jnp", us_e,
         f"n={n};bytes_unfused~26;bytes_fused~11;expected_tpu_gain="
         f"{26/11:.2f}x;fused_matches_jitted_ref={match}")
    fused["encode_ef"] = {
        "ref_us": us_e, "n": n, "block": block, "parity": "BITWISE",
        "fused_matches_jitted_ref": match,
        "bytes_per_elt_unfused": 26, "bytes_per_elt_fused": 11}

    # serve path: int8 GEMM on gathered codes.  Dense route materializes
    # the f32 weight (1 in + 4 out + 4 in per weight elt) then a bf16
    # GEMM; the kernel streams int8 codes straight into the MXU.
    k2 = 256 if quick else 1024
    n2 = 4 * k2
    wm = jnp.asarray(rng.normal(size=(k2, n2)).astype(np.float32)) * 0.05
    c2, s2 = ref.quantize_ref(wm.reshape(-1), block)
    c2 = c2.reshape(k2, n2)
    x2 = jnp.asarray(rng.normal(size=(8, k2)).astype(np.float32))
    mm_ref = jax.jit(lambda x, c, s: ref.q8_matmul_ref(x, c, s, block))
    us_m = timeit(mm_ref, x2, c2, s2, iters=iters)
    got = np.asarray(ops.q8_matmul(x2, c2, s2, block))
    want = np.asarray(mm_ref(x2, c2, s2))
    rel = float(np.abs(got - want).max() / max(np.abs(want).mean(), 1e-6))
    emit("kernel/q8_matmul_ref_jnp", us_m,
         f"k={k2};n={n2};weight_bytes_dense=9;weight_bytes_fused=1;"
         f"rel_err_vs_dense={rel:.4f}")
    fused["q8_matmul"] = {
        "ref_us": us_m, "k": k2, "n": n2, "block": block,
        "parity": "ALLCLOSE", "rel_err_vs_dense_oracle": rel,
        "weight_bytes_per_elt_dense": 9, "weight_bytes_per_elt_fused": 1}

    # ------------------------------------------------------------------ #
    # fp8 store codec: encode/decode are single casts; the entry records
    # the wire-bytes ratio (1 B/elt vs 4 fp32 / ~1.004 q8+scales) and the
    # round-trip determinism (cast -> cast is idempotent on codes)
    for fname, fdt in sorted(float8_dtypes().items()):
        enc = jax.jit(lambda x, d=fdt: x.astype(d))
        us_f = timeit(enc, w, iters=iters)
        codes8 = enc(w)
        stable = bool(np.array_equal(
            np.asarray(codes8), np.asarray(enc(codes8.astype(jnp.float32)))))
        emit(f"kernel/{fname}_cast", us_f,
             f"n={n};wire_bytes_per_elt=1;roundtrip_stable={stable}")
        fused[f"{fname}_codec"] = {
            "ref_us": us_f, "n": n, "parity": "BITWISE",
            "roundtrip_stable": stable, "wire_bytes_per_elt": 1}

    # fused optimizer-update + store-rebuild kernels: one pass fusing the
    # moment update, weight write, and the storage re-encode.  Unfused
    # (ref) runs the update then a second full read/write for the
    # re-encode; the fused kernel's epilogue writes the encoded form
    # directly from registers.
    store_fmts = ["fp32", "bf16", "q8_block"] + sorted(float8_dtypes())
    sc = (1e-3, 0.9, 0.95, 1e-8, 0.1, 0.5, 0.25)
    # scalars ride as traced f32 arguments (as in the optimizer and the
    # parity tests) so `1 - b1` etc. round identically in both graphs
    scj = tuple(jnp.float32(x) for x in sc)
    for fmt in store_fmts:
        r_up = jax.jit(lambda *a, fmt=fmt: ref.adamw_store_update_ref(
            *a, fmt, block))
        us_u = timeit(r_up, w, g, m, v, mask, *scj, iters=iters)
        got = ops.adamw_store_update(
            w, g, m, v, mask, lr=scj[0], b1=scj[1], b2=scj[2], eps=scj[3],
            wd=scj[4], c1=scj[5], c2=scj[6], fmt=fmt, block=block)
        want = r_up(w, g, m, v, mask, *scj)
        match = bool(all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(want))))
        emit(f"kernel/adamw_store_update_{fmt}_ref_jnp", us_u,
             f"n={n};fmt={fmt};fused_matches_jitted_ref={match}")
        fused[f"adamw_store_update_{fmt}"] = {
            "ref_us": us_u, "n": n, "block": block, "parity": "BITWISE",
            "fused_matches_jitted_ref": match}

        r_up8 = jax.jit(lambda *a, fmt=fmt: ref.adam8bit_store_update_ref(
            *a, fmt, block))
        us_u8 = timeit(r_up8, w, g, m8, v8, ms, vs, mask, *scj, iters=iters)
        got = ops.adam8bit_store_update(
            w, g, m8, v8, ms, vs, mask, lr=scj[0], b1=scj[1], b2=scj[2],
            eps=scj[3], wd=scj[4], c1=scj[5], c2=scj[6], fmt=fmt,
            block=block)
        want = r_up8(w, g, m8, v8, ms, vs, mask, *scj)
        match = _ulp_agree(got, want)
        emit(f"kernel/adam8bit_store_update_{fmt}_ref_jnp", us_u8,
             f"n={n};fmt={fmt};fused_matches_jitted_ref_ulp4={match}")
        fused[f"adam8bit_store_update_{fmt}"] = {
            "ref_us": us_u8, "n": n, "block": block, "parity": "ALLCLOSE",
            "fused_matches_jitted_ref_ulp4": match}

    doc = _append_history({"backend": jax.default_backend(), "quick": quick,
                           "fused_kernels": fused})
    emit("kernel/bench_json", 0.0,
         f"appended to {BENCH_JSON} (history={len(doc['history'])})")

    return {"adamw": us, "adam8bit": us8, "quant": usq, "fused": fused}


if __name__ == "__main__":
    run()
