"""Kernel microbenchmarks: fused Pallas (interpret on CPU) vs unfused jnp
reference.  On CPU the interpret-mode kernel is *slower* (it's a Python
interpreter of the kernel body) -- the number that matters here is the
oracle agreement + the HBM-stream count derived from the kernel structure;
wall-time wins appear on real TPU hardware.  We therefore report the jnp
reference timing and the analytic bytes-moved ratio."""
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from .common import emit, timeit


def run(quick: bool = False):
    n = 2**16 if quick else 2**20
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    g = jnp.asarray(rng.normal(size=n).astype(np.float32))
    m = jnp.asarray(rng.normal(size=n).astype(np.float32)) * 0.1
    v = jnp.abs(jnp.asarray(rng.normal(size=n).astype(np.float32))) * 0.01
    mask = jnp.ones((n,), jnp.float32)
    import jax

    f32 = jax.jit(lambda *a: ref.adamw_update_ref(
        *a, 1e-3, 0.9, 0.95, 1e-8, 0.1, 0.5, 0.25))
    us = timeit(f32, w, g, m, v, mask, iters=5 if quick else 20)
    # unfused jnp chain touches w,g,m,v,mask reads + m,v,upd,w writes with
    # intermediate spills ~ 12 streams; fused kernel: 5 in + 3 out
    emit("kernel/adamw_ref_jnp", us,
         f"n={n};fused_hbm_streams=8;unfused_streams~12;expected_tpu_gain="
         f"{12/8:.2f}x")

    m8, ms = ref.quantize_ref(m, 1024)
    v8, vs = ref.quantize_ref(v, 1024)
    f8 = jax.jit(lambda *a: ref.adam8bit_update_ref(
        *a, 1e-3, 0.9, 0.95, 1e-8, 0.1, 0.5, 0.25, 1024))
    us8 = timeit(f8, w, g, m8, v8, ms, vs, mask, iters=5 if quick else 20)
    emit("kernel/adam8bit_ref_jnp", us8,
         f"n={n};state_bytes_vs_fp32={(2*1+8/1024)/(8):.3f}")

    q = jax.jit(lambda x: ref.quantize_ref(x, 1024))
    usq = timeit(q, w, iters=5 if quick else 20)
    emit("kernel/blockwise_quant_ref", usq, f"n={n}")
    return {"adamw": us, "adam8bit": us8, "quant": usq}


if __name__ == "__main__":
    run()
