"""Kernel microbenchmarks: fused Pallas (interpret on CPU) vs unfused jnp
reference.  On CPU the interpret-mode kernel is *slower* (it's a Python
interpreter of the kernel body) -- the number that matters here is the
oracle agreement + the HBM-stream count derived from the kernel structure;
wall-time wins appear on real TPU hardware.  We therefore report the jnp
reference timing and the analytic bytes-moved ratio, and persist the fused
entries to BENCH_kernels.json at the repo root (the CI artifact)."""
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, timeit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")


def run(quick: bool = False):
    n = 2**16 if quick else 2**20
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    g = jnp.asarray(rng.normal(size=n).astype(np.float32))
    m = jnp.asarray(rng.normal(size=n).astype(np.float32)) * 0.1
    v = jnp.abs(jnp.asarray(rng.normal(size=n).astype(np.float32))) * 0.01
    mask = jnp.ones((n,), jnp.float32)
    import jax

    f32 = jax.jit(lambda *a: ref.adamw_update_ref(
        *a, 1e-3, 0.9, 0.95, 1e-8, 0.1, 0.5, 0.25))
    us = timeit(f32, w, g, m, v, mask, iters=5 if quick else 20)
    # unfused jnp chain touches w,g,m,v,mask reads + m,v,upd,w writes with
    # intermediate spills ~ 12 streams; fused kernel: 5 in + 3 out
    emit("kernel/adamw_ref_jnp", us,
         f"n={n};fused_hbm_streams=8;unfused_streams~12;expected_tpu_gain="
         f"{12/8:.2f}x")

    m8, ms = ref.quantize_ref(m, 1024)
    v8, vs = ref.quantize_ref(v, 1024)
    f8 = jax.jit(lambda *a: ref.adam8bit_update_ref(
        *a, 1e-3, 0.9, 0.95, 1e-8, 0.1, 0.5, 0.25, 1024))
    us8 = timeit(f8, w, g, m8, v8, ms, vs, mask, iters=5 if quick else 20)
    emit("kernel/adam8bit_ref_jnp", us8,
         f"n={n};state_bytes_vs_fp32={(2*1+8/1024)/(8):.3f}")

    q = jax.jit(lambda x: ref.quantize_ref(x, 1024))
    usq = timeit(q, w, iters=5 if quick else 20)
    emit("kernel/blockwise_quant_ref", usq, f"n={n}")

    # ------------------------------------------------------------------ #
    # fused quant hot-path kernels (PR: kernels as the execution engine)
    # ------------------------------------------------------------------ #
    fused = {}
    iters = 5 if quick else 20
    block = 1024

    # gather path: fused dequant-into-compute-dtype.  Unfused moves the
    # f32 dequant buffer to HBM and back (codes in + f32 out + f32 in +
    # bf16 out = 1+4+4+2 bytes/elt); fused streams codes in, bf16 out.
    codes, scales = ref.quantize_ref(w, block)
    d_ref = jax.jit(lambda c, s: ref.dequantize_into_ref(
        c, s, block, jnp.bfloat16))
    us_d = timeit(d_ref, codes, scales, iters=iters)
    match = bool(np.array_equal(
        np.asarray(ops.dequantize_into(codes, scales, block,
                                       out_dtype=jnp.bfloat16)),
        np.asarray(d_ref(codes, scales))))
    emit("kernel/dequantize_into_ref_jnp", us_d,
         f"n={n};bytes_unfused={11};bytes_fused={3};expected_tpu_gain="
         f"{11/3:.2f}x;fused_matches_jitted_ref={match}")
    fused["dequantize_into"] = {
        "ref_us": us_d, "n": n, "block": block, "parity": "BITWISE",
        "fused_matches_jitted_ref": match,
        "bytes_per_elt_unfused": 11, "bytes_per_elt_fused": 3}

    # reduce path: fused encode + error feedback.  Unfused: ct+ef reads,
    # comp write/read, codes+scales write, dequant write/read, new_ef
    # write (~26 B/elt with a bf16 ct); fused: ct+ef in, codes+scales+
    # new_ef out (~11 B/elt).
    ct = w.astype(jnp.bfloat16)
    ef = g * 1e-3
    e_ref = jax.jit(lambda c, e: ref.encode_ef_ref(c, e, block))
    us_e = timeit(e_ref, ct, ef, iters=iters)
    got = ops.encode_ef(ct, ef, block)
    want = e_ref(ct, ef)
    match = bool(all(np.array_equal(np.asarray(a), np.asarray(b))
                     for a, b in zip(got, want)))
    emit("kernel/encode_ef_ref_jnp", us_e,
         f"n={n};bytes_unfused~26;bytes_fused~11;expected_tpu_gain="
         f"{26/11:.2f}x;fused_matches_jitted_ref={match}")
    fused["encode_ef"] = {
        "ref_us": us_e, "n": n, "block": block, "parity": "BITWISE",
        "fused_matches_jitted_ref": match,
        "bytes_per_elt_unfused": 26, "bytes_per_elt_fused": 11}

    # serve path: int8 GEMM on gathered codes.  Dense route materializes
    # the f32 weight (1 in + 4 out + 4 in per weight elt) then a bf16
    # GEMM; the kernel streams int8 codes straight into the MXU.
    k2 = 256 if quick else 1024
    n2 = 4 * k2
    wm = jnp.asarray(rng.normal(size=(k2, n2)).astype(np.float32)) * 0.05
    c2, s2 = ref.quantize_ref(wm.reshape(-1), block)
    c2 = c2.reshape(k2, n2)
    x2 = jnp.asarray(rng.normal(size=(8, k2)).astype(np.float32))
    mm_ref = jax.jit(lambda x, c, s: ref.q8_matmul_ref(x, c, s, block))
    us_m = timeit(mm_ref, x2, c2, s2, iters=iters)
    got = np.asarray(ops.q8_matmul(x2, c2, s2, block))
    want = np.asarray(mm_ref(x2, c2, s2))
    rel = float(np.abs(got - want).max() / max(np.abs(want).mean(), 1e-6))
    emit("kernel/q8_matmul_ref_jnp", us_m,
         f"k={k2};n={n2};weight_bytes_dense=9;weight_bytes_fused=1;"
         f"rel_err_vs_dense={rel:.4f}")
    fused["q8_matmul"] = {
        "ref_us": us_m, "k": k2, "n": n2, "block": block,
        "parity": "ALLCLOSE", "rel_err_vs_dense_oracle": rel,
        "weight_bytes_per_elt_dense": 9, "weight_bytes_per_elt_fused": 1}

    with open(BENCH_JSON, "w") as f:
        json.dump({"backend": jax.default_backend(), "quick": quick,
                   "fused_kernels": fused}, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("kernel/bench_json", 0.0, f"wrote {BENCH_JSON}")

    return {"adamw": us, "adam8bit": us8, "quant": usq, "fused": fused}


if __name__ == "__main__":
    run()
