"""Table 2 reproduction: component ablation under 8-bit Adam.

  Combined                 = ragged plan + group-fused flat update
  Disable DBuffer only     = per-tensor unpack/update/repack each step
                             (the fragmented per-tensor kernels the paper's
                             DBuffer batches away)
  Disable Planning only    = naive concat layout; quant blocks straddle
                             shard boundaries, so block states must be
                             assembled via a full gather + requant detour
                             (the paper's DTensor-redistribute fallback)
  Disable RaggedShard only = N/A (the abstraction itself; without it,
                             block-wise 8-bit Adam is not runnable without
                             intrusive model changes -- reported as N/A,
                             matching the paper)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.schedule import VARIANTS, CommSchedule
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer
from repro.optim.adam8bit import Adam8bit
# the ablations model the paper's DISABLED configurations, so they run the
# unfused reference compositions (kernels.ref), not the fused dispatch layer
from repro.kernels.ref import (dequantize_blockwise,
    dequantize_blockwise_log, quantize_blockwise, quantize_blockwise_log)

from .common import emit, timeit


class Adam8bitPerTensor(Adam8bit):
    """DBuffer disabled: per-tensor update loop (unpack -> update -> repack)
    instead of one fused pass over the flat shard."""

    def update(self, runtime, params, grads, state, step):
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t
        bq = self.block
        new_p = {}
        new_s = {k: {} for k in ("m8", "v8", "ms", "vs")}
        for name, w in params.items():
            lo = runtime.layouts[name]
            g = grads[name].astype(jnp.float32)
            m = dequantize_blockwise(state["m8"][name], state["ms"][name], bq)
            v = dequantize_blockwise_log(state["v8"][name], state["vs"][name], bq)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            # fragmented per-tensor work: slice every tensor's local piece
            # and update it separately, then stitch back (what per-parameter
            # FSDP2-style state dicts force)
            upd = jnp.zeros_like(w)
            S = lo.plan.shard_size
            for pl_ in lo.plan.placements:
                a, b_ = pl_.offset, min(pl_.end, S)
                a = min(a, S)
                if a >= b_:
                    continue
                piece = (m[..., a:b_] / c1) / (
                    jnp.sqrt(v[..., a:b_] / c2) + self.eps)
                if len(pl_.spec.shape) >= 2:
                    piece = piece + self.wd * w[..., a:b_]
                upd = upd.at[..., a:b_].set(piece)
            new_p[name] = w - lr * upd
            m8, ms = quantize_blockwise(m, bq)
            v8, vs = quantize_blockwise_log(v, bq)
            new_s["m8"][name], new_s["ms"][name] = m8, ms
            new_s["v8"][name], new_s["vs"][name] = v8, vs
        return new_p, new_s


class Adam8bitUnplanned(Adam8bit):
    """Planning disabled: blocks straddle shard boundaries, so every step
    must assemble whole quant blocks by gathering the full buffer, requant-
    izing globally, and re-slicing the local shard (extra all-gather +
    redundant dequant/requant -- the paper's fallback path).

    Because S is not a quant-block multiple, per-device scale arrays can't
    even be sliced evenly: scales are stored REPLICATED at global size (the
    'scaling-factor metadata' complexity the paper calls out)."""

    def state_shapes(self, runtime):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        bq = self.block
        shapes = {
            "m8": self._like_params(runtime, jnp.int8),
            "v8": self._like_params(runtime, jnp.int8),
            "ms": {}, "vs": {},
        }
        for name, lo in runtime.layouts.items():
            # scales cover everything a device *gathers* (its outer/EP
            # rank's buffer), replicated across the FSDP axes; EP ranks hold
            # distinct scale sets -> shard the scale dim over the outer axis
            total = lo.outer_size * lo.plan.total
            if lo.plan.total % bq:
                raise ValueError(
                    f"group {name!r}: packed total {lo.plan.total} not a "
                    f"multiple of quant block {bq} -- planner align missing")
            gshape = ((lo.n_layers, total // bq) if lo.n_layers
                      else (total // bq,))
            entry = lo.outer_axis if lo.outer_axis else None
            spec = (P(None, entry) if lo.n_layers else P(entry))
            sds = jax.ShapeDtypeStruct(
                gshape, jnp.float32,
                sharding=NamedSharding(runtime.mesh, spec))
            shapes["ms"][name] = sds
            shapes["vs"][name] = sds
        return shapes

    def pspecs(self, runtime):
        from jax.sharding import PartitionSpec as P

        ps = {n: lo.pspec() for n, lo in runtime.layouts.items()}
        rep = {}
        for n, lo in runtime.layouts.items():
            entry = lo.outer_axis if lo.outer_axis else None
            rep[n] = P(None, entry) if lo.n_layers else P(entry)
        return {"m8": dict(ps), "v8": dict(ps), "ms": rep, "vs": dict(rep)}

    def update(self, runtime, params, grads, state, step):
        import jax.lax as lax

        from repro.optim.common import device_linear_index

        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t
        bq = self.block
        new_p = {}
        new_s = {k: {} for k in ("m8", "v8", "ms", "vs")}
        for name, w in params.items():
            lo = runtime.layouts[name]
            g = grads[name].astype(jnp.float32)
            mq, vq = state["m8"][name], state["v8"][name]
            ms, vs = state["ms"][name], state["vs"][name]  # replicated
            if lo.fsdp_axes:
                # blocks split across devices: assemble globally first
                mq = lax.all_gather(mq, lo.fsdp_axes, tiled=True, axis=-1)
                vq = lax.all_gather(vq, lo.fsdp_axes, tiled=True, axis=-1)
            m_full = dequantize_blockwise(mq, ms, bq)
            v_full = dequantize_blockwise_log(vq, vs, bq)
            S = lo.plan.shard_size
            dev = device_linear_index(runtime, lo)
            sl = lambda x: lax.dynamic_slice_in_dim(x, dev * S, S, axis=-1)
            m = self.b1 * sl(m_full) + (1 - self.b1) * g
            v = self.b2 * sl(v_full) + (1 - self.b2) * g * g
            upd = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            new_p[name] = w - lr * upd
            # requant requires whole blocks again: gather the fresh moments
            if lo.fsdp_axes:
                m_all = lax.all_gather(m, lo.fsdp_axes, tiled=True, axis=-1)
                v_all = lax.all_gather(v, lo.fsdp_axes, tiled=True, axis=-1)
            else:
                m_all, v_all = m, v
            m8f, msf = quantize_blockwise(m_all, bq)
            v8f, vsf = quantize_blockwise_log(v_all, bq)
            new_s["m8"][name] = sl(m8f)
            new_s["v8"][name] = sl(v8f)
            new_s["ms"][name] = msf  # replicated global scales
            new_s["vs"][name] = vsf
        return new_p, new_s


def run(quick: bool = False):
    cfg = get_config("gpt-oss-120b").reduced()
    cfg = dataclasses.replace(cfg, optimizer="adam8bit", quant_block=64)
    if not quick:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=512, d_ff=512)
    mesh = make_local_mesh(1, 1)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (8, 64)), jnp.int32)}

    results = {}
    variants = [
        ("combined", "ragged", Adam8bit, CommSchedule.default()),
        ("combined_overlap", "ragged", Adam8bit, VARIANTS["overlap_all"]),
        ("no_dbuffer", "ragged", Adam8bitPerTensor, CommSchedule.default()),
        ("no_planning", "naive", Adam8bitUnplanned, CommSchedule.default()),
    ]
    for name, planner, opt_cls, sched in variants:
        model = build_model(cfg)
        rt = FSDPRuntime(model, mesh, planner=planner, donate=False,
                         schedule=sched)
        params = rt.init_params(0)
        opt = opt_cls(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)

        def step(fn=fn, params=params, state=state, st=st):
            return fn(params, state, st, batch)

        us = timeit(step, iters=5 if quick else 10, warmup=2)
        results[name] = us
        emit(f"table2/{name}", us,
             f"normalized_throughput={results['combined']/us*100:.1f}%")
    emit("table2/no_raggedshard", 0.0,
         "N/A: without the RaggedShard abstraction block-wise 8-bit Adam "
         "requires intrusive model changes or manual collectives (paper "
         "reports N/A)")
    return results


if __name__ == "__main__":
    run()
