import time

import jax


def timeit(fn, *args, iters=20, warmup=3, **kw):
    """Median wall time in microseconds (CPU; relative numbers only)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
