"""Figure 11 + §6.4 reproduction: padding overhead of RaggedShard planning.

Sweeps expert-MLP row granularity {128, 16, 1} x FSDP size {8..512} on
DeepSeek-V3-671B-style (per-expert parameter tensors) and GPT-OSS-120B-style
(experts fused into one tensor) layouts, reporting relative padding --
reproducing the paper's contrast between the two (per-expert padding relaxes
the constraint; fused experts spike at coarse granularity).  Also reports
planner wall time at production scale (<0.3 s in the paper).
"""
import time

import numpy as np

from repro.core.planner import plan_group
from repro.core.ragged import TensorSpec, row_granularity

from .common import emit


def deepseek_layer(granularity_rows):
    """DeepSeek-V3-ish MoE layer: 256 routed experts, separate tensors,
    d=7168, moe_ff=2048 (scaled expert count for planning speed)."""
    d, ff, n_exp = 7168, 2048, 64
    ts = []
    for e in range(n_exp):
        for nm, shape in [(f"e{e}_w1", (ff, d)), (f"e{e}_w2", (d, ff)),
                          (f"e{e}_w3", (ff, d))]:
            g = row_granularity(shape, granularity_rows)
            size = int(np.prod(shape))
            if size % g:
                g = 1
            ts.append(TensorSpec(nm, shape, granularity=min(g, size)))
    ts.append(TensorSpec("router", (d, n_exp)))
    return ts


def gptoss_layer(granularity_rows):
    """GPT-OSS-style: all experts fused into single parameter tensors."""
    d, ff, n_exp = 2880, 2880, 128
    ts = []
    for nm, shape in [("w1", (n_exp * ff, d)), ("w2", (n_exp * d, ff))]:
        g = row_granularity(shape, granularity_rows)
        size = int(np.prod(shape))
        if size % g:
            g = 1
        ts.append(TensorSpec(nm, shape, granularity=g))
    ts.append(TensorSpec("router", (d, n_exp)))
    return ts


def run(quick: bool = False):
    sizes = [8, 32, 128] if quick else [8, 16, 32, 64, 128, 256, 512]
    out = {}
    for model, mk in [("deepseek_v3", deepseek_layer),
                      ("gpt_oss", gptoss_layer)]:
        for rows in (1, 16, 128):
            for m in sizes:
                t0 = time.perf_counter()
                plan = plan_group(mk(rows), m)
                dt = time.perf_counter() - t0
                out[(model, rows, m)] = plan.padding_ratio
                emit(f"fig11/{model}/rows{rows}/m{m}", dt * 1e6,
                     f"padding_ratio={plan.padding_ratio:.4f}")
    # paper claims: 1x/16x stays <3%; planner runtime sub-second
    worst_fine = max(v for (mo, r, m), v in out.items() if r in (1, 16))
    emit("fig11/worst_fine_granularity_padding", worst_fine * 1e6,
         f"max padding ratio at rows<=16 = {worst_fine:.4f} (paper: <0.03)")
    return out


if __name__ == "__main__":
    run()
