"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows.  --full uses larger problem
sizes (slower); default is the quick configuration.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from . import (bench_ablation, bench_comm, bench_copy_overhead,
                   bench_e2e, bench_kernels, bench_planner, bench_scaling)

    suites = [
        ("table1_copy_overhead", bench_copy_overhead.run),
        ("fig11_planner", bench_planner.run),
        ("fig8_e2e", bench_e2e.run),
        ("sched_e2e", bench_e2e.run_schedules),
        ("fig9_scaling", bench_scaling.run),
        ("table2_ablation", bench_ablation.run),
        ("kernels", bench_kernels.run),
        ("comm_autotune", bench_comm.run),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            fn(quick=quick)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
