"""Comm calibration harness: measure every wire codec x route x ring chunk
on the ACTUAL mesh and persist a ``comm-profile/v1`` (core.profile) as
``BENCH_comm.json`` at the repo root.

This is the measurement half of the autotuner.  ``CostModel.from_profile``
(core.policy) fits latency/bandwidth lines over these entries and the auto
planner prices formats -- and picks each ring group's ``ring_chunk_elems``
-- from the measured curves instead of the TPU-v5e paper constants.

Entries are END-TO-END: a q8_block gather includes the fused dequant
decode, a q8_block reduce includes encode + decode.  On CPU the quant
kernels run in Pallas interpret mode, so q8 wires measure *expensive* here
while the collectives are ~memcpy -- exactly the kind of backend truth a
roofline built from paper constants gets wrong, and the reason the
measured profile can legitimately disagree with ``builtin-roofline``.

    PYTHONPATH=src python -m benchmarks.bench_comm [--quick] [--out PATH]

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
``autotuner`` job) to calibrate real 8-way rings on a CPU host.
"""
import argparse
import json
import os

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_comm.json")

BLOCK = 1024
FMTS = ("fp32", "bf16", "q8_block")
# profile mode name -> (gather_mode, reduce_mode) args of the wire layer
REDUCE_ROUTES = {"xla": ("xla", "match"), "ring": ("ring", "match"),
                 "ring_acc": ("ring", "ring_acc")}


def _chunk_sweep(shard: int) -> list:
    """Ring-chunk candidates below the shard-sized default, q8-block
    aligned so one sweep serves every format."""
    return [shard // k for k in (2, 4, 8)
            if shard // k >= BLOCK and shard % (k * BLOCK) == 0]


def run(quick: bool = False, out: str = BENCH_JSON):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.profile import CommProfile, CommSample
    from repro.core.wire import (WireCodec, codec_gather,
                                 codec_reduce_scatter, payload_all_gather)
    from repro.launch.mesh import make_local_mesh

    from .common import emit, timeit

    n = jax.device_count()
    mesh = make_local_mesh(n, 1)
    axes = ("data",) if n > 1 else ()
    axis_sizes = (n,) if n > 1 else ()
    f32 = jnp.dtype(jnp.float32)

    sizes = (1 << 16, 1 << 18) if quick else (1 << 18, 1 << 21)
    iters = 3 if quick else 10
    warmup = 1 if quick else 3
    rng = np.random.default_rng(0)
    entries = []

    def sample(direction, fmt, mode, elems, chunk, us):
        # chunk_elems == elems is the schema's shard-sized-default marker;
        # sweep entries carry the actual ring_chunk_elems knob value
        entries.append(CommSample(direction=direction, fmt=fmt, mode=mode,
                                  elems=elems,
                                  chunk_elems=elems if chunk is None
                                  else chunk, time_us=us))
        emit(f"comm/{direction}/{fmt}/{mode}", us,
             f"elems={elems};chunk={'shard' if chunk is None else chunk}")

    def gather_fn(fmt, mode, chunk):
        codec = WireCodec(fmt, BLOCK)
        if not codec.quantized:
            def f(x):
                return codec_gather(x, axes, axis_sizes, codec,
                                    WireCodec("fp32"), f32, f32, mode,
                                    "match", chunk)
            return jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                     out_specs=P(None), check=False))

        # quantized store: params live pre-encoded, so the end-to-end
        # gather is payload movement + the fused dequant (store.py's
        # gather_payload + decode)
        def fq(c, s):
            cc = payload_all_gather(c, axes, axis_sizes, mode, chunk)
            ss = payload_all_gather(
                s, axes, axis_sizes, mode,
                max(chunk // BLOCK, 1) if chunk else None)
            return codec.decode({"codes": cc, "scales": ss}, f32)
        return jax.jit(shard_map(fq, mesh=mesh,
                                 in_specs=(P("data"), P("data")),
                                 out_specs=P(None), check=False))

    def reduce_fn(fmt, mode, chunk):
        codec = WireCodec(fmt, BLOCK)
        gmode, rmode = REDUCE_ROUTES[mode]

        def f(ct):
            shard, _ = codec_reduce_scatter(ct, None, codec, axes,
                                            axis_sizes, gmode, rmode, f32,
                                            chunk)
            return shard
        return jax.jit(shard_map(f, mesh=mesh, in_specs=P(None),
                                 out_specs=P("data"), check=False))

    for elems in sizes:
        shard = elems // max(n, 1)
        x = jnp.asarray(rng.normal(size=elems).astype(np.float32))
        q8 = WireCodec("q8_block", BLOCK).encode(x)
        sweep = _chunk_sweep(shard) if (n > 1 and elems == max(sizes)) \
            else []

        for fmt in FMTS:
            args = (q8["codes"], q8["scales"]) if fmt == "q8_block" \
                else (x,)
            for mode in ("xla", "ring"):
                us = timeit(gather_fn(fmt, mode, None), *args,
                            iters=iters, warmup=warmup)
                sample("gather", fmt, mode, elems, None, us)
                if mode == "ring":
                    for c in sweep:
                        us = timeit(gather_fn(fmt, mode, c), *args,
                                    iters=iters, warmup=warmup)
                        sample("gather", fmt, mode, elems, c, us)
            for mode in REDUCE_ROUTES:
                us = timeit(reduce_fn(fmt, mode, None), x,
                            iters=iters, warmup=warmup)
                sample("reduce", fmt, mode, elems, None, us)
                if mode in ("ring", "ring_acc"):
                    for c in sweep:
                        us = timeit(reduce_fn(fmt, mode, c), x,
                                    iters=iters, warmup=warmup)
                        sample("reduce", fmt, mode, elems, c, us)

    prof = CommProfile(
        name=f"measured-{jax.default_backend()}-{n}dev"
             + ("-quick" if quick else ""),
        entries=tuple(entries), backend=jax.default_backend(), world=n,
        builtin=False, end_to_end=True, quick=quick)
    prof.save(out)
    emit("comm/bench_json", 0.0,
         f"wrote {out};name={prof.name};hash={prof.content_hash()}")
    return prof


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer iters (CI calibration)")
    ap.add_argument("--out", default=BENCH_JSON,
                    help=f"output profile path (default {BENCH_JSON})")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
