"""Figure 9 reproduction: weak/strong/model scaling, projected from the
dry-run roofline terms (no hardware; Lesson-1 of the paper says exactly
this extrapolation is valid: per-GPU compute and FSDP comm are constant in
device count under weak scaling).

Reads results/dryrun.jsonl (+ _multipod) and reports projected step time
  t_step ~= max(t_compute, t_memory, t_collective)
and its scaling across meshes, plus a weak-scaling model for 1x..32x pods.
"""
import json
import pathlib

from .common import emit

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def _load(name):
    path = RESULTS / name
    if not path.exists():
        return {}
    rows = {}
    for line in path.read_text().splitlines():
        r = json.loads(line)
        if r.get("ok"):
            rows[(r["arch"], r["shape"], r.get("mesh", ""))] = r
    return rows


def run(quick: bool = False):
    single = _load("dryrun.jsonl")
    multi = _load("dryrun_multipod.jsonl")
    if not single:
        emit("fig9/no_dryrun_results", 0.0, "run repro.launch.dryrun first")
        return {}

    from repro.configs import get_config
    from repro.launch.mesh import ICI_BW
    from repro.launch.roofline import total_params

    out = {}
    for (arch, shape, mesh), r in sorted(single.items()):
        t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        out[(arch, shape)] = t
        mr = multi.get((arch, shape, "pod2x16x16"))
        if mr and shape == "train_4k":
            # multi-pod rows prove compile/sharding (uncalibrated);
            # weak-scaling projection = single-pod terms + the HSDP pod
            # grad all-reduce (2 pods: ring volume ~= local f32 grad bytes)
            cfg = get_config(arch)
            ar_bytes = total_params(cfg) / 256 * 4.0
            t2 = max(r["t_compute_s"], r["t_memory_s"],
                     r["t_collective_s"] + ar_bytes / ICI_BW)
            eff = t / t2 if t2 > 0 else 0.0
            emit(f"fig9/weak/{arch}", t * 1e6,
                 f"t_512_hsdp={t2:.4f}s;weak_scaling_eff={eff:.3f};"
                 f"pod_ar_gb={ar_bytes/1e9:.2f};multipod_compile_ok="
                 f"{bool(mr.get('ok'))}")
        elif shape == "train_4k":
            emit(f"fig9/single/{arch}", t * 1e6,
                 f"dominant={r['dominant']}")
    # model scaling at fixed 256 chips (Fig 9d): projected MFU per arch
    for (arch, shape), t in sorted(out.items()):
        if shape != "train_4k":
            continue
        r = single[(arch, shape, "pod16x16")]
        mfu = (r["model_gflops"] / 256) / (t * 197e3) if t else 0.0
        emit(f"fig9/model_scaling_mfu/{arch}", t * 1e6,
             f"projected_mfu={mfu:.3f}")
    return out


if __name__ == "__main__":
    run()
