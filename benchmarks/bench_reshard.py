"""Peak-host-memory / wall-time benchmark for offline resharding.

The claim under test (ISSUE: elastic resharding): ``tools/reshard.py``
streams tensor-by-tensor through two shard indices, so peak host memory is
bounded by the largest single logical tensor -- NOT by the largest layer
stack (n_layers x payload), which is what a naive "unpack everything,
repack everything" reshard would hold.

    PYTHONPATH=src python benchmarks/bench_reshard.py [--arch qwen2.5-14b]

Writes ``BENCH_reshard.json`` at the repo root.  tracemalloc sees numpy's
allocator, so transient full-tensor assemblies are counted; the npy shard
files on both sides are memory-mapped and do not.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402
import tempfile  # noqa: E402
import time  # noqa: E402
import tracemalloc  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

from repro.configs import build_model, get_config  # noqa: E402
from repro.configs.base import ParallelConfig  # noqa: E402
from repro.core.fsdp import FSDPRuntime  # noqa: E402
from repro.core.policy import make_plan  # noqa: E402
from repro.core.reshard import GroupIndex  # noqa: E402
from repro.checkpoint import ckpt  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from tools.reshard import reshard  # noqa: E402


def tensor_and_stack_bytes(rt) -> tuple[int, int]:
    """(largest single logical tensor, largest per-group layer stack)."""
    t_max = s_max = 0
    for lo in rt.layouts.values():
        idx = GroupIndex.from_layout(lo)
        for name in lo.plan.names:
            n = 1
            for d in idx.full_shape(name):
                n *= d
            t_max = max(t_max, 4 * n)
        s_max = max(s_max,
                    4 * (lo.n_layers or 1) * lo.outer_size * lo.plan.total)
    return t_max, s_max


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--out", default=str(REPO / "BENCH_reshard.json"))
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=ParallelConfig(("data",), ("data",)))
    model = build_model(cfg)
    rt8 = FSDPRuntime(model, make_local_mesh(8, 1))
    largest_tensor, largest_stack = tensor_and_stack_bytes(rt8)

    with tempfile.TemporaryDirectory() as td:
        src, dst = pathlib.Path(td) / "c8", pathlib.Path(td) / "c4"
        params = rt8.init_params(0)
        ckpt.save(src, rt8, params, step=0)
        del params

        plan4 = make_plan(build_model(cfg), {"data": 4, "model": 1}, None)
        tracemalloc.start()
        t0 = time.perf_counter()
        summary = reshard(src, dst, plan4, verbose=False)
        wall = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()

    result = {
        "arch": cfg.name,
        "direction": "8-way -> 4-way",
        "streamed_groups": sorted(summary["streamed"]),
        "peak_host_bytes": int(peak),
        "wall_s": round(wall, 3),
        "largest_tensor_bytes": int(largest_tensor),
        "largest_stack_bytes": int(largest_stack),
        "peak_over_tensor": round(peak / largest_tensor, 2),
        "peak_over_stack": round(peak / largest_stack, 3),
        "bounded_by_tensor": bool(peak < largest_stack),
    }
    pathlib.Path(args.out).write_text(json.dumps(result, indent=2) + "\n")
    print(json.dumps(result, indent=2))
    if not result["bounded_by_tensor"]:
        print("WARNING: peak host memory exceeded the layer-stack bound",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
