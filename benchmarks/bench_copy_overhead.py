"""Table 1 reproduction: interleaved Copy-Out/Copy-In overhead.

FSDP2's per-parameter Shard(0) layout leaves every tensor interleaved
(device-major) in the gathered buffer, forcing a strided copy per tensor;
the ragged plan keeps tensors contiguous, so unpack is slice/reshape views.
We measure unpack ("Copy-Out") and repack ("Copy-In") wall time over a
GPT-OSS-120B-style layer group, plus the HLO copy-op evidence.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbuffer import DBuffer
from repro.core.planner import plan_fsdp2, plan_group
from repro.core.ragged import TensorSpec

from .common import emit, timeit


def layer_specs(scale=8):
    """GPT-OSS-120B-ish decoder layer, scaled down by `scale` for CPU."""
    d, ff, e = 2880 // scale, 2880 // scale, 16
    hd, hq, hkv = 64 // scale * 8, 64, 8
    return [
        TensorSpec("wq", (d, 512 // scale * 8)),
        TensorSpec("wk", (d, 64 // scale * 8)),
        TensorSpec("wv", (d, 64 // scale * 8)),
        TensorSpec("wo", (512 // scale * 8, d)),
        TensorSpec("experts_w1", (e, d, ff)),
        TensorSpec("experts_w2", (e, ff, d)),
        TensorSpec("ln1", (d,)),
        TensorSpec("ln2", (d,)),
        TensorSpec("router", (d, e)),
    ]


def run(quick: bool = False):
    m = 64
    specs = layer_specs(scale=8 if quick else 4)
    rng = np.random.default_rng(0)

    results = {}
    for name, plan in [("ragged", plan_group(specs, m)),
                       ("fsdp2", plan_fsdp2(specs, m))]:
        buf = DBuffer(plan)
        flat = jnp.asarray(
            rng.normal(size=plan.total).astype(np.float32))

        @jax.jit
        def unpack_sum(x, buf=buf):
            return [t.sum() for t in buf.unpack(x).values()]

        us = timeit(unpack_sum, flat, iters=10 if quick else 30)
        arrays = {s.name: jnp.asarray(
            rng.normal(size=s.shape).astype(np.float32)) for s in specs}

        @jax.jit
        def repack(a, buf=buf):
            return buf.pack_traced(a)

        us_in = timeit(repack, arrays, iters=10 if quick else 30)
        results[name] = (us, us_in)
        emit(f"table1/{name}/copy_out", us,
             f"padding_ratio={plan.padding_ratio:.4f}")
        emit(f"table1/{name}/copy_in", us_in, "")
    ratio = results["fsdp2"][0] / max(results["ragged"][0], 1e-9)
    emit("table1/interleave_overhead_x", ratio * 100,
         "fsdp2 copy-out / ragged copy-out (x100)")
    return results


if __name__ == "__main__":
    run()
