"""Structure-aware planning for grouped RaggedShard tensors (paper §5, Alg. 1).

Given tensors t with sizes e_t and block granularities g_t, choose a uniform
per-device buffer size S and contiguous intervals [l_t, r_t) in the global
buffer (size m*S) minimizing S subject to:

  * contiguous tensor memory  (padding between tensors, never inside),
  * non-sharded blocks        (no device boundary splits a g_t-block),
  * balanced load             (all devices own exactly S elements).

The problem is NP-hard (Partition reduction).  Algorithm 1's heuristic:

  * candidate shard sizes are multiples of LCMs over *prefixes* of the
    granularities sorted ascending (the 2-approximation for which tensors may
    fully contain a shard — "case (3)"), seeded with the collective alignment
    unit g_coll;
  * for a fixed S, feasibility is checked by placing tensors in order at the
    *earliest feasible offset*.  For a fixed order and S this greedy is exact:
    the reachable end-position after a prefix is monotone in the prefix's end,
    so an earliest-end placement dominates.  This is an equivalent formulation
    of the paper's dp(t, i) with segment skipping (each tensor is handled in
    O(#boundary-cases), not O(#blocks));
  * feasibility is monotone in k for S = k*g (paper's absorption argument), so
    we binary-search k.

Baseline planners reproduce the systems the paper compares against:
``plan_fsdp2`` (per-parameter even Shard(0) + padding, interleaved layout),
``plan_megatron`` (concat with row/device-boundary padding), ``plan_naive``
(concat, blocks straddle boundaries — Fig. 6(a)).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Iterable, Sequence

from .ragged import LANE, GroupPlan, Placement, TensorSpec

# max boundaries probed for the one-interior-boundary case before declaring it
# infeasible; residues of boundaries mod g cycle with period g/gcd(S, g).
_MAX_BOUNDARY_PROBES = 4096


# ---------------------------------------------------------------------------
# Earliest feasible start of one tensor (the paper's three-case analysis)
# ---------------------------------------------------------------------------

def _earliest_start(pos: int, e: int, g: int, S: int,
                    align: int = 1) -> int | None:
    """Smallest l >= pos where a tensor (size e, block g) can start, given
    per-device shard size S, such that no shard boundary splits a block.

    ``align`` additionally rounds starts up to a multiple (used by quantized
    groups -- q8 stores, 8-bit optimizer state, and the q8_block gradient
    reduce wire, whose reduce-scatter chunks are shard-sized -- so
    fixed-size quant tiles over the local shard never straddle a tensor
    start; S is always a multiple of align via g_coll).
    """
    cands: list[int] = []

    def up(x: int, a: int) -> int:
        return -(-x // a) * a

    # case (1): entirely inside one shard -> no block-alignment constraint.
    if e <= S:
        l = up(pos, align)
        if (l % S) + e > S:
            l = up(l // S * S + S, align)  # next boundary (align | S)
        cands.append(l)

    # case (3): S is a multiple of g -> any g-aligned start works, with every
    # boundary then g-aligned relative to the tensor.
    if S % g == 0:
        cands.append(up(pos, math.lcm(g, align)))

    # case (2): exactly one boundary b strictly inside; need l ≡ b (mod g).
    # If align does not divide g the aligned-start constraint may interact
    # with the residue; search within the window for a start satisfying both.
    if e <= 2 * S:
        probes = (
            1
            if S % g == 0
            else min(g // math.gcd(S, g) + 1, _MAX_BOUNDARY_PROBES)
        )
        step = math.lcm(g, align) if align > 1 else g
        b = (pos // S + 1) * S
        found = None
        for _ in range(probes):
            lo = max(pos, b - S, b - e + 1)
            hi = min(b - 1, b + S - e)
            if lo <= hi:
                # smallest l >= lo with l ≡ b (mod g) and align | l
                l = lo + (b - lo) % g
                if align > 1:
                    # b ≡ 0 (mod align) when align | S; then l ≡ b (mod g)
                    # already implies align-alignment iff align | g; otherwise
                    # step forward by lcm to find a doubly-aligned start.
                    while l <= hi and l % align != 0:
                        l += g
                if l <= hi:
                    found = l
                    break
            b += S
        if found is not None:
            cands.append(found)

    return min(cands) if cands else None


def _place_all(
    tensors: Sequence[TensorSpec], S: int, align: int = 1
) -> list[Placement] | None:
    """Greedy earliest-feasible placement; None if some tensor can't start."""
    pos = 0
    out: list[Placement] = []
    for t in tensors:
        l = _earliest_start(pos, t.size, t.granularity, S, align)
        if l is None:
            return None
        out.append(Placement(t, l))
        pos = l + t.size
    return out


def check_valid_shard(tensors: Sequence[TensorSpec], S: int, m: int,
                      align: int = 1) -> bool:
    """Paper's CheckValidShard: can everything fit in m shards of size S?"""
    placed = _place_all(tensors, S, align)
    return placed is not None and (placed[-1].end if placed else 0) <= m * S


# ---------------------------------------------------------------------------
# Algorithm 1: minimal uniform shard size via LCM-prefix candidates
# ---------------------------------------------------------------------------

def _min_feasible_k(tensors, g: int, m: int, total: int, max_g: int,
                    align: int = 1) -> int | None:
    """Smallest k with S=k*g feasible (feasibility monotone in k)."""
    k_lo = max(1, -(-total // (m * g)), -(-max_g // g))
    k = k_lo
    # exponential search up, then binary search down.
    for _ in range(64):
        if check_valid_shard(tensors, k * g, m, align):
            break
        k *= 2
    else:
        return None
    hi, lo = k, max(k_lo, k // 2)
    while lo < hi:
        mid = (lo + hi) // 2
        if check_valid_shard(tensors, mid * g, m, align):
            hi = mid
        else:
            lo = mid + 1
    return hi


@dataclasses.dataclass(frozen=True)
class PlanStats:
    shard_size: int
    padding: int
    padding_ratio: float
    plan_seconds: float
    candidates_tried: int


def plan_group(
    tensors: Sequence[TensorSpec],
    num_shards: int,
    *,
    g_coll: int = LANE,
    order: str = "default",
    align: int = 1,
) -> GroupPlan:
    """Algorithm 1.  ``order`` in {default, by_granularity, by_size} — the
    paper evaluates all three and adopts default (near-optimal on
    transformers); the alternatives plug in without changing the DP.

    ``align``: additionally force every tensor start (and S) to a multiple —
    used by block-quantized groups so a fixed quant tile over the local shard
    never crosses a tensor start."""
    if not tensors:
        return GroupPlan((), shard_size=g_coll, num_shards=num_shards)
    g_coll = math.lcm(g_coll, align)
    tensors = list(tensors)
    if order == "by_granularity":
        tensors.sort(key=lambda t: t.granularity)
    elif order == "by_size":
        tensors.sort(key=lambda t: t.size, reverse=True)
    elif order != "default":
        raise ValueError(order)

    m = num_shards
    total = sum(t.size for t in tensors)
    max_g = max(t.granularity for t in tensors)

    t0 = time.perf_counter()
    best_S: int | None = None
    tried = 0
    g = g_coll
    # prefix LCMs over granularities sorted ascending, seeded with g_coll only
    # (the empty case-(3) set) — paper lines 19-25.
    grans = sorted({t.granularity for t in tensors})
    for g_next in [None] + grans:
        if g_next is not None:
            g = math.lcm(g, g_next)
        if best_S is not None and g > best_S:
            continue  # any k*g >= g can't beat the incumbent
        k = _min_feasible_k(tensors, g, m, total, max_g, align)
        tried += 1
        if k is not None:
            S = k * g
            if best_S is None or S < best_S:
                best_S = S
    if best_S is None:
        raise ValueError("planner: no feasible shard size found")

    placements = _place_all(tensors, best_S, align)
    if placements is None:
        raise RuntimeError(
            f"planner: shard size {best_S} was judged feasible but "
            f"placement failed -- feasibility probe and placer disagree")
    plan = GroupPlan(tuple(placements), shard_size=best_S, num_shards=m)
    plan.validate()
    # stash stats for benchmarks without widening the dataclass API
    object.__setattr__(
        plan,
        "stats",
        PlanStats(
            shard_size=best_S,
            padding=plan.padding,
            padding_ratio=plan.padding_ratio,
            plan_seconds=time.perf_counter() - t0,
            candidates_tried=tried,
        ),
    )
    return plan


# ---------------------------------------------------------------------------
# Exact solver (test oracle) — tiny instances only
# ---------------------------------------------------------------------------

def plan_exact(
    tensors: Sequence[TensorSpec], num_shards: int, *, g_coll: int = 1,
    max_S: int | None = None,
) -> GroupPlan:
    """Brute force over S (multiples of g_coll) with exhaustive placement
    search; exponential — for Hypothesis cross-checks of the heuristic."""
    m = num_shards
    total = sum(t.size for t in tensors)
    lb = max(-(-total // m), max(t.granularity for t in tensors), g_coll)
    lb = -(-lb // g_coll) * g_coll
    ub = max_S if max_S is not None else (total + g_coll) * 2

    def dfs(i: int, pos: int, S: int, acc: list[Placement]) -> list[Placement] | None:
        if i == len(tensors):
            return list(acc)
        t = tensors[i]
        # try every feasible start up to the buffer end (bounded: small tests)
        l = pos
        while l + t.size <= m * S:
            ok = True
            k0, k1 = l // S + 1, (l + t.size - 1) // S
            for k in range(k0, k1 + 1):
                if (k * S - l) % t.granularity != 0:
                    ok = False
                    break
            if ok:
                acc.append(Placement(t, l))
                res = dfs(i + 1, l + t.size, S, acc)
                if res is not None:
                    return res
                acc.pop()
            l += 1
        return None

    S = lb
    while S <= ub:
        res = dfs(0, 0, S, [])
        if res is not None:
            plan = GroupPlan(tuple(res), shard_size=S, num_shards=m)
            plan.validate()
            return plan
        S += g_coll
    raise ValueError("exact planner: no feasible S <= ub")


# ---------------------------------------------------------------------------
# Baseline planners (the systems the paper compares against)
# ---------------------------------------------------------------------------

def plan_fsdp2(tensors: Sequence[TensorSpec], num_shards: int) -> GroupPlan:
    """FSDP2 / fully_shard: per-parameter even Shard(0), each param padded to
    a multiple of m.  In the *gathered* buffer each parameter is interleaved
    (device-major), which is what forces FSDP2's Copy-Out/Copy-In — consumers
    of this plan must re-gather per-tensor (see DBuffer.unpack_interleaved)."""
    m = num_shards
    offset = 0
    placements = []
    for t in tensors:
        placements.append(Placement(t, offset))
        offset += -(-t.size // m) * m  # pad every tensor to m
    S = offset // m
    return GroupPlan(tuple(placements), shard_size=S, num_shards=m, mode="fsdp2")


def plan_megatron(tensors: Sequence[TensorSpec], num_shards: int) -> GroupPlan:
    """Megatron-FSDP: concatenated sharding with padding so every tensor
    begins at a device-row boundary — i.e. each tensor padded to a multiple of
    m * row_size, keeping Shard(0)-compatible checkpoints but inflating the
    buffer (the paper measures +33% on MoE)."""
    m = num_shards
    offset = 0
    placements = []
    for t in tensors:
        unit = m * max(t.row_size(), 1)
        placements.append(Placement(t, offset))
        offset += -(-t.size // unit) * unit
    S = offset // m
    return GroupPlan(tuple(placements), shard_size=S, num_shards=m, mode="megatron")


def plan_naive(tensors: Sequence[TensorSpec], num_shards: int,
               *, g_coll: int = LANE) -> GroupPlan:
    """Fig. 6(a): concatenate with no planning.  Blocks straddle shard
    boundaries (breaking quantization locality) and the tail is padded only to
    make the global size divisible by m."""
    m = num_shards
    offset = 0
    placements = []
    for t in tensors:
        placements.append(Placement(t, offset))
        offset += t.size
    S = -(-offset // (m * g_coll)) * g_coll
    return GroupPlan(tuple(placements), shard_size=S, num_shards=m, mode="naive")


def straddled_blocks(plan: GroupPlan) -> int:
    """#blocks split across device boundaries (0 for valid ragged plans) —
    each one costs a cross-device metadata exchange for block quantization."""
    S = plan.shard_size
    count = 0
    for p in plan.placements:
        g = p.spec.granularity
        for k in range(p.offset // S + 1, (p.end - 1) // S + 1):
            if (k * S - p.offset) % g != 0:
                count += 1
    return count


def plan_from_checkpoint_index(index: dict, shard_size: int, num_shards: int,
                               mode: str = "ragged") -> GroupPlan:
    """Reconstruct a ``GroupPlan`` from a saved checkpoint index
    (``ragged.checkpoint_index`` output as round-tripped through JSON).

    This is the read half of the plan artifact: an old checkpoint's layout
    becomes a live plan whose extent map (``GroupPlan.tensor_extents``) can
    address the saved shard files — no planner run, no array data touched.
    """
    placements = []
    for name, ent in index.items():
        spec = TensorSpec(name, tuple(int(s) for s in ent["shape"]),
                          ent.get("dtype", "float32"),
                          int(ent.get("granularity", 1)))
        placements.append(Placement(spec, int(ent["offset"])))
    placements.sort(key=lambda p: p.offset)
    return GroupPlan(tuple(placements), int(shard_size), int(num_shards),
                     mode=mode)


PLANNERS = {
    "ragged": plan_group,
    "fsdp2": plan_fsdp2,
    "megatron": plan_megatron,
    "naive": plan_naive,
}


def get_planner(mode: str):
    """Planner lookup with a config-grade error (a typo'd ``--planner``
    raises ValueError listing the choices instead of a bare KeyError)."""
    try:
        return PLANNERS[mode]
    except KeyError:
        raise ValueError(
            f"unknown planner mode {mode!r}; expected one of "
            f"{sorted(PLANNERS)}") from None
