"""CommProfile: versioned, schema-checked communication calibration profiles.

The auto planner's ``CostModel`` originally priced every group with the
hard-coded TPU-v5e constants in ``launch/mesh.py`` -- paper numbers, not
measurements.  OSDP's thesis (PAPERS.md) is that sharding decisions should
come from a cost model searched against the *measured* system, so this
module makes the measurement a first-class, reproducible artifact:

  * ``benchmarks/bench_comm.py`` micro-benchmarks each wire codec x
    gather/reduce mode x ring chunk size on the actual mesh and persists a
    ``CommProfile`` as ``BENCH_comm.json`` at the repo root (loadable from
    any path: the file is self-describing).
  * ``CostModel.from_profile(profile)`` (core.policy) prices gather/reduce
    formats from the profile's fitted latency/bandwidth curves, and the
    autotuner sets each ring-mode group's ``ring_chunk_elems`` by searching
    the profile's chunk-size curve (``best_ring_chunk``).
  * Every auto-priced ``ShardingPlan`` records the profile's ``name`` and
    ``content_hash()``, so a plan is reproducible from its profile and
    ``plan.diff`` flags profile drift.

Fallback doctrine: when no measured profile is supplied, ``CostModel``
prices through the closed-form roofline built from the ``launch/mesh.py``
constants -- ``builtin_profile()`` renders exactly those constants as a
profile tagged ``name="builtin-roofline"`` / ``builtin=True`` so the
provenance chain never has a hole.  A builtin profile is *synthesized*
(two exact points per curve, so the linear fit recovers the constants);
a measured profile is *end-to-end* (``end_to_end=True``): its q8 entries
include the encode/decode cost on this backend, so the cost model must
not add the analytic HBM terms on top of a measured curve.

Schema (``comm-profile/v1``)::

    {"schema": "comm-profile/v1",
     "name": "measured-cpu-8dev",        # or "builtin-roofline"
     "builtin": false,                   # true only for the fallback
     "end_to_end": true,                 # codec cost included in entries
     "backend": "cpu", "world": 8, "quick": true,
     "entries": [
        {"direction": "gather",          # gather | reduce
         "fmt": "fp32",                  # a core.wire WIRE_FORMATS name
         "mode": "xla",                  # xla | ring | ring_acc
         "elems": 65536,                 # full logical buffer elements
         "chunk_elems": 65536,           # ring message size (== elems for
                                         #   the shard-sized default)
         "time_us": 123.4}, ...]}

``python -m repro.core.profile <path>`` is the schema validator CI runs
against the calibrated artifact.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Mapping, Optional

SCHEMA = "comm-profile/v1"

DIRECTIONS = ("gather", "reduce")
# "xla" = the XLA collective (all_gather / psum_scatter); "ring" = the
# manual ppermute ring routes (order-exact reduce in ring gather mode);
# "ring_acc" = the accumulate-in-flight reduce ring (reduce only).
MODES = ("xla", "ring", "ring_acc")

BUILTIN_NAME = "builtin-roofline"

# the legacy CostModel per-collective issue latency (seconds); the builtin
# profile is synthesized from this + the launch/mesh.py bandwidth constants
BUILTIN_LATENCY_S = 5e-6


@dataclasses.dataclass(frozen=True)
class CommSample:
    """One measured (or synthesized) point on a comm curve."""

    direction: str   # gather | reduce
    fmt: str         # wire format name (core.wire.WIRE_FORMATS)
    mode: str        # xla | ring | ring_acc
    elems: int       # full logical buffer elements
    chunk_elems: int  # ring message elements (== elems: shard-sized)
    time_us: float

    def key(self) -> tuple[str, str, str]:
        return (self.direction, self.fmt, self.mode)

    def to_json(self) -> dict:
        return {"direction": self.direction, "fmt": self.fmt,
                "mode": self.mode, "elems": int(self.elems),
                "chunk_elems": int(self.chunk_elems),
                "time_us": float(self.time_us)}


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(f"invalid comm profile: {msg}")


@dataclasses.dataclass(frozen=True)
class CommProfile:
    """A versioned set of comm measurements plus fitted curves.

    ``linear(direction, fmt, mode)`` fits ``time_s = latency + elems *
    per_elem_s`` over the key's shard-sized-chunk entries (non-negative
    least squares via clamping); ``best_ring_chunk`` searches the chunk
    sweep.  Frozen + hashable so it can ride ``CostModel`` (also frozen).
    """

    name: str
    entries: tuple[CommSample, ...]
    backend: str = "cpu"
    world: int = 1           # devices the collectives ran over
    builtin: bool = False    # synthesized from the roofline constants
    end_to_end: bool = True  # entries include codec encode/decode cost
    quick: bool = False

    def __post_init__(self):
        _check(bool(self.name), "empty profile name")
        _check(bool(self.entries), "no entries")
        _check(self.world >= 1, f"world {self.world} < 1")
        for s in self.entries:
            _check(s.direction in DIRECTIONS,
                   f"direction {s.direction!r} not in {DIRECTIONS}")
            _check(s.mode in MODES, f"mode {s.mode!r} not in {MODES}")
            _check(not (s.direction == "gather" and s.mode == "ring_acc"),
                   "ring_acc is a reduce-only mode")
            _check(isinstance(s.fmt, str) and bool(s.fmt),
                   f"bad fmt {s.fmt!r}")
            _check(s.elems >= 1, f"elems {s.elems} < 1")
            _check(1 <= s.chunk_elems <= s.elems,
                   f"chunk_elems {s.chunk_elems} outside [1, {s.elems}]")
            _check(s.time_us >= 0, f"negative time_us {s.time_us}")

    # ------------------------------------------------------------------ #
    # serialization
    # ------------------------------------------------------------------ #
    def to_json(self) -> dict:
        return {
            "schema": SCHEMA,
            "name": self.name,
            "backend": self.backend,
            "world": int(self.world),
            "builtin": bool(self.builtin),
            "end_to_end": bool(self.end_to_end),
            "quick": bool(self.quick),
            "entries": [s.to_json() for s in self.entries],
        }

    def dumps(self) -> str:
        """Canonical JSON (sorted keys) -- ``content_hash`` hashes this."""
        return json.dumps(self.to_json(), sort_keys=True)

    def content_hash(self) -> str:
        """Short stable content hash; recorded by every plan this profile
        priced, so replanning can prove it used the same measurements."""
        return hashlib.sha256(self.dumps().encode()).hexdigest()[:12]

    @classmethod
    def from_json(cls, data: Mapping[str, Any]) -> "CommProfile":
        _check(isinstance(data, Mapping), f"not an object: {type(data)}")
        _check(data.get("schema") == SCHEMA,
               f"schema {data.get('schema')!r} != {SCHEMA!r}")
        for k in ("name", "entries"):
            _check(k in data, f"missing key {k!r}")
        raw = data["entries"]
        _check(isinstance(raw, (list, tuple)), "entries is not a list")
        entries = []
        for i, e in enumerate(raw):
            _check(isinstance(e, Mapping), f"entries[{i}] is not an object")
            missing = {"direction", "fmt", "mode", "elems", "chunk_elems",
                       "time_us"} - set(e)
            _check(not missing, f"entries[{i}] missing {sorted(missing)}")
            entries.append(CommSample(
                direction=str(e["direction"]), fmt=str(e["fmt"]),
                mode=str(e["mode"]), elems=int(e["elems"]),
                chunk_elems=int(e["chunk_elems"]),
                time_us=float(e["time_us"])))
        return cls(name=str(data["name"]), entries=tuple(entries),
                   backend=str(data.get("backend", "unknown")),
                   world=int(data.get("world", 1)),
                   builtin=bool(data.get("builtin", False)),
                   end_to_end=bool(data.get("end_to_end", True)),
                   quick=bool(data.get("quick", False)))

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")

    # ------------------------------------------------------------------ #
    # fitted curves
    # ------------------------------------------------------------------ #
    def has(self, direction: str, fmt: str, mode: str) -> bool:
        return any(s.key() == (direction, fmt, mode) for s in self.entries)

    def linear(self, direction: str, fmt: str, mode: str
               ) -> tuple[float, float]:
        """``(latency_s, per_elem_s)`` least-squares fit of the key's
        shard-sized-chunk entries (``chunk_elems == elems``), clamped to
        non-negative.  One point degenerates to a pure-slope model; a
        missing key raises (callers gate on ``has``)."""
        pts = [(s.elems, s.time_us * 1e-6) for s in self.entries
               if s.key() == (direction, fmt, mode)
               and s.chunk_elems == s.elems]
        if not pts:  # chunk-sweep-only key: fall back to its best chunk
            pts = [(s.elems, s.time_us * 1e-6) for s in self.entries
                   if s.key() == (direction, fmt, mode)]
        if not pts:
            raise KeyError(f"no profile entries for "
                           f"({direction}, {fmt}, {mode})")
        if len(pts) == 1 or len({x for x, _ in pts}) == 1:
            x, t = pts[0]
            return 0.0, max(t / x, 0.0)
        n = float(len(pts))
        sx = sum(x for x, _ in pts)
        st = sum(t for _, t in pts)
        sxx = sum(x * x for x, _ in pts)
        sxt = sum(x * t for x, t in pts)
        denom = n * sxx - sx * sx
        slope = (n * sxt - sx * st) / denom
        lat = (st - slope * sx) / n
        if slope < 0:  # noisy micro-bench: fall back to mean per-elem time
            return 0.0, max(st / sx, 0.0)
        return max(lat, 0.0), slope

    def time_s(self, direction: str, fmt: str, mode: str,
               elems: float) -> float:
        lat, slope = self.linear(direction, fmt, mode)
        return lat + elems * slope

    def best_ring_chunk(self, direction: str, fmt: str) -> Optional[int]:
        """The chunk size (elems per ring message) with the lowest
        normalized time across the key's ring-mode chunk sweep, or None
        when the profile has no sweep (or the shard-sized default wins).
        The autotuner snaps this to a divisor of the actual shard size
        (core.wire's chunk rule), so any positive answer is safe."""
        modes = ("ring",) if direction == "gather" else ("ring", "ring_acc")
        sweep: dict[int, list[float]] = {}
        default: dict[int, list[float]] = {}
        for s in self.entries:
            if s.direction != direction or s.fmt != fmt or s.mode not in modes:
                continue
            bucket = default if s.chunk_elems == s.elems else sweep
            bucket.setdefault(s.chunk_elems, []).append(
                s.time_us * 1e-6 / s.elems)
        if not sweep:
            return None
        norm = lambda v: sum(v) / len(v)
        best_chunk, best_t = min(
            ((c, norm(v)) for c, v in sweep.items()), key=lambda kv: kv[1])
        base = min((norm(v) for v in default.values()), default=None)
        if base is not None and base <= best_t:
            return None  # shard-sized default already wins
        return int(best_chunk)


# --------------------------------------------------------------------------- #
# the builtin fallback profile
# --------------------------------------------------------------------------- #
def builtin_profile(ici_bw: Optional[float] = None,
                    latency_s: float = BUILTIN_LATENCY_S) -> CommProfile:
    """The ``launch/mesh.py`` roofline constants rendered as a profile:
    two exact points per (direction, fmt, mode) curve, so the linear fit
    recovers ``latency_s`` + ``wire_bytes/ici_bw`` bit-for-bit.  Tagged
    ``builtin=True`` -- the cost model prices builtin profiles through the
    closed-form roofline (with the group's real quant block), and uses the
    fitted curves only for *measured* profiles."""
    if ici_bw is None:
        from ..launch.mesh import ICI_BW
        ici_bw = ICI_BW
    # synthesized wire bytes/elem at the default 1024 quant block; the
    # closed-form pricing uses each group's actual block, so these entries
    # are documentation + hash material, not the pricing path.  fp8 wire
    # entries (1 B/elem, no scales) appear only where the installed JAX
    # provides the dtypes, matching the guarded format registry.
    from ..compat import float8_dtypes

    bytes_per_elem = {"fp32": 4.0, "bf16": 2.0, "q8_block": 1.0 + 4.0 / 1024,
                      **{name: 1.0 for name in float8_dtypes()}}
    entries = []
    for direction in DIRECTIONS:
        for mode in MODES:
            if direction == "gather" and mode == "ring_acc":
                continue
            for fmt, bpe in bytes_per_elem.items():
                for elems in (1 << 20, 1 << 24):
                    t = latency_s + elems * bpe / ici_bw
                    entries.append(CommSample(
                        direction=direction, fmt=fmt, mode=mode,
                        elems=elems, chunk_elems=elems,
                        time_us=t * 1e6))
    return CommProfile(name=BUILTIN_NAME, entries=tuple(entries),
                       backend="roofline", world=1, builtin=True,
                       end_to_end=False, quick=False)


def load_profile(path) -> CommProfile:
    """Load + schema-check a profile from any path (``BENCH_comm.json`` at
    the repo root is just the conventional location)."""
    with open(path) as f:
        data = json.load(f)
    return CommProfile.from_json(data)


def main(argv=None) -> int:
    """``python -m repro.core.profile <path>`` -- the CI schema validator:
    exit 0 and print a summary iff the file is a valid comm-profile/v1."""
    import argparse

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("path", help="profile JSON to validate")
    args = ap.parse_args(argv)
    try:
        prof = load_profile(args.path)
    except (OSError, json.JSONDecodeError, ValueError) as e:
        print(f"INVALID {args.path}: {e}")
        return 1
    keys = sorted({s.key() for s in prof.entries})
    sweeps = sum(1 for s in prof.entries if s.chunk_elems != s.elems)
    print(f"OK {args.path}: name={prof.name} hash={prof.content_hash()} "
          f"backend={prof.backend} world={prof.world} "
          f"entries={len(prof.entries)} curves={len(keys)} "
          f"chunk_sweep_points={sweeps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
