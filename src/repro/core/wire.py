"""WireCodec: bidirectional wire formats for FSDP collectives.

Before this layer existed the two directions of FSDP traffic were encoded
by different machinery: the parameter all-gather had a structure-aware wire
(ParamStore's q8_block codes+scales payload, ~4x fewer bytes than fp32)
while the gradient reduce-scatter was a hard-coded dtype cast buried in the
``sharded_gather`` VJP.  QSDP (Markov et al.) shows the *gradient*
direction quantizes just as well -- with error feedback it converges at
full-precision quality -- so the wire format deserves to be one
abstraction, owned here, that both directions consume:

  * ``WireCodec``  -- one payload format on the wire: ``encode`` (dense ->
    payload), ``decode`` (payload -> dense), and the byte accounting.
    Formats: ``fp32``/``bf16`` (cast codecs: the payload is the buffer
    itself in that dtype, encode/decode are ``astype`` -- op-for-op what
    the pre-codec runtime emitted, so these paths stay bitwise identical),
    ``q8_block`` (block-wise INT8: payload is ``{"codes", "scales"}``,
    1 B/element + 4 B per ``block`` elements), plus -- when the installed
    JAX provides float8 (``compat.float8_dtypes``) -- ``fp8_e4m3``/
    ``fp8_e5m2`` cast codecs, registered only when present so fp8 is a
    legal wire dtype without any call-site version checks.
  * gather direction -- ``codec_gather`` encodes, all-gathers the payload
    (xla collective or explicit ppermute ring), and decodes locally.
    ``payload_all_gather`` is the pure-data-movement primitive quantized
    ParamStores feed their pre-encoded state through.
  * reduce direction -- the VJP of the gathers.  Cast codecs reduce-scatter
    exactly as before (psum_scatter / order-exact ring / accumulate-in-
    flight ring per mode).  The ``q8_block`` reduce codec implements the
    QSDP-style quantized gradient reduce-scatter: each device encodes its
    (error-compensated) full cotangent once -- blocks never straddle chunk
    boundaries because the planner aligns the shard size to the quant
    block -- and the reduce-combine rule is *dequantize-then-accumulate in
    fp32 in absolute device order* (match mode: quantized chunks are
    routed un-reduced, so xla and ring gather modes stay bitwise identical
    to each other) or the per-hop requantizing accumulate-in-flight ring
    (``reduce_mode="ring_acc"``: n-1 quantized chunk-hops, partial sums
    requantized each hop, allclose-not-bitwise).
  * error feedback -- the ``*_ef`` primitives thread a per-device residual
    through the VJP: backward adds the residual to the cotangent before
    encoding, and returns the fresh quantization error ``comp -
    decode(encode(comp))`` as the residual's "cotangent", so
    ``jax.grad`` hands the updated residual back alongside the gradient
    (the residual lives in the ParamStore state tree; see
    ``core.store``).  This is QSDP/1-bit-Adam sender-side error feedback:
    the residual is sized like the device's local gradient contribution.

Layering: this module sits below ``core.schedule`` (which owns the
*policy* -- CommSchedule's ``reduce_wire`` knob resolves to a WireCodec
here) and ``core.store`` (which owns what the state tree holds).  It
imports only ``kernels.ops`` (the quant execution engine; Pallas on TPU,
interpret-mode jnp elsewhere -- ``quant.blockwise`` stays the reference
oracle, reached only through the kernels layer) and ``compat``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import float8_dtypes
from ..kernels import ops

# --------------------------------------------------------------------------- #
# format registry
# --------------------------------------------------------------------------- #

# cast wire formats: the payload is the buffer itself in this dtype.
# float8 entries appear only when the installed JAX provides them
# (compat.float8_dtypes) -- the guarded-plumbing contract.
CAST_FORMATS: dict[str, jnp.dtype] = {
    "fp32": jnp.dtype(jnp.float32),
    "bf16": jnp.dtype(jnp.bfloat16),
    **float8_dtypes(),
}

# every format a WireCodec can take
WIRE_FORMATS: tuple[str, ...] = tuple(CAST_FORMATS) + ("q8_block",)

# storage formats a ParamStore can take (core.store).  fp8 stores keep
# an fp32 master shard next to the fp8 codes (the all-gather ships the
# codes as the wire payload), so they register only where the installed
# JAX provides the dtypes -- same guarded-plumbing contract as the fp8
# wire formats above.
STORE_FORMATS: tuple[str, ...] = ("fp32", "bf16", "q8_block") + tuple(
    n for n in CAST_FORMATS if n.startswith("fp8_"))


def check_wire_format(fmt: str | None, who: str = "wire") -> None:
    if fmt is not None and fmt not in WIRE_FORMATS:
        raise ValueError(
            f"unknown {who} format {fmt!r}; expected one of "
            f"{list(WIRE_FORMATS)}")


def fmt_of_dtype(dtype) -> str:
    """Canonical wire-format name of a cast dtype (the legacy
    gather/reduce dtype knobs lower through this)."""
    dt = jnp.dtype(dtype)
    for name, cdt in CAST_FORMATS.items():
        if cdt == dt:
            return name
    raise ValueError(
        f"dtype {dt} has no wire format; supported: {list(CAST_FORMATS)}")


@dataclasses.dataclass(frozen=True)
class WireCodec:
    """One payload format on the FSDP wire (either direction).

    ``encode``/``decode`` are the only places payload structure is known:
    cast codecs carry the buffer itself (payload == array), ``q8_block``
    carries ``{"codes": int8, "scales": fp32-per-block}``.  The codec is a
    frozen, hashable policy object, so it rides ``jax.custom_vjp``
    nondiff args directly.
    """

    fmt: str = "fp32"
    block: int = 1024  # quant block (flat elements) for q8_block

    def __post_init__(self):
        check_wire_format(self.fmt, "WireCodec")
        if self.block < 1:
            raise ValueError(f"quant block must be >= 1, got {self.block}")

    @property
    def quantized(self) -> bool:
        return self.fmt == "q8_block"

    @property
    def dtype(self) -> jnp.dtype:
        """Wire dtype of a cast codec (ValueError for quantized formats:
        their payload has two dtypes and callers must not assume one)."""
        if self.quantized:
            raise ValueError("q8_block payload has no single wire dtype")
        return CAST_FORMATS[self.fmt]

    # ------------------------------------------------------------------ #
    def encode(self, x: jax.Array):
        """Dense buffer -> wire payload (array for cast codecs, a
        codes/scales dict for q8_block; last dim must be a multiple of
        ``block`` -- the planner's align guarantee)."""
        if not self.quantized:
            return x.astype(self.dtype)
        codes, scales = ops.quantize(x, self.block)
        return {"codes": codes, "scales": scales}

    def decode(self, payload, out_dtype) -> jax.Array:
        """Wire payload -> dense buffer in ``out_dtype``.

        q8_block decodes through the fused dequant-into-compute-dtype
        kernel (``ops.dequantize_into``): codes + scales land directly in
        ``out_dtype``, never materializing an intermediate full-size fp32
        buffer (pinned by the jaxpr regression in
        tests/test_kernels_fused.py)."""
        if not self.quantized:
            return payload.astype(out_dtype)
        return ops.dequantize_into(
            payload["codes"], payload["scales"], self.block,
            out_dtype=out_dtype)

    # ------------------------------------------------------------------ #
    def wire_bytes(self, n_elements: int) -> int:
        """PAYLOAD bytes of ``n_elements`` in this format -- the
        per-moved-copy figure, before any route/volume factor.  Gather
        routes all ship (m-1)/m of this uniformly, so gather accounting
        uses it directly; reduce routes differ (order-exact chunk routing
        is m/2 x the bandwidth-optimal rings), so the reduce-side
        accounting (``GroupPlanEntry.reduce_wire_bytes``) applies that
        multiplier on top."""
        if not self.quantized:
            return n_elements * self.dtype.itemsize
        return n_elements + (n_elements // self.block) * 4  # codes + scales


# --------------------------------------------------------------------------- #
# manual ring collectives (gather_mode="ring")
# --------------------------------------------------------------------------- #
def _ring_axis(axes: tuple[str, ...]):
    # ppermute/axis_index treat a tuple of mesh axes as one flattened ring
    # in axis-major order -- the same order lax.all_gather tiles over
    return axes if len(axes) != 1 else axes[0]


def _snap_chunk(rows: int, chunk, unit: int = 1) -> int:
    """Snap a requested ring-chunk size (``ring_chunk_elems``) onto the
    largest divisor of ``rows`` that is <= ``chunk`` and a multiple of
    ``unit`` (the quant block for q8 payloads, so blocks never straddle a
    ring message).  ``None`` / anything >= ``rows`` means the shard-sized
    default -- no splitting.  Deterministic and host-side, so the knob can
    hold any positive value and still lower to a legal message size."""
    if chunk is None or int(chunk) >= rows or rows % unit:
        return rows
    target = max(int(chunk), unit)
    best = 0
    i = 1
    while i * i <= rows:
        if rows % i == 0:
            for d in (i, rows // i):
                if d <= target and d % unit == 0 and d > best:
                    best = d
        i += 1
    return best or rows


def _ring_all_gather(x, axes: tuple[str, ...], axis_sizes: tuple[int, ...],
                     ring_chunk=None):
    """Chunked ring all-gather over the flattened ``axes`` group: n-1
    ``ppermute`` hops, each forwarding one shard-sized chunk, written into
    the tiled output at absolute device offsets.  Pure data movement, so
    bitwise identical to ``lax.all_gather(..., tiled=True)``.

    ``ring_chunk`` (elements, i.e. leading-axis rows) splits each ring
    message into equal sub-chunks pipelined as independent rings -- still
    pure data movement, so still bitwise, at any chunk size.

    PARITY: BITWISE -- pure data movement vs lax.all_gather(tiled).
    """
    n = math.prod(axis_sizes)
    if n == 1:
        return x
    sub = _snap_chunk(x.shape[0], ring_chunk)
    if sub != x.shape[0]:
        k = x.shape[0] // sub
        parts = [_ring_all_gather(x[i * sub:(i + 1) * sub], axes, axis_sizes)
                 for i in range(k)]
        # part i holds every device's rows [i*sub, (i+1)*sub); interleave
        # back to the tiled (device-major) layout of the unchunked gather
        stacked = jnp.stack(
            [p.reshape((n, sub) + x.shape[1:]) for p in parts], axis=1)
        return stacked.reshape((n * x.shape[0],) + x.shape[1:])
    ax = _ring_axis(axes)
    idx = lax.axis_index(ax)
    perm = [((i + 1) % n, i) for i in range(n)]  # receive from the right
    c = x.shape[0]
    out = jnp.zeros((n * c,) + x.shape[1:], x.dtype)
    cur = x
    out = lax.dynamic_update_slice_in_dim(out, cur, idx * c, axis=0)
    for k in range(1, n):
        cur = lax.ppermute(cur, ax, perm)  # now holds device (idx+k)'s shard
        out = lax.dynamic_update_slice_in_dim(
            out, cur, ((idx + k) % n) * c, axis=0)
    return out


def _split_cols(buf, n: int, k: int, sub: int):
    # view the (n*c, ...) buffer as (n, k, sub, ...) and yield column i as
    # an (n*sub, ...) buffer -- one independent sub-ring per column
    cols = buf.reshape((n, k, sub) + buf.shape[1:])
    return [cols[:, i].reshape((n * sub,) + buf.shape[1:]) for i in range(k)]


def _ring_reduce_scatter(ct, axes: tuple[str, ...],
                         axis_sizes: tuple[int, ...], ring_chunk=None):
    """Ring reduce-scatter matching ``lax.psum_scatter`` bitwise.

    Chunks are routed *un-reduced* to their destination device -- each hop
    the in-flight buffer sheds the chunk that just arrived home, so hop k
    carries n-1-k chunks -- and the destination accumulates its n
    contributions in absolute device order, upcast to fp32, rounding to the
    reduce dtype once.  That is exactly the (deterministic, linear-order,
    fp32-accumulate) reduction XLA's CPU all-reduce family performs, which
    is what makes ring mode bitwise identical to xla mode.  Wire volume is
    sum(n-1-k) = n(n-1)/2 chunks vs the accumulate-in-flight ring's n-1:
    the cost of order-exactness, acceptable at repro scale and documented
    for paper scale.

    ``ring_chunk`` splits each destination chunk into equal sub-chunks run
    as independent sub-rings; every element keeps the same contributions in
    the same accumulation order, so chunking stays bitwise here.

    PARITY: BITWISE -- order-exact vs lax.psum_scatter.
    """
    n = math.prod(axis_sizes)
    if n == 1:
        return ct
    c = ct.shape[0] // n
    sub = _snap_chunk(c, ring_chunk)
    if sub != c:
        outs = [_ring_reduce_scatter(col, axes, axis_sizes)
                for col in _split_cols(ct, n, c // sub, sub)]
        return jnp.concatenate(outs, axis=0)
    ax = _ring_axis(axes)
    idx = lax.axis_index(ax)
    perm = [((i + 1) % n, i) for i in range(n)]  # receive from the right
    c = ct.shape[0] // n
    chunks = ct.reshape((n, c) + ct.shape[1:])
    # pre-rotate so row j holds this device's contribution to device idx+j:
    # every harvest below is then a *static* slice (the last row)
    chunks = jnp.roll(chunks, -idx, axis=0)
    parts = [chunks[0]]          # own contribution to own chunk
    buf = chunks[1:]
    for _ in range(n - 1):
        buf = lax.ppermute(buf, ax, perm)
        parts.append(buf[-1])    # device (idx+k)'s contribution, now home
        buf = buf[:-1]
    # parts[k] came from device (idx+k) % n; reduce in absolute device
    # order 0..n-1 in fp32, round once (== XLA's reduction order)
    stack = jnp.stack(parts)
    ordered = jnp.take(stack, (jnp.arange(n) - idx) % n, axis=0)
    total = ordered[0].astype(jnp.float32)
    for j in range(1, n):
        total = total + ordered[j].astype(jnp.float32)
    return total.astype(ct.dtype)


def _ring_acc_reduce_scatter(ct, axes: tuple[str, ...],
                             axis_sizes: tuple[int, ...], ring_chunk=None):
    """Accumulate-in-flight ring reduce-scatter (reduce_mode="ring_acc").

    One partial sum per destination chunk rides the ring: the chain for
    device ``d`` starts at ``d-1`` and every hop adds the local
    contribution, so the wire carries n-1 chunk-hops total -- the bandwidth-
    optimal ring -- vs the order-exact ring's n(n-1)/2 un-reduced chunks.
    The accumulation order is ring order (d-1, d-2, ..., d+1, d), NOT XLA's
    absolute device order, and it runs in the dtype ``ct`` arrives in (the
    schedule's reduce dtype): results are allclose to, but not bitwise
    reproducible against, the match-mode reduce-scatter.

    ``ring_chunk`` splits each destination chunk into independent
    sub-rings; each element's additions keep the same ring order and
    dtype, so chunking is bitwise-neutral *within* this mode (the mode
    itself stays in the allclose class vs match).

    PARITY: ALLCLOSE -- ring-order accumulation vs match mode.
    """
    n = math.prod(axis_sizes)
    if n == 1:
        return ct
    c = ct.shape[0] // n
    sub = _snap_chunk(c, ring_chunk)
    if sub != c:
        outs = [_ring_acc_reduce_scatter(col, axes, axis_sizes)
                for col in _split_cols(ct, n, c // sub, sub)]
        return jnp.concatenate(outs, axis=0)
    ax = _ring_axis(axes)
    idx = lax.axis_index(ax)
    perm = [((i + 1) % n, i) for i in range(n)]  # receive from the right
    c = ct.shape[0] // n
    chunks = ct.reshape((n, c) + ct.shape[1:])
    # pre-rotate so row j holds this device's contribution to device idx+j:
    # every add below is then a *static* row index
    chunks = jnp.roll(chunks, -idx, axis=0)
    acc = chunks[1 % n]  # chain I initiate, destined for device idx+1
    for k in range(2, n + 1):
        # receive the partial destined for idx+k, add my contribution;
        # k == n wraps to row 0 (my own chunk, last to be added)
        acc = lax.ppermute(acc, ax, perm)
        acc = acc + chunks[k % n]
    return acc


# --------------------------------------------------------------------------- #
# quantized reduce-scatter (the q8_block reduce-combine rules)
# --------------------------------------------------------------------------- #
def _q8_chunks(codes, scales, axes, axis_sizes, block):
    """Split an encoded payload into per-destination chunk pairs, rotated
    so row j is this device's contribution to device idx+j."""
    n = math.prod(axis_sizes)
    idx = lax.axis_index(_ring_axis(axes))
    c = codes.shape[0] // n
    if c % block:
        raise ValueError(
            f"reduce-scatter chunk size {c} not a multiple of quant block "
            f"{block} -- planner align missing for the reduce wire?")
    cch = jnp.roll(codes.reshape((n, c) + codes.shape[1:]), -idx, axis=0)
    sch = jnp.roll(scales.reshape((n, c // block) + scales.shape[1:]),
                   -idx, axis=0)
    return n, idx, cch, sch


def _q8_split_cols(payload, block: int, n: int, k: int, sub: int):
    # per-destination sub-chunk columns of an encoded payload: codes in
    # rows, scales in rows/block -- sub is block-aligned (_snap_chunk unit)
    ccols = _split_cols(payload["codes"], n, k, sub)
    scols = _split_cols(payload["scales"], n, k, sub // block)
    return [{"codes": c, "scales": s} for c, s in zip(ccols, scols)]


def _q8_route_reduce_scatter(payload, block: int, axes: tuple[str, ...],
                             axis_sizes: tuple[int, ...],
                             ring_chunk=None) -> jax.Array:
    """Order-exact quantized reduce-scatter (reduce_mode="match").

    The mirror of ``_ring_reduce_scatter`` with an int8 payload: quantized
    chunks (codes + per-block scales) are routed *un-reduced* to their
    destination, which dequantizes its n contributions and accumulates
    them in fp32 in absolute device order.  Because the payload is encoded
    once at the source and the accumulation order is device order, this
    path is bitwise identical for xla and ring gather modes (there is no
    XLA collective that dequant-accumulates, so both modes route manually).
    Returns the fp32 shard.  ``ring_chunk`` (block-aligned sub-chunks, see
    ``_snap_chunk``) keeps per-element contributions and device-order
    accumulation unchanged -- bitwise-neutral.

    PARITY: BITWISE -- match-mode q8: routed un-reduced, absolute-order accumulate.
    """
    codes, scales = payload["codes"], payload["scales"]
    n = math.prod(axis_sizes)
    if n == 1:
        return ops.dequantize(codes, scales, block)
    c = codes.shape[0] // n
    sub = _snap_chunk(c, ring_chunk, unit=block)
    if sub != c:
        outs = [_q8_route_reduce_scatter(col, block, axes, axis_sizes)
                for col in _q8_split_cols(payload, block, n, c // sub, sub)]
        return jnp.concatenate(outs, axis=0)
    ax = _ring_axis(axes)
    perm = [((i + 1) % n, i) for i in range(n)]
    n, idx, cch, sch = _q8_chunks(codes, scales, axes, axis_sizes, block)
    parts = [(cch[0], sch[0])]   # own contribution to own chunk
    cbuf, sbuf = cch[1:], sch[1:]
    for _ in range(n - 1):
        cbuf = lax.ppermute(cbuf, ax, perm)
        sbuf = lax.ppermute(sbuf, ax, perm)
        parts.append((cbuf[-1], sbuf[-1]))  # from device idx+k, now home
        cbuf, sbuf = cbuf[:-1], sbuf[:-1]
    deq = jnp.stack([ops.dequantize(pc, ps, block) for pc, ps in parts])
    # parts[k] came from device (idx+k) % n; fold in absolute device order
    ordered = jnp.take(deq, (jnp.arange(n) - idx) % n, axis=0)
    total = ordered[0]
    for j in range(1, n):
        total = total + ordered[j]
    return total


def _q8_ring_acc_reduce_scatter(payload, block: int, axes: tuple[str, ...],
                                axis_sizes: tuple[int, ...],
                                ring_chunk=None) -> jax.Array:
    """Accumulate-in-flight quantized reduce-scatter
    (reduce_mode="ring_acc"): the partial sum rides the ring *quantized*
    (n-1 chunk-hops of codes + scales) and every hop dequantizes, adds the
    local dequantized contribution, and requantizes.  The per-hop
    requantization error of partial sums is NOT error-compensated (only
    the one-time contribution encoding is, see ``codec_gather_ef``);
    accumulation order is ring order -- allclose, not bitwise, vs the
    match-mode rule.  Returns the fp32 shard.  ``ring_chunk`` sub-rings
    keep each element's dequant/add/requant sequence unchanged (per-block
    quantization never crosses the block-aligned sub-chunk boundary), so
    chunking is bitwise-neutral within this mode.

    PARITY: ALLCLOSE -- in-flight re-quantized partials vs the match-mode q8 route.
    """
    codes, scales = payload["codes"], payload["scales"]
    n = math.prod(axis_sizes)
    if n == 1:
        return ops.dequantize(codes, scales, block)
    c = codes.shape[0] // n
    sub = _snap_chunk(c, ring_chunk, unit=block)
    if sub != c:
        outs = [_q8_ring_acc_reduce_scatter(col, block, axes, axis_sizes)
                for col in _q8_split_cols(payload, block, n, c // sub, sub)]
        return jnp.concatenate(outs, axis=0)
    ax = _ring_axis(axes)
    perm = [((i + 1) % n, i) for i in range(n)]
    n, idx, cch, sch = _q8_chunks(codes, scales, axes, axis_sizes, block)
    acc_c, acc_s = cch[1 % n], sch[1 % n]  # chain I initiate, for idx+1
    val = None
    for k in range(2, n + 1):
        acc_c = lax.ppermute(acc_c, ax, perm)
        acc_s = lax.ppermute(acc_s, ax, perm)
        val = (ops.dequantize(acc_c, acc_s, block)
               + ops.dequantize(cch[k % n], sch[k % n], block))
        if k < n:  # still in flight: requantize for the next hop
            acc_c, acc_s = ops.quantize(val, block)
    return val


# --------------------------------------------------------------------------- #
# the reduce-combine dispatch
# --------------------------------------------------------------------------- #
def dtype_reduce_scatter(g, axes, axis_sizes, mode, reduce_mode,
                         ring_chunk=None):
    """The cast-codec gradient reduce-scatter: accumulate-in-flight ring
    when reduce_mode says so, else the gather mode's bitwise-exact match
    (psum_scatter for xla, the order-exact ring for ring).  ``ring_chunk``
    applies only to the ring routes; the xla collective ignores it.

    PARITY: BITWISE -- route selection only; each route carries its own class.
    """
    if not axes:
        return g
    if reduce_mode == "ring_acc":
        return _ring_acc_reduce_scatter(g, axes, axis_sizes, ring_chunk)
    if mode == "ring":
        return _ring_reduce_scatter(g, axes, axis_sizes, ring_chunk)
    return lax.psum_scatter(g, axes, scatter_dimension=0, tiled=True)


def codec_reduce_scatter(ct, ef, codec: WireCodec, axes, axis_sizes, mode,
                         reduce_mode, param_dtype, ring_chunk=None):
    """Reduce-scatter a cotangent through ``codec`` -- THE reduce-combine
    rule of the wire layer.  Returns ``(shard, new_ef)``.

    Cast codecs: cast to the codec dtype, reduce-scatter, cast to the
    param dtype -- op-for-op the pre-codec VJP, so fp32/bf16 reduce wires
    stay bitwise identical to the legacy ``reduce_dtype`` path (``ef``
    must be None: a lossless wire has no error to feed back).

    q8_block: add the error-feedback residual (if any), encode ONCE, route
    per ``reduce_mode``, and hand back the fresh quantization error as the
    new residual.  With no FSDP axes (m == 1) the encode/decode round-trip
    still runs, so a replicated/1-device run exercises the exact wire
    numerics of the sharded one.

    PARITY: BITWISE -- vs the jitted unfused encode+EF composition.
    """
    if not codec.quantized:
        if ef is not None:
            raise ValueError(
                f"error feedback is only defined for quantized reduce "
                f"wires, got codec {codec.fmt!r}")
        g = dtype_reduce_scatter(ct.astype(codec.dtype), axes, axis_sizes,
                                 mode, reduce_mode, ring_chunk)
        return g.astype(param_dtype), None
    if ef is not None:
        # fused EF-add + encode + residual update in one kernel pass;
        # bitwise identical to the unfused comp/encode/decode/subtract
        # sequence (pinned by tests/test_kernels_fused.py)
        codes, scales, new_ef = ops.encode_ef(ct, ef, codec.block)
        payload = {"codes": codes, "scales": scales}
    else:
        payload = codec.encode(ct.astype(jnp.float32))
        new_ef = None
    if reduce_mode == "ring_acc":
        shard = _q8_ring_acc_reduce_scatter(payload, codec.block, axes,
                                            axis_sizes, ring_chunk)
    else:
        shard = _q8_route_reduce_scatter(payload, codec.block, axes,
                                         axis_sizes, ring_chunk)
    return shard.astype(param_dtype), new_ef


# --------------------------------------------------------------------------- #
# payload all-gather (pure data movement)
# --------------------------------------------------------------------------- #
def payload_all_gather(x, axes, axis_sizes, mode, ring_chunk=None):
    """Pure data-movement all-gather for non-differentiable wire payloads
    (int8 codes, per-block scales): gathered in ``x``'s own dtype, no VJP --
    gradients for a quantized store flow through ``codec_grad_proxy``
    instead (straight-through to the master shard).  ``ring_chunk``
    applies only to the ring route (per-payload message size).

    PARITY: BITWISE -- data movement in the codec's wire payload.
    """
    x = lax.stop_gradient(x)
    if not axes:
        return x
    return (_ring_all_gather(x, axes, axis_sizes, ring_chunk)
            if mode == "ring" else lax.all_gather(x, axes, tiled=True))


# --------------------------------------------------------------------------- #
# the gather/reduce-scatter primitives
# --------------------------------------------------------------------------- #
def _leaf_chunk(ring_chunk, leaf_rows: int, rows: int):
    # ring_chunk is stated in logical buffer elements (codes rows); scale
    # it for payload leaves with a different row density (q8 scales are
    # rows/block) so codes and scales messages stay congruent
    if ring_chunk is None or leaf_rows == rows:
        return ring_chunk
    return max(int(ring_chunk) * leaf_rows // max(rows, 1), 1)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6, 7, 8, 9))
def codec_gather(x, axes, axis_sizes, gather_codec: WireCodec,
                 reduce_codec: WireCodec, out_dtype, param_dtype, mode,
                 reduce_mode, ring_chunk=None):
    """All-gather ``x`` (a device-local flat buffer slice, leading axis
    tiled) over the FSDP mesh ``axes`` (sizes ``axis_sizes``).

    forward:  ``gather_codec.encode`` -> all-gather the payload (xla
              collective or explicit ppermute ring, per ``mode``) ->
              ``gather_codec.decode`` to ``out_dtype``
    backward: ``reduce_codec`` reduce-scatter of the cotangent (the ZeRO-3
              gradient reduce-scatter; see ``codec_reduce_scatter``) ->
              cast to ``param_dtype``

    ``ring_chunk`` (``CommSchedule.ring_chunk_elems``) bounds the ring
    message size in both directions; ``None`` is the shard-sized legacy
    default and every value is bitwise-neutral within the mode pair.

    PARITY: BITWISE -- decode after bitwise gather == gather of decode.
    """
    payload = gather_codec.encode(x)
    gathered = jax.tree.map(
        lambda p: payload_all_gather(
            p, axes, axis_sizes, mode,
            _leaf_chunk(ring_chunk, p.shape[0], x.shape[0])), payload)
    return gather_codec.decode(gathered, out_dtype)


def _cgather_fwd(x, axes, axis_sizes, gather_codec, reduce_codec, out_dtype,
                 param_dtype, mode, reduce_mode, ring_chunk=None):
    return (codec_gather(x, axes, axis_sizes, gather_codec, reduce_codec,
                         out_dtype, param_dtype, mode, reduce_mode,
                         ring_chunk), None)


def _cgather_bwd(axes, axis_sizes, gather_codec, reduce_codec, out_dtype,
                 param_dtype, mode, reduce_mode, ring_chunk, _res, ct):
    g, _ = codec_reduce_scatter(ct, None, reduce_codec, axes, axis_sizes,
                                mode, reduce_mode, param_dtype, ring_chunk)
    return (g,)


codec_gather.defvjp(_cgather_fwd, _cgather_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9, 10))
def codec_gather_ef(x, ef, axes, axis_sizes, gather_codec: WireCodec,
                    reduce_codec: WireCodec, out_dtype, param_dtype, mode,
                    reduce_mode, ring_chunk=None):
    """``codec_gather`` with an error-feedback residual threaded through
    the quantized reduce wire.

    ``ef`` is this device's residual for this buffer (shape of the local
    cotangent, i.e. the *gathered* buffer -- sender-side EF is sized like
    the local gradient contribution, QSDP/1-bit-Adam semantics).  The
    forward ignores it; the backward adds it to the cotangent before
    encoding and returns the fresh quantization error as ``ef``'s
    cotangent, so ``jax.grad`` over ``(x, ef)`` yields
    ``(grad_shard, new_residual)``.

    PARITY: BITWISE -- codec_gather plus EF residual pass-through.
    """
    del ef
    return codec_gather(x, axes, axis_sizes, gather_codec, reduce_codec,
                        out_dtype, param_dtype, mode, reduce_mode,
                        ring_chunk)


def _cgather_ef_fwd(x, ef, axes, axis_sizes, gather_codec, reduce_codec,
                    out_dtype, param_dtype, mode, reduce_mode,
                    ring_chunk=None):
    y = codec_gather_ef(x, ef, axes, axis_sizes, gather_codec, reduce_codec,
                        out_dtype, param_dtype, mode, reduce_mode,
                        ring_chunk)
    return y, ef


def _cgather_ef_bwd(axes, axis_sizes, gather_codec, reduce_codec, out_dtype,
                    param_dtype, mode, reduce_mode, ring_chunk, ef, ct):
    g, new_ef = codec_reduce_scatter(ct, ef, reduce_codec, axes, axis_sizes,
                                     mode, reduce_mode, param_dtype,
                                     ring_chunk)
    return (g, new_ef)


codec_gather_ef.defvjp(_cgather_ef_fwd, _cgather_ef_bwd)


def _proxy_zeros(x, axes, axis_sizes, out_dtype):
    n = math.prod(axis_sizes) if axes else 1
    return jnp.zeros((n * x.shape[0],) + x.shape[1:], out_dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6, 7, 8))
def codec_grad_proxy(x, axes, axis_sizes, reduce_codec: WireCodec, out_dtype,
                     param_dtype, mode, reduce_mode, ring_chunk=None):
    """Straight-through gradient route for quantized stores.

    forward: zeros of the gathered shape (no collective, no wire bytes) --
    added to the dequantized payload so the gathered weights' value comes
    from the codes while the gradient flows here.  backward: the standard
    ZeRO-3 reduce-scatter of the cotangent through ``reduce_codec`` to
    ``param_dtype`` (the master shard's dtype), exactly as
    ``codec_gather``'s backward.

    PARITY: BITWISE -- backward route == declared reduce route.
    """
    return _proxy_zeros(x, axes, axis_sizes, out_dtype)


def _proxy_fwd(x, axes, axis_sizes, reduce_codec, out_dtype, param_dtype,
               mode, reduce_mode, ring_chunk=None):
    return (codec_grad_proxy(x, axes, axis_sizes, reduce_codec, out_dtype,
                             param_dtype, mode, reduce_mode, ring_chunk),
            None)


def _proxy_bwd(axes, axis_sizes, reduce_codec, out_dtype, param_dtype, mode,
               reduce_mode, ring_chunk, _res, ct):
    g, _ = codec_reduce_scatter(ct, None, reduce_codec, axes, axis_sizes,
                                mode, reduce_mode, param_dtype, ring_chunk)
    return (g,)


codec_grad_proxy.defvjp(_proxy_fwd, _proxy_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9))
def codec_grad_proxy_ef(x, ef, axes, axis_sizes, reduce_codec: WireCodec,
                        out_dtype, param_dtype, mode, reduce_mode,
                        ring_chunk=None):
    """``codec_grad_proxy`` with the error-feedback residual threaded
    through, for quantized stores whose *reduce* wire is also quantized
    (q8 payload both directions -- the full QSDP configuration).

    PARITY: BITWISE -- EF residual cotangent threading.
    """
    del ef
    return _proxy_zeros(x, axes, axis_sizes, out_dtype)


def _proxy_ef_fwd(x, ef, axes, axis_sizes, reduce_codec, out_dtype,
                  param_dtype, mode, reduce_mode, ring_chunk=None):
    y = codec_grad_proxy_ef(x, ef, axes, axis_sizes, reduce_codec, out_dtype,
                            param_dtype, mode, reduce_mode, ring_chunk)
    return y, ef


def _proxy_ef_bwd(axes, axis_sizes, reduce_codec, out_dtype, param_dtype,
                  mode, reduce_mode, ring_chunk, ef, ct):
    g, new_ef = codec_reduce_scatter(ct, ef, reduce_codec, axes, axis_sizes,
                                     mode, reduce_mode, param_dtype,
                                     ring_chunk)
    return (g, new_ef)


codec_grad_proxy_ef.defvjp(_proxy_ef_fwd, _proxy_ef_bwd)


# --------------------------------------------------------------------------- #
# deferred error feedback (microbatch gradient accumulation)
# --------------------------------------------------------------------------- #
# With gradient accumulation the quantized reduce wire must encode ONCE per
# optimizer step, at the accumulation boundary -- encoding every microbatch
# would quantize partial sums n_micro times and change the residual
# semantics.  The ``*_defer_ef`` primitives have the same forward as their
# eager twins, but their backward performs NO collective: the param slot
# gets zeros (shard-shaped, so the microbatch scan's tree accumulation
# stays well-typed) and the raw fp32 cotangent comes back as the
# residual's cotangent.  The scan then accumulates sum(ct) in the EF grad
# slot, and ``core.fsdp`` calls ``codec_reduce_scatter(sum_ct, ef, ...)``
# once at the boundary -- identical wire numerics to a single batch of the
# same total size.

def _defer_bwd(axes, axis_sizes, param_dtype, ct):
    n = math.prod(axis_sizes) if axes else 1
    shard = jnp.zeros((ct.shape[0] // n,) + ct.shape[1:], param_dtype)
    return shard, ct.astype(jnp.float32)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9, 10))
def codec_gather_defer_ef(x, ef, axes, axis_sizes, gather_codec: WireCodec,
                          reduce_codec: WireCodec, out_dtype, param_dtype,
                          mode, reduce_mode, ring_chunk=None):
    """``codec_gather_ef`` for microbatch accumulation: the backward defers
    the quantized reduce-scatter, returning (zero shard, ct.f32) so the
    accumulated cotangent can be encoded once at the boundary (where
    ``core.fsdp`` applies ``ring_chunk`` to the one real reduce).

    PARITY: BITWISE -- deferred-EF gather: no encode in microbatch backward.
    """
    del ef
    return codec_gather(x, axes, axis_sizes, gather_codec, reduce_codec,
                        out_dtype, param_dtype, mode, reduce_mode,
                        ring_chunk)


def _cgather_def_fwd(x, ef, axes, axis_sizes, gather_codec, reduce_codec,
                     out_dtype, param_dtype, mode, reduce_mode,
                     ring_chunk=None):
    y = codec_gather_defer_ef(x, ef, axes, axis_sizes, gather_codec,
                              reduce_codec, out_dtype, param_dtype, mode,
                              reduce_mode, ring_chunk)
    return y, None


def _cgather_def_bwd(axes, axis_sizes, gather_codec, reduce_codec, out_dtype,
                     param_dtype, mode, reduce_mode, ring_chunk, _res, ct):
    return _defer_bwd(axes, axis_sizes, param_dtype, ct)


codec_gather_defer_ef.defvjp(_cgather_def_fwd, _cgather_def_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9))
def codec_grad_proxy_defer_ef(x, ef, axes, axis_sizes,
                              reduce_codec: WireCodec, out_dtype,
                              param_dtype, mode, reduce_mode,
                              ring_chunk=None):
    """``codec_grad_proxy_ef`` with the deferred (microbatch) backward.

    PARITY: BITWISE -- raw-cotangent residual slot, boundary encode.
    """
    del ef
    return _proxy_zeros(x, axes, axis_sizes, out_dtype)


def _proxy_def_fwd(x, ef, axes, axis_sizes, reduce_codec, out_dtype,
                   param_dtype, mode, reduce_mode, ring_chunk=None):
    y = codec_grad_proxy_defer_ef(x, ef, axes, axis_sizes, reduce_codec,
                                  out_dtype, param_dtype, mode, reduce_mode,
                                  ring_chunk)
    return y, None


def _proxy_def_bwd(axes, axis_sizes, reduce_codec, out_dtype, param_dtype,
                   mode, reduce_mode, ring_chunk, _res, ct):
    return _defer_bwd(axes, axis_sizes, param_dtype, ct)


codec_grad_proxy_defer_ef.defvjp(_proxy_def_fwd, _proxy_def_bwd)


# --------------------------------------------------------------------------- #
# legacy dtype-level spelling (kept for callers/tests that think in dtypes)
# --------------------------------------------------------------------------- #
def sharded_gather(x, axes, axis_sizes, wire_dtype, reduce_dtype, out_dtype,
                   param_dtype, mode, reduce_mode):
    """The pre-codec primitive signature: cast-to-wire all-gather whose
    backward is a cast-to-reduce reduce-scatter.  Now a thin lowering onto
    ``codec_gather`` with cast codecs -- op-for-op identical, which is what
    keeps every fp32/bf16 schedule bitwise-stable across the refactor.

    PARITY: BITWISE -- dispatch over bitwise gather implementations.
    """
    return codec_gather(
        x, axes, axis_sizes, WireCodec(fmt_of_dtype(wire_dtype)),
        WireCodec(fmt_of_dtype(reduce_dtype)), jnp.dtype(out_dtype),
        jnp.dtype(param_dtype), mode, reduce_mode)
