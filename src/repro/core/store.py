"""ParamStore: the storage format of a group's sharded parameter buffer.

The seed runtime hard-coded one format -- an fp32 flat master buffer -- into
``FSDPRuntime`` (``param_shapes`` pinned ``jnp.float32``, optimizers assumed
``params[name]`` was the fp32 weights, the checkpoint saved one array per
group).  The paper's flexibility claim, though, is that RaggedShard
"empowers block-wise quantized training": the storage/communication format
of a group is a *policy*, not a constant.  ``ParamStore`` makes it one layer
(SimpleFSDP's argument: keep the format a traceable, compile-friendly
transformation rather than ad-hoc branches):

  * ``fp32``      -- one fp32 flat buffer; master weights == stored weights.
                     Every path is bitwise identical to the pre-store
                     runtime (``master_f32``/``rebuild`` are identity and
                     ``gather`` is exactly the cast-codec ``codec_gather``).
  * ``bf16``      -- one bf16 flat buffer (half the parameter memory, bf16
                     native on the wire).  The optimizer computes in fp32
                     and rounds the result back to bf16.
  * ``q8_block``  -- block-wise INT8: the state is ``{"codes", "master",
                     "scales"}`` -- int8 codes + one fp32 absmax scale per
                     ``block`` contiguous elements (quant/blockwise.py),
                     alongside the fp32 master shard (QSDP-style: quantized
                     weights travel, fp32 masters stay sharded).  The
                     all-gather moves codes + scales (~4x fewer wire bytes
                     than fp32) and dequantizes locally; gradients take the
                     straight-through route (``codec_grad_proxy``) and
                     reduce-scatter onto the fp32 master, which the
                     optimizer updates and requantizes in the same fused
                     pass.  The planner's ``align`` guarantee (tensor starts
                     and the shard size are multiples of ``block``) makes
                     the per-shard quantization communication-free: no quant
                     block ever straddles a device boundary.
  * ``fp8_e4m3`` / ``fp8_e5m2`` -- float8 codes + fp32 master shard,
                     registered only when the installed JAX provides the
                     dtypes (``compat.float8_dtypes``).  The state is
                     ``{"codes", "master"}``: the all-gather ships the fp8
                     codes (1 B/element, no scales) through
                     ``payload_all_gather`` and decodes with a single cast;
                     gradients take the same straight-through proxy route
                     as q8_block onto the fp32 master.  Re-encoding after
                     the optimizer step is one rounding cast, fused into
                     the update kernel (``kernels.fused_update``).  Scale-
                     free means no planner alignment requirement: fp8
                     stores work at any shard size.

A store *state* is what ``params[name]`` holds for one group: a bare array
for flat formats, a dict of arrays otherwise.  The runtime never inspects
the format outside this module -- it asks the store to split the state into
the differentiable part (``trainable``: the master/storage buffer the
optimizer's grads target, plus the reduce-wire error-feedback residual when
one exists) and the non-differentiable rest (``frozen``: codes/scales), to
gather a compute-dtype flat buffer, and to rebuild a state from updated
fp32 master values.

Quantized *gradient* wire (``CommSchedule.reduce_wire="q8_block"``, the
QSDP direction): the per-device error-feedback residual lives in the state
tree as a ``"reduce_ef"`` leaf, fp32, sized like the device's local
gradient contribution -- the *gathered* buffer, i.e. ``ef_m`` (= the FSDP
world size m) times the shard (sender-side EF memory == local gradient
size, as in QSDP/1-bit Adam).  ``gather`` threads it into the EF variants
of the wire primitives, whose VJP hands back ``(grad, new_residual)``; the
runtime splits the residual out of the grad tree before loss scaling and
re-attaches it to the updated state (``attach_ef``), so it checkpoints and
restores alongside the weights and optimizer state.

The format is selected by ``CommSchedule.param_store`` (global default via
``ParallelConfig.param_store``, per-group via ``group_schedules``) and
validated by ``CommSchedule.validate_for``; see DESIGN.md §ParamStore and
§Wire formats.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import float8_dtypes
from ..kernels import ops
from .schedule import CommSchedule
from .wire import (STORE_FORMATS, WireCodec, codec_gather, codec_gather_ef,
                   codec_gather_defer_ef, codec_grad_proxy,
                   codec_grad_proxy_defer_ef, codec_grad_proxy_ef,
                   payload_all_gather)

# q8_block state keys, in tree-sorted order (dict iteration order of the
# states the store builds; checkpoints rely on the names, not the order).
# An EF-carrying state appends "reduce_ef" (see ``state_keys``).
Q8_KEYS = ("codes", "master", "scales")

# fp8 state keys: float8 codes + fp32 master, no scales (the fp8 dtype IS
# the scale structure).  Same ordering/EF conventions as Q8_KEYS.
FP8_KEYS = ("codes", "master")

# the reduce-wire error-feedback residual leaf (fp32, contribution-sized)
EF_KEY = "reduce_ef"


@dataclasses.dataclass(frozen=True)
class ParamStore:
    """Storage-format policy for one communication group's buffer.

    ``ef_m`` > 0 adds the quantized-reduce-wire error-feedback residual to
    the state: ``ef_m`` is the group's FSDP world size m (the residual is
    m shards long -- the local gradient contribution); 0 means no residual
    leaf (every pre-reduce-wire configuration, bit for bit).
    """

    fmt: str = "fp32"
    block: int = 1024  # quant block (flat elements) for q8_block
    ef_m: int = 0      # reduce-wire EF residual chunks (0 = no residual)

    def __post_init__(self):
        if self.fmt not in STORE_FORMATS:
            raise ValueError(
                f"unknown param_store {self.fmt!r}; expected one of "
                f"{list(STORE_FORMATS)}")
        if self.block < 1:
            raise ValueError(f"quant block must be >= 1, got {self.block}")
        if self.ef_m < 0:
            raise ValueError(f"ef_m must be >= 0, got {self.ef_m}")

    # ------------------------------------------------------------------ #
    # format properties
    # ------------------------------------------------------------------ #
    @property
    def quantized(self) -> bool:
        return self.fmt == "q8_block"

    @property
    def fp8(self) -> bool:
        """True for the float8 code+master formats (fp8_e4m3/fp8_e5m2)."""
        return self.fmt.startswith("fp8_")

    @property
    def fp8_dtype(self) -> jnp.dtype:
        """The float8 code dtype of an fp8 store."""
        if not self.fp8:
            raise ValueError(f"fp8_dtype on a {self.fmt!r} store")
        return jnp.dtype(float8_dtypes()[self.fmt])

    @property
    def has_ef(self) -> bool:
        return self.ef_m > 0

    @property
    def storage_dtype(self) -> jnp.dtype:
        """Dtype of the differentiable (trainable) buffer."""
        return jnp.dtype(jnp.bfloat16 if self.fmt == "bf16" else jnp.float32)

    def align(self) -> int:
        """Planner alignment this store needs: quantized stores pin tensor
        starts and the shard size to the quant block so fixed tiles over the
        local shard never straddle a tensor start or a device boundary.
        A quantized reduce wire (``ef_m`` set by the planner iff
        reduce_wire="q8_block") needs the same guarantee: reduce-scatter
        chunks are shard-sized, so S must be a multiple of the block."""
        return self.block if (self.quantized or self.has_ef) else 1

    def state_keys(self) -> tuple[str, ...] | None:
        """Leaf names of a dict state (None = the state is a bare array:
        flat formats without an EF residual, the seed's format)."""
        if self.quantized:
            keys = Q8_KEYS
        elif self.fp8:
            keys = FP8_KEYS
        else:
            keys = ("master",) if self.has_ef else None
        if keys is None:
            return None
        return keys + ((EF_KEY,) if self.has_ef else ())

    def leaf_dtype(self, key: str) -> jnp.dtype:
        return jnp.dtype({
            "codes": self.fp8_dtype if self.fp8 else jnp.dtype(jnp.int8),
            "master": self.storage_dtype
            if not (self.quantized or self.fp8) else jnp.dtype(jnp.float32),
            "scales": jnp.float32, EF_KEY: jnp.float32,
        }[key])

    # ------------------------------------------------------------------ #
    # state structure
    # ------------------------------------------------------------------ #
    def _scales_shape(self, shape: tuple[int, ...]) -> tuple[int, ...]:
        if shape[-1] % self.block:
            raise ValueError(
                f"buffer last dim {shape[-1]} not a multiple of quant block "
                f"{self.block} -- planner align missing?")
        return shape[:-1] + (shape[-1] // self.block,)

    def _ef_shape(self, shape: tuple[int, ...]) -> tuple[int, ...]:
        """Global EF-residual shape for a global buffer ``shape``: the last
        dim scales by ``ef_m`` so each device's local slice is one full
        gathered buffer (its reduce-scatter contribution)."""
        return shape[:-1] + (shape[-1] * self.ef_m,)

    def _leaf_shape(self, key: str, shape: tuple[int, ...]):
        if key == "scales":
            return self._scales_shape(shape)
        if key == EF_KEY:
            return self._ef_shape(shape)
        return shape

    def state_struct(self, shape: tuple[int, ...], sharding):
        """ShapeDtypeStruct tree of one group's param state (``sharding``
        applies to every leaf: scales and the EF residual shard evenly
        because S % block == 0 and the residual is m shard-lengths)."""
        keys = self.state_keys()
        if keys is None:
            return jax.ShapeDtypeStruct(shape, self.storage_dtype,
                                        sharding=sharding)
        return {k: jax.ShapeDtypeStruct(self._leaf_shape(k, shape),
                                        self.leaf_dtype(k), sharding=sharding)
                for k in keys}

    def state_pspecs(self, pspec):
        """PartitionSpec tree matching ``state_struct`` (all leaves shard
        identically along the flat buffer axis)."""
        keys = self.state_keys()
        if keys is None:
            return pspec
        return {k: pspec for k in keys}

    def leaf_div(self, key: str) -> int:
        """Buffer elements per leaf element: scales cover ``block``
        elements; other leaves are 1:1 (EF is 1:1 per its *own* last dim,
        which is ``ef_m`` x the buffer's — see ``leaf_shard_len``)."""
        return self.block if key == "scales" else 1

    def leaf_shard_len(self, key: str, shard_size: int) -> int:
        """Per-uniform-shard length of one state leaf for an FSDP shard of
        ``shard_size`` buffer elements -- the row length of that leaf's
        per-shard checkpoint file."""
        if key == "scales":
            return shard_size // self.block
        if key == EF_KEY:
            return shard_size * self.ef_m
        return shard_size

    def as_leaves(self, state) -> dict:
        """Uniform dict view of a state (bare array -> {"master": arr}) --
        the checkpoint writer iterates leaves without caring about fmt."""
        if isinstance(state, dict):
            return dict(state)
        return {"master": state}

    def from_leaves(self, leaves: Mapping) -> Any:
        """Inverse of ``as_leaves``: collapse back to a bare array when the
        format stores one."""
        if self.state_keys() is None:
            return leaves["master"]
        return dict(leaves)

    # ------------------------------------------------------------------ #
    # host-side construction (init / checkpoint restore)
    # ------------------------------------------------------------------ #
    def create(self, master_f32: np.ndarray):
        """Build a state from a host-side fp32 global buffer (EF residuals
        start at zero: a fresh error-feedback history is always valid)."""
        master_f32 = np.asarray(master_f32, np.float32)
        if self.fmt == "fp32":
            state = master_f32
        elif self.fmt == "bf16":
            state = np.asarray(
                jnp.asarray(master_f32).astype(jnp.bfloat16))
        elif self.fp8:
            codes = np.asarray(
                jnp.asarray(master_f32).astype(self.fp8_dtype))
            state = {"codes": codes, "master": master_f32}
        else:
            codes, scales = ops.quantize(jnp.asarray(master_f32), self.block)
            state = {"codes": np.asarray(codes), "master": master_f32,
                     "scales": np.asarray(scales)}
        if not self.has_ef:
            return state
        ef = np.zeros(self._ef_shape(master_f32.shape), np.float32)
        if not isinstance(state, dict):
            state = {"master": state}
        return {**state, EF_KEY: ef}

    # ------------------------------------------------------------------ #
    # traced views (inside shard_map, on device-local shards)
    # ------------------------------------------------------------------ #
    def trainable(self, state):
        """The differentiable leaves: the master/storage buffer ``jax.grad``
        runs against (and the gradient reduce-scatter targets), plus the
        reduce-wire EF residual when one exists (its "gradient" IS the
        updated residual -- see core.wire's EF primitives)."""
        if self.has_ef:
            return {"master": state["master"], EF_KEY: state[EF_KEY]}
        return state["master"] if (self.quantized or self.fp8) else state

    def frozen(self, state):
        """The non-differentiable rest of the state (closed over by the
        loss as constants); None unless the store carries codes."""
        if self.quantized:
            return {"codes": state["codes"], "scales": state["scales"]}
        if self.fp8:
            return {"codes": state["codes"]}
        return None

    def combine(self, trainable, frozen):
        """Inverse of (trainable, frozen): the full state again."""
        if self.has_ef:
            state = dict(trainable)
            if self.quantized:
                state.update(codes=frozen["codes"], scales=frozen["scales"])
            elif self.fp8:
                state.update(codes=frozen["codes"])
            return state
        if self.quantized:
            return {"codes": frozen["codes"], "master": trainable,
                    "scales": frozen["scales"]}
        if self.fp8:
            return {"codes": frozen["codes"], "master": trainable}
        return trainable

    def master_f32(self, state) -> jax.Array:
        """fp32 view of the weights the optimizer updates.  For fp32 this is
        the state itself (no cast: bitwise-identical update graph)."""
        if isinstance(state, dict):
            state = state["master"]
        return state if state.dtype == jnp.float32 else state.astype(
            jnp.float32)

    def rebuild(self, new_master_f32: jax.Array):
        """State from updated fp32 master values -- for q8_block this is the
        requantize fused into the same optimizer pass.  The EF residual is
        NOT part of the rebuild (optimizers don't see it): the runtime
        re-attaches the residual that came back through the grad tree via
        ``attach_ef``."""
        if self.fmt == "fp32":
            core = new_master_f32
        elif self.fmt == "bf16":
            core = new_master_f32.astype(jnp.bfloat16)
        elif self.fp8:
            return {"codes": new_master_f32.astype(self.fp8_dtype),
                    "master": new_master_f32}
        else:
            codes, scales = ops.quantize(new_master_f32, self.block)
            return ({"codes": codes, "master": new_master_f32,
                     "scales": scales})
        return {"master": core} if self.has_ef else core

    def wrap_core(self, core):
        """Normalize a rebuilt core (bare array or codes dict, e.g. from
        the fused update kernels) into this store's state layout, minus
        the EF residual (``attach_ef`` re-attaches that)."""
        if self.has_ef and not isinstance(core, dict):
            return {"master": core}
        return core

    def attach_ef(self, core_state, new_ef):
        """Re-attach the updated EF residual to a rebuilt state (the step
        function's last move before returning new params)."""
        if not self.has_ef:
            raise ValueError("attach_ef on a store without an EF residual")
        if not isinstance(core_state, dict):
            core_state = {"master": core_state}
        return {**core_state, EF_KEY: new_ef}

    # ------------------------------------------------------------------ #
    # the gather (what the schedule moves for this format)
    # ------------------------------------------------------------------ #
    def gather(self, state, axes: tuple[str, ...],
               axis_sizes: tuple[int, ...], sched: CommSchedule,
               compute_dtype, defer_ef: bool = False) -> jax.Array:
        """All-gather one device-local state into the flat compute-dtype
        buffer the model unpacks, through the schedule's WireCodecs
        (core.wire).  Flat formats go through ``codec_gather`` (whose
        backward is the ZeRO-3 reduce-scatter in the reduce codec's
        format); q8_block states are already wire-encoded, so their
        codes + scales move through ``payload_all_gather``, are decoded
        locally (the fused dequant-into-compute-dtype kernel), and
        gradients route straight-through to the master shard via
        ``codec_grad_proxy``.  fp8 states take the same pre-encoded
        route with a scale-free payload: the fp8 codes ride
        ``payload_all_gather`` (1 B/element) and decode is a single
        deterministic cast.  When the reduce wire is quantized, the
        EF residual is threaded through the ``*_ef`` variants and its
        updated value returns through the grad tree; ``defer_ef`` selects
        the deferred backward (microbatch accumulation: no collective per
        microbatch, the runtime reduce-scatters the accumulated cotangent
        once at the boundary -- see core.wire).

        PARITY: BITWISE -- dispatch over the tagged core.wire primitives.
        """
        cd = jnp.dtype(compute_dtype)
        rcodec = sched.reduce_codec(cd, self.block)
        rc = sched.ring_chunk_elems
        ef = state[EF_KEY] if self.has_ef else None
        if defer_ef and ef is None:
            raise ValueError("defer_ef on a store without an EF residual")
        if not (self.quantized or self.fp8):
            flat = state["master"] if self.has_ef else state
            gcodec = sched.gather_codec(cd)
            pdt = jnp.dtype(flat.dtype)
            if ef is None:
                return codec_gather(flat, axes, axis_sizes, gcodec, rcodec,
                                    cd, pdt, sched.gather_mode,
                                    sched.reduce_mode, rc)
            prim = codec_gather_defer_ef if defer_ef else codec_gather_ef
            return prim(flat, ef, axes, axis_sizes, gcodec,
                        rcodec, cd, pdt, sched.gather_mode,
                        sched.reduce_mode, rc)
        if self.fp8:
            deq = payload_all_gather(state["codes"], axes, axis_sizes,
                                     sched.gather_mode, rc).astype(cd)
        else:
            deq = WireCodec("q8_block", self.block).decode(
                self.gather_payload(state, axes, axis_sizes, sched), cd)
        f32 = jnp.dtype(jnp.float32)
        if ef is None:
            proxy = codec_grad_proxy(state["master"], axes, axis_sizes,
                                     rcodec, cd, f32, sched.gather_mode,
                                     sched.reduce_mode, rc)
        else:
            prim = (codec_grad_proxy_defer_ef if defer_ef
                    else codec_grad_proxy_ef)
            proxy = prim(state["master"], ef, axes,
                         axis_sizes, rcodec, cd, f32,
                         sched.gather_mode, sched.reduce_mode, rc)
        return deq + proxy

    def gather_payload(self, state, axes: tuple[str, ...],
                       axis_sizes: tuple[int, ...], sched: CommSchedule):
        """All-gather a quantized state's wire payload WITHOUT decoding:
        ``{"codes", "scales"}`` of the full flat buffer, pure data
        movement.  The serve path uses this to keep eligible weights in
        int8 end to end (``DBuffer.unpack_quant`` -> ``ops.q8_matmul``);
        training's ``gather`` decodes it through the fused kernel.

        PARITY: BITWISE -- pure data movement of the encoded payload.
        """
        if not self.quantized:
            raise ValueError(
                f"gather_payload on a {self.fmt!r} store (quantized only)")
        rc = sched.ring_chunk_elems
        return {
            "codes": payload_all_gather(state["codes"], axes, axis_sizes,
                                        sched.gather_mode, rc),
            "scales": payload_all_gather(state["scales"], axes, axis_sizes,
                                         sched.gather_mode,
                                         max(rc // self.block, 1)
                                         if rc else None),
        }

    # ------------------------------------------------------------------ #
    # accounting
    # ------------------------------------------------------------------ #
    def wire_bytes(self, n_elements: int, wire_dtype) -> int:
        """Bytes one all-gather of an ``n_elements`` buffer puts on the
        wire in this format (per gathered copy; the ~4x q8-vs-fp32 drop
        ``bench_e2e --schedule`` reports).  fp8 stores ship their codes:
        1 B/element flat, no scales overhead."""
        if self.fp8:
            return n_elements * self.fp8_dtype.itemsize
        if not self.quantized:
            return n_elements * jnp.dtype(wire_dtype).itemsize
        return WireCodec("q8_block", self.block).wire_bytes(n_elements)
