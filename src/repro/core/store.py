"""ParamStore: the storage format of a group's sharded parameter buffer.

The seed runtime hard-coded one format -- an fp32 flat master buffer -- into
``FSDPRuntime`` (``param_shapes`` pinned ``jnp.float32``, optimizers assumed
``params[name]`` was the fp32 weights, the checkpoint saved one array per
group).  The paper's flexibility claim, though, is that RaggedShard
"empowers block-wise quantized training": the storage/communication format
of a group is a *policy*, not a constant.  ``ParamStore`` makes it one layer
(SimpleFSDP's argument: keep the format a traceable, compile-friendly
transformation rather than ad-hoc branches):

  * ``fp32``      -- one fp32 flat buffer; master weights == stored weights.
                     Every path is bitwise identical to the pre-store
                     runtime (``master_f32``/``rebuild`` are identity and
                     ``gather`` is exactly ``sharded_gather``).
  * ``bf16``      -- one bf16 flat buffer (half the parameter memory, bf16
                     native on the wire).  The optimizer computes in fp32
                     and rounds the result back to bf16.
  * ``q8_block``  -- block-wise INT8: the state is ``{"codes", "master",
                     "scales"}`` -- int8 codes + one fp32 absmax scale per
                     ``block`` contiguous elements (quant/blockwise.py),
                     alongside the fp32 master shard (QSDP-style: quantized
                     weights travel, fp32 masters stay sharded).  The
                     all-gather moves codes + scales (~4x fewer wire bytes
                     than fp32) and dequantizes locally; gradients take the
                     straight-through route (``gather_grad_proxy``) and
                     reduce-scatter onto the fp32 master, which the
                     optimizer updates and requantizes in the same fused
                     pass.  The planner's ``align`` guarantee (tensor starts
                     and the shard size are multiples of ``block``) makes
                     the per-shard quantization communication-free: no quant
                     block ever straddles a device boundary.

A store *state* is what ``params[name]`` holds for one group: a bare array
for flat formats, a dict of arrays for ``q8_block``.  The runtime never
inspects the format outside this module -- it asks the store to split the
state into the differentiable part (``trainable``: the master/storage
buffer, whose grads the optimizer consumes) and the non-differentiable rest
(``frozen``: codes/scales), to gather a compute-dtype flat buffer, and to
rebuild a state from updated fp32 master values.

The format is selected by ``CommSchedule.param_store`` (global default via
``ParallelConfig.param_store``, per-group via ``group_schedules``) and
validated by ``CommSchedule.validate_for``; see DESIGN.md §ParamStore.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..quant.blockwise import dequantize_blockwise, quantize_blockwise
from .schedule import (STORE_FORMATS, CommSchedule, gather_grad_proxy,
                       payload_all_gather, sharded_gather)

# q8_block state keys, in tree-sorted order (dict iteration order of the
# states the store builds; checkpoints rely on the names, not the order)
Q8_KEYS = ("codes", "master", "scales")


@dataclasses.dataclass(frozen=True)
class ParamStore:
    """Storage-format policy for one communication group's buffer."""

    fmt: str = "fp32"
    block: int = 1024  # quant block (flat elements) for q8_block

    def __post_init__(self):
        if self.fmt not in STORE_FORMATS:
            raise ValueError(
                f"unknown param_store {self.fmt!r}; expected one of "
                f"{list(STORE_FORMATS)}")
        if self.block < 1:
            raise ValueError(f"quant block must be >= 1, got {self.block}")

    # ------------------------------------------------------------------ #
    # format properties
    # ------------------------------------------------------------------ #
    @property
    def quantized(self) -> bool:
        return self.fmt == "q8_block"

    @property
    def storage_dtype(self) -> jnp.dtype:
        """Dtype of the differentiable (trainable) buffer."""
        return jnp.dtype(jnp.bfloat16 if self.fmt == "bf16" else jnp.float32)

    def align(self) -> int:
        """Planner alignment this store needs: quantized stores pin tensor
        starts and the shard size to the quant block so fixed tiles over the
        local shard never straddle a tensor start or a device boundary."""
        return self.block if self.quantized else 1

    # ------------------------------------------------------------------ #
    # state structure
    # ------------------------------------------------------------------ #
    def _scales_shape(self, shape: tuple[int, ...]) -> tuple[int, ...]:
        if shape[-1] % self.block:
            raise ValueError(
                f"buffer last dim {shape[-1]} not a multiple of quant block "
                f"{self.block} -- planner align missing?")
        return shape[:-1] + (shape[-1] // self.block,)

    def state_struct(self, shape: tuple[int, ...], sharding):
        """ShapeDtypeStruct tree of one group's param state (``sharding``
        applies to every leaf: scales shard evenly because S % block == 0)."""
        def sds(shp, dt):
            return jax.ShapeDtypeStruct(shp, dt, sharding=sharding)

        if not self.quantized:
            return sds(shape, self.storage_dtype)
        return {
            "codes": sds(shape, jnp.int8),
            "master": sds(shape, jnp.float32),
            "scales": sds(self._scales_shape(shape), jnp.float32),
        }

    def state_pspecs(self, pspec):
        """PartitionSpec tree matching ``state_struct`` (all leaves shard
        identically along the flat buffer axis)."""
        if not self.quantized:
            return pspec
        return {k: pspec for k in Q8_KEYS}

    # ------------------------------------------------------------------ #
    # host-side construction (init / checkpoint restore)
    # ------------------------------------------------------------------ #
    def create(self, master_f32: np.ndarray):
        """Build a state from a host-side fp32 global buffer."""
        if self.fmt == "fp32":
            return np.asarray(master_f32, np.float32)
        if self.fmt == "bf16":
            return np.asarray(jnp.asarray(master_f32).astype(jnp.bfloat16))
        master = np.asarray(master_f32, np.float32)
        codes, scales = quantize_blockwise(jnp.asarray(master), self.block)
        return {"codes": np.asarray(codes), "master": master,
                "scales": np.asarray(scales)}

    # ------------------------------------------------------------------ #
    # traced views (inside shard_map, on device-local shards)
    # ------------------------------------------------------------------ #
    def trainable(self, state):
        """The differentiable leaf: what ``jax.grad`` runs against and what
        the gradient reduce-scatter targets (the master for q8_block)."""
        return state["master"] if self.quantized else state

    def frozen(self, state):
        """The non-differentiable rest of the state (closed over by the
        loss as constants); None for flat formats."""
        if not self.quantized:
            return None
        return {"codes": state["codes"], "scales": state["scales"]}

    def combine(self, trainable, frozen):
        """Inverse of (trainable, frozen): the full state again."""
        if not self.quantized:
            return trainable
        return {"codes": frozen["codes"], "master": trainable,
                "scales": frozen["scales"]}

    def master_f32(self, state) -> jax.Array:
        """fp32 view of the weights the optimizer updates.  For fp32 this is
        the state itself (no cast: bitwise-identical update graph)."""
        if self.quantized:
            return state["master"]
        return state if state.dtype == jnp.float32 else state.astype(
            jnp.float32)

    def rebuild(self, new_master_f32: jax.Array):
        """State from updated fp32 master values -- for q8_block this is the
        requantize fused into the same optimizer pass."""
        if self.fmt == "fp32":
            return new_master_f32
        if self.fmt == "bf16":
            return new_master_f32.astype(jnp.bfloat16)
        codes, scales = quantize_blockwise(new_master_f32, self.block)
        return {"codes": codes, "master": new_master_f32, "scales": scales}

    # ------------------------------------------------------------------ #
    # the gather (what the schedule moves for this format)
    # ------------------------------------------------------------------ #
    def gather(self, state, axes: tuple[str, ...],
               axis_sizes: tuple[int, ...], sched: CommSchedule,
               compute_dtype) -> jax.Array:
        """All-gather one device-local state into the flat compute-dtype
        buffer the model unpacks.  Flat formats go through
        ``sharded_gather`` (whose backward is the ZeRO-3 reduce-scatter);
        q8_block gathers codes + scales (the quantized wire), dequantizes
        locally, and routes gradients straight-through to the master shard
        via ``gather_grad_proxy``."""
        cd = jnp.dtype(compute_dtype)
        if not self.quantized:
            return sharded_gather(
                state, axes, axis_sizes, sched.wire_dtype(cd),
                sched.accum_dtype(cd), cd, jnp.dtype(state.dtype),
                sched.gather_mode, sched.reduce_mode)
        codes = payload_all_gather(state["codes"], axes, axis_sizes,
                                   sched.gather_mode)
        scales = payload_all_gather(state["scales"], axes, axis_sizes,
                                    sched.gather_mode)
        deq = dequantize_blockwise(codes, scales, self.block).astype(cd)
        return deq + gather_grad_proxy(
            state["master"], axes, axis_sizes, sched.accum_dtype(cd), cd,
            jnp.dtype(jnp.float32), sched.gather_mode, sched.reduce_mode)

    # ------------------------------------------------------------------ #
    # accounting
    # ------------------------------------------------------------------ #
    def wire_bytes(self, n_elements: int, wire_dtype) -> int:
        """Bytes one all-gather of an ``n_elements`` buffer puts on the
        wire in this format (per gathered copy; the ~4x q8-vs-fp32 drop
        ``bench_e2e --schedule`` reports)."""
        if not self.quantized:
            return n_elements * jnp.dtype(wire_dtype).itemsize
        return n_elements + (n_elements // self.block) * 4  # codes + scales
