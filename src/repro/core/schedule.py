"""CommSchedule: the FSDP runtime's communication schedule, made explicit.

The seed runtime hard-coded its collective behavior inside the layer scan:
all-gather the current layer in bf16, remat everything (so backward
re-gathers every layer), and let autodiff pick the gradient reduce-scatter
dtype.  This module turns each of those decisions into a policy knob,
mirroring the ``fully_shard(reshard_after_forward=..., mp_policy=...)``
surface of production FSDP:

  * ``prefetch``       -- two-slot double-buffered layer all-gathers: the
                          scan runs over layer *pairs* and both slots'
                          gathers (slot ``i % 2`` holds layer ``i``) are
                          issued before either layer's compute, so the
                          odd slot's gather overlaps the even layer's
                          compute.  The gathered buffers live only inside
                          the checkpointed pair body -- never in the scan
                          carry -- so backward re-gathers (ZeRO-3) and peak
                          gathered memory stays at two layer buffers
                          regardless of depth.  (The seed's first cut
                          threaded the next layer's gathered buffer through
                          the checkpointed carry, which made backward retain
                          one gathered buffer *per layer*.)
  * ``reshard_after_forward`` -- True (default): gathered parameters are
                          dropped after each layer's forward and re-gathered
                          in backward (ZeRO-3).  False keeps every layer's
                          gathered parameters live into backward (no
                          backward re-gather, more memory).  Orthogonal to
                          activation remat, which stays on either way: with
                          resharding off, only the gather moves outside the
                          checkpointed region.
  * ``keep_last_gathered``    -- run the *last* layer un-rematted even when
                          resharding: its gathered parameters stay live into
                          backward, where they are needed first (FSDP2 skips
                          resharding the final block for the same reason).
  * ``gather_mode``    -- "xla" (default): one ``lax.all_gather`` /
                          ``lax.psum_scatter`` pair per layer, overlap left
                          to XLA's latency-hiding scheduler.  "ring": a
                          manual ``lax.ppermute`` ring -- the all-gather is
                          n-1 explicit chunk hops written into the output at
                          absolute device offsets, so issue order (and hence
                          overlap) is visible in the HLO as
                          collective-permutes rather than inferred.  Its
                          backward is the matching ring reduce-scatter:
                          chunks are routed un-reduced to their destination
                          (the buffer shrinks by one chunk per hop) and
                          accumulated there in *absolute device order* in
                          fp32.  That destination-ordered reduction is what
                          XLA's CPU all-reduce does, so ring mode is bitwise
                          identical to xla mode -- the price is n/2x the
                          reduce-scatter wire volume of an
                          accumulate-in-flight ring, which a production
                          deployment would buy back by giving up bitwise
                          reproducibility.
  * ``gather_dtype``   -- wire dtype of the parameter all-gather
                          ("bf16"/"fp32"; None = the runtime compute dtype).
  * ``reduce_dtype``   -- accumulate dtype of the gradient reduce-scatter
                          ("bf16"/"fp32"; None = same as the wire dtype).
                          fp32 trades 2x reduce bandwidth for exact
                          accumulation across large FSDP groups.  When set,
                          it also pins the accumulate dtype of the *replica*
                          gradient psums (HSDP cross-pod, TP-replicated
                          groups, unsharded groups) in
                          ``FSDPRuntime._reduce_grads``.  Legacy spelling:
                          it lowers bitwise-neutrally onto ``reduce_wire``
                          (a cast codec of the same dtype).
  * ``reduce_wire``    -- wire *format* of the gradient reduce-scatter
                          (core.wire.WireCodec): None (default) derives a
                          cast codec from ``reduce_dtype``/the gather wire
                          dtype -- the legacy path, bit for bit --
                          "fp32"/"bf16" name that cast codec explicitly,
                          and "q8_block" is the QSDP-style quantized
                          gradient wire: each device encodes its (error-
                          feedback-compensated) cotangent as int8 codes +
                          per-block scales (~4x fewer bytes than fp32),
                          destinations dequantize and accumulate in fp32.
                          Requires a sharded group; per-shard error-
                          feedback residuals ride the ParamStore state
                          tree (see core.store / DESIGN.md §Wire formats).
  * ``reduce_mode``    -- "match" (default): the gradient reduce-scatter
                          mirrors the gather mode (psum_scatter for xla, the
                          order-exact ring for ring) and stays bitwise
                          identical to XLA's linear-device-order reduction.
                          "ring_acc": accumulate-in-flight ring
                          reduce-scatter -- each chunk's partial sum rides
                          the ring and every hop adds the local contribution,
                          so wire volume is n-1 chunk-hops instead of the
                          order-exact ring's n(n-1)/2.  The price is the
                          reduction order (ring order, not XLA's linear
                          device order), so results are allclose- but not
                          bitwise-reproducible vs the xla/match path.
  * ``param_store``    -- storage format of the group's sharded buffer (see
                          ``core.store.ParamStore``): "fp32" (master
                          weights, today's format), "bf16" (half-size
                          storage, bf16 wire), or "q8_block" (block-wise
                          INT8 codes + per-block absmax scales alongside an
                          fp32 master shard; the all-gather moves codes +
                          scales -- ~4x fewer wire bytes than fp32 -- and
                          dequantizes locally; gradients reduce-scatter to
                          the fp32 master, which the optimizer updates and
                          requantizes in the same fused pass), or -- when
                          the installed JAX provides float8
                          (``compat.float8_dtypes``) -- "fp8_e4m3"/
                          "fp8_e5m2" (float8 codes + fp32 master shard:
                          the all-gather ships the codes at 1 B/element
                          with no scales, decode is a single cast).
  * ``sharded``        -- per-group knob (see below): False keeps the
                          group's flat buffer replicated instead of
                          FSDP-sharding it.  No gather is emitted at all;
                          gradients are psum'd over the axes the group would
                          have been sharded on.  Meant for small groups
                          (e.g. ``globals``) whose per-layer gather latency
                          outweighs the memory saved.

Per-group overrides: ``ParallelConfig.group_schedules`` (or the
``group_schedules=`` kwarg of ``FSDPRuntime``) maps a communication-group
name to a dict of overrides drawn from ``GROUP_OVERRIDE_KEYS``
(``gather_mode``, ``gather_dtype``, ``reduce_dtype``, ``sharded``), e.g.::

    group_schedules={"globals": {"sharded": False},
                     "layers":  {"reduce_dtype": "fp32"}}

keeps the small globals group unsharded and fp32-reduces only the layer
stack.  Scan *structure* knobs (prefetch / reshard / keep_last) always come
from the base schedule; overrides affect how each group's buffer is moved.

The wire *primitives* (codec gathers, ring collectives, the quantized
reduce-scatter) live in ``core.wire``; this module owns the policy surface
and resolves its knobs into ``WireCodec``s (``gather_codec``/
``reduce_codec``).  ``sharded_gather`` -- re-exported from core.wire -- is
the legacy dtype-level spelling: forward = cast-to-wire + all-gather (xla
or ring), backward = cast-to-reduce + reduce-scatter (the ZeRO-3 gradient
reduce-scatter).  With default dtypes its VJP is op-for-op the autodiff
transpose of the seed's ``astype(bf16); all_gather``, so the default
schedule is bitwise identical to the pre-schedule runtime, and ring mode is
bitwise identical to xla mode.

Validation happens in two stages: ``__post_init__`` checks dtype *names*
and the gather mode at construction, and ``validate_for(compute_dtype)``
(called by ``FSDPRuntime.__init__`` with the actual compute dtype) resolves
the full wire/accum dtype path so a ``None`` dtype that would inherit an
unsupported compute dtype fails at runtime construction instead of at first
trace.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping

import jax.numpy as jnp

from .wire import (CAST_FORMATS, STORE_FORMATS, WIRE_FORMATS, WireCodec,
                   check_wire_format, codec_gather, codec_gather_ef,
                   codec_grad_proxy, codec_grad_proxy_ef,
                   codec_reduce_scatter, fmt_of_dtype, payload_all_gather,
                   sharded_gather)

# cast-dtype aliases the legacy gather_dtype/reduce_dtype knobs accept;
# float8 entries appear only when the installed JAX provides them
# (compat.float8_dtypes via core.wire.CAST_FORMATS)
_DTYPES = {
    "bf16": jnp.bfloat16,
    "bfloat16": jnp.bfloat16,
    "fp32": jnp.float32,
    "f32": jnp.float32,
    "float32": jnp.float32,
    **{name: dt for name, dt in CAST_FORMATS.items()
       if name.startswith("fp8_")},
}

_GATHER_MODES = ("xla", "ring")
_REDUCE_MODES = ("match", "ring_acc")

# Per-group schedule override surface (ParallelConfig.group_schedules /
# FSDPRuntime(group_schedules=...)).  Scan-structure knobs are deliberately
# excluded: one scan gathers several groups per layer, so prefetch /
# reshard / keep_last must agree across them and come from the base
# schedule.
GROUP_OVERRIDE_KEYS = frozenset(
    {"gather_mode", "gather_dtype", "reduce_dtype", "sharded",
     "reduce_mode", "param_store", "reduce_wire", "ring_chunk_elems"})


def _check_name(name: str | None) -> None:
    if name is not None and name not in _DTYPES:
        raise ValueError(
            f"unknown schedule dtype {name!r}; expected one of "
            f"{sorted(_DTYPES)}")


def _resolve(name: str | None, default) -> jnp.dtype:
    if name is None:
        return jnp.dtype(default)
    _check_name(name)
    return jnp.dtype(_DTYPES[name])


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Resolved layer-scan structure for one ``n_layers`` stack.

    ``CommSchedule.plan_layers`` makes the small-n fallbacks explicit
    instead of leaving them to guard conditions inside the scan:

      * ``split_last`` needs remat + reshard (otherwise the last layer's
        gathered params are live into backward anyway).  With n == 1 the
        only layer *is* the last: the main scan is empty and the single
        layer runs un-rematted (``main == 0``).
      * ``prefetch`` double-buffers layer pairs, so it needs at least two
        main-scan layers; with ``main < 2`` (n == 1, or n == 2 with
        keep_last_gathered) it falls back to the sequential scan.
    """

    n_layers: int
    main: int          # layers run by the main scan (pair or sequential)
    split_last: bool   # last layer split out of the main scan
    prefetch: bool     # two-slot double buffering actually in effect
    pairs: int         # prefetch pair-scan length (main // 2)
    tail: int          # odd layer after the pair scan (0 or 1)


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    prefetch: bool = False
    reshard_after_forward: bool = True
    keep_last_gathered: bool = False
    gather_dtype: str | None = None
    reduce_dtype: str | None = None
    gather_mode: str = "xla"
    reduce_mode: str = "match"
    param_store: str = "fp32"
    reduce_wire: str | None = None
    sharded: bool = True
    # max elements per ring message for the manual ppermute routes (ring
    # gather, order-exact ring reduce, ring_acc, and the q8 reduce rings).
    # None = one shard-sized message per hop (the legacy behavior).  Any
    # positive value is legal: core.wire snaps it to the largest divisor of
    # the shard size (block-aligned for q8 payloads), and chunking is
    # bitwise-neutral *within* every mode pair -- it changes message
    # granularity, never per-element contributions or accumulation order.
    # The autotuner sets this per group from a measured profile's
    # chunk-size curve (core.profile / CostModel.from_profile).
    ring_chunk_elems: int | None = None
    # serve-only: run eligible gathered q8_block weights through the
    # int8 x int8 GEMM (kernels.q8_matmul) instead of dequantizing the
    # all-gather -- the weight never materializes in the compute dtype.
    # Ignored by the train step (training needs the dense gather for the
    # straight-through gradient route) and by non-quantized stores.
    serve_quant_matmul: bool = False

    def __post_init__(self):
        # name/mode validation at construction; the dtype *path* is checked
        # against the real compute dtype by validate_for (runtime init)
        _check_name(self.gather_dtype)
        _check_name(self.reduce_dtype)
        check_wire_format(self.reduce_wire, "reduce_wire")
        if self.reduce_wire is not None and self.reduce_dtype is not None:
            raise ValueError(
                f"pass either reduce_wire ({self.reduce_wire!r}) or the "
                f"legacy reduce_dtype ({self.reduce_dtype!r}), not both: "
                f"reduce_dtype lowers onto a cast reduce_wire")
        if self.gather_mode not in _GATHER_MODES:
            raise ValueError(
                f"unknown gather_mode {self.gather_mode!r}; expected one of "
                f"{list(_GATHER_MODES)}")
        if self.reduce_mode not in _REDUCE_MODES:
            raise ValueError(
                f"unknown reduce_mode {self.reduce_mode!r}; expected one of "
                f"{list(_REDUCE_MODES)}")
        if self.param_store not in STORE_FORMATS:
            raise ValueError(
                f"unknown param_store {self.param_store!r}; expected one of "
                f"{list(STORE_FORMATS)}")
        if self.ring_chunk_elems is not None:
            if (not isinstance(self.ring_chunk_elems, int)
                    or isinstance(self.ring_chunk_elems, bool)
                    or self.ring_chunk_elems < 1):
                raise ValueError(
                    f"ring_chunk_elems must be a positive int or None, got "
                    f"{self.ring_chunk_elems!r}")
            if (self.gather_mode != "ring" and self.reduce_mode != "ring_acc"
                    and self.reduce_wire != "q8_block"):
                raise ValueError(
                    "ring_chunk_elems only affects the manual ring routes; "
                    "this schedule has none (gather_mode='xla', "
                    "reduce_mode='match', cast reduce wire) -- drop the "
                    "knob or pick a ring mode")

    @classmethod
    def default(cls) -> "CommSchedule":
        return cls()

    @classmethod
    def from_config(cls, cfg) -> "CommSchedule":
        return cls.from_parallel(cfg.parallel)

    @classmethod
    def from_parallel(cls, par) -> "CommSchedule":
        return cls(
            prefetch=par.prefetch,
            reshard_after_forward=par.reshard_after_forward,
            keep_last_gathered=par.keep_last_gathered,
            gather_dtype=par.gather_dtype,
            reduce_dtype=par.reduce_dtype,
            gather_mode=par.gather_mode,
            reduce_mode=par.reduce_mode,
            param_store=par.param_store,
            reduce_wire=par.reduce_wire,
        )

    def wire_dtype(self, compute_dtype) -> jnp.dtype:
        return _resolve(self.gather_dtype, compute_dtype)

    def accum_dtype(self, compute_dtype) -> jnp.dtype:
        """Accumulate dtype of gradient reductions (the reduce-scatter's
        cast codec, and the replica psums in ``_reduce_grads``).  A
        quantized reduce wire accumulates dequantized contributions in
        fp32; cast reduce wires ARE the accum dtype; otherwise the legacy
        reduce_dtype-falls-back-to-wire-dtype rule applies unchanged."""
        if self.reduce_wire == "q8_block":
            return jnp.dtype(jnp.float32)
        if self.reduce_wire is not None:
            return jnp.dtype(CAST_FORMATS[self.reduce_wire])
        return _resolve(self.reduce_dtype, self.wire_dtype(compute_dtype))

    # ---- resolved WireCodecs (core.wire) --------------------------------- #
    def gather_codec(self, compute_dtype) -> WireCodec:
        """Cast codec of the parameter all-gather for flat (non-quantized)
        stores; quantized stores pre-encode their payload in the state
        tree and bypass this (core.store)."""
        return WireCodec(fmt_of_dtype(self.wire_dtype(compute_dtype)))

    def reduce_codec(self, compute_dtype, block: int = 1024) -> WireCodec:
        """The gradient reduce-scatter's WireCodec: ``reduce_wire`` when
        set (``block`` sizes the q8 payload -- the group's quant block),
        else a cast codec of the legacy accum dtype, bit for bit.

        PARITY: BITWISE -- codec resolution only; routes carry their own
        class (see core.wire's tagged primitives).
        """
        if self.reduce_wire is not None:
            return WireCodec(self.reduce_wire, block)
        return WireCodec(fmt_of_dtype(self.accum_dtype(compute_dtype)))

    @property
    def ef_enabled(self) -> bool:
        """Quantized reduce wires always run QSDP-style error feedback:
        the residual state exists iff the reduce codec is lossy."""
        return self.reduce_wire == "q8_block"

    def validate_for(self, compute_dtype) -> None:
        """Resolve the full wire/accum dtype path against the *actual*
        compute dtype and reject unsupported results.  A ``None``
        gather_dtype inherits the compute dtype, so e.g. fp16 compute must
        fail here (at runtime construction), not at first trace."""
        supported = {jnp.dtype(v).type for v in _DTYPES.values()}
        for role, dt in (("gather", self.wire_dtype(compute_dtype)),
                         ("reduce", self.accum_dtype(compute_dtype))):
            if dt.type not in supported:
                raise ValueError(
                    f"schedule {role} dtype resolves to unsupported {dt} "
                    f"(compute dtype {jnp.dtype(compute_dtype)}); supported: "
                    f"{sorted(set(_DTYPES))}")
        if self.param_store == "q8_block" and self.gather_dtype is not None:
            raise ValueError(
                "param_store='q8_block' fixes the all-gather payload (int8 "
                "codes + fp32 scales); gather_dtype must stay None, got "
                f"{self.gather_dtype!r}")
        if self.param_store.startswith("fp8_") and self.gather_dtype \
                is not None:
            raise ValueError(
                f"param_store={self.param_store!r} fixes the all-gather "
                "payload (the fp8 codes themselves); gather_dtype must "
                f"stay None, got {self.gather_dtype!r}")
        if self.reduce_wire == "q8_block" and not self.sharded:
            raise ValueError(
                "reduce_wire='q8_block' quantizes the gradient "
                "reduce-scatter; a schedule-unsharded (replicated) group "
                "has no reduce-scatter to quantize -- its grads are "
                "psum'd in full precision")
        if self.serve_quant_matmul and self.param_store != "q8_block":
            raise ValueError(
                "serve_quant_matmul runs the int8 GEMM on gathered q8_block "
                "codes; it requires param_store='q8_block', got "
                f"{self.param_store!r}")

    def plan_layers(self, n_layers: int, remat: bool = True) -> LayerPlan:
        """Resolve the scan structure for an ``n_layers`` stack (see
        ``LayerPlan`` for the explicit small-n fallback rules)."""
        n = int(n_layers)
        split_last = bool(self.keep_last_gathered and remat
                          and self.reshard_after_forward and n >= 1)
        main = n - 1 if split_last else n
        prefetch = bool(self.prefetch and main >= 2)
        pairs = main // 2 if prefetch else 0
        tail = main - 2 * pairs if prefetch else 0
        return LayerPlan(n_layers=n, main=main, split_last=split_last,
                         prefetch=prefetch, pairs=pairs, tail=tail)

    def describe(self) -> str:
        return (f"prefetch={int(self.prefetch)} "
                f"reshard={int(self.reshard_after_forward)} "
                f"keep_last={int(self.keep_last_gathered)} "
                f"mode={self.gather_mode} "
                f"rmode={self.reduce_mode} "
                f"store={self.param_store} "
                f"gather={self.gather_dtype or 'compute'} "
                f"reduce={self.reduce_wire or self.reduce_dtype or 'wire'}"
                + (f" chunk={self.ring_chunk_elems}"
                   if self.ring_chunk_elems is not None else ""))


def resolve_group_schedules(base: CommSchedule, overrides) -> dict:
    """Apply per-group override dicts to ``base``.  Only keys in
    ``GROUP_OVERRIDE_KEYS`` are allowed; anything else (including scan
    structure knobs) raises at construction time."""
    out: dict[str, CommSchedule] = {}
    for name, ov in (overrides or {}).items():
        if not isinstance(ov, Mapping):
            # a whole CommSchedule would smuggle scan-structure knobs past
            # the override surface (scan() only reads them from base)
            raise ValueError(
                f"group_schedules[{name!r}] must be a dict over "
                f"{sorted(GROUP_OVERRIDE_KEYS)}, got {type(ov).__name__}")
        bad = set(ov) - GROUP_OVERRIDE_KEYS
        if bad:
            raise ValueError(
                f"group_schedules[{name!r}]: unknown override keys "
                f"{sorted(bad)}; allowed: {sorted(GROUP_OVERRIDE_KEYS)}")
        ov = dict(ov)
        # reduce_dtype and reduce_wire are two spellings of one knob: an
        # override that sets one displaces whatever the base set for the
        # other (only setting both in the SAME override is the user error
        # the CommSchedule validator rejects)
        if "reduce_wire" in ov and "reduce_dtype" not in ov:
            ov["reduce_dtype"] = None
        elif "reduce_dtype" in ov and "reduce_wire" not in ov:
            ov["reduce_wire"] = None
        out[name] = dataclasses.replace(base, **ov)
    return out


# Named variants used by tests/benchmarks (parity: all must match default
# bitwise on one device; multi-device dtype variants differ only on the
# wire, and ring variants are bitwise identical to their xla twins).
VARIANTS: dict[str, CommSchedule] = {
    "default": CommSchedule(),
    "prefetch": CommSchedule(prefetch=True),
    "no_reshard": CommSchedule(reshard_after_forward=False),
    "keep_last": CommSchedule(keep_last_gathered=True),
    "fp32_wire": CommSchedule(gather_dtype="fp32"),
    "fp32_reduce": CommSchedule(reduce_dtype="fp32"),
    "overlap_all": CommSchedule(prefetch=True, keep_last_gathered=True,
                                reduce_dtype="fp32"),
    "ring": CommSchedule(gather_mode="ring"),
    "ring_overlap": CommSchedule(gather_mode="ring", prefetch=True,
                                 keep_last_gathered=True,
                                 reduce_dtype="fp32"),
}

# Variants that change *numerics*, not just the comm path: ring_acc reduces
# in ring order (allclose to, not bitwise with, XLA's linear order), the
# quantized store trains on block-dequantized weights, and the quantized
# reduce wire trains on block-quantized (error-compensated) gradients.
# Kept out of VARIANTS so the bitwise parity suite stays honest; benchmarks
# and their own parity tests (allclose / self-consistency) iterate these
# separately.
APPROX_VARIANTS: dict[str, CommSchedule] = {
    "ring_acc": CommSchedule(gather_mode="ring", reduce_mode="ring_acc",
                             reduce_dtype="fp32"),
    "q8_store": CommSchedule(param_store="q8_block"),
    "q8_ring_prefetch": CommSchedule(param_store="q8_block",
                                     gather_mode="ring", prefetch=True),
    "q8_reduce": CommSchedule(reduce_wire="q8_block"),
    "q8_both_wires": CommSchedule(param_store="q8_block",
                                  reduce_wire="q8_block"),
    "q8_reduce_ring_acc": CommSchedule(gather_mode="ring",
                                       reduce_mode="ring_acc",
                                       reduce_wire="q8_block"),
    "q8_serve_matmul": CommSchedule(param_store="q8_block",
                                    serve_quant_matmul=True),
}

# fp8 store variants register only where the installed JAX provides the
# dtypes (compat.float8_dtypes via core.wire.STORE_FORMATS) -- the same
# guarded-plumbing contract as the fp8 wire formats.
if "fp8_e4m3" in STORE_FORMATS:
    APPROX_VARIANTS.update({
        "fp8_store": CommSchedule(param_store="fp8_e4m3"),
        "fp8_e5m2_store": CommSchedule(param_store="fp8_e5m2"),
        "fp8_ring_prefetch": CommSchedule(param_store="fp8_e4m3",
                                          gather_mode="ring", prefetch=True),
    })


# --------------------------------------------------------------------------- #
# wire primitives -- re-exported from core.wire, where they now live.
# ``sharded_gather`` keeps the legacy dtype-level signature (a thin lowering
# onto cast WireCodecs); new code should resolve codecs via
# ``CommSchedule.gather_codec``/``reduce_codec`` and call the codec
# primitives directly.
# --------------------------------------------------------------------------- #
__all__ = [
    "CommSchedule", "LayerPlan", "VARIANTS", "APPROX_VARIANTS",
    "GROUP_OVERRIDE_KEYS", "STORE_FORMATS", "WIRE_FORMATS", "WireCodec",
    "resolve_group_schedules", "sharded_gather", "payload_all_gather",
    "codec_gather", "codec_gather_ef", "codec_grad_proxy",
    "codec_grad_proxy_ef", "codec_reduce_scatter",
]
