"""CommSchedule: the FSDP runtime's communication schedule, made explicit.

The seed runtime hard-coded its collective behavior inside the layer scan:
all-gather the current layer in bf16, remat everything (so backward
re-gathers every layer), and let autodiff pick the gradient reduce-scatter
dtype.  This module turns each of those decisions into a policy knob,
mirroring the ``fully_shard(reshard_after_forward=..., mp_policy=...)``
surface of production FSDP:

  * ``prefetch``       -- double-buffer layer all-gathers inside the scan:
                          layer k+1's gather is issued *before* layer k's
                          compute, so XLA's latency-hiding scheduler can
                          overlap communication with compute.  Costs one
                          extra gathered layer buffer carried through the
                          scan (classic FSDP double-buffering).
  * ``reshard_after_forward`` -- True (default): gathered parameters are
                          dropped after each layer's forward and re-gathered
                          in backward (ZeRO-3).  False keeps every layer's
                          gathered parameters live into backward (no
                          backward re-gather, more memory).  Orthogonal to
                          activation remat, which stays on either way: with
                          resharding off, only the gather moves outside the
                          checkpointed region.
  * ``keep_last_gathered``    -- run the *last* layer un-rematted even when
                          resharding: its gathered parameters stay live into
                          backward, where they are needed first (FSDP2 skips
                          resharding the final block for the same reason).
  * ``gather_dtype``   -- wire dtype of the parameter all-gather
                          ("bf16"/"fp32"; None = the runtime compute dtype).
  * ``reduce_dtype``   -- accumulate dtype of the gradient reduce-scatter
                          ("bf16"/"fp32"; None = same as the wire dtype).
                          fp32 trades 2x reduce bandwidth for exact
                          accumulation across large FSDP groups.

``sharded_gather`` is the one primitive the runtime gathers parameters
through: forward = cast-to-wire + all-gather, backward = cast-to-reduce +
psum-scatter (the ZeRO-3 gradient reduce-scatter).  With default dtypes its
VJP is op-for-op the autodiff transpose of the seed's
``astype(bf16); all_gather``, so the default schedule is bitwise identical
to the pre-schedule runtime.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

_DTYPES = {
    "bf16": jnp.bfloat16,
    "bfloat16": jnp.bfloat16,
    "fp32": jnp.float32,
    "f32": jnp.float32,
    "float32": jnp.float32,
}


def _resolve(name: str | None, default):
    if name is None:
        return jnp.dtype(default)
    try:
        return jnp.dtype(_DTYPES[name])
    except KeyError:
        raise ValueError(
            f"unknown schedule dtype {name!r}; expected one of "
            f"{sorted(_DTYPES)}") from None


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    prefetch: bool = False
    reshard_after_forward: bool = True
    keep_last_gathered: bool = False
    gather_dtype: str | None = None
    reduce_dtype: str | None = None

    def __post_init__(self):
        # fail at construction, not at first trace
        _resolve(self.gather_dtype, jnp.bfloat16)
        _resolve(self.reduce_dtype, jnp.bfloat16)

    @classmethod
    def default(cls) -> "CommSchedule":
        return cls()

    @classmethod
    def from_config(cls, cfg) -> "CommSchedule":
        par = cfg.parallel
        return cls(
            prefetch=par.prefetch,
            reshard_after_forward=par.reshard_after_forward,
            keep_last_gathered=par.keep_last_gathered,
            gather_dtype=par.gather_dtype,
            reduce_dtype=par.reduce_dtype,
        )

    def wire_dtype(self, compute_dtype) -> jnp.dtype:
        return _resolve(self.gather_dtype, compute_dtype)

    def accum_dtype(self, compute_dtype) -> jnp.dtype:
        return _resolve(self.reduce_dtype, self.wire_dtype(compute_dtype))

    def describe(self) -> str:
        return (f"prefetch={int(self.prefetch)} "
                f"reshard={int(self.reshard_after_forward)} "
                f"keep_last={int(self.keep_last_gathered)} "
                f"gather={self.gather_dtype or 'compute'} "
                f"reduce={self.reduce_dtype or 'wire'}")


# Named variants used by tests/benchmarks (parity: all must match default
# bitwise on one device; multi-device dtype variants differ only on the wire).
VARIANTS: dict[str, CommSchedule] = {
    "default": CommSchedule(),
    "prefetch": CommSchedule(prefetch=True),
    "no_reshard": CommSchedule(reshard_after_forward=False),
    "keep_last": CommSchedule(keep_last_gathered=True),
    "fp32_wire": CommSchedule(gather_dtype="fp32"),
    "fp32_reduce": CommSchedule(reduce_dtype="fp32"),
    "overlap_all": CommSchedule(prefetch=True, keep_last_gathered=True,
                                reduce_dtype="fp32"),
}


# --------------------------------------------------------------------------- #
# the gather/reduce-scatter primitive
# --------------------------------------------------------------------------- #
@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def sharded_gather(x, axes, wire_dtype, reduce_dtype, out_dtype, param_dtype):
    """All-gather ``x`` (a device-local flat buffer slice, leading axis
    tiled) over the FSDP mesh ``axes``.

    forward:  cast to ``wire_dtype`` -> all_gather -> cast to ``out_dtype``
    backward: cast cotangent to ``reduce_dtype`` -> psum_scatter (the ZeRO-3
              gradient reduce-scatter) -> cast to ``param_dtype``
    """
    y = x.astype(wire_dtype)
    if axes:
        y = lax.all_gather(y, axes, tiled=True)
    return y.astype(out_dtype)


def _gather_fwd(x, axes, wire_dtype, reduce_dtype, out_dtype, param_dtype):
    return (
        sharded_gather(x, axes, wire_dtype, reduce_dtype, out_dtype,
                       param_dtype),
        None,
    )


def _gather_bwd(axes, wire_dtype, reduce_dtype, out_dtype, param_dtype,
                _res, ct):
    g = ct.astype(reduce_dtype)
    if axes:
        g = lax.psum_scatter(g, axes, scatter_dimension=0, tiled=True)
    return (g.astype(param_dtype),)


sharded_gather.defvjp(_gather_fwd, _gather_bwd)
