"""CommSchedule: the FSDP runtime's communication schedule, made explicit.

The seed runtime hard-coded its collective behavior inside the layer scan:
all-gather the current layer in bf16, remat everything (so backward
re-gathers every layer), and let autodiff pick the gradient reduce-scatter
dtype.  This module turns each of those decisions into a policy knob,
mirroring the ``fully_shard(reshard_after_forward=..., mp_policy=...)``
surface of production FSDP:

  * ``prefetch``       -- two-slot double-buffered layer all-gathers: the
                          scan runs over layer *pairs* and both slots'
                          gathers (slot ``i % 2`` holds layer ``i``) are
                          issued before either layer's compute, so the
                          odd slot's gather overlaps the even layer's
                          compute.  The gathered buffers live only inside
                          the checkpointed pair body -- never in the scan
                          carry -- so backward re-gathers (ZeRO-3) and peak
                          gathered memory stays at two layer buffers
                          regardless of depth.  (The seed's first cut
                          threaded the next layer's gathered buffer through
                          the checkpointed carry, which made backward retain
                          one gathered buffer *per layer*.)
  * ``reshard_after_forward`` -- True (default): gathered parameters are
                          dropped after each layer's forward and re-gathered
                          in backward (ZeRO-3).  False keeps every layer's
                          gathered parameters live into backward (no
                          backward re-gather, more memory).  Orthogonal to
                          activation remat, which stays on either way: with
                          resharding off, only the gather moves outside the
                          checkpointed region.
  * ``keep_last_gathered``    -- run the *last* layer un-rematted even when
                          resharding: its gathered parameters stay live into
                          backward, where they are needed first (FSDP2 skips
                          resharding the final block for the same reason).
  * ``gather_mode``    -- "xla" (default): one ``lax.all_gather`` /
                          ``lax.psum_scatter`` pair per layer, overlap left
                          to XLA's latency-hiding scheduler.  "ring": a
                          manual ``lax.ppermute`` ring -- the all-gather is
                          n-1 explicit chunk hops written into the output at
                          absolute device offsets, so issue order (and hence
                          overlap) is visible in the HLO as
                          collective-permutes rather than inferred.  Its
                          backward is the matching ring reduce-scatter:
                          chunks are routed un-reduced to their destination
                          (the buffer shrinks by one chunk per hop) and
                          accumulated there in *absolute device order* in
                          fp32.  That destination-ordered reduction is what
                          XLA's CPU all-reduce does, so ring mode is bitwise
                          identical to xla mode -- the price is n/2x the
                          reduce-scatter wire volume of an
                          accumulate-in-flight ring, which a production
                          deployment would buy back by giving up bitwise
                          reproducibility.
  * ``gather_dtype``   -- wire dtype of the parameter all-gather
                          ("bf16"/"fp32"; None = the runtime compute dtype).
  * ``reduce_dtype``   -- accumulate dtype of the gradient reduce-scatter
                          ("bf16"/"fp32"; None = same as the wire dtype).
                          fp32 trades 2x reduce bandwidth for exact
                          accumulation across large FSDP groups.  When set,
                          it also pins the accumulate dtype of the *replica*
                          gradient psums (HSDP cross-pod, TP-replicated
                          groups, unsharded groups) in
                          ``FSDPRuntime._reduce_grads``.
  * ``reduce_mode``    -- "match" (default): the gradient reduce-scatter
                          mirrors the gather mode (psum_scatter for xla, the
                          order-exact ring for ring) and stays bitwise
                          identical to XLA's linear-device-order reduction.
                          "ring_acc": accumulate-in-flight ring
                          reduce-scatter -- each chunk's partial sum rides
                          the ring and every hop adds the local contribution,
                          so wire volume is n-1 chunk-hops instead of the
                          order-exact ring's n(n-1)/2.  The price is the
                          reduction order (ring order, not XLA's linear
                          device order), so results are allclose- but not
                          bitwise-reproducible vs the xla/match path.
  * ``param_store``    -- storage format of the group's sharded buffer (see
                          ``core.store.ParamStore``): "fp32" (master
                          weights, today's format), "bf16" (half-size
                          storage, bf16 wire), or "q8_block" (block-wise
                          INT8 codes + per-block absmax scales alongside an
                          fp32 master shard; the all-gather moves codes +
                          scales -- ~4x fewer wire bytes than fp32 -- and
                          dequantizes locally; gradients reduce-scatter to
                          the fp32 master, which the optimizer updates and
                          requantizes in the same fused pass).
  * ``sharded``        -- per-group knob (see below): False keeps the
                          group's flat buffer replicated instead of
                          FSDP-sharding it.  No gather is emitted at all;
                          gradients are psum'd over the axes the group would
                          have been sharded on.  Meant for small groups
                          (e.g. ``globals``) whose per-layer gather latency
                          outweighs the memory saved.

Per-group overrides: ``ParallelConfig.group_schedules`` (or the
``group_schedules=`` kwarg of ``FSDPRuntime``) maps a communication-group
name to a dict of overrides drawn from ``GROUP_OVERRIDE_KEYS``
(``gather_mode``, ``gather_dtype``, ``reduce_dtype``, ``sharded``), e.g.::

    group_schedules={"globals": {"sharded": False},
                     "layers":  {"reduce_dtype": "fp32"}}

keeps the small globals group unsharded and fp32-reduces only the layer
stack.  Scan *structure* knobs (prefetch / reshard / keep_last) always come
from the base schedule; overrides affect how each group's buffer is moved.

``sharded_gather`` is the one primitive the runtime gathers parameters
through: forward = cast-to-wire + all-gather (xla or ring), backward =
cast-to-reduce + reduce-scatter (the ZeRO-3 gradient reduce-scatter).  With
default dtypes its VJP is op-for-op the autodiff transpose of the seed's
``astype(bf16); all_gather``, so the default schedule is bitwise identical
to the pre-schedule runtime, and ring mode is bitwise identical to xla mode.

Validation happens in two stages: ``__post_init__`` checks dtype *names*
and the gather mode at construction, and ``validate_for(compute_dtype)``
(called by ``FSDPRuntime.__init__`` with the actual compute dtype) resolves
the full wire/accum dtype path so a ``None`` dtype that would inherit an
unsupported compute dtype fails at runtime construction instead of at first
trace.
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Mapping
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

_DTYPES = {
    "bf16": jnp.bfloat16,
    "bfloat16": jnp.bfloat16,
    "fp32": jnp.float32,
    "f32": jnp.float32,
    "float32": jnp.float32,
}

_GATHER_MODES = ("xla", "ring")
_REDUCE_MODES = ("match", "ring_acc")

# Storage formats a group's sharded buffer can take (core.store.ParamStore).
# Defined here (not in store.py) because the format is a schedule knob --
# validated by CommSchedule -- and store.py imports this module's gather
# primitives, so the dependency must point this way.
STORE_FORMATS = ("fp32", "bf16", "q8_block")

# Per-group schedule override surface (ParallelConfig.group_schedules /
# FSDPRuntime(group_schedules=...)).  Scan-structure knobs are deliberately
# excluded: one scan gathers several groups per layer, so prefetch /
# reshard / keep_last must agree across them and come from the base
# schedule.
GROUP_OVERRIDE_KEYS = frozenset(
    {"gather_mode", "gather_dtype", "reduce_dtype", "sharded",
     "reduce_mode", "param_store"})


def _check_name(name: str | None) -> None:
    if name is not None and name not in _DTYPES:
        raise ValueError(
            f"unknown schedule dtype {name!r}; expected one of "
            f"{sorted(_DTYPES)}")


def _resolve(name: str | None, default) -> jnp.dtype:
    if name is None:
        return jnp.dtype(default)
    _check_name(name)
    return jnp.dtype(_DTYPES[name])


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Resolved layer-scan structure for one ``n_layers`` stack.

    ``CommSchedule.plan_layers`` makes the small-n fallbacks explicit
    instead of leaving them to guard conditions inside the scan:

      * ``split_last`` needs remat + reshard (otherwise the last layer's
        gathered params are live into backward anyway).  With n == 1 the
        only layer *is* the last: the main scan is empty and the single
        layer runs un-rematted (``main == 0``).
      * ``prefetch`` double-buffers layer pairs, so it needs at least two
        main-scan layers; with ``main < 2`` (n == 1, or n == 2 with
        keep_last_gathered) it falls back to the sequential scan.
    """

    n_layers: int
    main: int          # layers run by the main scan (pair or sequential)
    split_last: bool   # last layer split out of the main scan
    prefetch: bool     # two-slot double buffering actually in effect
    pairs: int         # prefetch pair-scan length (main // 2)
    tail: int          # odd layer after the pair scan (0 or 1)


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    prefetch: bool = False
    reshard_after_forward: bool = True
    keep_last_gathered: bool = False
    gather_dtype: str | None = None
    reduce_dtype: str | None = None
    gather_mode: str = "xla"
    reduce_mode: str = "match"
    param_store: str = "fp32"
    sharded: bool = True

    def __post_init__(self):
        # name/mode validation at construction; the dtype *path* is checked
        # against the real compute dtype by validate_for (runtime init)
        _check_name(self.gather_dtype)
        _check_name(self.reduce_dtype)
        if self.gather_mode not in _GATHER_MODES:
            raise ValueError(
                f"unknown gather_mode {self.gather_mode!r}; expected one of "
                f"{list(_GATHER_MODES)}")
        if self.reduce_mode not in _REDUCE_MODES:
            raise ValueError(
                f"unknown reduce_mode {self.reduce_mode!r}; expected one of "
                f"{list(_REDUCE_MODES)}")
        if self.param_store not in STORE_FORMATS:
            raise ValueError(
                f"unknown param_store {self.param_store!r}; expected one of "
                f"{list(STORE_FORMATS)}")

    @classmethod
    def default(cls) -> "CommSchedule":
        return cls()

    @classmethod
    def from_config(cls, cfg) -> "CommSchedule":
        return cls.from_parallel(cfg.parallel)

    @classmethod
    def from_parallel(cls, par) -> "CommSchedule":
        return cls(
            prefetch=par.prefetch,
            reshard_after_forward=par.reshard_after_forward,
            keep_last_gathered=par.keep_last_gathered,
            gather_dtype=par.gather_dtype,
            reduce_dtype=par.reduce_dtype,
            gather_mode=par.gather_mode,
            reduce_mode=par.reduce_mode,
            param_store=par.param_store,
        )

    def wire_dtype(self, compute_dtype) -> jnp.dtype:
        return _resolve(self.gather_dtype, compute_dtype)

    def accum_dtype(self, compute_dtype) -> jnp.dtype:
        return _resolve(self.reduce_dtype, self.wire_dtype(compute_dtype))

    def validate_for(self, compute_dtype) -> None:
        """Resolve the full wire/accum dtype path against the *actual*
        compute dtype and reject unsupported results.  A ``None``
        gather_dtype inherits the compute dtype, so e.g. fp16 compute must
        fail here (at runtime construction), not at first trace."""
        supported = set(_DTYPES.values())
        for role, dt in (("gather", self.wire_dtype(compute_dtype)),
                         ("reduce", self.accum_dtype(compute_dtype))):
            if dt.type not in supported:
                raise ValueError(
                    f"schedule {role} dtype resolves to unsupported {dt} "
                    f"(compute dtype {jnp.dtype(compute_dtype)}); supported: "
                    f"{sorted(set(_DTYPES))}")
        if self.param_store == "q8_block" and self.gather_dtype is not None:
            raise ValueError(
                "param_store='q8_block' fixes the all-gather payload (int8 "
                "codes + fp32 scales); gather_dtype must stay None, got "
                f"{self.gather_dtype!r}")

    def plan_layers(self, n_layers: int, remat: bool = True) -> LayerPlan:
        """Resolve the scan structure for an ``n_layers`` stack (see
        ``LayerPlan`` for the explicit small-n fallback rules)."""
        n = int(n_layers)
        split_last = bool(self.keep_last_gathered and remat
                          and self.reshard_after_forward and n >= 1)
        main = n - 1 if split_last else n
        prefetch = bool(self.prefetch and main >= 2)
        pairs = main // 2 if prefetch else 0
        tail = main - 2 * pairs if prefetch else 0
        return LayerPlan(n_layers=n, main=main, split_last=split_last,
                         prefetch=prefetch, pairs=pairs, tail=tail)

    def describe(self) -> str:
        return (f"prefetch={int(self.prefetch)} "
                f"reshard={int(self.reshard_after_forward)} "
                f"keep_last={int(self.keep_last_gathered)} "
                f"mode={self.gather_mode} "
                f"rmode={self.reduce_mode} "
                f"store={self.param_store} "
                f"gather={self.gather_dtype or 'compute'} "
                f"reduce={self.reduce_dtype or 'wire'}")


def resolve_group_schedules(base: CommSchedule, overrides) -> dict:
    """Apply per-group override dicts to ``base``.  Only keys in
    ``GROUP_OVERRIDE_KEYS`` are allowed; anything else (including scan
    structure knobs) raises at construction time."""
    out: dict[str, CommSchedule] = {}
    for name, ov in (overrides or {}).items():
        if not isinstance(ov, Mapping):
            # a whole CommSchedule would smuggle scan-structure knobs past
            # the override surface (scan() only reads them from base)
            raise ValueError(
                f"group_schedules[{name!r}] must be a dict over "
                f"{sorted(GROUP_OVERRIDE_KEYS)}, got {type(ov).__name__}")
        bad = set(ov) - GROUP_OVERRIDE_KEYS
        if bad:
            raise ValueError(
                f"group_schedules[{name!r}]: unknown override keys "
                f"{sorted(bad)}; allowed: {sorted(GROUP_OVERRIDE_KEYS)}")
        out[name] = dataclasses.replace(base, **dict(ov))
    return out


# Named variants used by tests/benchmarks (parity: all must match default
# bitwise on one device; multi-device dtype variants differ only on the
# wire, and ring variants are bitwise identical to their xla twins).
VARIANTS: dict[str, CommSchedule] = {
    "default": CommSchedule(),
    "prefetch": CommSchedule(prefetch=True),
    "no_reshard": CommSchedule(reshard_after_forward=False),
    "keep_last": CommSchedule(keep_last_gathered=True),
    "fp32_wire": CommSchedule(gather_dtype="fp32"),
    "fp32_reduce": CommSchedule(reduce_dtype="fp32"),
    "overlap_all": CommSchedule(prefetch=True, keep_last_gathered=True,
                                reduce_dtype="fp32"),
    "ring": CommSchedule(gather_mode="ring"),
    "ring_overlap": CommSchedule(gather_mode="ring", prefetch=True,
                                 keep_last_gathered=True,
                                 reduce_dtype="fp32"),
}

# Variants that change *numerics*, not just the comm path: ring_acc reduces
# in ring order (allclose to, not bitwise with, XLA's linear order) and the
# quantized store trains on block-dequantized weights.  Kept out of VARIANTS
# so the bitwise parity suite stays honest; benchmarks and their own parity
# tests (allclose / self-consistency) iterate these separately.
APPROX_VARIANTS: dict[str, CommSchedule] = {
    "ring_acc": CommSchedule(gather_mode="ring", reduce_mode="ring_acc",
                             reduce_dtype="fp32"),
    "q8_store": CommSchedule(param_store="q8_block"),
    "q8_ring_prefetch": CommSchedule(param_store="q8_block",
                                     gather_mode="ring", prefetch=True),
}


# --------------------------------------------------------------------------- #
# manual ring collectives (gather_mode="ring")
# --------------------------------------------------------------------------- #
def _ring_axis(axes: tuple[str, ...]):
    # ppermute/axis_index treat a tuple of mesh axes as one flattened ring
    # in axis-major order -- the same order lax.all_gather tiles over
    return axes if len(axes) != 1 else axes[0]


def _ring_all_gather(x, axes: tuple[str, ...], axis_sizes: tuple[int, ...]):
    """Chunked ring all-gather over the flattened ``axes`` group: n-1
    ``ppermute`` hops, each forwarding one shard-sized chunk, written into
    the tiled output at absolute device offsets.  Pure data movement, so
    bitwise identical to ``lax.all_gather(..., tiled=True)``."""
    n = math.prod(axis_sizes)
    if n == 1:
        return x
    ax = _ring_axis(axes)
    idx = lax.axis_index(ax)
    perm = [((i + 1) % n, i) for i in range(n)]  # receive from the right
    c = x.shape[0]
    out = jnp.zeros((n * c,) + x.shape[1:], x.dtype)
    cur = x
    out = lax.dynamic_update_slice_in_dim(out, cur, idx * c, axis=0)
    for k in range(1, n):
        cur = lax.ppermute(cur, ax, perm)  # now holds device (idx+k)'s shard
        out = lax.dynamic_update_slice_in_dim(
            out, cur, ((idx + k) % n) * c, axis=0)
    return out


def _ring_reduce_scatter(ct, axes: tuple[str, ...],
                         axis_sizes: tuple[int, ...]):
    """Ring reduce-scatter matching ``lax.psum_scatter`` bitwise.

    Chunks are routed *un-reduced* to their destination device -- each hop
    the in-flight buffer sheds the chunk that just arrived home, so hop k
    carries n-1-k chunks -- and the destination accumulates its n
    contributions in absolute device order, upcast to fp32, rounding to the
    reduce dtype once.  That is exactly the (deterministic, linear-order,
    fp32-accumulate) reduction XLA's CPU all-reduce family performs, which
    is what makes ring mode bitwise identical to xla mode.  Wire volume is
    sum(n-1-k) = n(n-1)/2 chunks vs the accumulate-in-flight ring's n-1:
    the cost of order-exactness, acceptable at repro scale and documented
    for paper scale."""
    n = math.prod(axis_sizes)
    if n == 1:
        return ct
    ax = _ring_axis(axes)
    idx = lax.axis_index(ax)
    perm = [((i + 1) % n, i) for i in range(n)]  # receive from the right
    c = ct.shape[0] // n
    chunks = ct.reshape((n, c) + ct.shape[1:])
    # pre-rotate so row j holds this device's contribution to device idx+j:
    # every harvest below is then a *static* slice (the last row)
    chunks = jnp.roll(chunks, -idx, axis=0)
    parts = [chunks[0]]          # own contribution to own chunk
    buf = chunks[1:]
    for _ in range(n - 1):
        buf = lax.ppermute(buf, ax, perm)
        parts.append(buf[-1])    # device (idx+k)'s contribution, now home
        buf = buf[:-1]
    # parts[k] came from device (idx+k) % n; reduce in absolute device
    # order 0..n-1 in fp32, round once (== XLA's reduction order)
    stack = jnp.stack(parts)
    ordered = jnp.take(stack, (jnp.arange(n) - idx) % n, axis=0)
    total = ordered[0].astype(jnp.float32)
    for j in range(1, n):
        total = total + ordered[j].astype(jnp.float32)
    return total.astype(ct.dtype)


def _ring_acc_reduce_scatter(ct, axes: tuple[str, ...],
                             axis_sizes: tuple[int, ...]):
    """Accumulate-in-flight ring reduce-scatter (reduce_mode="ring_acc").

    One partial sum per destination chunk rides the ring: the chain for
    device ``d`` starts at ``d-1`` and every hop adds the local
    contribution, so the wire carries n-1 chunk-hops total -- the bandwidth-
    optimal ring -- vs the order-exact ring's n(n-1)/2 un-reduced chunks.
    The accumulation order is ring order (d-1, d-2, ..., d+1, d), NOT XLA's
    absolute device order, and it runs in the dtype ``ct`` arrives in (the
    schedule's reduce dtype): results are allclose to, but not bitwise
    reproducible against, the match-mode reduce-scatter."""
    n = math.prod(axis_sizes)
    if n == 1:
        return ct
    ax = _ring_axis(axes)
    idx = lax.axis_index(ax)
    perm = [((i + 1) % n, i) for i in range(n)]  # receive from the right
    c = ct.shape[0] // n
    chunks = ct.reshape((n, c) + ct.shape[1:])
    # pre-rotate so row j holds this device's contribution to device idx+j:
    # every add below is then a *static* row index
    chunks = jnp.roll(chunks, -idx, axis=0)
    acc = chunks[1 % n]  # chain I initiate, destined for device idx+1
    for k in range(2, n + 1):
        # receive the partial destined for idx+k, add my contribution;
        # k == n wraps to row 0 (my own chunk, last to be added)
        acc = lax.ppermute(acc, ax, perm)
        acc = acc + chunks[k % n]
    return acc


# --------------------------------------------------------------------------- #
# the gather/reduce-scatter primitive
# --------------------------------------------------------------------------- #
def _reduce_scatter(g, axes, axis_sizes, mode, reduce_mode):
    """The gradient reduce-scatter all stores share: accumulate-in-flight
    ring when reduce_mode says so, else the gather mode's bitwise-exact
    match (psum_scatter for xla, the order-exact ring for ring)."""
    if not axes:
        return g
    if reduce_mode == "ring_acc":
        return _ring_acc_reduce_scatter(g, axes, axis_sizes)
    if mode == "ring":
        return _ring_reduce_scatter(g, axes, axis_sizes)
    return lax.psum_scatter(g, axes, scatter_dimension=0, tiled=True)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6, 7, 8))
def sharded_gather(x, axes, axis_sizes, wire_dtype, reduce_dtype, out_dtype,
                   param_dtype, mode, reduce_mode):
    """All-gather ``x`` (a device-local flat buffer slice, leading axis
    tiled) over the FSDP mesh ``axes`` (sizes ``axis_sizes``).

    forward:  cast to ``wire_dtype`` -> all-gather (xla collective or
              explicit ppermute ring, per ``mode``) -> cast to ``out_dtype``
    backward: cast cotangent to ``reduce_dtype`` -> reduce-scatter (the
              ZeRO-3 gradient reduce-scatter; psum_scatter, the matching
              ring, or the accumulate-in-flight ring per ``reduce_mode``)
              -> cast to ``param_dtype``
    """
    y = x.astype(wire_dtype)
    if axes:
        y = (_ring_all_gather(y, axes, axis_sizes) if mode == "ring"
             else lax.all_gather(y, axes, tiled=True))
    return y.astype(out_dtype)


def _gather_fwd(x, axes, axis_sizes, wire_dtype, reduce_dtype, out_dtype,
                param_dtype, mode, reduce_mode):
    return (
        sharded_gather(x, axes, axis_sizes, wire_dtype, reduce_dtype,
                       out_dtype, param_dtype, mode, reduce_mode),
        None,
    )


def _gather_bwd(axes, axis_sizes, wire_dtype, reduce_dtype, out_dtype,
                param_dtype, mode, reduce_mode, _res, ct):
    g = _reduce_scatter(ct.astype(reduce_dtype), axes, axis_sizes, mode,
                        reduce_mode)
    return (g.astype(param_dtype),)


sharded_gather.defvjp(_gather_fwd, _gather_bwd)


# --------------------------------------------------------------------------- #
# store-payload primitives (quantized-wire gathers, core.store.ParamStore)
# --------------------------------------------------------------------------- #
def payload_all_gather(x, axes, axis_sizes, mode):
    """Pure data-movement all-gather for non-differentiable store payloads
    (int8 codes, per-block scales): gathered in ``x``'s own dtype, no VJP --
    gradients for a quantized store flow through ``gather_grad_proxy``
    instead (straight-through to the master shard)."""
    x = lax.stop_gradient(x)
    if not axes:
        return x
    return (_ring_all_gather(x, axes, axis_sizes) if mode == "ring"
            else lax.all_gather(x, axes, tiled=True))


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6, 7))
def gather_grad_proxy(x, axes, axis_sizes, reduce_dtype, out_dtype,
                      param_dtype, mode, reduce_mode):
    """Straight-through gradient route for quantized stores.

    forward: zeros of the gathered shape (no collective, no wire bytes) --
    added to the dequantized payload so the gathered weights' value comes
    from the codes while the gradient flows here.  backward: the standard
    ZeRO-3 reduce-scatter of the cotangent to ``param_dtype`` (the master
    shard's dtype), exactly as ``sharded_gather``'s backward."""
    n = math.prod(axis_sizes) if axes else 1
    return jnp.zeros((n * x.shape[0],) + x.shape[1:], out_dtype)


def _proxy_fwd(x, axes, axis_sizes, reduce_dtype, out_dtype, param_dtype,
               mode, reduce_mode):
    return (gather_grad_proxy(x, axes, axis_sizes, reduce_dtype, out_dtype,
                              param_dtype, mode, reduce_mode), None)


def _proxy_bwd(axes, axis_sizes, reduce_dtype, out_dtype, param_dtype, mode,
               reduce_mode, _res, ct):
    g = _reduce_scatter(ct.astype(reduce_dtype), axes, axis_sizes, mode,
                        reduce_mode)
    return (g.astype(param_dtype),)


gather_grad_proxy.defvjp(_proxy_fwd, _proxy_bwd)
