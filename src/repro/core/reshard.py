"""Plan→plan resharding through the per-tensor shard index (ROADMAP #4).

The primitive here is ``GroupIndex``: one group's ``GroupPlan`` plus its
outer (TP/EP) composition, viewed as an *address map* from any tensor to the
``(shard, lo, hi)`` extents holding it (``GroupPlan.tensor_extents``).  Two
``GroupIndex`` objects — one for the layout data was saved under, one for the
layout it must land in — are enough to move a tensor between arbitrary plans
without ever materializing more than that single tensor on the host:

  * cross-mesh-size (different ``num_shards``/``shard_size``),
  * cross-mode (ragged ↔ fsdp2/megatron/naive),
  * cross-TP (different ``outer_size``; split tensors are concatenated from
    the source parts and re-split for the destination, tensors replicated
    over the outer axis are read once and written into every part),
  * cross-group (the owning group is looked up by tensor name on each side,
    so tensors that migrate between groups — e.g. ``layers`` ↔ ``layers_rep``
    when the TP degree changes — still land correctly).

Shard addressing: a group buffer's sharded dim is split into
``outer_size * num_shards`` uniform rows; flat shard ``j = r*m + k`` is FSDP
shard ``k`` of outer part ``r`` (outer-major, matching ``GroupLayout``).
Readers/writers are callables ``read(j, layer) -> 1-D row`` and
``write(j, layer) -> writable 1-D row`` so the same copy loop streams through
host arrays, npy memmaps, or anything else.

Block-granular leaves (quant scales, one unit per ``div`` elements) and
integer code leaves move on the *aligned* path: extents are rescaled to
``div`` units (exact — the planner aligns tensor starts and S to the quant
block) and copied extent-to-extent, which requires the outer layout to be
identical on both sides.  A layout change that would alter block membership
raises instead of silently corrupting state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping

import numpy as np

from .planner import plan_from_checkpoint_index
from .ragged import Extent, GroupPlan

Reader = Callable[[int, int | None], np.ndarray]
Writer = Callable[[int, int | None], np.ndarray]


@dataclasses.dataclass(frozen=True)
class GroupIndex:
    """One group's layout as an addressable per-tensor shard index."""

    plan: GroupPlan
    outer_size: int = 1
    outer_dims: Mapping[str, int] = dataclasses.field(default_factory=dict)
    n_layers: int = 0

    def __post_init__(self):
        # outer_size 1 means no effective split: normalize so layouts that
        # differ only in vestigial outer metadata compare equal.
        dims = dict(self.outer_dims) if self.outer_size > 1 else {}
        object.__setattr__(self, "outer_dims", dims)

    # ---- constructors ----------------------------------------------------
    @classmethod
    def from_layout(cls, lo) -> "GroupIndex":
        """From a live ``GroupLayout`` (core.fsdp)."""
        return cls(plan=lo.plan, outer_size=lo.outer_size,
                   outer_dims={n: sd.dim for n, sd in lo.gdef.outer.items()},
                   n_layers=lo.n_layers or 0)

    @classmethod
    def from_entry(cls, entry) -> "GroupIndex":
        """From a ``GroupPlanEntry`` (core.policy) — no runtime needed."""
        return cls(plan=entry.plan, outer_size=entry.outer_size,
                   outer_dims=dict(entry.outer_dims),
                   n_layers=entry.n_layers or 0)

    @classmethod
    def from_meta(cls, saved: Mapping) -> "GroupIndex":
        """From one group's checkpoint ``meta.json`` entry (any version)."""
        plan = plan_from_checkpoint_index(
            saved["index"], saved["shard_size"], saved["num_shards"],
            mode=saved.get("mode", "ragged"))
        return cls(plan=plan, outer_size=int(saved.get("outer_size", 1)),
                   outer_dims={k: int(v)
                               for k, v in saved.get("outer_dims", {}).items()},
                   n_layers=int(saved.get("n_layers") or 0))

    # ---- addressing ------------------------------------------------------
    @property
    def num_shards(self) -> int:
        return self.plan.num_shards

    @property
    def num_rows(self) -> int:
        """Uniform rows in the sharded dim: outer parts × FSDP shards."""
        return self.outer_size * self.plan.num_shards

    def row(self, part: int, shard: int) -> int:
        return part * self.plan.num_shards + shard

    def extents(self, name: str, div: int = 1) -> tuple[Extent, ...]:
        exts = self.plan.tensor_extents(name)
        if div == 1:
            return exts
        return tuple(e.scaled(div) for e in exts)

    def local_shape(self, name: str) -> tuple[int, ...]:
        """Part-local tensor shape (the shape the planner packed)."""
        return self.plan.placement(name).spec.shape

    def full_shape(self, name: str) -> tuple[int, ...]:
        """Logical (outer-unsplit) tensor shape."""
        shape = list(self.local_shape(name))
        d = self.outer_dims.get(name)
        if d is not None:
            shape[d] *= self.outer_size
        return tuple(shape)

    def same_outer(self, other: "GroupIndex", name: str) -> bool:
        return (self.outer_size == other.outer_size
                and self.outer_dims.get(name) == other.outer_dims.get(name))

    # ---- tensor assembly / scatter ---------------------------------------
    def _read_part(self, name: str, part: int, read: Reader,
                   layer: int | None, div: int = 1) -> np.ndarray:
        size = -(-self.plan.placement(name).spec.size // div)
        flat = None
        for e in self.extents(name, div):
            row = np.asarray(read(self.row(part, e.shard), layer))
            if flat is None:
                flat = np.empty(size, dtype=row.dtype)
            flat[e.tensor_lo: e.tensor_lo + e.size] = row[e.lo: e.hi]
        return flat

    def _write_part(self, name: str, part: int, flat: np.ndarray,
                    write: Writer, layer: int | None, div: int = 1) -> None:
        for e in self.extents(name, div):
            row = write(self.row(part, e.shard), layer)
            row[e.lo: e.hi] = flat[e.tensor_lo: e.tensor_lo + e.size]

    def read_tensor(self, name: str, read: Reader,
                    layer: int | None = None) -> np.ndarray:
        """Assemble the full logical tensor from its extents.

        Outer-split tensors concatenate all parts along their split dim;
        replicated tensors (no entry in ``outer_dims``) read part 0.
        """
        d = self.outer_dims.get(name)
        if d is None:
            return self._read_part(name, 0, read, layer).reshape(
                self.local_shape(name))
        parts = [
            self._read_part(name, r, read, layer).reshape(
                self.local_shape(name))
            for r in range(self.outer_size)
        ]
        return np.concatenate(parts, axis=d)

    def write_tensor(self, name: str, full: np.ndarray, write: Writer,
                     layer: int | None = None) -> None:
        """Scatter the full logical tensor into its extents.

        Outer-split tensors are split along their dim; tensors replicated
        over the outer axis are written into every part.
        """
        d = self.outer_dims.get(name)
        if d is None:
            parts = [full] * self.outer_size
        else:
            parts = np.split(full, self.outer_size, axis=d)
        for r, part in enumerate(parts):
            self._write_part(name, r, np.ascontiguousarray(part).reshape(-1),
                             write, layer)


def copy_tensor(src: GroupIndex, dst: GroupIndex, name: str,
                read: Reader, write: Writer, *, layer: int | None = None,
                div: int = 1, aligned: bool = False) -> None:
    """Move one tensor's data from layout ``src`` to layout ``dst``.

    ``div`` > 1 copies block-granular units (e.g. quant scales: one unit per
    ``div`` elements).  ``aligned`` forces the extent-to-extent path, required
    for leaves whose values depend on position (int8 codes, scales): both
    layouts must then agree on the outer split of ``name``, else this raises
    rather than silently reinterpreting blocks.
    """
    if src.same_outer(dst, name):
        for r in range(src.outer_size):
            flat = src._read_part(name, r, read, layer, div)
            dst._write_part(name, r, flat, write, layer, div)
        return
    if aligned or div != 1:
        raise ValueError(
            f"{name}: outer layout changed (src outer_size={src.outer_size} "
            f"dim={src.outer_dims.get(name)}, dst outer_size={dst.outer_size} "
            f"dim={dst.outer_dims.get(name)}); block-granular state cannot be "
            f"remapped across an outer (TP/EP) change — rebuild it from the "
            f"master instead")
    full = src.read_tensor(name, read, layer)
    want = dst.full_shape(name)
    if tuple(full.shape) != want:
        raise ValueError(
            f"{name}: logical shape changed across plans "
            f"({tuple(full.shape)} -> {want}); cannot reshard")
    dst.write_tensor(name, full, write, layer)


def stream_tensors(dst: GroupIndex, write: Writer,
                   src_lookup: Callable[[str], tuple[GroupIndex, Reader]],
                   names: Iterable[str] | None = None) -> None:
    """Stream every tensor of ``dst``'s plan from its source layout.

    ``src_lookup(name)`` returns the source ``(GroupIndex, Reader)`` owning
    that tensor (sources may live in different groups than the destination).
    Peak host memory is one tensor: each is assembled, scattered, dropped.
    """
    for name in (dst.plan.names if names is None else names):
        s_idx, s_read = src_lookup(name)
        if (s_idx.n_layers or 0) != (dst.n_layers or 0):
            raise ValueError(
                f"{name}: layer count changed across plans "
                f"({s_idx.n_layers} -> {dst.n_layers}); cannot reshard")
        for li in (range(dst.n_layers) if dst.n_layers else [None]):
            copy_tensor(s_idx, dst, name, s_read, write, layer=li)


# ---------------------------------------------------------------------------
# Host-array readers/writers (the in-memory case; file-backed readers live
# with their formats in checkpoint/ckpt.py and tools/reshard.py)
# ---------------------------------------------------------------------------

def buffer_reader(arr: np.ndarray, num_rows: int) -> Reader:
    """Read rows of a full host buffer shaped ``(L, num_rows*Sleaf)`` or
    ``(num_rows*Sleaf,)``."""
    s = arr.shape[-1] // num_rows

    def read(j: int, layer: int | None) -> np.ndarray:
        row = arr if layer is None else arr[layer]
        return row[j * s: (j + 1) * s]

    return read


def buffer_writer(arr: np.ndarray, num_rows: int) -> Writer:
    """Write rows of a full host buffer (same shapes as ``buffer_reader``)."""
    s = arr.shape[-1] // num_rows

    def write(j: int, layer: int | None) -> np.ndarray:
        row = arr if layer is None else arr[layer]
        return row[j * s: (j + 1) * s]

    return write
