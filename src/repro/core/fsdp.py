"""veScale-FSDP runtime: fully_shard-style API over RaggedShard + DBuffer.

``FSDPRuntime`` wraps a model (repro.models.*) for a mesh.  Its layout is
a consumed artifact, not a derivation: construction resolves (or is
handed) a ``core.policy.ShardingPlan`` -- per-group ``ShardingPolicy`` +
planner placements -- and builds group layouts from it.  The legacy
``ParallelConfig`` knobs and the ``schedule=``/``group_schedules=``
kwargs lower onto a ``PolicySet`` bitwise-neutrally; ``policies="auto"``
runs the cost-model planner; ``plan=`` replays an explicit (e.g.
checkpoint-restored) plan exactly.  Then:

  * each communication group's tensors are localized (outer TP/EP sharding
    composed per paper §4), planned (Algorithm 1), and backed by a DBuffer
    whose flat buffer is sharded over the group's FSDP mesh axes.  The
    *storage format* of that buffer is a ParamStore policy (core.store):
    fp32 master weights (default), bf16, or block-wise int8 codes+scales
    alongside an fp32 master shard (``param_store="q8_block"``, the paper's
    block-wise quantized training scenario);
  * the train step runs under shard_map.  The layer scan all-gathers one
    layer's store payload (bf16 flat buffer by default; int8 codes + scales
    for quantized stores, dequantized locally), unpacks zero-copy, and
    computes; ``jax.grad`` transposes the gather into a psum-scatter, which
    IS the ZeRO-3 gradient reduce-scatter -- targeting the store's
    trainable (master) buffer.  Remat re-gathers parameters in the backward
    pass, matching FSDP's backward re-allgather;
  * HSDP: on the multi-pod mesh the ``pod`` axis replicates parameters and
    grads are psum'd across pods (paper §6.1); ``pod_fsdp=True`` extends
    ZeRO-3 over pods instead;
  * the optimizer update is group-fused over the flat local shard (DBuffer
    group ops), with buffers donated for in-place semantics.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import optimization_barrier, shard_map
from ..models.transformer import GroupDef
from .dbuffer import DBuffer
from .policy import PolicySet, ShardingPlan, make_plan
from .ragged import TensorSpec
from .schedule import CommSchedule
from .store import EF_KEY, ParamStore
from .wire import codec_reduce_scatter


# ---------------------------------------------------------------------------
# group layout resolution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupLayout:
    name: str
    gdef: GroupDef
    local_specs: tuple[TensorSpec, ...]
    plan: Any               # GroupPlan
    buffer: DBuffer
    fsdp_axes: tuple[str, ...]
    fsdp_axis_sizes: tuple[int, ...]
    outer_axis: str | None     # TP/EP axis the buffer is additionally split on
    outer_size: int
    n_layers: int | None
    # axes the group is replicated on because its schedule said
    # sharded=False: no gather is emitted; grads are psum'd here instead
    grad_sync_axes: tuple[str, ...] = ()
    # storage format of the group's sharded buffer (what params[name] holds
    # and what the all-gather moves) -- see core.store.ParamStore
    store: ParamStore = ParamStore()

    @property
    def sharded_dim(self) -> int:
        return self.outer_size * self.plan.total

    def global_shape(self) -> tuple[int, ...]:
        d = (self.sharded_dim,)
        return (self.n_layers,) + d if self.n_layers else d

    def pspec(self) -> P:
        axes = ((self.outer_axis,) if self.outer_axis else ()) + self.fsdp_axes
        if not axes:
            entry = None  # unsharded (replicated) group
        else:
            entry = axes if len(axes) > 1 else axes[0]
        return P(None, entry) if self.n_layers else P(entry)


class FSDPRuntime:
    def __init__(self, model, mesh: Mesh, *, planner: str = "ragged",
                 compute_dtype=jnp.bfloat16, donate: bool = True,
                 scan_unroll: int = 1, schedule: CommSchedule | None = None,
                 group_schedules: Mapping[str, Any] | None = None,
                 policies=None, plan: ShardingPlan | None = None,
                 cost_model=None, verify: bool = False):
        self.model = model
        self.cfg = model.cfg
        self.mesh = mesh
        self.compute_dtype = compute_dtype
        self.donate = donate
        self.scan_unroll = scan_unroll  # cost-calibration dry runs unroll
        par = self.cfg.parallel
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        cdt = jnp.dtype(self.compute_dtype)

        # resolve the ShardingPlan the runtime consumes: an explicit plan,
        # a policies spec (PolicySet / ShardingPolicy / "auto" / ...), or
        # the legacy ParallelConfig knobs + schedule/group_schedules kwargs
        # lowered onto a PolicySet (bitwise-neutral -- the parity suites pin
        # the lowering down)
        if plan is not None:
            if (policies is not None or schedule is not None
                    or group_schedules is not None):
                raise ValueError(
                    "pass either plan= or policies=/schedule="
                    "/group_schedules=, not both")
            got = {a: int(s) for a, s in plan.axis_sizes.items()}
            if got != axis_sizes:
                raise ValueError(
                    f"plan was resolved for mesh axes {got}, runtime mesh "
                    f"has {axis_sizes}; re-plan for this mesh")
            if plan.compute_dtype != cdt.name:
                raise ValueError(
                    f"plan was resolved for compute dtype "
                    f"{plan.compute_dtype}, runtime uses {cdt.name}")
        else:
            if policies is None:
                policies = PolicySet.from_parallel_config(
                    par, schedule=schedule, group_schedules=group_schedules)
            elif schedule is not None or group_schedules is not None:
                raise ValueError(
                    "pass either policies= or schedule=/group_schedules=, "
                    "not both")
            plan = make_plan(model, mesh, policies, planner=planner,
                             compute_dtype=cdt, cost_model=cost_model)
        self.plan = plan
        self.planner_mode = plan.planner
        self.schedule = plan.base_schedule()
        self._group_scheds = plan.schedules()
        self.schedule.validate_for(cdt)
        for s in self._group_scheds.values():
            s.validate_for(cdt)

        self.has_pod = "pod" in axis_sizes
        self.tp = par.tp
        self.ep = par.ep
        self.tp_axis = "model" if par.tp > 1 else None
        self.ep_axis = "model" if par.ep > 1 else None

        gdefs = model.groups()
        if set(gdefs) != set(plan.groups):
            raise ValueError(
                f"plan groups {sorted(plan.groups)} do not match this "
                f"model's groups {sorted(gdefs)}")
        self.layouts: dict[str, GroupLayout] = {
            name: GroupLayout(
                name=name, gdef=gdefs[name], local_specs=e.local_specs,
                plan=e.plan, buffer=DBuffer(e.plan), fsdp_axes=e.fsdp_axes,
                fsdp_axis_sizes=e.fsdp_axis_sizes, outer_axis=e.outer_axis,
                outer_size=e.outer_size, n_layers=e.n_layers,
                grad_sync_axes=e.grad_sync_axes, store=e.store)
            for name, e in plan.groups.items()
        }

        self.batch_axes = tuple(
            a for a in (("pod",) if self.has_pod else ()) + par.batch_axes
            if a in axis_sizes
        )
        self.batch_size_divisor = int(
            np.prod([axis_sizes[a] for a in self.batch_axes])
        )

        if verify:
            # prove the plan's declared invariants against the traced step
            # (repro.analysis: abstract eval only, nothing compiles) before
            # handing the runtime out; raises VerificationError with the
            # full Violation report on failure
            from ..analysis import verify_runtime

            verify_runtime(self).raise_if_failed()

    # ------------------------------------------------------------------ #
    def sched_for(self, name: str) -> CommSchedule:
        """The (possibly group-overridden) schedule for one comm group."""
        return self._group_scheds.get(name, self.schedule)

    # ------------------------------------------------------------------ #
    # state construction
    # ------------------------------------------------------------------ #
    def param_shapes(self) -> dict[str, Any]:
        """Per-group param-state structure: a ShapeDtypeStruct for flat
        stores (fp32 -- the seed's format -- or bf16), a dict of structs
        (codes/master/scales) for quantized stores."""
        out = {}
        for name, lo in self.layouts.items():
            out[name] = lo.store.state_struct(
                lo.global_shape(), NamedSharding(self.mesh, lo.pspec()))
        return out

    @staticmethod
    def _init_tensor(spec: TensorSpec, seed: int, layer: int | None):
        """Deterministic per-tensor init: identical values regardless of how
        tensors are grouped/sharded (so FSDP == TP == HSDP numerics)."""
        import zlib

        rng = np.random.default_rng(
            [seed, zlib.crc32(spec.name.encode()),
             0 if layer is None else layer + 1]
        )
        if len(spec.shape) >= 2:
            fan_in = spec.shape[0]
            a = rng.normal(0, 1.0 / math.sqrt(max(fan_in, 1)),
                           size=spec.shape)
        elif any(t in spec.name for t in ("ln", "norm", "skip", "scale")):
            a = np.ones(spec.shape)
        else:
            a = np.zeros(spec.shape)
        return a.astype(np.float32)

    def init_params(self, seed: int = 0) -> dict[str, jax.Array]:
        """Host-side init (small/reduced models and examples; the dry run
        never calls this)."""
        params = {}
        for name, lo in self.layouts.items():
            layers = list(range(lo.n_layers)) if lo.n_layers else [None]
            flats = []
            for li in layers:
                packs = []
                for r in range(lo.outer_size):
                    arrays = {}
                    for full_spec in lo.gdef.specs:
                        a = self._init_tensor(full_spec, seed, li)
                        sd = lo.gdef.outer.get(full_spec.name)
                        if sd is not None:
                            a = np.split(a, lo.outer_size, axis=sd.dim)[r]
                        arrays[full_spec.name] = a
                    packs.append(lo.buffer.pack(arrays))
                flats.append(np.concatenate(packs))
            arr = np.stack(flats) if lo.n_layers else flats[0]
            sharding = NamedSharding(self.mesh, lo.pspec())
            params[name] = jax.tree.map(
                lambda a: jax.device_put(a, sharding),
                lo.store.create(arr))
        return params

    # ------------------------------------------------------------------ #
    # in-job elastic resharding (ROADMAP #4)
    # ------------------------------------------------------------------ #
    def replan(self, params, opt_state=None, *, mesh: Mesh | None = None,
               model=None, plan: ShardingPlan | None = None, policies=None,
               schedule=None, group_schedules=None, planner: str | None = None,
               optimizer=None):
        """Re-plan in place: a new mesh / policies / TP degree without a
        save/load round trip.  Returns ``(new_runtime, new_params,
        new_opt_state)`` (``new_opt_state`` is None unless ``opt_state``
        and ``optimizer`` are given).

        ``plan.diff`` (via ``policy.layout_changed_groups``) splits the
        groups: unchanged layout+store moves bitwise as raw shard bytes
        (EF history included); changed groups stream their fp32 master
        tensor-by-tensor through the extent map and rebuild their store
        state (codes requantized, EF re-zeroed) — the same parity classes
        as a checkpoint reshard, minus the disk."""
        from ..compat import tree_flatten_with_path, tree_unflatten
        from .policy import layout_changed_groups
        from .reshard import (GroupIndex, buffer_reader, buffer_writer,
                              stream_tensors)

        model = model if model is not None else self.model
        mesh = mesh if mesh is not None else self.mesh
        kwargs: dict[str, Any] = {}
        if plan is not None:
            kwargs["plan"] = plan
        elif policies is not None:
            kwargs["policies"] = policies
        elif schedule is not None or group_schedules is not None:
            kwargs["schedule"] = schedule
            kwargs["group_schedules"] = group_schedules
        elif model is self.model:
            # same model: keep this runtime's resolved per-group decisions
            kwargs["policies"] = self.plan.policy_set()
        # else: a new model (e.g. changed TP degree) lowers its own
        # ParallelConfig knobs
        new_rt = FSDPRuntime(
            model, mesh, planner=planner or self.planner_mode,
            compute_dtype=self.compute_dtype, donate=self.donate,
            scan_unroll=self.scan_unroll, **kwargs)

        changed = layout_changed_groups(self.plan, new_rt.plan)
        old_idx = {n: GroupIndex.from_layout(lo)
                   for n, lo in self.layouts.items()}
        tensor_src = {t: n for n, lo in self.layouts.items()
                      for t in lo.plan.names}
        # lazily-pulled host masters of changed source groups (one at a
        # time would be even leaner, but group granularity matches the
        # device_put batching below)
        masters: dict[str, np.ndarray] = {}

        def src_master(gname: str) -> np.ndarray:
            m = masters.get(gname)
            if m is None:
                state = params[gname]
                if isinstance(state, dict):
                    m = np.asarray(state["master"], np.float32)
                else:
                    m = np.asarray(
                        jnp.asarray(state).astype(jnp.float32))
                masters[gname] = m
            return m

        new_params = {}
        for name, lo in new_rt.layouts.items():
            sharding = NamedSharding(new_rt.mesh, lo.pspec())
            if name in self.layouts and name not in changed:
                new_params[name] = jax.tree.map(
                    lambda a: jax.device_put(np.asarray(a), sharding),
                    params[name])
                continue
            dst = GroupIndex.from_layout(lo)
            master = np.zeros(lo.global_shape(), np.float32)
            write = buffer_writer(master, dst.num_rows)

            def lookup(tname):
                g = tensor_src.get(tname)
                if g is None:
                    raise ValueError(
                        f"tensor {tname!r} (group {name!r}) does not exist "
                        f"in the current runtime; replan cannot invent "
                        f"parameters")
                return old_idx[g], buffer_reader(src_master(g),
                                                 old_idx[g].num_rows)

            stream_tensors(dst, write, lookup)
            new_params[name] = jax.tree.map(
                lambda a: jax.device_put(a, sharding),
                lo.store.create(master))

        if opt_state is None:
            return new_rt, new_params, None
        if optimizer is None:
            raise ValueError(
                "replan(opt_state=...) needs optimizer= to shape the new "
                "state tree")
        old_flat, _ = tree_flatten_with_path(opt_state)
        old_by_path = {
            tuple(getattr(p, "key", str(p)) for p in kp): v
            for kp, v in old_flat}
        like_flat, like_tree = tree_flatten_with_path(
            optimizer.state_shapes(new_rt))
        moved = []
        for kp, like in like_flat:
            keys = tuple(getattr(p, "key", str(p)) for p in kp)
            moved.append(jax.device_put(
                self._replan_opt_leaf(new_rt, keys, like, old_by_path,
                                      old_idx, tensor_src, changed),
                like.sharding))
        return new_rt, new_params, tree_unflatten(like_tree, moved)

    def _replan_opt_leaf(self, new_rt, keys, like, old_by_path, old_idx,
                         tensor_src, changed):
        from ..checkpoint.ckpt import _classify_opt_leaf
        from .reshard import GroupIndex, buffer_reader, buffer_writer, \
            copy_tensor

        pathname = "/".join(keys)
        kind, g_new, div = _classify_opt_leaf(new_rt, keys, like.shape)
        if kind != "buffer":
            old = old_by_path.get(keys)
            if old is None:
                raise ValueError(
                    f"optimizer state leaf {pathname!r} has no counterpart "
                    f"in the current state")
            a = np.asarray(old)
            if kind == "factor":
                # unpad to the true layer count, repad for the new plan
                L = self.layouts[g_new].n_layers
                if a.shape[1:] != like.shape[1:] or like.shape[0] < L:
                    raise ValueError(
                        f"optimizer state {pathname!r}: factor shape "
                        f"{a.shape} incompatible with {tuple(like.shape)}")
                out = np.zeros(like.shape, a.dtype)
                out[:L] = a[:L]
                return out
            if tuple(a.shape) != tuple(like.shape):
                raise ValueError(
                    f"optimizer state {pathname!r}: shape {a.shape} != "
                    f"expected {tuple(like.shape)}")
            return a
        lo = new_rt.layouts[g_new]
        old = old_by_path.get(keys)
        if g_new not in changed and old is not None \
                and tuple(old.shape) == tuple(like.shape):
            return np.asarray(old)
        dst = GroupIndex.from_layout(lo)
        dest = None
        aligned = div > 1 or jnp.dtype(like.dtype).kind in "iu"
        for name in lo.plan.names:
            g_old = tensor_src.get(name)
            src = old_by_path.get(keys[:-1] + (g_old,)) \
                if g_old is not None else None
            if src is None:
                raise ValueError(
                    f"optimizer state {pathname!r}: no source buffer for "
                    f"tensor {name!r} (old group {g_old!r})")
            src = np.asarray(src)
            s_idx = old_idx[g_old]
            src_div = (self.layouts[g_old].global_shape()[-1]
                       // src.shape[-1])
            if src_div != div:
                raise ValueError(
                    f"optimizer state {pathname!r}: block granularity "
                    f"changed ({src_div} -> {div}); 8-bit optimizer state "
                    f"cannot be resharded across it")
            if dest is None:
                dest = np.zeros(like.shape, src.dtype)
            if (s_idx.n_layers or 0) != (lo.n_layers or 0):
                raise ValueError(
                    f"optimizer state {pathname!r}: layer count changed "
                    f"for {name!r} ({s_idx.n_layers} -> {lo.n_layers})")
            read = buffer_reader(src, s_idx.num_rows)
            write = buffer_writer(dest, dst.num_rows)
            for li in (range(lo.n_layers) if lo.n_layers else [None]):
                copy_tensor(s_idx, dst, name, read, write,
                            layer=li, div=div, aligned=aligned)
        return np.asarray(
            jnp.asarray(dest).astype(like.dtype)) \
            if jnp.dtype(dest.dtype) != jnp.dtype(like.dtype) else dest

    # ------------------------------------------------------------------ #
    # the ParamGetter handed to model code inside shard_map
    # ------------------------------------------------------------------ #
    def _getter(self, local_bufs: Mapping[str, jax.Array], remat: bool = True,
                defer_ef: bool = False, quant_matmul: bool = False):
        return _ParamGetter(self, local_bufs, remat, defer_ef=defer_ef,
                            quant_matmul=quant_matmul)

    # specs for shard_map (a pspec per state leaf; scales shard like the
    # buffer because S % block == 0)
    def _param_specs(self) -> dict[str, Any]:
        return {n: lo.store.state_pspecs(lo.pspec())
                for n, lo in self.layouts.items()}

    def _usable_batch_axes(self, batch: int) -> tuple[str, ...]:
        """Longest prefix of batch axes that evenly divides ``batch`` --
        smaller global batches shard over fewer axes and replicate on the
        rest (e.g. decode_32k batch=128 on a 16x16 mesh -> data only)."""
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        usable = []
        rem = batch
        for a in self.batch_axes:
            if rem % sizes[a] == 0 and rem >= sizes[a]:
                usable.append(a)
                rem //= sizes[a]
        return tuple(usable)

    def batch_pspec(self, batch_tree) -> Any:
        def spec_for(leaf):
            usable = self._usable_batch_axes(leaf.shape[0]) if leaf.ndim else ()
            if usable:
                entry = usable if len(usable) > 1 else usable[0]
                return P(entry, *([None] * (leaf.ndim - 1)))
            return P(*([None] * leaf.ndim))

        return jax.tree.map(spec_for, batch_tree)

    # ------------------------------------------------------------------ #
    # train step
    # ------------------------------------------------------------------ #
    def make_train_step(self, optimizer) -> Callable:
        """optimizer: repro.optim.* object with init(layouts, params) and
        update(runtime, params, grads, state, step)."""
        par = self.cfg.parallel
        pspecs = self._param_specs()

        # groups whose reduce wire runs error feedback: their trainable
        # tree carries the residual, whose "gradient" is the updated
        # residual (core.wire EF primitives) -- split out of the grad tree
        # before loss scaling / replica psums, re-attached after the
        # optimizer update
        ef_groups = tuple(n for n, lo in self.layouts.items()
                          if lo.store.has_ef)
        # Gradient accumulation composes with the quantized reduce wire via
        # DEFERRED error feedback: the per-microbatch backward performs no
        # collective and no encode (core.wire's *_defer_ef primitives
        # return the raw fp32 cotangent as the residual slot's cotangent),
        # the scan accumulates sum(ct), and ONE codec_reduce_scatter at the
        # accumulation boundary applies the residual, encodes, and routes --
        # identical wire numerics and residual semantics to a single batch
        # of the same total size (encoding per microbatch would quantize
        # partial sums ``micro`` times and corrupt the EF history).
        for n in ef_groups:
            # groups whose grads are additionally psum'd over replica axes
            # (_reduce_grads: HSDP cross-pod, TP-replicated) would compute
            # a DIFFERENT residual per replica -- violating the state's
            # declared replication on those axes and corrupting EF through
            # a checkpoint (which saves one replica).  Quantized replica
            # reductions are a ROADMAP item; reject the combination.
            lo = self.layouts[n]
            replica = []
            if lo.gdef.replicated_over_model and self.tp > 1:
                replica.append("model")
            if (self.has_pod and "pod" not in lo.fsdp_axes
                    and "pod" not in lo.grad_sync_axes):
                replica.append("pod")
            if replica:
                raise ValueError(
                    f"reduce_wire='q8_block' on group {n!r} is unsupported "
                    f"with replica gradient axes {replica}: the error-"
                    f"feedback residual would diverge across replicas "
                    f"(quantized replica reductions are future work; use a "
                    f"cast reduce wire for this group)")

        def split_ef(raw):
            """(master grads, updated EF residuals) from the raw grad tree
            of ``trainable`` -- residuals must not see grad scaling,
            replica psums, or the grad-norm."""
            grads, efs = {}, {}
            for n, g in raw.items():
                if n in ef_groups:
                    grads[n] = g["master"]
                    efs[n] = g[EF_KEY]
                else:
                    grads[n] = g
            return grads, efs

        def step_fn(params, opt_state, step, batch):
            def sharded(params, opt_state, step, batch):
                # split each group's store state into the differentiable
                # part (the master/storage buffer the grads target, plus
                # the reduce-wire EF residual when one exists) and the
                # frozen payload (q8 codes/scales, closed over as
                # constants).  For fp32 stores trainable IS the params dict,
                # so the autodiff graph is unchanged from the seed.
                trainable = {n: self.layouts[n].store.trainable(params[n])
                             for n in params}
                frozen = {n: self.layouts[n].store.frozen(params[n])
                          for n in params}

                # clamp accumulation to a divisor of the local batch (the
                # multi-pod mesh halves the per-device batch vs single-pod)
                b_loc = jax.tree.leaves(batch)[0].shape[0]
                micro = par.microbatches
                while b_loc % micro:
                    micro -= 1
                # EF groups defer the quantized reduce-scatter to the
                # accumulation boundary when accumulating (micro == 1 keeps
                # the eager path, bit for bit)
                defer = bool(ef_groups) and micro > 1

                def loss_of(tr, mb):
                    bufs = {n: self.layouts[n].store.combine(tr[n], frozen[n])
                            for n in tr}
                    pg = self._getter(bufs, defer_ef=defer)
                    nll, w = self.model.loss(pg, mb)
                    return nll, w

                if micro > 1:
                    def micro_body(acc, mb):
                        grads, nll_a, w_a = acc
                        (nll, w), g = jax.value_and_grad(
                            loss_of, has_aux=True)(trainable, mb)
                        grads = jax.tree.map(jnp.add, grads, g)
                        return (grads, nll_a + nll, w_a + w), None

                    mbs = jax.tree.map(
                        lambda t: t.reshape((micro, t.shape[0] // micro)
                                            + t.shape[1:]), batch)
                    zero = jax.tree.map(jnp.zeros_like, trainable)
                    (grads, nll, w), _ = lax.scan(
                        micro_body, (zero, 0.0, 0.0), mbs)
                    if defer:
                        grads = dict(grads)
                        cd = jnp.dtype(self.compute_dtype)
                        for n in ef_groups:
                            # the accumulation boundary: sum(ct) rode the
                            # grad tree's EF slot (master slot held zeros);
                            # apply the residual, encode once, reduce-
                            # scatter -- exactly the eager EF backward on
                            # the accumulated cotangent
                            lo = self.layouts[n]
                            sched = self.sched_for(n)
                            rcodec = sched.reduce_codec(cd, lo.store.block)
                            pdt = (jnp.dtype(jnp.float32)
                                   if lo.store.quantized
                                   else lo.store.storage_dtype)

                            def rs(ct1, ef1, lo=lo, sched=sched,
                                   rcodec=rcodec, pdt=pdt):
                                return codec_reduce_scatter(
                                    ct1, ef1, rcodec, lo.fsdp_axes,
                                    lo.fsdp_axis_sizes, sched.gather_mode,
                                    sched.reduce_mode, pdt,
                                    sched.ring_chunk_elems)

                            sum_ct = grads[n][EF_KEY]
                            ef0 = trainable[n][EF_KEY]
                            if sum_ct.ndim > 1:
                                # layered group: one reduce-scatter per
                                # layer (collectives-in-scan, the same
                                # structure the layer gather runs)
                                _, (shard, new_ef) = lax.scan(
                                    lambda c, a: (c, rs(*a)), None,
                                    (sum_ct, ef0))
                            else:
                                shard, new_ef = rs(sum_ct, ef0)
                            grads[n] = {"master": shard, EF_KEY: new_ef}
                else:
                    (nll, w), grads = jax.value_and_grad(
                        loss_of, has_aux=True)(trainable, batch)

                # the EF residuals ride back through the grad tree (their
                # cotangent IS the updated residual); peel them off before
                # any scaling -- residuals live in unscaled cotangent units
                grads, new_efs = split_ef(grads)

                # cross-device normalization
                nll_g = lax.psum(nll, self.batch_axes) if self.batch_axes else nll
                w_g = lax.psum(w, self.batch_axes) if self.batch_axes else w
                grads = self._reduce_grads(grads)
                scale = 1.0 / jnp.maximum(w_g, 1.0)
                grads = jax.tree.map(lambda g: g * scale, grads)
                new_params, new_opt = optimizer.update(
                    self, params, grads, opt_state, step)
                for n in ef_groups:
                    # optimizers are EF-oblivious (rebuild returns the core
                    # state); re-attach the updated residual here
                    new_params[n] = self.layouts[n].store.attach_ef(
                        new_params[n], new_efs[n])
                metrics = {
                    "loss": nll_g / jnp.maximum(w_g, 1.0),
                    "tokens": w_g,
                    "grad_norm": _global_norm(self, grads),
                }
                return new_params, new_opt, metrics

            opt_specs = optimizer.pspecs(self)
            fn = shard_map(
                sharded, mesh=self.mesh,
                in_specs=(pspecs, opt_specs, P(), self.batch_pspec(batch)),
                out_specs=(pspecs, opt_specs,
                           {"loss": P(), "tokens": P(), "grad_norm": P()}),
            )
            new_params, new_opt, metrics = fn(params, opt_state, step, batch)
            return new_params, new_opt, step + 1, metrics

        donate = (0, 1) if self.donate else ()
        return jax.jit(step_fn, donate_argnums=donate)

    def _reduce_grads(self, grads):
        """Extra reductions beyond the autodiff reduce-scatter: replicated
        groups psum over 'model'; schedule-unsharded groups psum over their
        would-be FSDP axes; HSDP psums over 'pod'.

        When the group's schedule pins a reduce dtype or wire, these
        replica psums accumulate in the resolved accum dtype (the fp32
        option matters for the HSDP cross-pod sum at paper scale; a
        quantized reduce wire accumulates in fp32, and its replica psums
        stay full-precision -- only the reduce-scatter is quantized); with
        neither set they run in whatever dtype the grads arrive in, which
        preserves the seed trajectory."""
        cd = jnp.dtype(self.compute_dtype)
        out = {}
        for name, g in grads.items():
            lo = self.layouts[name]
            sched = self.sched_for(name)
            pinned = (sched.reduce_dtype is not None
                      or sched.reduce_wire is not None)
            ad = sched.accum_dtype(cd) if pinned else jnp.dtype(g.dtype)

            def _psum(v, axes, ad=ad):
                if ad != v.dtype:
                    return lax.psum(v.astype(ad), axes).astype(v.dtype)
                return lax.psum(v, axes)

            if lo.gdef.replicated_over_model and self.tp > 1:
                g = _psum(g, "model")
            if lo.grad_sync_axes:
                g = _psum(g, lo.grad_sync_axes)
            if (self.has_pod and "pod" not in lo.fsdp_axes
                    and "pod" not in lo.grad_sync_axes):
                # HSDP cross-pod psum -- unless the group is schedule-
                # unsharded on a pod_fsdp mesh, where grad_sync_axes
                # already covered "pod"
                g = _psum(g, "pod")
            out[name] = g
        return out

    # ------------------------------------------------------------------ #
    def gathered_peak_bytes(self) -> int:
        """Analytic peak of simultaneously-live gathered layer buffers in
        the training step -- the quantity the two-slot prefetch bounds:
        2 slots with prefetch, 1 without, +1 for the split-out last layer,
        or every layer when reshard_after_forward=False."""
        cd = jnp.dtype(self.compute_dtype)
        per_layer, n = 0, 0
        for name, lo in self.layouts.items():
            if lo.n_layers and lo.fsdp_axes:
                # the gather runs over fsdp_axes only: the outer (TP/EP)
                # shard stays local, so the per-device gathered buffer is
                # plan.total elements, not sharded_dim
                per_layer += lo.plan.total * cd.itemsize
                n = max(n, lo.n_layers)
        if not n:
            return 0
        if not self.schedule.reshard_after_forward:
            slots = n
        else:
            plan = self.schedule.plan_layers(n, remat=True)
            # no main-scan slot when the main scan is empty (n == 1 with
            # keep_last_gathered: only the split-out layer is ever live)
            main_slots = (2 if plan.prefetch else 1) if plan.main else 0
            slots = main_slots + int(plan.split_last)
        return per_layer * slots

    def gather_wire_bytes(self) -> int:
        """Analytic bytes the parameter all-gathers of ONE forward pass put
        on the wire, per gathered copy: the quantity the q8_block store cuts
        ~4x vs an fp32 wire (codes are 1 byte/element + 4 bytes per block of
        scales vs 4 bytes/element).  Schedule-unsharded and single-group
        replicated buffers move nothing; backward re-gathers (remat) and
        the (m-1)/m ring discount apply uniformly across formats, so they
        are deliberately left out of the ratio.  Delegates to the resolved
        ``ShardingPlan`` (same accounting, now a plan-level prediction
        available before a runtime exists)."""
        return self.plan.gather_wire_bytes()

    def reduce_wire_bytes(self) -> int:
        """Analytic bytes ONE gradient reduce-scatter pass puts on the
        wire, per reduced copy, in each group's reduce WireCodec -- the
        mirror of ``gather_wire_bytes`` (the q8_block gradient wire cuts
        this ~4x vs an fp32 reduce).  Delegates to the plan."""
        return self.plan.reduce_wire_bytes()

    # ------------------------------------------------------------------ #
    # serving steps (ZeRO-3 inference: per-layer gather, sharded at rest)
    # ------------------------------------------------------------------ #
    def cache_pspec(self, cache_tree, batch: int) -> Any:
        """Cache sharding: batch dim (declared by the model via
        ``cache_batch_dims`` -- size-based guessing collides when
        n_layers == batch) over the usable batch axes; with TP, KV head dims
        (== tp) over "model"."""
        usable = list(self._usable_batch_axes(batch))
        bdims = self.model.cache_batch_dims()

        def spec_for(leaf, bdim):
            nd = leaf.ndim
            entries = [None] * nd
            if usable and leaf.shape[bdim] == batch:
                entries[bdim] = (
                    tuple(usable) if len(usable) > 1 else usable[0])
            if self.tp > 1 and nd >= 5:
                # KV leaves: head dim (== tp) sharded over "model"
                for hdim in range(nd):
                    if entries[hdim] is None and leaf.shape[hdim] == self.tp:
                        entries[hdim] = "model"
                        break
            return P(*entries)

        return jax.tree.map(spec_for, cache_tree, bdims)

    def make_prefill_step(self):
        pspecs = self._param_specs()

        def step_fn(params, batch, cache):
            bsz = batch["tokens"].shape[0]
            cspec = self.cache_pspec(cache, bsz)

            def sharded(params, batch, cache):
                pg = self._getter(
                    params, remat=False,
                    quant_matmul=self.schedule.serve_quant_matmul)
                return self.model.prefill(pg, batch, cache)

            fn = shard_map(
                sharded, mesh=self.mesh,
                in_specs=(pspecs, self.batch_pspec(batch), cspec),
                out_specs=(self.batch_pspec(
                    {"tokens": jax.ShapeDtypeStruct((bsz, 1, 1), jnp.float32)}
                )["tokens"], cspec),
            )
            return fn(params, batch, cache)

        return jax.jit(step_fn)

    def make_decode_step(self):
        pspecs = self._param_specs()

        def step_fn(params, batch, cache, index):
            bsz = batch["tokens"].shape[0]
            cspec = self.cache_pspec(cache, bsz)
            # scalar position, or per-row (B,) positions sharded with batch
            idx_spec = (P() if jnp.ndim(index) == 0
                        else self.batch_pspec({"i": index})["i"])

            def sharded(params, batch, cache, index):
                pg = self._getter(
                    params, remat=False,
                    quant_matmul=self.schedule.serve_quant_matmul)
                return self.model.decode(pg, batch, cache, index)

            fn = shard_map(
                sharded, mesh=self.mesh,
                in_specs=(pspecs, self.batch_pspec(batch), cspec, idx_spec),
                out_specs=(self.batch_pspec(
                    {"tokens": jax.ShapeDtypeStruct((bsz, 1, 1), jnp.float32)}
                )["tokens"], cspec),
            )
            return fn(params, batch, cache, index)

        return jax.jit(step_fn, donate_argnums=(2,))


def _is_arr(x):
    return hasattr(x, "shape")


def _global_norm(runtime, grads):
    sq = 0.0
    for name, g in grads.items():
        lo = runtime.layouts[name]
        s = jnp.sum(g.astype(jnp.float32) ** 2)
        axes = lo.fsdp_axes + ((lo.outer_axis,) if lo.outer_axis else ())
        s = lax.psum(s, axes) if axes else s
        sq = sq + s
    return jnp.sqrt(sq)


# ---------------------------------------------------------------------------
# ParamGetter: gather + zero-copy unpack, layer scan driven by CommSchedule
# ---------------------------------------------------------------------------

class _ParamGetter:
    def __init__(self, runtime: FSDPRuntime, bufs, remat: bool,
                 defer_ef: bool = False, quant_matmul: bool = False):
        self.rt = runtime
        self.bufs = bufs
        self.remat = remat
        self.defer_ef = defer_ef
        # serve-only: keep eligible q8_block layer weights as int8
        # QuantTensors (ops.q8_matmul) instead of dequantizing the gather
        self.quant_matmul = quant_matmul
        self.schedule = runtime.schedule
        self.tp_axis = runtime.tp_axis
        self.ep_axis = runtime.ep_axis
        self.compute_dtype = runtime.compute_dtype

    def _gather_flat(self, name: str, local) -> jax.Array:
        """All-gather one group's store state per its (possibly
        group-overridden) schedule -- gather mode, wire/reduce dtypes, and
        storage format (backward = the ZeRO-3 gradient reduce-scatter onto
        the store's trainable buffer).  ``local`` is the device-local state:
        a flat slice for fp32/bf16 stores, a codes/master/scales dict for
        q8_block (the quantized wire)."""
        lo = self.rt.layouts[name]
        return lo.store.gather(
            local, lo.fsdp_axes, lo.fsdp_axis_sizes, self.rt.sched_for(name),
            self.rt.compute_dtype,
            defer_ef=self.defer_ef and lo.store.has_ef)

    def _quant_group(self, name: str) -> bool:
        return self.quant_matmul and self.rt.layouts[name].store.quantized

    def _gather_unpack(self, name: str, local: jax.Array):
        return self.rt.layouts[name].buffer.unpack(
            self._gather_flat(name, local))

    def globals(self, group: str) -> dict[str, jax.Array]:
        return self._gather_unpack(group, self.bufs[group])

    def scan(self, groups, body, carry, xs=None):
        """FSDP layer scan.  The CommSchedule controls gather prefetching,
        whether gathered params are resharded after forward, and whether
        the last layer's gathered params stay live into backward.  The
        small-``n_layers`` fallbacks are resolved explicitly by
        ``CommSchedule.plan_layers`` (see ``LayerPlan``).

        Remat structure: activation rematerialization (``self.remat``) and
        parameter resharding (``schedule.reshard_after_forward``) are
        orthogonal.  Resharding puts the gather *inside* the checkpointed
        region (backward re-gathers = ZeRO-3); with resharding off, the
        gather moves outside so the gathered buffer is saved as a residual
        while layer activations are still rematted.

        Prefetch runs the main scan over layer *pairs* with a two-slot
        double buffer: slot ``i % 2`` holds layer ``i``'s gathered params,
        and both slots' gathers are issued before either layer's compute,
        so the odd slot's gather overlaps the even layer's compute.  The
        gathered buffers live only inside the (checkpointed) pair body --
        never in the scan carry -- so backward re-gathers each pair and
        peak gathered memory is two layer buffers regardless of depth.
        (Threading the next layer's gathered buffer through the
        checkpointed carry, as the first cut did, made it a per-step scan
        residual: backward retained one gathered buffer per layer.)"""
        sched = self.schedule
        stacks = tuple(self.bufs[g] for g in groups)
        n = self.rt.layouts[groups[0]].n_layers
        remat = self.remat
        reshard = sched.reshard_after_forward
        plan = sched.plan_layers(n, remat)

        def gather_layer(layer_bufs):
            out = []
            for g, lb in zip(groups, layer_bufs):
                if self._quant_group(g):
                    # serve quant mode: move the wire payload, defer the
                    # dequantize decision to unpack_quant (eligible 2-D
                    # weights never dequantize -- ops.q8_matmul)
                    lo = self.rt.layouts[g]
                    out.append(lo.store.gather_payload(
                        lb, lo.fsdp_axes, lo.fsdp_axis_sizes,
                        self.rt.sched_for(g)))
                else:
                    out.append(self._gather_flat(g, lb))
            return tuple(out)

        def unpack_all(gathered):
            p = {}
            for g, gb in zip(groups, gathered):
                lo = self.rt.layouts[g]
                if self._quant_group(g):
                    p.update(lo.buffer.unpack_quant(
                        gb, lo.store.block, self.compute_dtype))
                else:
                    p.update(lo.buffer.unpack(gb))
            return p

        def compute(gathered, c, user_xs):
            return body(unpack_all(gathered), c, user_xs)

        # activation-only remat: gathered buffers enter as checkpoint
        # inputs, so they are saved into backward (no re-gather)
        inner = (jax.checkpoint(compute) if remat and not reshard
                 else compute)

        def slices(lo, hi):
            # stacks entries are store states (arrays or code/scale trees)
            return (tuple(jax.tree.map(lambda t: t[lo:hi], s)
                          for s in stacks),
                    jax.tree.map(lambda t: t[lo:hi], xs))

        def seq_scan(carry, lo, hi):
            """Sequential layers [lo, hi): gather inside the checkpointed
            body, so backward re-gathers (ZeRO-3)."""
            def scan_body(c, scan_xs):
                layer_bufs, user_xs = scan_xs
                return inner(gather_layer(layer_bufs), c, user_xs)

            if remat and reshard:
                scan_body = jax.checkpoint(scan_body)
            length = hi - lo
            return lax.scan(scan_body, carry, slices(lo, hi), length=length,
                            unroll=max(1, min(self.rt.scan_unroll, length)))

        ys_parts = []
        if plan.prefetch:
            k = 2 * plan.pairs

            def to_pairs(t):
                return t[:k].reshape((plan.pairs, 2) + t.shape[1:])

            pair_bufs = tuple(jax.tree.map(to_pairs, s) for s in stacks)
            pair_xs = jax.tree.map(to_pairs, xs)

            def pair_body(c, scan_xs):
                bufs2, xs2 = scan_xs
                # two-slot double buffer: issue both slots' gathers before
                # either layer's compute (slot 1 overlaps slot 0's compute)
                g0 = gather_layer(tuple(
                    jax.tree.map(lambda t: t[0], b) for b in bufs2))
                g1 = gather_layer(tuple(
                    jax.tree.map(lambda t: t[1], b) for b in bufs2))
                # pin the two-slot issue order explicitly: both slots'
                # gathered buffers materialize together before either
                # layer's compute.  Because remat replays this barrier, the
                # *backward* re-gathers are issued as a pair too -- the
                # issue order is in the jaxpr (regression-tested), not left
                # to XLA's scheduler.  The barrier is the identity, so
                # bitwise parity with the sequential schedule holds.
                g0, g1 = optimization_barrier((g0, g1))
                c, y0 = inner(g0, c, jax.tree.map(lambda t: t[0], xs2))
                # materialize the carry at the layer seam exactly as a
                # per-layer scan-iteration boundary would (bitwise parity
                # with the sequential schedule, forward and backward)
                c = optimization_barrier(c)
                c, y1 = inner(g1, c, jax.tree.map(lambda t: t[1], xs2))
                return c, (y0, y1)

            if remat and reshard:
                pair_body = jax.checkpoint(pair_body)
            carry, (ys0, ys1) = lax.scan(
                pair_body, carry, (pair_bufs, pair_xs), length=plan.pairs,
                unroll=max(1, min(self.rt.scan_unroll, plan.pairs)))
            ys_parts.append(jax.tree.map(
                lambda a, b: jnp.stack([a, b], axis=1).reshape(
                    (k,) + a.shape[1:]), ys0, ys1))
            if plan.tail:
                carry, y_tail = seq_scan(carry, k, plan.main)
                ys_parts.append(y_tail)
        elif plan.main:
            carry, y_main = seq_scan(carry, 0, plan.main)
            ys_parts.append(y_main)

        if plan.split_last:
            # last layer: gather outside the checkpointed compute -- its
            # gathered params are saved into backward (first to be needed
            # there), skipping one re-gather, as in FSDP2's skip-reshard-
            # last-block policy; activations still remat
            last_inner = jax.checkpoint(compute)

            def last_body(c, scan_xs):
                layer_bufs, user_xs = scan_xs
                return last_inner(gather_layer(layer_bufs), c, user_xs)

            carry, y_last = lax.scan(last_body, carry, slices(plan.main, n),
                                     length=n - plan.main)
            ys_parts.append(y_last)

        ys_parts = [p for p in ys_parts
                    if p is not None and jax.tree.leaves(p)]
        if not ys_parts:
            ys = None
        elif len(ys_parts) == 1:
            ys = ys_parts[0]
        else:
            ys = jax.tree.map(
                lambda *parts: jnp.concatenate(parts, axis=0), *ys_parts)
        return carry, ys
