"""ShardingPolicy / ShardingPlan: the typed, composable planning front-end.

The runtime's knobs grew bottom-up: ``ParallelConfig`` accumulated 10+
orthogonal schedule fields plus a stringly-typed ``group_schedules``
dict-of-dicts that could not express "quantize every MoE expert group"
without enumerating group names.  This module is the top-down redesign the
paper's flexibility claim actually calls for (SimpleFSDP's minimal
composable front-end; OSDP's cost-model-chosen per-group strategies):

  * ``ShardingPolicy``  -- one group's complete sharding/communication
                           policy as a typed, validated dataclass: storage
                           format, gather/reduce mode + wire dtypes, scan
                           structure (prefetch/reshard/keep-last), and
                           whether the group is FSDP-sharded at all.  It is
                           a 1:1 view over ``CommSchedule`` (``to_schedule``
                           / ``from_schedule``), so everything the parity
                           suites guarantee about schedules transfers.
  * ``PolicyRule``      -- a selector + policy.  Selectors match groups by
                           name glob (``match="layers*"``), by structural
                           tag (``tag="experts"``: every MoE expert group,
                           whatever its name), or by predicate over the
                           group's ``GroupInfo`` (name, tag, n_layers, the
                           full ``TensorSpec`` list).  Criteria AND
                           together; rules compose first-match-wins in a
                           ``PolicySet``.  A rule that matches no group of
                           the model raises at planning time -- the typo'd
                           group name is an error, not a silent no-op.
  * ``ShardingPlan``    -- the resolved artifact: per-group policy + the
                           structure-aware planner's ``GroupPlan``
                           placements + predicted wire/memory costs.  It is
                           inspectable (``describe()`` renders the audit
                           table), JSON-serializable (``to_json`` /
                           ``from_json`` / ``dumps``; saved alongside
                           checkpoints for exact-restore validation), and
                           diffable (``diff``).  ``FSDPRuntime`` consumes a
                           ShardingPlan instead of re-deriving layout from
                           config -- a plan restored from JSON reconstructs
                           the exact layout, bit for bit.
  * ``plan(model, mesh, policies)`` -- the single entry point.
                           ``policies`` may be a ``PolicySet``, a uniform
                           ``ShardingPolicy``/``CommSchedule``, ``None``
                           (lower the legacy ``ParallelConfig`` knobs), or
                           ``"auto"`` -- run the structure-aware cost model
                           (``CostModel``, roofline link/HBM timings) over
                           every group to pick store format and comm policy:
                           q8_block wire for bandwidth-bound layer stacks,
                           replication for tiny unstacked groups whose
                           per-step gather latency outweighs the memory
                           saved.

Scan-structure knobs (``prefetch`` / ``reshard_after_forward`` /
``keep_last_gathered``) are whole-model: one layer scan gathers several
groups, so they come from the PolicySet's *default* policy, and a rule
whose policy disagrees on them is rejected at construction.

Legacy lowering: ``PolicySet.from_parallel_config`` maps the flat
``ParallelConfig`` knobs onto a default policy plus one exact-name rule per
``group_schedules`` entry.  The lowering is bitwise-neutral -- it produces
the same per-group ``CommSchedule`` objects the runtime used to build
directly, which the schedule/store parity suites pin down.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Any, Callable, Mapping, Optional, Sequence

import numpy as np

from .planner import get_planner, plan_group
from .profile import CommProfile, builtin_profile, load_profile
from .ragged import LANE, GroupPlan, Placement, TensorSpec, compose_granularity
from .schedule import CommSchedule, resolve_group_schedules
from .store import ParamStore
from .wire import STORE_FORMATS

# structural tags a PolicyRule can select on (see group_tag)
TAGS = ("layers", "experts", "globals")

# scan-structure knobs: one layer scan gathers several groups per step, so
# these must agree across groups and always come from the PolicySet default
STRUCTURE_FIELDS = ("prefetch", "reshard_after_forward", "keep_last_gathered",
                    "serve_quant_matmul")


# --------------------------------------------------------------------------- #
# policy
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """One communication group's complete sharding policy.

    A typed 1:1 view over ``CommSchedule`` (``store`` maps to
    ``param_store``); validation is delegated to ``CommSchedule`` so the two
    can never drift.
    """

    store: str = "fp32"            # fp32 | bf16 | q8_block (ParamStore)
    gather_mode: str = "xla"       # xla | ring
    reduce_mode: str = "match"     # match | ring_acc
    gather_dtype: Optional[str] = None   # all-gather wire dtype (None=compute)
    reduce_dtype: Optional[str] = None   # grad reduce dtype (None=wire)
    reduce_wire: Optional[str] = None    # grad reduce WireCodec (None=dtype)
    prefetch: bool = False               # two-slot double-buffered gathers
    reshard_after_forward: bool = True   # ZeRO-3 backward re-gather
    keep_last_gathered: bool = False     # last layer stays gathered
    sharded: bool = True                 # False: replicate, psum grads
    serve_quant_matmul: bool = False     # serve-only int8-GEMM on q8 weights
    ring_chunk_elems: Optional[int] = None  # max elems per ring message
    #   (None = shard-sized; the autotuner sets this from a measured
    #   profile's chunk curve; bitwise-neutral within every mode pair)

    def __post_init__(self):
        self.to_schedule()  # knob validation lives in CommSchedule

    def to_schedule(self) -> CommSchedule:
        return CommSchedule(
            prefetch=self.prefetch,
            reshard_after_forward=self.reshard_after_forward,
            keep_last_gathered=self.keep_last_gathered,
            gather_dtype=self.gather_dtype,
            reduce_dtype=self.reduce_dtype,
            gather_mode=self.gather_mode,
            reduce_mode=self.reduce_mode,
            param_store=self.store,
            reduce_wire=self.reduce_wire,
            sharded=self.sharded,
            serve_quant_matmul=self.serve_quant_matmul,
            ring_chunk_elems=self.ring_chunk_elems,
        )

    @classmethod
    def from_schedule(cls, sched: CommSchedule) -> "ShardingPolicy":
        return cls(
            store=sched.param_store,
            gather_mode=sched.gather_mode,
            reduce_mode=sched.reduce_mode,
            gather_dtype=sched.gather_dtype,
            reduce_dtype=sched.reduce_dtype,
            reduce_wire=sched.reduce_wire,
            prefetch=sched.prefetch,
            reshard_after_forward=sched.reshard_after_forward,
            keep_last_gathered=sched.keep_last_gathered,
            sharded=sched.sharded,
            serve_quant_matmul=sched.serve_quant_matmul,
            ring_chunk_elems=sched.ring_chunk_elems,
        )

    def describe(self) -> str:
        return (f"{self.store} {self.gather_mode}/{self.reduce_mode} "
                f"g={self.gather_dtype or 'compute'} "
                f"r={self.reduce_wire or self.reduce_dtype or 'wire'}"
                + (f" chunk={self.ring_chunk_elems}"
                   if self.ring_chunk_elems is not None else "")
                + ("" if self.sharded else " replicated"))


# --------------------------------------------------------------------------- #
# selectors
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class GroupInfo:
    """What a PolicyRule selector sees of one communication group."""

    name: str
    tag: str                       # layers | experts | globals
    n_layers: Optional[int]
    specs: tuple[TensorSpec, ...]  # the group's FULL logical tensor specs

    @property
    def payload(self) -> int:
        """Logical elements across the whole layer stack."""
        return sum(s.size for s in self.specs) * (self.n_layers or 1)


def group_tag(name: str, gdef) -> str:
    """Structural tag of a communication group: ``experts`` for MoE expert
    groups (whatever the model called them), ``layers`` for any other
    stacked group, ``globals`` for unstacked groups."""
    if "expert" in name:
        return "experts"
    return "layers" if gdef.n_layers else "globals"


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """Selector + policy.  Criteria AND together; at least one required."""

    policy: ShardingPolicy
    match: Optional[str] = None                       # fnmatch name glob
    tag: Optional[str] = None                         # layers|experts|globals
    where: Optional[Callable[[GroupInfo], bool]] = None

    def __post_init__(self):
        if self.match is None and self.tag is None and self.where is None:
            raise ValueError(
                "PolicyRule needs at least one selector (match=, tag=, or "
                "where=); to change the default policy, set PolicySet.default")
        if self.tag is not None and self.tag not in TAGS:
            raise ValueError(
                f"unknown PolicyRule tag {self.tag!r}; expected one of "
                f"{list(TAGS)}")

    def matches(self, info: GroupInfo) -> bool:
        if self.match is not None and not fnmatch.fnmatchcase(
                info.name, self.match):
            return False
        if self.tag is not None and info.tag != self.tag:
            return False
        if self.where is not None and not self.where(info):
            return False
        return True

    def selector(self) -> str:
        parts = []
        if self.match is not None:
            parts.append(f"match={self.match!r}")
        if self.tag is not None:
            parts.append(f"tag={self.tag!r}")
        if self.where is not None:
            parts.append(f"where={getattr(self.where, '__name__', 'fn')}")
        return " ".join(parts)


@dataclasses.dataclass(frozen=True)
class PolicySet:
    """First-match-wins rules over a default policy."""

    rules: tuple[PolicyRule, ...] = ()
    default: ShardingPolicy = ShardingPolicy()

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        for r in self.rules:
            bad = [f for f in STRUCTURE_FIELDS
                   if getattr(r.policy, f) != getattr(self.default, f)]
            if bad:
                raise ValueError(
                    f"PolicyRule ({r.selector()}) changes scan-structure "
                    f"knobs {bad}: one layer scan gathers several groups, so "
                    f"{list(STRUCTURE_FIELDS)} come from PolicySet.default")

    def policy_for(self, info: GroupInfo) -> tuple[ShardingPolicy,
                                                   Optional[int]]:
        """(policy, index of the matching rule or None for the default)."""
        for i, r in enumerate(self.rules):
            if r.matches(info):
                return r.policy, i
        return self.default, None

    @classmethod
    def from_parallel_config(cls, par, schedule: CommSchedule | None = None,
                             group_schedules=None) -> "PolicySet":
        """Lower the legacy ``ParallelConfig`` knob surface (or explicit
        ``schedule=``/``group_schedules=`` overrides of it) onto a
        PolicySet: a default policy plus one exact-name rule per
        ``group_schedules`` entry.  Bitwise-neutral: the resolved per-group
        ``CommSchedule``s are exactly what the runtime used to build."""
        import glob as _glob

        base = schedule if schedule is not None else CommSchedule.from_parallel(par)
        overrides = (par.group_schedules if group_schedules is None
                     else group_schedules)
        scheds = resolve_group_schedules(base, overrides)
        # glob-escape the keys: legacy group_schedules names are EXACT
        # group names, so metacharacters in a key must not quietly become
        # a pattern (an unknown name keeps raising at plan time)
        rules = tuple(
            PolicyRule(match=_glob.escape(name),
                       policy=ShardingPolicy.from_schedule(s))
            for name, s in scheds.items())
        return cls(rules=rules, default=ShardingPolicy.from_schedule(base))


# --------------------------------------------------------------------------- #
# the resolved plan artifact
# --------------------------------------------------------------------------- #

def store_for(policy: ShardingPolicy, quant_block: int, m: int) -> ParamStore:
    """THE policy -> ParamStore mapping: the EF residual exists iff the
    policy's reduce wire is quantized, sized by the group's FSDP world m.
    Used both by ``plan()``'s align/shard-size validation and by
    ``GroupPlanEntry.store`` (what the runtime consumes), so the two can
    never diverge."""
    return ParamStore(policy.store, quant_block,
                      ef_m=m if policy.to_schedule().ef_enabled else 0)


@dataclasses.dataclass(frozen=True)
class GroupPlanEntry:
    """One group's resolved slice of a ShardingPlan: the policy that won,
    the planner's placements, and the mesh-axis decomposition."""

    name: str
    tag: str
    policy: ShardingPolicy
    local_specs: tuple[TensorSpec, ...]
    plan: GroupPlan
    fsdp_axes: tuple[str, ...]
    fsdp_axis_sizes: tuple[int, ...]
    outer_axis: Optional[str]
    outer_size: int
    n_layers: Optional[int]
    grad_sync_axes: tuple[str, ...]
    quant_block: int
    # per-tensor outer (TP/EP) split dims: tensor name -> dim index, for
    # tensors evenly split over ``outer_axis`` before FSDP packing; a tensor
    # absent here in an outer_size>1 group is replicated into every outer
    # part.  Serialized (plan JSON v2) so a restored plan can drive
    # resharding without the model's GroupDefs.
    outer_dims: Mapping[str, int] = dataclasses.field(default_factory=dict)

    @property
    def fsdp_world(self) -> int:
        """The group's FSDP world size m (1 for unsharded groups)."""
        return int(np.prod(self.fsdp_axis_sizes)) if self.fsdp_axes else 1

    @property
    def store(self) -> ParamStore:
        return store_for(self.policy, self.quant_block, self.fsdp_world)

    def schedule(self) -> CommSchedule:
        return self.policy.to_schedule()

    def gather_wire_bytes(self, compute_dtype) -> int:
        """Bytes one forward pass's all-gathers of this group put on the
        wire, per gathered copy (same accounting as
        ``FSDPRuntime.gather_wire_bytes``: unsharded groups move nothing;
        remat re-gathers and the ring discount apply uniformly)."""
        import jax.numpy as jnp

        if not self.fsdp_axes:
            return 0
        cd = jnp.dtype(compute_dtype)
        per_layer = self.store.wire_bytes(self.plan.total,
                                          self.schedule().wire_dtype(cd))
        return per_layer * (self.n_layers or 1)

    def reduce_wire_bytes(self, compute_dtype) -> int:
        """Bytes one gradient reduce-scatter of this group puts on the
        wire, per reduced copy, in the group's reduce WireCodec -- the
        mirror of ``gather_wire_bytes`` (unsharded groups reduce via psum,
        accounted as zero here).  Unlike the gather side, reduce *routes*
        do NOT all ship the same volume: the order-exact routes (ring
        gather mode's match reduce, and any match-mode q8 reduce, which
        must route un-reduced chunks) carry m/2 x the payload of the
        bandwidth-optimal psum_scatter/ring_acc routes, so that multiplier
        is included here -- the table tells the truth about a match-mode
        q8 wire costing MORE than fp32 psum_scatter at large m.  The
        >=3x-below-fp32 figure ``bench_e2e --schedule`` reports as
        ``reduce_wire_mb`` is the bandwidth-optimal q8 route (what
        ``policies='auto'`` emits: q8 paired with ring_acc)."""
        import jax.numpy as jnp

        if not self.fsdp_axes:
            return 0
        sched = self.schedule()
        codec = sched.reduce_codec(jnp.dtype(compute_dtype),
                                   self.quant_block)
        per = codec.wire_bytes(self.plan.total)
        m = self.fsdp_world
        order_exact = (sched.reduce_mode == "match"
                       and (codec.quantized or sched.gather_mode == "ring"))
        if order_exact and m > 1:
            per = per * m // 2  # un-reduced chunk routing, n(n-1)/2 hops
        return per * (self.n_layers or 1)

    def param_bytes(self) -> int:
        """Stored bytes per device for this group's param state (master +
        any quantized payload + the reduce-wire EF residual, which is m
        shard-lengths of fp32 per device), across the layer stack."""
        s = self.store
        if s.quantized:
            per_elem = 1 + 4 + 4.0 / s.block  # codes + fp32 master + scales
        elif s.fp8:
            per_elem = 1 + 4                  # fp8 codes + fp32 master
        else:
            per_elem = s.storage_dtype.itemsize
        per_elem += 4.0 * s.ef_m              # fp32 EF residual (m shards)
        local = self.plan.shard_size if self.fsdp_axes else self.plan.total
        return int(local * per_elem * (self.n_layers or 1))

    def invariants(self, compute_dtype) -> tuple[dict, ...]:
        """The group's declared invariant set: what ``repro.analysis``
        proves about the traced step for this policy (DESIGN.md §Static
        analysis has the catalog).  Each entry is a plain dict (name +
        parameters + bitwise-vs-allclose class) so the declaration is
        serializable beside the plan.  New comm/store variants MUST extend
        this -- a policy combination with no declared invariants is
        unverifiable by doctrine."""
        import jax.numpy as jnp

        from .wire import _snap_chunk

        sched = self.schedule()
        cd = jnp.dtype(compute_dtype)
        inv: list[dict] = []
        if self.fsdp_axes and self.fsdp_world > 1:
            shard = self.plan.shard_size
            # wire legs of one gather copy: (dtype name, per-device elems)
            if self.store.quantized:
                legs = (("int8", shard),
                        ("float32", shard // self.quant_block))
            elif self.store.fp8:
                legs = ((str(self.store.fp8_dtype), shard),)
            else:
                legs = ((str(sched.wire_dtype(cd)), shard),)
            rcodec = sched.reduce_codec(cd, self.quant_block)
            ring_gather = sched.gather_mode == "ring"
            ring_reduce = (sched.reduce_mode == "ring_acc"
                           or (sched.reduce_mode == "match"
                               and (rcodec.quantized or ring_gather)))
            if rcodec.quantized:
                rdtypes = ("int8", "float32")
            else:
                rdtypes = (str(sched.accum_dtype(cd)),)
            inv.append({
                "name": "comm_bytes", "group": self.name,
                "class": "exact",
                "gather_legs": legs,
                "reduce_route": ("ring" if ring_reduce else "psum_scatter"),
                "reduce_dtypes": rdtypes,
                "gather_mb_per_copy": self.gather_wire_bytes(cd) / 1e6
                / (self.n_layers or 1),
                "reduce_mb_per_copy": self.reduce_wire_bytes(cd) / 1e6
                / (self.n_layers or 1),
            })
            inv.append({
                "name": "wire_dtype", "group": self.name, "class": "exact",
                "legal": sorted({d for d, _ in legs} | set(rdtypes)),
            })
            if ring_gather or ring_reduce:
                unit = self.quant_block if self.store.quantized else 1
                declared = sched.ring_chunk_elems
                # "snapped" is the block-aligned snap the declaration
                # promises; "wire" is the unit-1 snap the gather data path
                # performs.  They must agree, or the declared chunk makes
                # quant blocks straddle ring messages (the misalignment
                # class the q8 align guarantee exists to prevent).
                inv.append({
                    "name": "ring_chunk", "group": self.name,
                    "class": "exact", "declared": declared,
                    "snapped": _snap_chunk(shard, declared, unit),
                    "wire": _snap_chunk(shard, declared),
                    "unit": unit,
                })
        if self.store.quantized and cd != jnp.dtype(jnp.float32):
            inv.append({"name": "no_f32_dequant", "group": self.name,
                        "class": "exact", "src_dtype": "int8",
                        "gathered_elems": int(self.plan.total)})
        if self.store.fp8 and cd != jnp.dtype(jnp.float32):
            # the fused gather decode is a single fp8 -> compute cast;
            # a full-size fp8 -> f32 convert would betray an unfused
            # dequant-then-downcast path
            inv.append({"name": "no_f32_dequant", "group": self.name,
                        "class": "exact",
                        "src_dtype": str(self.store.fp8_dtype),
                        "gathered_elems": int(self.plan.total)})
        if sched.ef_enabled:
            inv.append({"name": "ef_threading", "group": self.name,
                        "class": "exact"})
        return tuple(inv)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """The resolved, first-class planning artifact.

    Inspect with ``describe()``, serialize with ``to_json``/``dumps``,
    compare with ``diff``.  A plan round-tripped through JSON reconstructs
    the exact layout (placements carry name/shape/dtype/granularity/offset),
    so ``FSDPRuntime`` can be built from a restored plan bit-for-bit --
    checkpoints save ``plan.json`` next to the weights for exactly that.
    """

    base: ShardingPolicy
    groups: Mapping[str, GroupPlanEntry]
    axis_sizes: Mapping[str, int]
    planner: str
    compute_dtype: str  # dtype name, e.g. "bfloat16"
    # pricing provenance (ISSUE 8): which comm profile the auto cost model
    # priced this plan with.  "none" = the plan was not cost-model-priced
    # (explicit policies / legacy lowering).  The hash is the profile's
    # content hash, so re-planning from the same BENCH_comm.json provably
    # reproduces the same decisions and ``diff`` flags profile drift.
    profile_name: str = "none"
    profile_hash: str = ""
    # per-group predicted comm ms under the pricing profile vs the builtin
    # roofline -- describe() renders these side by side so a measured
    # profile's divergent decision is visible, not just different
    pricing: Mapping[str, Mapping[str, float]] = dataclasses.field(
        default_factory=dict)

    def base_schedule(self) -> CommSchedule:
        return self.base.to_schedule()

    def schedules(self) -> dict[str, CommSchedule]:
        return {n: e.schedule() for n, e in self.groups.items()}

    def policy_set(self) -> PolicySet:
        """The plan's policies as an explicit exact-name PolicySet -- e.g.
        to re-plan a size-reduced variant of the same model under identical
        per-group decisions (the dry-run calibrator does this for
        ``--policies auto``)."""
        return PolicySet(
            rules=tuple(PolicyRule(match=n, policy=e.policy)
                        for n, e in self.groups.items()
                        if e.policy != self.base),
            default=self.base)

    # ---- accounting ------------------------------------------------------ #
    def gather_wire_bytes(self) -> int:
        return sum(e.gather_wire_bytes(self.compute_dtype)
                   for e in self.groups.values())

    def reduce_wire_bytes(self) -> int:
        return sum(e.reduce_wire_bytes(self.compute_dtype)
                   for e in self.groups.values())

    def invariants(self) -> tuple[dict, ...]:
        """The plan's full declared invariant set: every group's
        declarations (``GroupPlanEntry.invariants``) plus the plan-level
        entries only the whole plan can state -- the gathered-buffer peak
        the scan structure bounds, and the pricing-profile freshness
        warning for auto plans.  ``repro.analysis.verify`` consumes this;
        the declaration is data, the checkers live there."""
        inv: list[dict] = []
        for e in self.groups.values():
            inv.extend(e.invariants(self.compute_dtype))
        sched = self.base_schedule()
        layered = {n: e for n, e in self.groups.items()
                   if e.n_layers and e.fsdp_axes and e.fsdp_world > 1}
        if layered:
            n = max(e.n_layers for e in layered.values())
            if not sched.reshard_after_forward:
                slots = n
            else:
                lp = sched.plan_layers(n, remat=True)
                main_slots = (2 if lp.prefetch else 1) if lp.main else 0
                slots = main_slots + int(lp.split_last)
            inv.append({
                "name": "gathered_peak", "group": "*", "class": "exact",
                "max_slots": slots,
                "groups": {name: {"elems": int(e.plan.total)}
                           for name, e in layered.items()},
            })
        if self.profile_name != "none":
            inv.append({
                "name": "profile_fresh", "group": "*", "class": "warn",
                "profile": self.profile_name, "hash": self.profile_hash,
            })
        return tuple(inv)

    # ---- inspection ------------------------------------------------------ #
    def describe(self) -> str:
        """The audit table: per-group policy (including each group's
        reduce wire format), shard size S, padding, and predicted wire
        bytes for both comm directions -- what ``dryrun --plan-only`` and
        ``bench_e2e --schedule`` print."""
        mesh = ",".join(f"{a}={s}" for a, s in self.axis_sizes.items())
        head = (f"ShardingPlan mesh[{mesh}] planner={self.planner} "
                f"compute={self.compute_dtype} "
                f"scan[prefetch={int(self.base.prefetch)} "
                f"reshard={int(self.base.reshard_after_forward)} "
                f"keep_last={int(self.base.keep_last_gathered)}]")
        if self.profile_name != "none":
            head += f" profile={self.profile_name}@{self.profile_hash}"
        cols = ["group", "tag", "L", "m", "S", "pad%", "policy",
                "gather_wire_mb", "reduce_wire_mb"]
        priced = bool(self.pricing)
        if priced:
            # measured-vs-builtin pricing side by side: what the pricing
            # profile predicts for the chosen policy, next to what the
            # builtin roofline predicts for it
            cols += ["auto_ms", "builtin_ms"]
        rows = []
        for name, e in self.groups.items():
            rows.append([
                name, e.tag, str(e.n_layers or "-"), str(e.fsdp_world),
                str(e.plan.shard_size),
                f"{100 * e.plan.padding_ratio:.2f}",
                e.policy.describe(),
                f"{e.gather_wire_bytes(self.compute_dtype) / 1e6:.3f}",
                f"{e.reduce_wire_bytes(self.compute_dtype) / 1e6:.3f}",
            ])
            if priced:
                p = self.pricing.get(name, {})
                rows[-1] += [f"{p.get('auto_ms', 0.0):.4f}",
                             f"{p.get('builtin_ms', 0.0):.4f}"]
        widths = [max(len(c), *(len(r[i]) for r in rows)) if rows else len(c)
                  for i, c in enumerate(cols)]
        lines = [head,
                 "  ".join(c.ljust(w) for c, w in zip(cols, widths))]
        lines += ["  ".join(v.ljust(w) for v, w in zip(r, widths))
                  for r in rows]
        return "\n".join(lines)

    # ---- serialization --------------------------------------------------- #
    def to_json(self) -> dict:
        return {
            # v2 added per-group "outer_dims"; v3 adds the pricing
            # provenance ("profile") and the per-group "pricing" table
            "version": 3,
            "axis_sizes": {a: int(s) for a, s in self.axis_sizes.items()},
            "planner": self.planner,
            "compute_dtype": self.compute_dtype,
            "profile": {"name": self.profile_name,
                        "hash": self.profile_hash},
            "pricing": {name: {k: float(v) for k, v in p.items()}
                        for name, p in self.pricing.items()},
            "base": dataclasses.asdict(self.base),
            "groups": {
                name: {
                    "tag": e.tag,
                    "policy": dataclasses.asdict(e.policy),
                    "shard_size": e.plan.shard_size,
                    "num_shards": e.plan.num_shards,
                    "mode": e.plan.mode,
                    "padding": e.plan.padding,
                    "n_layers": e.n_layers,
                    "outer_axis": e.outer_axis,
                    "outer_size": e.outer_size,
                    "outer_dims": {k: int(v)
                                   for k, v in e.outer_dims.items()},
                    "fsdp_axes": list(e.fsdp_axes),
                    "fsdp_axis_sizes": [int(s) for s in e.fsdp_axis_sizes],
                    "grad_sync_axes": list(e.grad_sync_axes),
                    "quant_block": e.quant_block,
                    "gather_wire_mb": round(
                        e.gather_wire_bytes(self.compute_dtype) / 1e6, 6),
                    "reduce_wire_mb": round(
                        e.reduce_wire_bytes(self.compute_dtype) / 1e6, 6),
                    "param_mb": round(e.param_bytes() / 1e6, 6),
                    "placements": [
                        {"name": p.spec.name, "shape": list(p.spec.shape),
                         "dtype": p.spec.dtype,
                         "granularity": p.spec.granularity,
                         "offset": p.offset}
                        for p in e.plan.placements],
                }
                for name, e in self.groups.items()
            },
        }

    def dumps(self) -> str:
        """Canonical JSON string (sorted keys) -- plan equality is string
        equality of ``dumps()``."""
        return json.dumps(self.to_json(), sort_keys=True)

    @classmethod
    def from_json(cls, data: Mapping[str, Any]) -> "ShardingPlan":
        groups = {}
        for name, g in data["groups"].items():
            placements = tuple(
                Placement(TensorSpec(p["name"], tuple(p["shape"]),
                                     p.get("dtype", "float32"),
                                     p["granularity"]),
                          p["offset"])
                for p in g["placements"])
            gplan = GroupPlan(placements, shard_size=g["shard_size"],
                              num_shards=g["num_shards"], mode=g["mode"])
            groups[name] = GroupPlanEntry(
                name=name, tag=g["tag"],
                policy=ShardingPolicy(**g["policy"]),
                local_specs=tuple(p.spec for p in placements),
                plan=gplan,
                fsdp_axes=tuple(g["fsdp_axes"]),
                fsdp_axis_sizes=tuple(g["fsdp_axis_sizes"]),
                outer_axis=g["outer_axis"], outer_size=g["outer_size"],
                n_layers=g["n_layers"],
                grad_sync_axes=tuple(g["grad_sync_axes"]),
                quant_block=g["quant_block"],
                # v1 plan files predate outer_dims; absent == no outer split
                outer_dims={k: int(v)
                            for k, v in g.get("outer_dims", {}).items()})
        prof = data.get("profile", {})  # v1/v2 plan files: unpriced
        return cls(base=ShardingPolicy(**data["base"]), groups=groups,
                   axis_sizes=dict(data["axis_sizes"]),
                   planner=data["planner"],
                   compute_dtype=data["compute_dtype"],
                   profile_name=prof.get("name", "none"),
                   profile_hash=prof.get("hash", ""),
                   pricing={name: {k: float(v) for k, v in p.items()}
                            for name, p in data.get("pricing", {}).items()})

    def diff(self, other: "ShardingPlan") -> list[str]:
        """Human-readable field-level differences vs ``other`` (empty ==
        plans are identical)."""
        out: list[str] = []

        def walk(path, a, b):
            if isinstance(a, dict) and isinstance(b, dict):
                for k in sorted(set(a) | set(b)):
                    if k not in a:
                        out.append(f"{path}{k}: <absent> != {b[k]!r}")
                    elif k not in b:
                        out.append(f"{path}{k}: {a[k]!r} != <absent>")
                    else:
                        walk(f"{path}{k}.", a[k], b[k])
            elif a != b:
                out.append(f"{path[:-1]}: {a!r} != {b!r}")

        walk("", self.to_json(), other.to_json())
        return out


# fields of a group's JSON entry whose change means the group's *data
# layout or storage* changed (shards are not movable bitwise); everything
# else (wire formats, gather modes, accounting) leaves shard bytes intact
_LAYOUT_FIELDS = frozenset({
    "shard_size", "num_shards", "mode", "n_layers", "outer_axis",
    "outer_size", "outer_dims", "fsdp_axes", "fsdp_axis_sizes",
    "grad_sync_axes", "placements", "quant_block",
})
_LAYOUT_POLICY_FIELDS = frozenset({"store", "reduce_wire"})


def layout_changed_groups(old: ShardingPlan, new: ShardingPlan) -> set[str]:
    """Group names whose stored bytes cannot move bitwise from ``old`` to
    ``new``: the layout (placements/shards/outer split) or the stored
    format (store fmt, EF presence via reduce_wire) differs.  Built on
    ``ShardingPlan.diff`` — the elastic paths (``FSDPRuntime.replan``,
    ``tools/reshard.py``) move every other group as raw shards and route
    only these through the extent map.  Groups present in only one plan
    count as changed."""
    import re

    changed: set[str] = set()
    changed |= set(old.groups) ^ set(new.groups)
    pat = re.compile(r"^groups\.([^.]+)\.([^.:]+)")
    for line in old.diff(new):
        m = pat.match(line)
        if not m:
            continue
        gname, field = m.group(1), m.group(2)
        if field in _LAYOUT_FIELDS:
            changed.add(gname)
        elif field == "policy":
            sub = re.match(r"^groups\.[^.]+\.policy\.([^.:]+)", line)
            if sub and sub.group(1) in _LAYOUT_POLICY_FIELDS:
                changed.add(gname)
    return changed & (set(old.groups) | set(new.groups))


# --------------------------------------------------------------------------- #
# the auto planner's cost model
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class CostModel:
    """The terms the auto-planner scores candidate policies with.

    Two pricing sources, one interface:

    * **builtin roofline** (``profile`` is None or a ``builtin=True``
      profile): the closed-form model over the ``launch/mesh.py``
      constants -- ``gathers_per_step * wire_bytes * (m-1)/m / ici_bw``
      plus, for quantized payloads, the analytic encode/decode HBM traffic
      and a per-collective issue latency.  Latency is now *per mode*
      (satellite of ISSUE 8): the xla collective pays ``xla_latency_s``
      once, the manual rings pay ``ring_hop_latency_s`` per hop (m-1 hops).
    * **measured profile** (``from_profile``): per (direction, fmt, mode)
      latency/bandwidth curves fitted from ``BENCH_comm.json``
      measurements on the actual mesh (core.profile).  Measured curves are
      end-to-end -- codec encode/decode cost is inside the measurement --
      so the analytic HBM add-ons are skipped.

    The format with the smallest predicted time wins, ties broken toward
    the earlier (more exact) candidate -- so an m=1 mesh keeps fp32
    everywhere and a bandwidth-bound layer stack at scale takes the
    ~4x-cheaper q8_block wire.  fp8 store formats (``FP8_CANDIDATES``,
    guarded on ``compat.float8_dtypes``) are scored after the base
    candidates and only when the profile carries a *measured* fp8 gather
    curve for the mode under consideration: the builtin roofline's
    apparent fp8-over-q8 win is just the per-block scales overhead
    (4/quant_block B/elem, up to ~10% at block 32), not evidence that
    this backend's fused fp8 cast is actually faster, so analytic-only
    pricing never nominates fp8 and every historical builtin decision
    is stable.  A measured fp8 curve must still beat the incumbent by
    more than the near-tie band (``FP8_NEAR_TIE_RTOL``) to displace it.
    Tiny *unstacked* groups (< ``replicate_bytes`` of master weights) are
    kept replicated: their per-step gather latency outweighs the memory
    the shard would save.
    """

    ici_bw: float
    hbm_bw: float
    peak_flops: float
    xla_latency_s: float = 5e-6       # per xla collective issue
    ring_hop_latency_s: float = 5e-6  # per ppermute hop (rings pay m-1)
    replicate_bytes: int = 4 << 20
    profile: Optional[CommProfile] = None

    # store formats in preference order (ties break toward the left)
    CANDIDATES = ("fp32", "bf16", "q8_block")
    # fp8 store candidates (guarded: empty where the installed JAX lacks
    # float8).  Scored after CANDIDATES, and only when the profile has a
    # *measured* fp8 gather curve for the mode: the analytic fp8-vs-q8
    # gap is pure scales overhead (4/quant_block B/elem -- ~0.4% at
    # block 1024 but ~10% at block 32), which says nothing about whether
    # this backend's fused fp8 cast actually wins, so the builtin
    # roofline never nominates fp8 and historical auto decisions hold.
    # A measured curve must additionally beat the incumbent by more than
    # FP8_NEAR_TIE_RTOL to flip a group to fp8.
    FP8_CANDIDATES = tuple(f for f in STORE_FORMATS
                           if f.startswith("fp8_"))
    FP8_NEAR_TIE_RTOL = 0.02
    # gather modes in preference order (xla wins ties)
    GATHER_MODES = ("xla", "ring")

    @classmethod
    def default(cls) -> "CostModel":
        from ..launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

        return cls(ici_bw=ICI_BW, hbm_bw=HBM_BW, peak_flops=PEAK_FLOPS_BF16)

    @classmethod
    def from_profile(cls, profile, hbm_bw: float | None = None,
                     peak_flops: float | None = None) -> "CostModel":
        """A CostModel pricing from a measured ``CommProfile`` (the object,
        or any path to a ``BENCH_comm.json``-schema file).  ``ici_bw`` is
        back-derived from the fitted fp32 gather curve for reporting and
        for curves the profile does not cover; HBM/FLOPS stay the mesh
        constants (the profile measures the wire, not the memory system)."""
        from ..launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

        if not isinstance(profile, CommProfile):
            profile = load_profile(profile)
        ici = ICI_BW
        for mode in ("xla", "ring"):
            if profile.has("gather", "fp32", mode):
                _, slope = profile.linear("gather", "fp32", mode)
                if slope > 0:
                    ici = 4.0 / slope  # fp32: 4 wire bytes per element
                    break
        return cls(ici_bw=ici, hbm_bw=hbm_bw or HBM_BW,
                   peak_flops=peak_flops or PEAK_FLOPS_BF16,
                   profile=profile)

    # ---- provenance ------------------------------------------------------ #
    @property
    def measured(self) -> bool:
        return self.profile is not None and not self.profile.builtin

    def provenance_profile(self) -> CommProfile:
        """The profile this model prices with: the attached measured one,
        or the builtin roofline rendered from its own constants -- so every
        auto plan can record a (name, content hash) provenance pair."""
        if self.profile is not None:
            return self.profile
        return builtin_profile(self.ici_bw, self.xla_latency_s)

    # ---- shared pricing helpers ------------------------------------------ #
    def _latency(self, mode: str, m: int) -> float:
        """Per-collective issue latency under the builtin roofline: no
        collective at m=1; one xla issue, or m-1 ring hops."""
        if m <= 1:
            return 0.0
        if mode == "xla":
            return self.xla_latency_s
        return self.ring_hop_latency_s * (m - 1)

    def _measured_time(self, direction: str, fmt: str, mode: str,
                       elems: float, m: int) -> Optional[float]:
        """One collective's seconds from the measured curve, or None when
        this model has no measured entry for the key.  The fitted slope
        includes the profile world's (w-1)/w ring volume factor, so it is
        rescaled to the group's m (0 at m=1: no wire)."""
        if not (self.measured and self.profile.has(direction, fmt, mode)):
            return None
        lat, slope = self.profile.linear(direction, fmt, mode)
        w = self.profile.world
        rw = (w - 1) / w if w > 1 else 1.0
        rm = (m - 1) / m if m > 1 else 0.0
        return lat + elems * slope * (rm / rw)

    def gather_time(self, fmt: str, elems_per_layer: int, n_layers: int,
                    m: int, quant_block: int, compute_itemsize: int,
                    reshard: bool = True, mode: str = "xla") -> float:
        """Predicted per-step parameter-gather seconds for one group under
        store format ``fmt`` and gather mode ``mode`` (forward + backward
        re-gather when resharding)."""
        gathers = 2.0 if reshard else 1.0
        measured = self._measured_time("gather", fmt, mode,
                                       elems_per_layer, m)
        if measured is not None:
            t = gathers * n_layers * measured
            if not self.profile.end_to_end and fmt == "q8_block":
                deq = elems_per_layer * (
                    1 + 4.0 / quant_block + compute_itemsize)
                t += gathers * n_layers * deq / self.hbm_bw
            if not self.profile.end_to_end and fmt.startswith("fp8_"):
                # scale-free decode: fp8 codes in, compute-dtype out
                deq = elems_per_layer * (1 + compute_itemsize)
                t += gathers * n_layers * deq / self.hbm_bw
            return t
        store = ParamStore(fmt, quant_block)
        wire_dtype = np.dtype(np.float32 if compute_itemsize == 4
                              else np.float16)  # itemsize is all that matters
        wire = store.wire_bytes(elems_per_layer, wire_dtype)
        ring = (m - 1) / m if m > 1 else 0.0
        t = gathers * n_layers * (
            wire * ring / self.ici_bw + self._latency(mode, m))
        if store.quantized:
            # local dequant traffic: codes+scales in, compute-dtype out
            deq = elems_per_layer * (1 + 4.0 / quant_block + compute_itemsize)
            t += gathers * n_layers * deq / self.hbm_bw
        elif store.fp8:
            # the decode cast: fp8 codes in, compute-dtype out (no scales)
            deq = elems_per_layer * (1 + compute_itemsize)
            t += gathers * n_layers * deq / self.hbm_bw
        return t

    def choose_store(self, elems_per_layer: int, n_layers: int, m: int,
                     quant_block: int, compute_itemsize: int,
                     reshard: bool = True, mode: str = "xla") -> str:
        best, best_t = None, None
        for fmt in self.CANDIDATES:
            t = self.gather_time(fmt, elems_per_layer, n_layers, m,
                                 quant_block, compute_itemsize, reshard,
                                 mode)
            if best_t is None or t < best_t:
                best, best_t = fmt, t
        for fmt in self.FP8_CANDIDATES:
            if self._measured_time("gather", fmt, mode,
                                   elems_per_layer, m) is None:
                continue  # fp8 competes only on measured evidence
            t = self.gather_time(fmt, elems_per_layer, n_layers, m,
                                 quant_block, compute_itemsize, reshard,
                                 mode)
            if t < best_t * (1.0 - self.FP8_NEAR_TIE_RTOL):
                best, best_t = fmt, t
        return best

    def choose_gather(self, elems_per_layer: int, n_layers: int, m: int,
                      quant_block: int, compute_itemsize: int,
                      reshard: bool = True) -> tuple[str, str]:
        """Joint (store format, gather mode) choice, strict-less-than with
        fmt-major, xla-first iteration order -- so under the builtin
        roofline (where the ring route never strictly beats the xla
        collective: same wire volume, >= issue latency at m >= 2) every
        decision matches the historical per-format ``choose_store``."""
        best, best_t = None, None
        for fmt in self.CANDIDATES:
            for mode in self.GATHER_MODES:
                t = self.gather_time(fmt, elems_per_layer, n_layers, m,
                                     quant_block, compute_itemsize, reshard,
                                     mode)
                if best_t is None or t < best_t:
                    best, best_t = (fmt, mode), t
        for fmt in self.FP8_CANDIDATES:
            for mode in self.GATHER_MODES:
                if self._measured_time("gather", fmt, mode,
                                       elems_per_layer, m) is None:
                    continue  # fp8 competes only on measured evidence
                t = self.gather_time(fmt, elems_per_layer, n_layers, m,
                                     quant_block, compute_itemsize, reshard,
                                     mode)
                if t < best_t * (1.0 - self.FP8_NEAR_TIE_RTOL):
                    best, best_t = (fmt, mode), t
        return best

    # ---- reduce direction (the gradient wire) ---------------------------- #
    # reduce wire candidates in preference order: None keeps the legacy
    # dtype wire (compute/accum dtype -- exact), "q8_block" is the QSDP
    # quantized gradient wire.  Ties break toward the exact wire.
    REDUCE_CANDIDATES = (None, "q8_block")

    def reduce_time(self, fmt: Optional[str], elems_per_layer: int,
                    n_layers: int, m: int, quant_block: int,
                    compute_itemsize: int,
                    mode: Optional[str] = None) -> float:
        """Predicted per-step gradient reduce-scatter seconds for one group
        under reduce wire ``fmt`` (one reduce per layer per step).  The
        quantized wire pays local encode/decode HBM traffic plus the
        error-feedback residual read+write (fp32, contribution-sized) --
        the roofline prices *both* comm directions, so the auto planner
        only takes the q8 gradient wire where the step is genuinely
        wire-bound.

        ``mode`` is the reduce *route*: "xla" (psum_scatter), "ring"
        (order-exact), "ring_acc" (accumulate-in-flight).  None picks the
        route ``auto_policies`` would pair with the wire: xla for the cast
        wire, ring_acc for q8 -- the (m-1)/m volume here models the
        bandwidth-optimal routes this pairing lands on (the order-exact
        match-mode q8 route ships (m-1)/2 x the payload; DESIGN.md §Wire
        formats)."""
        from .wire import WireCodec

        codec = (WireCodec("q8_block", quant_block) if fmt == "q8_block"
                 else WireCodec("fp32" if compute_itemsize == 4 else "bf16"))
        if mode is None:
            mode = "ring_acc" if codec.quantized else "xla"
        measured = self._measured_time("reduce", codec.fmt, mode,
                                       elems_per_layer, m)
        if measured is not None and self.profile.end_to_end:
            return n_layers * measured
        wire = codec.wire_bytes(elems_per_layer)
        ring = (m - 1) / m if m > 1 else 0.0
        t = n_layers * (wire * ring / self.ici_bw + self._latency(mode, m))
        if codec.quantized:
            # encode (read fp32 ct + ef, write codes+scales+ef) and decode
            # (read m contributions' codes+scales, write the fp32 shard)
            enc = elems_per_layer * (4 + 1 + 4.0 / quant_block + 2 * 4)
            dec = elems_per_layer * (1 + 4.0 / quant_block) + 4 * (
                elems_per_layer / max(m, 1))
            t += n_layers * (enc + dec) / self.hbm_bw
        return t

    def choose_reduce_wire(self, elems_per_layer: int, n_layers: int,
                           m: int, quant_block: int,
                           compute_itemsize: int) -> Optional[str]:
        best, best_t = None, None
        for fmt in self.REDUCE_CANDIDATES:
            t = self.reduce_time(fmt, elems_per_layer, n_layers, m,
                                 quant_block, compute_itemsize)
            if best_t is None or t < best_t:
                best, best_t = fmt, t
        return best

    # ---- ring chunking --------------------------------------------------- #
    def choose_ring_chunk(self, direction: str, fmt: str,
                          shard_elems: int) -> Optional[int]:
        """The ring message size for a group whose route is a manual ring,
        from the measured profile's chunk-size curve (None = keep the
        shard-sized default -- always the answer under the builtin
        roofline, which has no chunk sweep to search)."""
        if not self.measured:
            return None
        best = self.profile.best_ring_chunk(direction, fmt)
        if best is None or best >= shard_elems:
            return None
        return best


def auto_policies(model, axis_sizes: Mapping[str, int],
                  compute_dtype=None,
                  cost_model: CostModel | None = None) -> PolicySet:
    """The ``policies="auto"`` planner: run the structure-aware cost model
    over every communication group and emit an explicit exact-name
    PolicySet (the decisions are then first-class in the ShardingPlan)."""
    import jax.numpy as jnp

    cm = cost_model or CostModel.default()
    cfg = model.cfg
    cd = jnp.dtype(compute_dtype or jnp.bfloat16)
    groups = model.groups()

    # scan structure: overlap gathers when there is a real stack to overlap
    max_layers = max((g.n_layers or 0) for g in groups.values())
    default = ShardingPolicy(
        prefetch=max_layers >= 3, keep_last_gathered=max_layers >= 3)

    rules = []
    for name, gdef in groups.items():
        elems, m, _axes = _group_shape(name, gdef, cfg.parallel, axis_sizes)
        n_layers = gdef.n_layers or 1
        master_bytes = elems * n_layers * 4  # fp32 master weights
        if gdef.n_layers is None and m > 1 and (
                master_bytes <= cm.replicate_bytes):
            pol = dataclasses.replace(default, sharded=False)
        else:
            fmt, gmode = cm.choose_gather(elems, n_layers, m,
                                          cfg.quant_block, cd.itemsize,
                                          reshard=default.reshard_after_forward)
            # price the gradient direction too: bandwidth-bound stacks take
            # the QSDP q8 gradient wire (error feedback keeps convergence
            # at full-precision quality; see DESIGN.md §Wire formats).
            # The EF wire does not compose with gradient accumulation, and
            # EF residuals would diverge across replica gradient axes
            # (HSDP cross-pod, TP-replicated groups) -- the runtime rejects
            # both, so 'auto' must only score legal candidates
            replica_grads = (
                ("pod" in axis_sizes and not cfg.parallel.pod_fsdp)
                or (gdef.replicated_over_model and cfg.parallel.tp > 1))
            rwire = (None if (cfg.parallel.microbatches > 1 or replica_grads)
                     else cm.choose_reduce_wire(elems, n_layers, m,
                                                cfg.quant_block,
                                                cd.itemsize))
            pol = dataclasses.replace(default, store=fmt, gather_mode=gmode,
                                      reduce_wire=rwire)
            if rwire == "q8_block":
                # the cost model prices the bandwidth-optimal route; the
                # order-exact match-mode q8 routing ships (m-1)/2 x the
                # payload, so pair the quantized gradient wire with the
                # accumulate-in-flight ring it is actually cheap on
                pol = dataclasses.replace(pol, reduce_mode="ring_acc")
            # the chunking knob only exists on the manual ring routes; a
            # measured profile's chunk-size curve picks the message size
            # (the shard snap happens in core.wire, so elems-per-layer is a
            # safe upper-bound argument here)
            chunk = None
            if pol.gather_mode == "ring":
                chunk = cm.choose_ring_chunk("gather", fmt, elems // max(m, 1))
            elif pol.reduce_mode == "ring_acc" or pol.reduce_wire == "q8_block":
                rfmt = pol.reduce_wire or ("fp32" if cd.itemsize == 4
                                           else "bf16")
                chunk = cm.choose_ring_chunk("reduce", rfmt,
                                             elems // max(m, 1))
            if chunk is not None:
                pol = dataclasses.replace(pol, ring_chunk_elems=int(chunk))
        if pol != default:
            rules.append(PolicyRule(match=name, policy=pol))
    return PolicySet(rules=tuple(rules), default=default)


# --------------------------------------------------------------------------- #
# resolution: policies x model x mesh -> ShardingPlan
# --------------------------------------------------------------------------- #

def _axis_sizes(mesh) -> dict[str, int]:
    """Mesh axis sizes from a jax Mesh or a plain {axis: size} mapping --
    planning is pure host-side metadata, no devices required."""
    if isinstance(mesh, Mapping):
        return {a: int(s) for a, s in mesh.items()}
    return {a: int(s) for a, s in zip(mesh.axis_names, mesh.devices.shape)}


def _group_axes(name: str, gdef, par, axis_sizes: Mapping[str, int]):
    """The (outer_axis, outer_size, local_specs, fsdp_axes) decomposition of
    one group -- TP/EP outer sharding composed before FSDP, exactly the
    runtime's historical layout rules."""
    outer_axis, outer_size = None, 1
    local_specs = []
    for s in gdef.specs:
        sd = gdef.outer.get(s.name)
        if sd is not None:
            outer_axis = sd.axis
            outer_size = axis_sizes[sd.axis]
            local_specs.append(compose_granularity(s, sd, outer_size))
        else:
            local_specs.append(s)
    if outer_axis or gdef.replicated_over_model:
        fsdp_axes = tuple(a for a in par.fsdp_axes if a != "model")
    else:
        fsdp_axes = tuple(a for a in par.fsdp_axes if a in axis_sizes)
    if "pod" in axis_sizes and par.pod_fsdp:
        fsdp_axes = ("pod",) + fsdp_axes
    return outer_axis, outer_size, tuple(local_specs), fsdp_axes


def _group_shape(name: str, gdef, par, axis_sizes: Mapping[str, int]):
    """(per-layer local payload elements, FSDP world size, fsdp_axes) --
    the quantities the auto cost model scores."""
    _, _, local_specs, fsdp_axes = _group_axes(name, gdef, par, axis_sizes)
    m = int(np.prod([axis_sizes[a] for a in fsdp_axes])) or 1
    return sum(s.size for s in local_specs), m, fsdp_axes


def _price_entry(cm: CostModel, e: GroupPlanEntry, compute_itemsize: int
                 ) -> float:
    """Predicted per-step comm seconds (both directions) of one resolved
    group entry under ``cm`` -- the figure the pricing table and the
    describe() auto/builtin columns show.  Replicated groups price as 0
    (no gather; their psum is shared with every candidate)."""
    if not e.fsdp_axes:
        return 0.0
    elems = sum(s.size for s in e.local_specs)
    n_layers = e.n_layers or 1
    m = e.fsdp_world
    pol = e.policy
    t = cm.gather_time(pol.store, elems, n_layers, m, e.quant_block,
                       compute_itemsize, reshard=pol.reshard_after_forward,
                       mode=pol.gather_mode)
    rmode = ("ring_acc" if pol.reduce_mode == "ring_acc"
             else pol.gather_mode)
    t += cm.reduce_time(pol.reduce_wire, elems, n_layers, m, e.quant_block,
                        compute_itemsize, mode=rmode)
    return t


def _resolve_policies(policies, model, axis_sizes, compute_dtype,
                      cost_model) -> PolicySet:
    if policies is None:
        return PolicySet.from_parallel_config(model.cfg.parallel)
    if isinstance(policies, str):
        if policies != "auto":
            raise ValueError(
                f"unknown policies spec {policies!r}; expected 'auto', a "
                f"PolicySet, a ShardingPolicy, a CommSchedule, or None")
        return auto_policies(model, axis_sizes, compute_dtype, cost_model)
    if isinstance(policies, PolicySet):
        return policies
    if isinstance(policies, ShardingPolicy):
        return PolicySet(default=policies)
    if isinstance(policies, CommSchedule):
        return PolicySet(default=ShardingPolicy.from_schedule(policies))
    raise ValueError(
        f"unknown policies spec of type {type(policies).__name__}; expected "
        f"'auto', a PolicySet, a ShardingPolicy, a CommSchedule, or None")


def plan(model, mesh, policies=None, *, planner: str = "ragged",
         compute_dtype=None, cost_model: CostModel | None = None
         ) -> ShardingPlan:
    """THE planning entry point: resolve ``policies`` against the model's
    communication groups on ``mesh`` (a jax Mesh or an {axis: size} mapping)
    into a ``ShardingPlan``.

    ``policies``: ``PolicySet`` / ``ShardingPolicy`` / ``CommSchedule`` /
    ``None`` (lower the legacy ``ParallelConfig`` knobs) / ``"auto"`` (the
    ``CostModel`` picks per-group store format, and replication for tiny
    unstacked groups).  Rules that match no group raise -- a typo'd group
    name is an error, never a silent no-op.
    """
    import jax.numpy as jnp

    axis_sizes = _axis_sizes(mesh)
    cfg = model.cfg
    par = cfg.parallel
    cd = jnp.dtype(compute_dtype or jnp.bfloat16)
    pset = _resolve_policies(policies, model, axis_sizes, cd, cost_model)
    planner_fn = get_planner(planner)

    entries: dict[str, GroupPlanEntry] = {}
    matched: set[int] = set()
    for name, gdef in model.groups().items():
        info = GroupInfo(name=name, tag=group_tag(name, gdef),
                         n_layers=gdef.n_layers, specs=gdef.specs)
        pol, _ = pset.policy_for(info)
        # typo protection is independent of precedence: a rule shadowed by
        # an earlier one still "matches"; only a selector that names
        # nothing in this model is an error
        matched.update(i for i, r in enumerate(pset.rules)
                       if r.matches(info))
        sched = pol.to_schedule()
        sched.validate_for(cd)

        outer_axis, outer_size, local_specs, fsdp_axes = _group_axes(
            name, gdef, par, axis_sizes)
        grad_sync_axes: tuple[str, ...] = ()
        if not sched.sharded:
            # group kept replicated by policy: no gather, grads psum'd over
            # the axes it would have been sharded on
            grad_sync_axes, fsdp_axes = fsdp_axes, ()
        m = int(np.prod([axis_sizes[a] for a in fsdp_axes])) or 1

        store = store_for(pol, cfg.quant_block, m)
        # quant blocks must never straddle a shard boundary or a tensor
        # start -- for the 8-bit optimizer states, for any group whose
        # *store* is quantized (the paper's block-wise quantized training),
        # AND for a quantized *reduce wire* (reduce-scatter chunks are
        # shard-sized, so S must be a block multiple for the gradient
        # quantization to stay communication-free)
        align = max(
            store.align(),
            cfg.quant_block if cfg.optimizer == "adam8bit" else 1,
        )
        if planner == "ragged":
            gplan = plan_group(local_specs, m, g_coll=LANE, align=align)
        else:
            gplan = planner_fn(local_specs, m)
        if ((store.quantized or sched.ef_enabled)
                and gplan.shard_size % store.block):
            raise ValueError(
                f"group {name}: planner mode {planner!r} produced shard "
                f"size {gplan.shard_size} not aligned to quant block "
                f"{store.block}; quantized stores and the q8_block reduce "
                f"wire need the ragged planner's align guarantee")
        entries[name] = GroupPlanEntry(
            name=name, tag=info.tag, policy=pol, local_specs=local_specs,
            plan=gplan, fsdp_axes=fsdp_axes,
            fsdp_axis_sizes=tuple(axis_sizes[a] for a in fsdp_axes),
            outer_axis=outer_axis, outer_size=outer_size,
            n_layers=gdef.n_layers, grad_sync_axes=grad_sync_axes,
            quant_block=cfg.quant_block,
            outer_dims={s.name: gdef.outer[s.name].dim
                        for s in gdef.specs if s.name in gdef.outer})

    unmatched = [r.selector() for i, r in enumerate(pset.rules)
                 if i not in matched]
    if unmatched:
        raise ValueError(
            f"policy rules matched no communication group: {unmatched}; "
            f"this model's groups: {sorted(entries)}")
    profile_name, profile_hash = "none", ""
    pricing: dict[str, dict[str, float]] = {}
    if policies == "auto":
        # record which profile priced the decisions (reproducibility +
        # drift detection) and the measured-vs-builtin price of each
        # chosen policy (describe() renders them side by side)
        cm = cost_model or CostModel.default()
        prof = cm.provenance_profile()
        profile_name, profile_hash = prof.name, prof.content_hash()
        builtin_cm = CostModel.default()
        for name, e in entries.items():
            pricing[name] = {
                "auto_ms": round(
                    _price_entry(cm, e, cd.itemsize) * 1e3, 6),
                "builtin_ms": round(
                    _price_entry(builtin_cm, e, cd.itemsize) * 1e3, 6),
            }
    return ShardingPlan(base=pset.default, groups=entries,
                        axis_sizes=axis_sizes, planner=planner,
                        compute_dtype=cd.name,
                        profile_name=profile_name,
                        profile_hash=profile_hash, pricing=pricing)


# alias for call sites where ``plan`` the name is taken by a local
make_plan = plan
