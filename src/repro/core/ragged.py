"""RaggedShard: the paper's flexible sharding format, as host-side metadata.

A RaggedShard placement of a tensor ``t`` is described by

  * a *sharding granularity* ``g_t``: the size (in contiguous elements, row-major)
    of the atomic non-shardable block, and
  * a *distribution*: which contiguous interval ``[l_t, r_t)`` of a global
    communication buffer the tensor occupies.  Device ``k`` of ``m`` owns the
    buffer interval ``[k*S, (k+1)*S)``, so a tensor may contribute *different
    numbers of blocks* to different devices -- that raggedness is the point.

In JAX the placement is static compile-time metadata: the flat group buffer is
a real array sharded with ``NamedSharding``/``shard_map`` over the FSDP mesh
axes, and ``unpack`` lowers to static slices (zero-copy in XLA: fusable,
aliasable, no interleaved gather like FSDP2's per-parameter layout).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping, Sequence

import numpy as np

# TPU lane width: collectives and VMEM tiles like multiples of 128 elements.
# This plays the role of NCCL's alignment unit (g_coll) in the paper.
LANE = 128


def _prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A logical tensor to be ragged-sharded.

    ``granularity`` is g_t: elements per atomic block.  Helpers:
      * granularity=1            -> element-wise (DeepSpeed/FSDP1-equivalent)
      * granularity=row_size     -> row-wise ragged
      * granularity=rows*row_sz  -> block-wise (e.g. 32 rows for 32x32 quant
                                    blocks over a d-multiple-of-32 matrix)
    """

    name: str
    shape: tuple[int, ...]
    dtype: str = "float32"
    granularity: int = 1

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        if self.granularity < 1:
            raise ValueError(f"{self.name}: granularity must be >= 1")
        if self.size % self.granularity != 0:
            raise ValueError(
                f"{self.name}: size {self.size} not divisible by granularity "
                f"{self.granularity}"
            )

    @property
    def size(self) -> int:
        return _prod(self.shape)

    @property
    def num_blocks(self) -> int:
        return self.size // self.granularity

    def row_size(self) -> int:
        return _prod(self.shape[1:]) if len(self.shape) > 1 else 1


def row_granularity(shape: Sequence[int], rows: int = 1) -> int:
    """Granularity of ``rows`` leading-dim rows (the paper's row-wise ragged)."""
    return rows * (_prod(shape[1:]) if len(shape) > 1 else 1)


@dataclasses.dataclass(frozen=True)
class Placement:
    """A planned RaggedShard placement: tensor ``spec`` lives at
    ``[offset, offset+spec.size)`` in the group's global buffer."""

    spec: TensorSpec
    offset: int

    @property
    def end(self) -> int:
        return self.offset + self.spec.size


@dataclasses.dataclass(frozen=True)
class LocalPiece:
    """The part of one tensor owned by one device.

    ``buf_lo:buf_hi`` index the device's *local* shard (size S);
    ``tensor_lo`` is where this piece begins inside the flat tensor.
    Planner guarantees (buf_hi-buf_lo) % granularity == 0 and
    tensor_lo % granularity == 0 -- i.e. whole blocks only.
    """

    name: str
    buf_lo: int
    buf_hi: int
    tensor_lo: int
    granularity: int

    @property
    def size(self) -> int:
        return self.buf_hi - self.buf_lo


@dataclasses.dataclass(frozen=True)
class Extent:
    """One contiguous piece of one tensor inside one uniform shard.

    ``shard`` is the FSDP shard index (0..num_shards); ``[lo, hi)`` indexes
    that shard's local buffer (size S); ``tensor_lo`` is where the piece
    begins inside the flat tensor.  A tensor's extents cover it exactly, in
    flat order -- the per-tensor shard index resharding streams through
    (see repro.core.reshard): ``tensor[tensor_lo : tensor_lo + hi - lo] ==
    shards[shard][lo:hi]`` for every extent, under ANY plan mode.
    """

    shard: int
    lo: int
    hi: int
    tensor_lo: int

    @property
    def size(self) -> int:
        return self.hi - self.lo

    def scaled(self, div: int) -> "Extent":
        """The same extent in ``div``-granular units (e.g. one quant scale
        per ``div`` elements).  ``lo`` and ``tensor_lo`` must be exact
        multiples (planner align); ``hi`` rounds up so a tensor's tail
        partial block keeps its scale."""
        if self.lo % div or self.tensor_lo % div:
            raise ValueError(
                f"extent (shard {self.shard}, lo {self.lo}, tensor_lo "
                f"{self.tensor_lo}) not aligned to block {div}; this layout "
                f"cannot carry block-granular state")
        return Extent(self.shard, self.lo // div, -(-self.hi // div),
                      self.tensor_lo // div)


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """Output of the planner for one communication group.

    The global buffer has ``num_shards * shard_size`` elements; device k owns
    ``[k*S, (k+1)*S)``.  ``placements`` are in buffer order and pairwise
    disjoint; gaps are padding (between tensors only, never inside one).
    """

    placements: tuple[Placement, ...]
    shard_size: int
    num_shards: int
    mode: str = "ragged"  # ragged | fsdp2 | megatron | naive

    # ---- sizes -----------------------------------------------------------
    @property
    def total(self) -> int:
        return self.shard_size * self.num_shards

    @property
    def payload(self) -> int:
        return sum(p.spec.size for p in self.placements)

    @property
    def padding(self) -> int:
        return self.total - self.payload

    @property
    def padding_ratio(self) -> float:
        return self.padding / max(self.payload, 1)

    def __post_init__(self):
        object.__setattr__(self, "placements", tuple(self.placements))

    # ---- lookups ---------------------------------------------------------
    def placement(self, name: str) -> Placement:
        for p in self.placements:
            if p.spec.name == name:
                return p
        raise KeyError(name)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(p.spec.name for p in self.placements)

    # ---- validation (the paper's three constraints) ----------------------
    def validate(self) -> None:
        S, m = self.shard_size, self.num_shards
        prev_end = 0
        for p in sorted(self.placements, key=lambda p: p.offset):
            if p.offset < prev_end:
                raise ValueError(f"{p.spec.name}: overlaps previous tensor")
            prev_end = p.end
            if p.end > m * S:
                raise ValueError(f"{p.spec.name}: exceeds global buffer")
            if self.mode != "ragged":
                continue  # baselines intentionally violate block constraints
            g = p.spec.granularity
            # every shard boundary strictly inside the tensor must be
            # block-aligned relative to the tensor start
            k0 = p.offset // S + 1
            k1 = (p.end - 1) // S
            for k in range(k0, k1 + 1):
                if (k * S - p.offset) % g != 0:
                    raise ValueError(
                        f"{p.spec.name}: shard boundary {k}*{S} splits a "
                        f"block (granularity {g})"
                    )

    # ---- per-device ragged layout ----------------------------------------
    def local_layout(self, device: int) -> tuple[LocalPiece, ...]:
        """Which (whole-block) pieces of which tensors live on ``device``."""
        S = self.shard_size
        lo, hi = device * S, (device + 1) * S
        pieces = []
        for p in self.placements:
            a, b = max(p.offset, lo), min(p.end, hi)
            if a >= b:
                continue
            pieces.append(
                LocalPiece(
                    name=p.spec.name,
                    buf_lo=a - lo,
                    buf_hi=b - lo,
                    tensor_lo=a - p.offset,
                    granularity=p.spec.granularity,
                )
            )
        return tuple(pieces)

    def tensor_extents(self, name: str) -> tuple[Extent, ...]:
        """The per-tensor shard index: every ``(shard, lo, hi, tensor_lo)``
        extent holding tensor ``name`` under this plan, in flat-tensor order.

        Pure placement arithmetic — no array data is touched.  Contiguous
        modes (ragged/megatron/naive) intersect the tensor interval with the
        uniform shard windows; fsdp2's interleaved layout yields one extent
        per shard chunk (matching DBuffer._pack_interleaved).
        """
        p = self.placement(name)
        S, m = self.shard_size, self.num_shards
        exts: list[Extent] = []
        if self.mode == "fsdp2":
            chunk = -(-p.spec.size // m)
            col = p.offset // m
            for k in range(m):
                t_lo = k * chunk
                n = min((k + 1) * chunk, p.spec.size) - t_lo
                if n <= 0:
                    break
                exts.append(Extent(k, col, col + n, t_lo))
        else:
            k0, k1 = p.offset // S, (p.end - 1) // S
            for k in range(k0, k1 + 1):
                a, b = max(p.offset, k * S), min(p.end, (k + 1) * S)
                exts.append(Extent(k, a - k * S, b - k * S, a - p.offset))
        return tuple(exts)

    def blocks_per_device(self) -> list[dict[str, int]]:
        """#blocks of each tensor per device -- the ragged distribution."""
        out = []
        for k in range(self.num_shards):
            counts = {}
            for piece in self.local_layout(k):
                counts[piece.name] = piece.size // piece.granularity
            out.append(counts)
        return out


# ---------------------------------------------------------------------------
# Composition with evenly-sharded DTensor placements (paper Fig. 5)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardDim:
    """An (outer) even sharding along one tensor dim over a mesh axis —
    the TP/EP placements RaggedShard composes with."""

    dim: int
    axis: str  # mesh axis name, e.g. "model"


def compose_granularity(spec: TensorSpec, outer: ShardDim | None,
                        axis_size: int) -> TensorSpec:
    """Adapt a TensorSpec for FSDP packing *after* an outer Shard(dim).

    Per the paper (§4): EP/TP is applied before FSDP, so the planner packs the
    TP/EP-*local* tensor.  For Shard(dim>0) the ragged granularity must never
    cut into that dim, so it becomes LCM(user granularity, stride of dim).
    For Shard(0) — StridedRaggedShard — the local tensor is a contiguous row
    range, so granularity passes through unchanged (the reshuffle metadata is
    carried by `StridedRagged` below).
    """
    if outer is None:
        return spec
    shape = list(spec.shape)
    if shape[outer.dim] % axis_size != 0:
        raise ValueError(
            f"{spec.name}: dim {outer.dim} (={shape[outer.dim]}) not divisible "
            f"by axis size {axis_size}"
        )
    shape[outer.dim] //= axis_size
    g = spec.granularity
    if outer.dim > 0:
        stride = _prod(shape[outer.dim:])  # local stride below the cut dim
        g = math.lcm(g, stride)
        g = min(g, _prod(shape))
        if _prod(shape) % g:
            g = stride  # fall back to dim-stride granularity
    return TensorSpec(spec.name, tuple(shape), spec.dtype, g)


@dataclasses.dataclass(frozen=True)
class StridedRagged:
    """Metadata for (RaggedShard, Shard(0)) composition.

    The logical tensor's dim-0 is first split over ``outer_axis`` (size n);
    each local part is then ragged-packed over the FSDP axis.  Materializing
    the full tensor therefore needs an all-gather over *both* axes plus a
    reorder: gathered layout is [outer0: rows 0..r, outer1: rows r..2r, ...]
    which is already the logical row order — the 'stride' bookkeeping is that
    offsets in the group buffer are per-outer-shard, not global.
    """

    name: str
    full_shape: tuple[int, ...]
    outer_axis: str
    outer_size: int


def checkpoint_index(plan: GroupPlan) -> dict:
    """A DCP-style index: name -> (shape, dtype, granularity, offset).

    RaggedShard inherits DTensor-based checkpointing (paper §4): this index
    plus the per-device local shard is enough to save/load without any
    communication (see repro.checkpoint.ckpt).
    """
    return {
        p.spec.name: dict(
            shape=list(p.spec.shape),
            dtype=p.spec.dtype,
            granularity=p.spec.granularity,
            offset=p.offset,
        )
        for p in plan.placements
    }
