"""Distributed Buffer (DBuffer): flat group buffers backing RaggedShard tensors.

The paper's DBuffer gives (1) global-buffer semantics over an N-D device
topology, (2) group-level fused ops instead of per-tensor kernel launches,
(3) zero-copy views of each tensor in the gathered buffer, (4) in-place
communication.  The JAX/TPU mapping:

  (1) the buffer is one jnp array, logically ``(m*S,)`` (or ``(L, m*S)`` for a
      scanned layer stack), sharded along the FSDP mesh axes with
      ``NamedSharding`` / ``shard_map`` specs;
  (2) group ops (zero/scale/axpy/cast) act on the flat array — XLA fuses them
      into one kernel by construction, the analogue of DBuffer's batched
      kernels;
  (3) ``unpack`` is static-slice + reshape over the planner's layout.  Because
      the planner keeps every tensor contiguous, XLA lowers these to views /
      fusions, not gathers.  The FSDP2 baseline layout (interleaved
      device-major chunks) goes through ``unpack`` too — there it lowers to a
      real strided copy, reproducing the paper's Copy-Out overhead;
  (4) in-place update = buffer donation on the train step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .ragged import GroupPlan, Placement


@dataclasses.dataclass(frozen=True)
class DBuffer:
    """Static descriptor binding a GroupPlan to array packing/unpacking."""

    plan: GroupPlan
    dtype: jnp.dtype = jnp.float32

    # ------------------------------------------------------------------ #
    # host-side packing (init / checkpoint)
    # ------------------------------------------------------------------ #
    def pack(self, arrays: Mapping[str, np.ndarray]) -> np.ndarray:
        """Dense pack of full tensors into the (total,) global buffer."""
        out = np.zeros(self.plan.total, dtype=self.dtype)
        m = self.plan.num_shards
        for p in self.plan.placements:
            a = np.asarray(arrays[p.spec.name], dtype=self.dtype).reshape(-1)
            if a.size != p.spec.size:
                raise ValueError(f"{p.spec.name}: size mismatch")
            if self.plan.mode == "fsdp2":
                self._pack_interleaved(out, p, a)
            else:
                out[p.offset : p.offset + a.size] = a
        return out

    def _pack_interleaved(self, out: np.ndarray, p: Placement, a: np.ndarray):
        """FSDP2 layout: tensor split into m even chunks, chunk k at
        [k*S + p.offset//m, ...) — device-major interleaving."""
        m, S = self.plan.num_shards, self.plan.shard_size
        chunk = -(-p.spec.size // m)
        col = p.offset // m
        padded = np.zeros(chunk * m, dtype=a.dtype)
        padded[: a.size] = a
        for k in range(m):
            out[k * S + col : k * S + col + chunk] = padded[
                k * chunk : (k + 1) * chunk
            ]

    def unpack_np(self, flat: np.ndarray) -> dict[str, np.ndarray]:
        """Host-side inverse of pack (checkpoint restore, tests)."""
        out = {}
        m, S = self.plan.num_shards, self.plan.shard_size
        for p in self.plan.placements:
            if self.plan.mode == "fsdp2":
                chunk = -(-p.spec.size // m)
                col = p.offset // m
                parts = [flat[k * S + col : k * S + col + chunk] for k in range(m)]
                a = np.concatenate(parts)[: p.spec.size]
            else:
                a = flat[p.offset : p.offset + p.spec.size]
            out[p.spec.name] = a.reshape(p.spec.shape)
        return out

    # ------------------------------------------------------------------ #
    # traced unpacking (inside jit / shard_map, after all-gather)
    # ------------------------------------------------------------------ #
    def unpack(self, flat: jax.Array,
               cast: jnp.dtype | None = None) -> dict[str, jax.Array]:
        """Materialize every tensor from the gathered global buffer.

        ragged/megatron/naive layouts: static contiguous slices (zero-copy in
        XLA).  fsdp2 layout: strided re-gather (the interleaved Copy-Out the
        paper measures in Table 1)."""
        out = {}
        m, S = self.plan.num_shards, self.plan.shard_size
        for p in self.plan.placements:
            if self.plan.mode == "fsdp2":
                chunk = -(-p.spec.size // m)
                col = p.offset // m
                mat = flat.reshape(m, S)[:, col : col + chunk]  # strided copy
                t = mat.reshape(m * chunk)[: p.spec.size]
            else:
                t = jax.lax.slice(flat, (p.offset,), (p.offset + p.spec.size,))
            t = t.reshape(p.spec.shape)
            if cast is not None:
                t = t.astype(cast)
            out[p.spec.name] = t
        return out

    def unpack_quant(self, payload, block: int,
                     compute_dtype) -> dict[str, jax.Array]:
        """Unpack a gathered q8_block wire payload (``{"codes",
        "scales"}``) per tensor WITHOUT a whole-buffer dequantize.

        Eligible 2-D tensors (``ops.quant_eligible``: separable scale
        layout; a trailing partial block is fine -- the ceil-count scales
        fold per row) come out as ``QuantTensor`` views of their codes +
        scales slices -- the dense weight never materializes,
        ``layers.dense`` routes them to the int8 GEMM
        (``ops.q8_matmul``).  Everything else gets a per-tensor fused
        dequant into the compute dtype.  Per-tensor payload slicing relies
        on the planner's align guarantee (tensor starts at quant-block
        multiples); the fsdp2 interleaved layout has no contiguous
        per-tensor payload, so it decodes the whole buffer and unpacks
        densely (the same Copy-Out it pays for dense unpacks)."""
        if self.plan.mode == "fsdp2":
            dense = ops.dequantize_into(payload["codes"], payload["scales"],
                                        block, out_dtype=compute_dtype)
            return self.unpack(dense)
        codes, scales = payload["codes"], payload["scales"]
        out = {}
        for p in self.plan.placements:
            off, size = p.offset, p.spec.size
            if off % block:
                raise ValueError(
                    f"{p.spec.name}: payload offset {off} not a multiple "
                    f"of quant block {block} -- planner align missing?")
            nb = -(-size // block)  # blocks covering the tensor (+ padding)
            c = jax.lax.slice(codes, (off,), (off + nb * block,))
            s = jax.lax.slice(scales, (off // block,),
                              (off // block + nb,))
            if ops.quant_eligible(p.spec.shape, block):
                k, n = p.spec.shape
                # overhang case: nb*block > size; the codes view keeps
                # exactly the tensor's elements, the ceil-count scales
                # stay (q8_matmul folds them per row, truncated at k)
                out[p.spec.name] = ops.QuantTensor(
                    jax.lax.slice(c, (0,), (size,)).reshape(k, n), s, block)
            else:
                t = ops.dequantize_into(c, s, block, out_dtype=compute_dtype)
                out[p.spec.name] = jax.lax.slice(
                    t, (0,), (size,)).reshape(p.spec.shape)
        return out

    def pack_traced(self, arrays: Mapping[str, jax.Array]) -> jax.Array:
        """Traced pack (e.g. repacking gradients in non-autodiff paths)."""
        flat = jnp.zeros(self.plan.total, dtype=self.dtype)
        for p in self.plan.placements:
            a = arrays[p.spec.name].astype(self.dtype).reshape(-1)
            flat = jax.lax.dynamic_update_slice(flat, a, (p.offset,))
        return flat

    # ------------------------------------------------------------------ #
    # group-fused elementwise ops (paper: batched kernels before collectives)
    # ------------------------------------------------------------------ #
    @staticmethod
    def group_zero(buf: jax.Array) -> jax.Array:
        return jnp.zeros_like(buf)

    @staticmethod
    def group_scale(buf: jax.Array, c) -> jax.Array:
        return buf * c

    @staticmethod
    def group_axpy(a, x: jax.Array, y: jax.Array) -> jax.Array:
        return a * x + y

    # ------------------------------------------------------------------ #
    def init(self, rng: np.random.Generator,
             init_fns: Mapping[str, Callable[..., np.ndarray]] | None = None,
             default_scale: float = 0.02) -> np.ndarray:
        """Host-side parameter init into the packed layout."""
        arrays = {}
        for p in self.plan.placements:
            fn = (init_fns or {}).get(p.spec.name)
            if fn is not None:
                arrays[p.spec.name] = fn(rng, p.spec.shape)
            elif len(p.spec.shape) >= 2:
                arrays[p.spec.name] = rng.normal(
                    0.0, default_scale, size=p.spec.shape
                ).astype(np.float32)
            elif "scale" in p.spec.name or "norm" in p.spec.name:
                arrays[p.spec.name] = np.ones(p.spec.shape, np.float32)
            else:
                arrays[p.spec.name] = np.zeros(p.spec.shape, np.float32)
        return self.pack(arrays)
