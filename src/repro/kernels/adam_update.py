"""Pallas TPU kernel: fused AdamW step over the flat DBuffer shard.

One VMEM pass reads (w, g, m, v, wd_mask) and writes (w', m', v') -- 5 HBM
streams in, 3 out, versus ~12 round trips for the unfused jnp chain.  This
is the DBuffer group-fused optimizer claim made concrete for TPU.

Scalars (lr, beta-corrections) arrive as a (8,) f32 array broadcast to every
tile (simple + interpret-friendly; SMEM prefetch would shave a copy on real
hardware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
TILE_ROWS = 64  # 64 x 128 x 4B x 8 bufs = 256 KiB VMEM working set


def _adamw_kernel(s_ref, w_ref, g_ref, m_ref, v_ref, mask_ref,
                  w_out, m_out, v_out):
    lr, b1, b2, eps, wd, c1, c2, _ = [s_ref[i] for i in range(8)]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
    w = w_ref[...]
    w_out[...] = w - lr * (upd + wd * mask_ref[...] * w)
    m_out[...] = m
    v_out[...] = v


@functools.partial(jax.jit, static_argnames=("interpret",))
def adamw_update(w, g, m, v, mask, lr, b1, b2, eps, wd, c1, c2,
                 *, interpret: bool = False):
    """All arrays flat (n,) with n % 128 == 0 (DBuffer lane alignment)."""
    n = w.size
    rows = n // LANES
    tr = min(TILE_ROWS, rows)
    scalars = jnp.stack([
        jnp.asarray(x, jnp.float32)
        for x in (lr, b1, b2, eps, wd, c1, c2, 0.0)
    ])

    def r(x, dt=jnp.float32):
        return x.reshape(rows, LANES).astype(dt)

    outs = pl.pallas_call(
        _adamw_kernel,
        grid=(pl.cdiv(rows, tr),),
        in_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((tr, LANES), lambda i: (i, 0)),
            pl.BlockSpec((tr, LANES), lambda i: (i, 0)),
            pl.BlockSpec((tr, LANES), lambda i: (i, 0)),
            pl.BlockSpec((tr, LANES), lambda i: (i, 0)),
            pl.BlockSpec((tr, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tr, LANES), lambda i: (i, 0)),
            pl.BlockSpec((tr, LANES), lambda i: (i, 0)),
            pl.BlockSpec((tr, LANES), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.float32)] * 3,
        interpret=interpret,
    )(scalars, r(w), r(g), r(m), r(v), r(mask))
    return tuple(o.reshape(w.shape) for o in outs)
