"""Pallas TPU kernel: block-wise INT8 quantize / dequantize.

The paper's 8-bit Adam path quantizes each device's *local shard* in fixed
blocks (32x32 == 1024 flat elements), which RaggedShard's planner guarantees
never straddle tensors or device boundaries.  This is bandwidth-bound
elementwise work -- exactly what wants a fused VMEM pass.

Layout: x is viewed as (n_blocks, block); one grid row handles TILE_BLOCKS
quant blocks.  block is a multiple of 128 (lane width); TILE_BLOCKS x block
tiles fit comfortably in VMEM (default 8 x 1024 x 4B = 32 KiB per ref).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCKS = 8


def _quant_kernel(x_ref, codes_ref, scales_ref):
    x = x_ref[...].astype(jnp.float32)           # (TB, block)
    absmax = jnp.max(jnp.abs(x), axis=1)         # (TB,)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    codes = jnp.clip(jnp.round(x * inv[:, None]), -127, 127)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale


def _dequant_kernel(codes_ref, scales_ref, out_ref):
    out_ref[...] = (
        codes_ref[...].astype(jnp.float32) * scales_ref[...][:, None]
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize(x, *, block: int = 1024, interpret: bool = False):
    """x: (..., n) with n % block == 0 -> (codes int8 like x, scales f32
    (..., n//block))."""
    shape = x.shape
    n = shape[-1]
    nb = n // block
    lead = 1
    for s in shape[:-1]:
        lead *= s
    xb = x.reshape(lead * nb, block)
    total = lead * nb
    tb = min(TILE_BLOCKS, total)
    grid = (pl.cdiv(total, tb),)
    codes, scales = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tb, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tb, block), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total, block), jnp.int8),
            jax.ShapeDtypeStruct((total,), jnp.float32),
        ],
        interpret=interpret,
    )(xb)
    return codes.reshape(shape), scales.reshape(shape[:-1] + (nb,))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize(codes, scales, *, block: int = 1024,
               interpret: bool = False):
    shape = codes.shape
    n = shape[-1]
    nb = n // block
    lead = 1
    for s in shape[:-1]:
        lead *= s
    cb = codes.reshape(lead * nb, block)
    sb = scales.reshape(lead * nb)
    total = lead * nb
    tb = min(TILE_BLOCKS, total)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(pl.cdiv(total, tb),),
        in_specs=[
            pl.BlockSpec((tb, block), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tb, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((total, block), jnp.float32),
        interpret=interpret,
    )(cb, sb)
    return out.reshape(shape)
