"""Pallas TPU kernel: block-wise INT8 quantize / dequantize.

The paper's 8-bit Adam path quantizes each device's *local shard* in fixed
blocks (32x32 == 1024 flat elements), which RaggedShard's planner guarantees
never straddle tensors or device boundaries.  This is bandwidth-bound
elementwise work -- exactly what wants a fused VMEM pass.

Layout: x is viewed as (n_blocks, block); one grid row handles ``tile``
quant blocks.  block is a multiple of 128 (lane width); TILE_BLOCKS x block
tiles fit comfortably in VMEM (default 8 x 1024 x 4B = 32 KiB per ref).

Tiling rule (``_resolve_tile``): compiled (TPU) runs the TILE_BLOCKS grid;
interpret mode (the CPU container, where the grid is unrolled by the
interpreter) defaults to ONE full-width tile -- the kernel body applied to
the whole (n_blocks, block) view, which is bitwise identical and keeps the
trace linear in ops, not in grid steps.  Tests pass ``tile_blocks=`` to
force the tiled grid in interpret mode and exercise the cdiv overhang
(partial last tile): per-block absmax has no cross-row dataflow and Pallas
pads reads / clips writes, so the overhang needs no masking -- pinned by
the partial-tile parity suite in tests/test_kernels.py.

Contract: ``n % block != 0``, ``block < 1``, and a scales/blocks mismatch
raise the same ValueError as the jnp reference (the checks are shared with
``quant.blockwise``), instead of failing later with a cryptic reshape
error.

``dequantize_into`` is the gather-path fused kernel: codes + scales ->
*compute dtype* in one pass, so no full-size fp32 buffer exists between
the dequant multiply and the cast (the jaxpr regression in
tests/test_kernels_fused.py pins this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant.blockwise import _check_blocking, _check_scales

TILE_BLOCKS = 8


def _resolve_tile(total: int, interpret: bool,
                  tile_blocks: int | None) -> int:
    """Blocks per grid row: explicit override > full-width (interpret) >
    TILE_BLOCKS (compiled)."""
    if tile_blocks is not None:
        return max(1, min(tile_blocks, total))
    if interpret:
        return max(1, total)
    return max(1, min(TILE_BLOCKS, total))


def _quant_kernel(x_ref, codes_ref, scales_ref):
    x = x_ref[...].astype(jnp.float32)           # (TB, block)
    absmax = jnp.max(jnp.abs(x), axis=1)         # (TB,)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    codes = jnp.clip(jnp.round(x * inv[:, None]), -127, 127)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale


def _dequant_kernel(out_dtype, codes_ref, scales_ref, out_ref):
    # one fused pass: int8 -> f32 multiply -> target dtype, never writing
    # the f32 product to memory (out_ref IS the compute-dtype buffer)
    out_ref[...] = (
        codes_ref[...].astype(jnp.float32) * scales_ref[...][:, None]
    ).astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "interpret", "tile_blocks"))
def quantize(x, *, block: int = 1024, interpret: bool = False,
             tile_blocks: int | None = None):
    """x: (..., n) with n % block == 0 -> (codes int8 like x, scales f32
    (..., n//block))."""
    shape = x.shape
    n = shape[-1]
    _check_blocking(n, block, "quantize")
    nb = n // block
    lead = 1
    for s in shape[:-1]:
        lead *= s
    xb = x.reshape(lead * nb, block)
    total = lead * nb
    tb = _resolve_tile(total, interpret, tile_blocks)
    grid = (pl.cdiv(total, tb),)
    codes, scales = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tb, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tb, block), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total, block), jnp.int8),
            jax.ShapeDtypeStruct((total,), jnp.float32),
        ],
        interpret=interpret,
    )(xb)
    return codes.reshape(shape), scales.reshape(shape[:-1] + (nb,))


@functools.partial(jax.jit,
                   static_argnames=("block", "out_dtype", "interpret",
                                    "tile_blocks"))
def dequantize_into(codes, scales, *, block: int = 1024,
                    out_dtype=jnp.float32, interpret: bool = False,
                    tile_blocks: int | None = None):
    """Fused dequant-into-compute-dtype: codes + scales -> ``out_dtype``
    in one VMEM pass (the all-gather decode hot path).  With
    out_dtype=float32 this is the plain dequantize."""
    shape = codes.shape
    n = shape[-1]
    _check_blocking(n, block, "dequantize")
    nb = n // block
    _check_scales(n, block, scales.shape[-1], "dequantize")
    lead = 1
    for s in shape[:-1]:
        lead *= s
    cb = codes.reshape(lead * nb, block)
    sb = scales.reshape(lead * nb)
    total = lead * nb
    tb = _resolve_tile(total, interpret, tile_blocks)
    out_dtype = jnp.dtype(out_dtype)
    out = pl.pallas_call(
        functools.partial(_dequant_kernel, out_dtype),
        grid=(pl.cdiv(total, tb),),
        in_specs=[
            pl.BlockSpec((tb, block), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tb, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((total, block), out_dtype),
        interpret=interpret,
    )(cb, sb)
    return out.reshape(shape)


def dequantize(codes, scales, *, block: int = 1024, interpret: bool = False,
               tile_blocks: int | None = None):
    """f32 dequantize (the pre-fusion signature, kept for the optimizer
    paths that want the fp32 buffer anyway)."""
    return dequantize_into(codes, scales, block=block,
                           out_dtype=jnp.float32, interpret=interpret,
                           tile_blocks=tile_blocks)
