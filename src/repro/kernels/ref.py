"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax.numpy as jnp

from ..quant.blockwise import (
    dequantize_blockwise, dequantize_blockwise_log, quantize_blockwise,
    quantize_blockwise_log,
)


def quantize_ref(x, block: int):
    return quantize_blockwise(x, block)


def dequantize_ref(codes, scales, block: int):
    return dequantize_blockwise(codes, scales, block)


def dequantize_into_ref(codes, scales, block: int, out_dtype):
    """Unfused gather-path decode: f32 dequant buffer, THEN the cast --
    exactly what the fused kernel eliminates (same values, one more
    full-size fp32 materialization)."""
    return dequantize_blockwise(codes, scales, block).astype(out_dtype)


def encode_ef_ref(ct, ef, block: int):
    """Unfused reduce-path encode + error feedback (the op sequence
    core.wire ran before fusion): returns (codes, scales, new_ef)."""
    comp = ct.astype(jnp.float32) + ef
    codes, scales = quantize_blockwise(comp, block)
    new_ef = comp - dequantize_blockwise(codes, scales, block)
    return codes, scales, new_ef


def q8_matmul_ref(x, codes, scales, block: int, out_dtype=None):
    """Dense semantic oracle for the int8-GEMM path: dequantize the whole
    weight, matmul in f32.  The kernel is ALLCLOSE to this (activation
    row-quantization error), never bitwise."""
    k, n = codes.shape
    w = dequantize_blockwise(codes.reshape(-1), scales, block).reshape(k, n)
    y = x.astype(jnp.float32) @ w
    return y.astype(out_dtype if out_dtype is not None else x.dtype)


def adamw_update_ref(w, g, m, v, mask, lr, b1, b2, eps, wd, c1, c2):
    g = g.astype(jnp.float32)
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    upd = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
    w2 = w - lr * (upd + wd * mask * w)
    return w2, m2, v2


def adam8bit_update_ref(w, g, m8, v8, ms, vs, mask, lr, b1, b2, eps, wd,
                        c1, c2, block: int):
    m = dequantize_blockwise(m8, ms, block)
    v = dequantize_blockwise_log(v8, vs, block)
    w2, m2, v2 = adamw_update_ref(w, g, m, v, mask, lr, b1, b2, eps, wd,
                                  c1, c2)
    m8o, mso = quantize_blockwise(m2, block)
    v8o, vso = quantize_blockwise_log(v2, block)
    return w2, m8o, v8o, mso, vso
