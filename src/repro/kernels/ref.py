"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax.numpy as jnp

from ..quant.blockwise import (
    dequantize_blockwise, dequantize_blockwise_log, quantize_blockwise,
    quantize_blockwise_log,
)


def quantize_ref(x, block: int):
    return quantize_blockwise(x, block)


def dequantize_ref(codes, scales, block: int):
    return dequantize_blockwise(codes, scales, block)


def dequantize_into_ref(codes, scales, block: int, out_dtype):
    """Unfused gather-path decode: f32 dequant buffer, THEN the cast --
    exactly what the fused kernel eliminates (same values, one more
    full-size fp32 materialization)."""
    return dequantize_blockwise(codes, scales, block).astype(out_dtype)


def encode_ef_ref(ct, ef, block: int):
    """Unfused reduce-path encode + error feedback (the op sequence
    core.wire ran before fusion): returns (codes, scales, new_ef)."""
    comp = ct.astype(jnp.float32) + ef
    codes, scales = quantize_blockwise(comp, block)
    new_ef = comp - dequantize_blockwise(codes, scales, block)
    return codes, scales, new_ef


def q8_matmul_ref(x, codes, scales, block: int, out_dtype=None):
    """Dense semantic oracle for the int8-GEMM path: dequantize the whole
    weight, matmul in f32.  The kernel is ALLCLOSE to this (activation
    row-quantization error), never bitwise."""
    k, n = codes.shape
    w = dequantize_blockwise(codes.reshape(-1), scales, block).reshape(k, n)
    y = x.astype(jnp.float32) @ w
    return y.astype(out_dtype if out_dtype is not None else x.dtype)


def adamw_update_ref(w, g, m, v, mask, lr, b1, b2, eps, wd, c1, c2):
    g = g.astype(jnp.float32)
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    upd = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
    w2 = w - lr * (upd + wd * mask * w)
    return w2, m2, v2


def adam8bit_update_ref(w, g, m8, v8, ms, vs, mask, lr, b1, b2, eps, wd,
                        c1, c2, block: int):
    m = dequantize_blockwise(m8, ms, block)
    v = dequantize_blockwise_log(v8, vs, block)
    w2, m2, v2 = adamw_update_ref(w, g, m, v, mask, lr, b1, b2, eps, wd,
                                  c1, c2)
    m8o, mso = quantize_blockwise(m2, block)
    v8o, vso = quantize_blockwise_log(v2, block)
    return w2, m8o, v8o, mso, vso


def store_pack_ref(w2_f32, fmt: str, block: int):
    """Unfused ``ParamStore.rebuild`` semantics on an updated fp32 buffer:
    the storage re-encode the fused update kernels fold into their
    epilogue (bare array for flat formats, codes(+scales)+master dict for
    fp8/q8)."""
    if fmt == "fp32":
        return w2_f32
    if fmt == "bf16":
        return w2_f32.astype(jnp.bfloat16)
    if fmt.startswith("fp8_"):
        from ..compat import float8_dtypes

        return {"codes": w2_f32.astype(float8_dtypes()[fmt]),
                "master": w2_f32}
    if fmt == "q8_block":
        codes, scales = quantize_blockwise(w2_f32, block)
        return {"codes": codes, "master": w2_f32, "scales": scales}
    raise ValueError(f"unknown store fmt {fmt!r}")


def adamw_store_update_ref(w, g, m, v, mask, lr, b1, b2, eps, wd, c1, c2,
                           fmt: str, block: int):
    """Unfused oracle for the fused AdamW + store-rebuild kernel: the
    update math on the fp32 view of the storage buffer, THEN the store
    re-encode as a second full pass."""
    w2, m2, v2 = adamw_update_ref(w.astype(jnp.float32), g, m, v, mask,
                                  lr, b1, b2, eps, wd, c1, c2)
    return store_pack_ref(w2, fmt, block), m2, v2


def adam8bit_store_update_ref(w, g, m8, v8, ms, vs, mask, lr, b1, b2, eps,
                              wd, c1, c2, fmt: str, block: int):
    """Unfused oracle for the fused 8-bit Adam + store-rebuild kernel."""
    w2, m8o, v8o, mso, vso = adam8bit_update_ref(
        w.astype(jnp.float32), g, m8, v8, ms, vs, mask, lr, b1, b2, eps,
        wd, c1, c2, block)
    return store_pack_ref(w2, fmt, block), m8o, v8o, mso, vso
