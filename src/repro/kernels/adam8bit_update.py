"""Pallas TPU kernel: fused 8-bit Adam step (paper §6.3).

Per tile of quant blocks: dequantize(m8, v8) -> Adam math -> weight update
-> requantize, all in one VMEM residency.  The unfused path round-trips the
dequantized fp32 moments through HBM twice; fusing keeps the moments at
int8 in HBM (the whole point of 8-bit Adam) *and* avoids the fp32 spill.

Grid row = TILE_BLOCKS quant blocks of ``block`` elements; scales are one
f32 per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCKS = 8


_RANGE_NATS = 24.0  # keep in sync with repro.quant.blockwise.RANGE_NATS


def _requant(x):
    absmax = jnp.max(jnp.abs(x), axis=1)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    codes = jnp.clip(jnp.round(x * inv[:, None]), -127, 127).astype(jnp.int8)
    return codes, scale


def _requant_log(x):
    """Non-negative log-space requant (second moment: linear int8 underflows
    and explodes the update; see repro.quant.blockwise)."""
    absmax = jnp.max(x, axis=1)
    safe = x / jnp.maximum(absmax[:, None], 1e-38)
    logq = jnp.log(jnp.maximum(safe, 1e-38)) / _RANGE_NATS
    codes = jnp.round(127.0 * (1.0 + logq))
    codes = jnp.where(x > 0, jnp.clip(codes, 1, 127), 0).astype(jnp.int8)
    return codes, absmax


def _dequant_log(codes, scales):
    c = codes.astype(jnp.float32)
    val = jnp.exp((c - 127.0) / 127.0 * _RANGE_NATS) * scales[:, None]
    return jnp.where(c > 0, val, 0.0)


def _adam8_kernel(s_ref, w_ref, g_ref, m8_ref, v8_ref, ms_ref, vs_ref,
                  mask_ref, w_out, m8_out, v8_out, ms_out, vs_out):
    lr, b1, b2, eps, wd, c1, c2, _ = [s_ref[i] for i in range(8)]
    g = g_ref[...].astype(jnp.float32)
    m = m8_ref[...].astype(jnp.float32) * ms_ref[...][:, None]
    v = _dequant_log(v8_ref[...], vs_ref[...])
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
    w = w_ref[...]
    w_out[...] = w - lr * (upd + wd * mask_ref[...] * w)
    m8, ms = _requant(m)
    v8, vs = _requant_log(v)
    m8_out[...] = m8
    v8_out[...] = v8
    ms_out[...] = ms
    vs_out[...] = vs


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def adam8bit_update(w, g, m8, v8, ms, vs, mask, lr, b1, b2, eps, wd, c1, c2,
                    *, block: int = 1024, interpret: bool = False):
    """Flat (n,) arrays, n % block == 0; ms/vs are (n//block,)."""
    n = w.size
    nb = n // block
    tb = min(TILE_BLOCKS, nb)
    scalars = jnp.stack([
        jnp.asarray(x, jnp.float32)
        for x in (lr, b1, b2, eps, wd, c1, c2, 0.0)
    ])

    def r(x, dt):
        return x.reshape(nb, block).astype(dt)

    blk = lambda: pl.BlockSpec((tb, block), lambda i: (i, 0))
    vec = lambda: pl.BlockSpec((tb,), lambda i: (i,))
    outs = pl.pallas_call(
        _adam8_kernel,
        grid=(pl.cdiv(nb, tb),),
        in_specs=[pl.BlockSpec((8,), lambda i: (0,)),
                  blk(), blk(), blk(), blk(), vec(), vec(), blk()],
        out_specs=[blk(), blk(), blk(), vec(), vec()],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, r(w, jnp.float32), r(g, jnp.float32), r(m8, jnp.int8),
      r(v8, jnp.int8), ms.reshape(nb), vs.reshape(nb), r(mask, jnp.float32))
    w2, m8o, v8o, mso, vso = outs
    return (w2.reshape(w.shape), m8o.reshape(w.shape), v8o.reshape(w.shape),
            mso.reshape(ms.shape), vso.reshape(vs.shape))
