"""Pallas TPU kernel: int8 x int8 matmul on gathered q8_block codes.

The serve/decode hot path with ``param_store="q8_block"`` previously
dequantized every gathered layer to the compute dtype before its matmuls.
This kernel keeps the weight in int8 end to end (the rtp-llm dequant-GEMM
pattern): the per-block weight scale is folded into the *activation*, the
scaled activation is quantized per row, and the MXU contracts int8 x int8
into int32.

Scale algebra.  A (K, N) weight is stored row-major in the flat buffer, so
quant block ``b`` covers flat elements [b*block, (b+1)*block) and the
dequant scale of element (k, n) varies along the contraction index k --
a post-hoc rescale of an int8 GEMM is impossible.  Two layouts make the
scale separable per output-column group j (both produced by the planner's
block-aligned tensor starts):

  * case A -- ``N % block == 0``: each row k holds nj = N/block blocks;
    block j of row k covers columns [j*block, (j+1)*block), scale
    s(k, j) = scales[k*nj + j].
  * case B -- ``block % N == 0``: one block spans r = block/N whole rows,
    s(k) = scales[k // r] independent of n (nj = 1).  K need NOT be a
    multiple of r: a trailing partial block (ceil(K/r) scales) folds to
    per-row scales truncated at K -- the codes and scales are the
    buffer's own, so the dequant semantics match the fallback path
    bitwise whatever shares the overhang block.

Both cases reduce to one contract: scales arranged (nj, K); for group j,
``y[:, cols_j] = rowquant(x * s[j]) @ codes[:, cols_j]`` rescaled by the
activation row scale.  Shapes outside these two cases are ineligible
(``quant_eligible``) and fall back to the fused dequantize.

``q8_slice_cols`` slices columns out of a QuantTensor when the scale
layout permits (case B -> per-row scales, any slice; case A -> block-
aligned slices), so the serve path's KV head slicing stays on the int8
GEMM instead of densifying the whole projection.

Parity class: ALLCLOSE vs the dense reference (x @ dequantize(w)) -- the
activation row-quantization is new error by design, bounded by ~1/254
relative per element.  The kernel-vs-jnp-equivalent comparison is bitwise
(same op sequence); both are pinned in tests/test_kernels_fused.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant.blockwise import _check_blocking, _check_scales
from .blockwise_quant import _resolve_tile  # noqa: F401  (shared tiling doc)


def quant_eligible(shape: tuple[int, ...], block: int) -> bool:
    """Can a tensor of ``shape`` run the int8-GEMM path with this quant
    block?  2-D with a separable scale layout: N % block == 0 (case A)
    or block % N == 0 (case B; K need not be a multiple of block//N --
    the trailing partial block folds to truncated per-row scales)."""
    if len(shape) != 2:
        return False
    k, n = shape
    return n % block == 0 or block % n == 0


def fold_scales(scales_flat, k: int, n: int, block: int) -> jax.Array:
    """Rearrange flat row-major block scales into the kernel's (nj, K)
    contract (see module docstring)."""
    if n % block == 0:
        nj = n // block
        return scales_flat.reshape(k, nj).T           # s[j, k]
    if block % n == 0:
        r = block // n
        # ceil(k/r) scales cover k rows; truncate the overhang block's
        # repeat at k (partial last block, see module docstring)
        return jnp.repeat(scales_flat, r)[:k].reshape(1, k)
    raise ValueError(
        f"q8_matmul: weight ({k}, {n}) has no separable scale layout for "
        f"block {block} (need N % block == 0 or block % N == 0)")


def _q8mm_kernel(out_dtype, x_ref, s_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                # (M, K)
    a = x * s_ref[...]                                # fold w-scales, (M, K)
    rmax = jnp.max(jnp.abs(a), axis=1)                # per-row absmax
    rs = rmax / 127.0
    inv = jnp.where(rs > 0, 1.0 / jnp.maximum(rs, 1e-30), 0.0)
    a8 = jnp.clip(jnp.round(a * inv[:, None]), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(
        a8, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)             # int8 x int8 -> int32
    o_ref[...] = (acc.astype(jnp.float32) * rs[:, None]).astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "out_dtype", "interpret"))
def q8_matmul(x, codes, scales, *, block: int = 1024, out_dtype=None,
              interpret: bool = False):
    """x: (..., K) float; codes: (K, N) int8; scales: flat f32
    ((K*N)//block,) row-major block scales.  Returns (..., N) in
    ``out_dtype`` (default: x.dtype) without ever materializing the
    dequantized weight."""
    k, n = codes.shape
    if n % block == 0:
        _check_blocking(k * n, block, "q8_matmul")
        _check_scales(k * n, block, scales.shape[-1], "q8_matmul")
    elif block % n == 0:
        # case B tolerates a trailing partial block: ceil-count scales
        nb = -(-(k * n) // block)
        if scales.shape[-1] != nb:
            raise ValueError(
                f"q8_matmul: expected {nb} block scales for ({k}, {n}) "
                f"with block {block}, got {scales.shape[-1]}")
    else:
        raise ValueError(
            f"q8_matmul: weight ({k}, {n}) has no separable scale layout "
            f"for block {block} (need N % block == 0 or block % N == 0)")
    out_dtype = jnp.dtype(out_dtype if out_dtype is not None else x.dtype)
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    xm = x.reshape(m, k)
    s2 = fold_scales(scales, k, n, block)             # (nj, K)
    nj = s2.shape[0]
    ncols = n // nj
    out = pl.pallas_call(
        functools.partial(_q8mm_kernel, out_dtype),
        grid=(nj,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((1, k), lambda j: (j, 0)),
            pl.BlockSpec((k, ncols), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, ncols), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(xm, s2, codes)
    return out.reshape(lead + (n,))


# --------------------------------------------------------------------------- #
# QuantTensor: a gathered-but-still-quantized weight view
# --------------------------------------------------------------------------- #
class QuantTensor:
    """A 2-D weight as int8 codes + flat block scales, as unpacked from a
    gathered q8_block buffer (core.dbuffer.unpack_quant).  Model code
    multiplies through ``layers.dense`` -> ``ops.q8_matmul`` so the dense
    weight never materializes.  Registered as a pytree (codes/scales are
    leaves, block is static) so it traces through scan/jit."""

    __slots__ = ("codes", "scales", "block")

    def __init__(self, codes, scales, block: int):
        self.codes = codes
        self.scales = scales
        self.block = int(block)

    @property
    def shape(self):
        return self.codes.shape

    @property
    def ndim(self):
        return self.codes.ndim

    def __repr__(self):
        return (f"QuantTensor(shape={tuple(self.codes.shape)}, "
                f"block={self.block})")


jax.tree_util.register_pytree_node(
    QuantTensor,
    lambda qt: ((qt.codes, qt.scales), qt.block),
    lambda block, leaves: QuantTensor(leaves[0], leaves[1], block),
)


def q8_slice_cols(qt: QuantTensor, start, width: int):
    """Slice columns [start, start + width) out of a (K, N) QuantTensor
    without densifying, when the scale layout permits:

      * case B (``block % N == 0``): the block scale never varies along
        n, so ANY column slice keeps the layout.  Re-expressed with
        per-row scales (new block = width, nj = 1), truncating the
        overhang block's repeat at K -- dequant values are exactly those
        of the sliced dense weight.  ``start`` may be traced (the serve
        path slices by a ``lax.axis_index``-derived KV head).
      * case A (``N % block == 0``): only whole-block slices are
        representable -- requires ``width % block == 0`` and ``start``
        a block multiple.  A traced ``start`` is accepted under the
        caller contract ``start % width == 0`` (head slicing), which
        implies block alignment when ``width % block == 0``.

    Returns the sliced QuantTensor, or None when the slice is not
    scale-representable (caller falls back to ``to_dense``).
    """
    k, n = qt.codes.shape
    block = qt.block
    width = int(width)
    if not 0 < width <= n:
        raise ValueError(
            f"q8_slice_cols: width {width} out of range for N={n}")
    if block % n == 0:
        r = block // n
        row_scales = jnp.repeat(qt.scales, r)[:k]
        codes = jax.lax.dynamic_slice(qt.codes, (0, start), (k, width))
        return QuantTensor(codes, row_scales, width)
    if n % block == 0 and width % block == 0:
        if isinstance(start, int) and start % block:
            return None
        nj = n // block
        codes = jax.lax.dynamic_slice(qt.codes, (0, start), (k, width))
        s2 = jax.lax.dynamic_slice(qt.scales.reshape(k, nj),
                                   (0, start // block),
                                   (k, width // block))
        return QuantTensor(codes, s2.reshape(-1), block)
    return None
