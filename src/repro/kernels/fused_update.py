"""Pallas TPU kernels: fused optimizer step + ParamStore rebuild.

The optimizers used to run the update as an unfused jnp chain -- gather
the fp32 master view, do the Adam math, then hand the result to
``store.rebuild`` (a second full pass for bf16 rounding, the fp8 cast, or
the q8 blockwise requantize).  These kernels fuse the whole group update
into one VMEM residency per tile: grad-apply + moment update + weight
write + the store re-encode, so the updated fp32 weights never round-trip
HBM between the math and the encode (the 8-to-12-stream win
``bench_kernels.py`` prices).

Four store epilogues, one math core:

  * fp32      -- write w' as-is (bitwise the pre-fusion path).
  * bf16      -- round w' to bf16 in-register (the storage buffer).
  * fp8_*     -- emit fp8 codes + the fp32 master in one pass (dtypes via
                 ``compat.float8_dtypes``: no versioned jnp symbols here).
  * q8_block  -- blockwise absmax requantize in-register (the same
                 ``_requant`` the fused 8-bit Adam kernel uses, bitwise
                 identical to ``ops.quantize``).

Tiling: flat epilogues run (rows, 128) lane tiles over the flat shard,
zero-padding the tail lane (elementwise math on zero inputs stays zero,
so the pad is inert and sliced back off); block epilogues run
(TILE_BLOCKS, block) tiles and require the planner's align guarantee
(shard last dim % block == 0).  Interpret mode (non-TPU) runs ONE
full-width tile per the kernels doctrine (blockwise_quant._resolve_tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import float8_dtypes
from .adam8bit_update import _dequant_log, _requant, _requant_log
from .blockwise_quant import _resolve_tile

LANES = 128
TILE_ROWS = 64  # flat-epilogue grid rows (matches adam_update.py)


def _tile_rows(rows: int, interpret: bool) -> int:
    return max(1, rows) if interpret else max(1, min(TILE_ROWS, rows))


def _scalar_stack(lr, b1, b2, eps, wd, c1, c2):
    return jnp.stack([jnp.asarray(x, jnp.float32)
                      for x in (lr, b1, b2, eps, wd, c1, c2, 0.0)])


# --------------------------------------------------------------------------- #
# shared in-kernel math (op-for-op kernels/ref.py's adamw_update_ref)
# --------------------------------------------------------------------------- #
def _adam_math(s_ref, w_ref, g_ref, m_ref, v_ref, mask_ref):
    lr, b1, b2, eps, wd, c1, c2, _ = [s_ref[i] for i in range(8)]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
    w = w_ref[...].astype(jnp.float32)
    w2 = w - lr * (upd + wd * mask_ref[...] * w)
    return w2, m, v


def _adam8_math(s_ref, w_ref, g_ref, m8_ref, v8_ref, ms_ref, vs_ref,
                mask_ref):
    lr, b1, b2, eps, wd, c1, c2, _ = [s_ref[i] for i in range(8)]
    g = g_ref[...].astype(jnp.float32)
    m = m8_ref[...].astype(jnp.float32) * ms_ref[...][:, None]
    v = _dequant_log(v8_ref[...], vs_ref[...])
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
    w = w_ref[...].astype(jnp.float32)
    w2 = w - lr * (upd + wd * mask_ref[...] * w)
    return w2, m, v


# --------------------------------------------------------------------------- #
# AdamW epilogues
# --------------------------------------------------------------------------- #
def _adamw_flat_kernel(out_dt, s_ref, w_ref, g_ref, m_ref, v_ref, mask_ref,
                       w_out, m_out, v_out):
    w2, m, v = _adam_math(s_ref, w_ref, g_ref, m_ref, v_ref, mask_ref)
    w_out[...] = w2.astype(out_dt)
    m_out[...] = m
    v_out[...] = v


def _adamw_fp8_kernel(code_dt, s_ref, w_ref, g_ref, m_ref, v_ref, mask_ref,
                      codes_out, w_out, m_out, v_out):
    w2, m, v = _adam_math(s_ref, w_ref, g_ref, m_ref, v_ref, mask_ref)
    codes_out[...] = w2.astype(code_dt)
    w_out[...] = w2
    m_out[...] = m
    v_out[...] = v


def _adamw_q8_kernel(s_ref, w_ref, g_ref, m_ref, v_ref, mask_ref,
                     codes_out, w_out, scales_out, m_out, v_out):
    w2, m, v = _adam_math(s_ref, w_ref, g_ref, m_ref, v_ref, mask_ref)
    codes, scales = _requant(w2)
    codes_out[...] = codes
    scales_out[...] = scales
    w_out[...] = w2
    m_out[...] = m
    v_out[...] = v


# --------------------------------------------------------------------------- #
# 8-bit Adam epilogues (moments always blockwise-quantized)
# --------------------------------------------------------------------------- #
def _adam8_flat_kernel(out_dt, s_ref, w_ref, g_ref, m8_ref, v8_ref, ms_ref,
                       vs_ref, mask_ref, w_out, m8_out, v8_out, ms_out,
                       vs_out):
    w2, m, v = _adam8_math(s_ref, w_ref, g_ref, m8_ref, v8_ref, ms_ref,
                           vs_ref, mask_ref)
    w_out[...] = w2.astype(out_dt)
    m8, ms = _requant(m)
    v8, vs = _requant_log(v)
    m8_out[...] = m8
    v8_out[...] = v8
    ms_out[...] = ms
    vs_out[...] = vs


def _adam8_fp8_kernel(code_dt, s_ref, w_ref, g_ref, m8_ref, v8_ref, ms_ref,
                      vs_ref, mask_ref, codes_out, w_out, m8_out, v8_out,
                      ms_out, vs_out):
    w2, m, v = _adam8_math(s_ref, w_ref, g_ref, m8_ref, v8_ref, ms_ref,
                           vs_ref, mask_ref)
    codes_out[...] = w2.astype(code_dt)
    w_out[...] = w2
    m8, ms = _requant(m)
    v8, vs = _requant_log(v)
    m8_out[...] = m8
    v8_out[...] = v8
    ms_out[...] = ms
    vs_out[...] = vs


def _adam8_q8_kernel(s_ref, w_ref, g_ref, m8_ref, v8_ref, ms_ref, vs_ref,
                     mask_ref, codes_out, w_out, scales_out, m8_out, v8_out,
                     ms_out, vs_out):
    w2, m, v = _adam8_math(s_ref, w_ref, g_ref, m8_ref, v8_ref, ms_ref,
                           vs_ref, mask_ref)
    codes, scales = _requant(w2)
    codes_out[...] = codes
    scales_out[...] = scales
    w_out[...] = w2
    m8, ms = _requant(m)
    v8, vs = _requant_log(v)
    m8_out[...] = m8
    v8_out[...] = v8
    ms_out[...] = ms
    vs_out[...] = vs


# --------------------------------------------------------------------------- #
# wrappers
# --------------------------------------------------------------------------- #
def _check_fmt(fmt: str) -> None:
    if fmt not in ("fp32", "bf16", "q8_block") and not (
            fmt.startswith("fp8_") and fmt in float8_dtypes()):
        raise ValueError(f"unknown store fmt {fmt!r} for the fused update")


def _check_block(shape, block: int, who: str) -> None:
    if shape[-1] % block:
        raise ValueError(
            f"{who} needs last dim % block == 0, got {shape[-1]} % "
            f"{block} -- planner align missing?")


@functools.partial(jax.jit, static_argnames=("fmt", "block", "interpret"))
def adamw_store_update(w, g, m, v, mask, lr, b1, b2, eps, wd, c1, c2, *,
                       fmt: str = "fp32", block: int = 1024,
                       interpret: bool = False):
    """One fused pass: AdamW step + store re-encode.  ``w`` is the
    storage buffer (fp32, or bf16 for the bf16 store; fp8/q8 pass the
    fp32 master).  Returns ``(core, m2, v2)`` where ``core`` mirrors
    ``ParamStore.rebuild``: a bare array for flat formats, the
    codes(+scales)+master dict for fp8/q8."""
    _check_fmt(fmt)
    scalars = _scalar_stack(lr, b1, b2, eps, wd, c1, c2)
    n = w.size

    if fmt == "q8_block":
        _check_block(w.shape, block, "q8_block store update")
        nb = n // block
        tb = _resolve_tile(nb, interpret, None)
        blk = lambda: pl.BlockSpec((tb, block), lambda i: (i, 0))
        vec = lambda: pl.BlockSpec((tb,), lambda i: (i,))
        r = lambda x: x.reshape(nb, block)
        codes, w2, scales, m2, v2 = pl.pallas_call(
            _adamw_q8_kernel,
            grid=(pl.cdiv(nb, tb),),
            in_specs=[pl.BlockSpec((8,), lambda i: (0,)),
                      blk(), blk(), blk(), blk(), blk()],
            out_specs=[blk(), blk(), vec(), blk(), blk()],
            out_shape=[
                jax.ShapeDtypeStruct((nb, block), jnp.int8),
                jax.ShapeDtypeStruct((nb, block), jnp.float32),
                jax.ShapeDtypeStruct((nb,), jnp.float32),
                jax.ShapeDtypeStruct((nb, block), jnp.float32),
                jax.ShapeDtypeStruct((nb, block), jnp.float32),
            ],
            interpret=interpret,
        )(scalars, r(w), r(g), r(m), r(v), r(mask))
        core = {"codes": codes.reshape(w.shape),
                "master": w2.reshape(w.shape),
                "scales": scales.reshape(
                    w.shape[:-1] + (w.shape[-1] // block,))}
        return core, m2.reshape(w.shape), v2.reshape(w.shape)

    # flat epilogues: lane tiles over the flat shard, inert zero pad
    pn = -(-n // LANES) * LANES
    rows = pn // LANES
    tr = _tile_rows(rows, interpret)

    def r(x):
        flat = x.reshape(-1)
        if pn != n:
            flat = jnp.pad(flat, (0, pn - n))
        return flat.reshape(rows, LANES)

    def unpad(o):
        return o.reshape(-1)[:n].reshape(w.shape) if pn != n \
            else o.reshape(w.shape)

    tile = lambda: pl.BlockSpec((tr, LANES), lambda i: (i, 0))
    f32_out = jax.ShapeDtypeStruct((rows, LANES), jnp.float32)
    args = (scalars, r(w), r(g), r(m), r(v), r(mask))
    in_specs = [pl.BlockSpec((8,), lambda i: (0,)),
                tile(), tile(), tile(), tile(), tile()]

    if fmt.startswith("fp8_"):
        code_dt = jnp.dtype(float8_dtypes()[fmt])
        codes, w2, m2, v2 = pl.pallas_call(
            functools.partial(_adamw_fp8_kernel, code_dt),
            grid=(pl.cdiv(rows, tr),),
            in_specs=in_specs,
            out_specs=[tile(), tile(), tile(), tile()],
            out_shape=[jax.ShapeDtypeStruct((rows, LANES), code_dt),
                       f32_out, f32_out, f32_out],
            interpret=interpret,
        )(*args)
        return ({"codes": unpad(codes), "master": unpad(w2)},
                unpad(m2), unpad(v2))

    out_dt = jnp.dtype(jnp.bfloat16 if fmt == "bf16" else jnp.float32)
    w2, m2, v2 = pl.pallas_call(
        functools.partial(_adamw_flat_kernel, out_dt),
        grid=(pl.cdiv(rows, tr),),
        in_specs=in_specs,
        out_specs=[tile(), tile(), tile()],
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), out_dt),
                   f32_out, f32_out],
        interpret=interpret,
    )(*args)
    return unpad(w2), unpad(m2), unpad(v2)


@functools.partial(jax.jit, static_argnames=("fmt", "block", "interpret"))
def adam8bit_store_update(w, g, m8, v8, ms, vs, mask, lr, b1, b2, eps, wd,
                          c1, c2, *, fmt: str = "fp32", block: int = 1024,
                          interpret: bool = False):
    """One fused pass: 8-bit Adam step (blockwise moment dequant/requant)
    + store re-encode.  All formats run the (TILE_BLOCKS, block) grid --
    the quantized moments pin the block layout, so the planner align
    guarantee (last dim % block == 0) is already required.  Returns
    ``(core, m8', v8', ms', vs')``."""
    _check_fmt(fmt)
    _check_block(w.shape, block, "adam8bit store update")
    scalars = _scalar_stack(lr, b1, b2, eps, wd, c1, c2)
    n = w.size
    nb = n // block
    tb = _resolve_tile(nb, interpret, None)
    blk = lambda: pl.BlockSpec((tb, block), lambda i: (i, 0))
    vec = lambda: pl.BlockSpec((tb,), lambda i: (i,))
    r = lambda x: x.reshape(nb, block)
    in_specs = [pl.BlockSpec((8,), lambda i: (0,)),
                blk(), blk(), blk(), blk(), vec(), vec(), blk()]
    args = (scalars, r(w), r(g), r(m8), r(v8), ms.reshape(nb),
            vs.reshape(nb), r(mask))
    moment_outs = [
        jax.ShapeDtypeStruct((nb, block), jnp.int8),
        jax.ShapeDtypeStruct((nb, block), jnp.int8),
        jax.ShapeDtypeStruct((nb,), jnp.float32),
        jax.ShapeDtypeStruct((nb,), jnp.float32),
    ]

    def pack_moments(m8o, v8o, mso, vso):
        return (m8o.reshape(w.shape), v8o.reshape(w.shape),
                mso.reshape(ms.shape), vso.reshape(vs.shape))

    if fmt == "q8_block":
        codes, w2, scales, m8o, v8o, mso, vso = pl.pallas_call(
            _adam8_q8_kernel,
            grid=(pl.cdiv(nb, tb),),
            in_specs=in_specs,
            out_specs=[blk(), blk(), vec(), blk(), blk(), vec(), vec()],
            out_shape=[
                jax.ShapeDtypeStruct((nb, block), jnp.int8),
                jax.ShapeDtypeStruct((nb, block), jnp.float32),
                jax.ShapeDtypeStruct((nb,), jnp.float32),
            ] + moment_outs,
            interpret=interpret,
        )(*args)
        core = {"codes": codes.reshape(w.shape),
                "master": w2.reshape(w.shape),
                "scales": scales.reshape(
                    w.shape[:-1] + (w.shape[-1] // block,))}
        return (core,) + pack_moments(m8o, v8o, mso, vso)

    if fmt.startswith("fp8_"):
        code_dt = jnp.dtype(float8_dtypes()[fmt])
        codes, w2, m8o, v8o, mso, vso = pl.pallas_call(
            functools.partial(_adam8_fp8_kernel, code_dt),
            grid=(pl.cdiv(nb, tb),),
            in_specs=in_specs,
            out_specs=[blk(), blk(), blk(), blk(), vec(), vec()],
            out_shape=[
                jax.ShapeDtypeStruct((nb, block), code_dt),
                jax.ShapeDtypeStruct((nb, block), jnp.float32),
            ] + moment_outs,
            interpret=interpret,
        )(*args)
        core = {"codes": codes.reshape(w.shape),
                "master": w2.reshape(w.shape)}
        return (core,) + pack_moments(m8o, v8o, mso, vso)

    out_dt = jnp.dtype(jnp.bfloat16 if fmt == "bf16" else jnp.float32)
    w2, m8o, v8o, mso, vso = pl.pallas_call(
        functools.partial(_adam8_flat_kernel, out_dt),
        grid=(pl.cdiv(nb, tb),),
        in_specs=in_specs,
        out_specs=[blk(), blk(), blk(), vec(), vec()],
        out_shape=[jax.ShapeDtypeStruct((nb, block), out_dt)]
        + moment_outs,
        interpret=interpret,
    )(*args)
    return (w2.reshape(w.shape),) + pack_moments(m8o, v8o, mso, vso)
