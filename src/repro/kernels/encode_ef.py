"""Pallas TPU kernel: fused gradient-wire encode + error feedback.

The q8_block reduce wire (QSDP, Markov et al.) runs, per backward pass and
per device: ``comp = ct.astype(f32) + ef`` (apply the residual), blockwise
INT8 encode of ``comp``, and ``new_ef = comp - decode(encode(comp))`` (the
fresh quantization error).  Unfused that is three full-size passes over the
cotangent with an fp32 intermediate per step; this kernel does EF-add,
absmax/scale, round/clip, and residual update in ONE VMEM pass.

Bitwise contract: the kernel body performs the exact op sequence of the
unfused path (cast, add, absmax, divide, round/clip, multiply, subtract),
so codes, scales, and the residual are bitwise identical to
``core.wire.codec_reduce_scatter``'s unfused composition -- pinned by
tests/test_kernels_fused.py.  Tiling/contract rules are shared with
``blockwise_quant`` (full-width single tile in interpret mode, TILE_BLOCKS
grid compiled, identical ValueErrors to the jnp reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant.blockwise import _check_blocking
from .blockwise_quant import _resolve_tile


def _encode_ef_kernel(ct_ref, ef_ref, codes_ref, scales_ref, newef_ref):
    comp = ct_ref[...].astype(jnp.float32) + ef_ref[...]   # (TB, block)
    absmax = jnp.max(jnp.abs(comp), axis=1)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    codes = jnp.clip(jnp.round(comp * inv[:, None]), -127, 127)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale
    # codes holds integral f32 values in [-127, 127]: multiplying here is
    # bit-identical to dequantizing the int8 output
    newef_ref[...] = comp - codes * scale[:, None]


@functools.partial(jax.jit,
                   static_argnames=("block", "interpret", "tile_blocks"))
def encode_ef(ct, ef, *, block: int = 1024, interpret: bool = False,
              tile_blocks: int | None = None):
    """(ct (..., n) any float, ef (..., n) f32) ->
    (codes int8 (..., n), scales f32 (..., n//block), new_ef f32 (..., n)).

    Semantics: ``comp = ct.f32 + ef; codes, scales = quantize(comp);
    new_ef = comp - dequantize(codes, scales)`` -- fused."""
    shape = ct.shape
    n = shape[-1]
    _check_blocking(n, block, "encode_ef")
    if ef.shape != ct.shape:
        raise ValueError(
            f"encode_ef: ef shape {ef.shape} != ct shape {ct.shape}")
    nb = n // block
    lead = 1
    for s in shape[:-1]:
        lead *= s
    total = lead * nb
    ctb = ct.reshape(total, block)
    efb = ef.astype(jnp.float32).reshape(total, block)
    tb = _resolve_tile(total, interpret, tile_blocks)
    codes, scales, new_ef = pl.pallas_call(
        _encode_ef_kernel,
        grid=(pl.cdiv(total, tb),),
        in_specs=[
            pl.BlockSpec((tb, block), lambda i: (i, 0)),
            pl.BlockSpec((tb, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, block), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total, block), jnp.int8),
            jax.ShapeDtypeStruct((total,), jnp.float32),
            jax.ShapeDtypeStruct((total, block), jnp.float32),
        ],
        interpret=interpret,
    )(ctb, efb)
    return (codes.reshape(shape), scales.reshape(shape[:-1] + (nb,)),
            new_ef.reshape(shape))
