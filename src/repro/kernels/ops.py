"""THE dispatch layer for every quant hot path (repro.kernels).

Every hot-path call site (core.wire encode/decode, core.store
create/rebuild, the q8 reduce-scatter internals, optim.adam8bit, the serve
int8-GEMM) goes through these wrappers -- never through ``quant.blockwise``
directly (CI greps for that).  Dispatch rule:

  * TPU backend: the Pallas kernels compile to Mosaic with the TILE_BLOCKS
    grid.
  * everywhere else (this CPU container): the same kernel body runs in
    ``interpret=True`` mode as ONE full-width tile -- traced jnp, bitwise
    identical to the jitted jnp reference and O(ops), not O(grid steps)
    (see blockwise_quant._resolve_tile).

``quant.blockwise`` stays the reference implementation and the parity
oracle (re-exported through ref.py); the log-space variants used by 8-bit
Adam's second moment have no standalone fused kernel (the fused
adam8bit_update kernel inlines them), so their dispatch is the reference
on every backend -- documented here so the import-check story stays
one sentence: hot paths import repro.kernels.ops, full stop.
"""
from __future__ import annotations

import jax

from ..quant.blockwise import (dequantize_blockwise_log,
                               quantize_blockwise_log)
from .adam8bit_update import adam8bit_update as _adam8
from .adam_update import adamw_update as _adamw
from .blockwise_quant import (dequantize as _deq,
                              dequantize_into as _deq_into, quantize as _q)
from .encode_ef import encode_ef as _encode_ef
from .fused_update import (adam8bit_store_update as _adam8_store,
                           adamw_store_update as _adamw_store)
from .q8_matmul import (QuantTensor, fold_scales, q8_matmul as _q8mm,
                        q8_slice_cols as _q8_slice, quant_eligible)

__all__ = [
    "quantize", "dequantize", "dequantize_into", "encode_ef", "q8_matmul",
    "quantize_log", "dequantize_log", "adamw_update", "adam8bit_update",
    "adamw_store_update", "adam8bit_store_update", "q8_slice_cols",
    "QuantTensor", "quant_eligible", "fold_scales",
]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize(x, block: int = 1024):
    """Blockwise absmax int8 encode (store create/rebuild, wire encode).

    PARITY: BITWISE -- vs the jitted quant.blockwise reference.
    """
    return _q(x, block=block, interpret=_interpret())


def dequantize(codes, scales, block: int = 1024):
    """Blockwise decode to fp32 (cold paths, 8-bit Adam moments).

    PARITY: BITWISE -- vs the jitted quant.blockwise reference.
    """
    return _deq(codes, scales, block=block, interpret=_interpret())


def dequantize_into(codes, scales, block: int = 1024, *, out_dtype):
    """Gather-path fused decode: codes + scales -> out_dtype, no
    intermediate full-size fp32 buffer.

    PARITY: BITWISE -- vs the jitted decode+cast composition.
    """
    return _deq_into(codes, scales, block=block, out_dtype=out_dtype,
                     interpret=_interpret())


def encode_ef(ct, ef, block: int = 1024):
    """Reduce-path fused encode + error feedback:
    (codes, scales, new_ef) of ``comp = ct.f32 + ef``.

    PARITY: BITWISE -- vs the jitted unfused compensate+encode.
    """
    return _encode_ef(ct, ef, block=block, interpret=_interpret())


def q8_matmul(x, codes, scales, block: int = 1024, *, out_dtype=None):
    """Serve-path int8 x int8 GEMM on gathered codes: the weight scale
    folds into the activation, which is row-quantized to int8.

    PARITY: ALLCLOSE -- bounded new error vs the dense oracle (bitwise
    only against its own jnp op-sequence twin).
    """
    return _q8mm(x, codes, scales, block=block, out_dtype=out_dtype,
                 interpret=_interpret())


def quantize_log(x, block: int = 1024):
    """Log-space blockwise quantize (8-bit Adam's v): reference on every
    backend -- no standalone fused kernel (adam8bit_update fuses it).

    PARITY: BITWISE -- reference passthrough.
    """
    return quantize_blockwise_log(x, block)


def dequantize_log(codes, scales, block: int = 1024):
    """Log-space blockwise decode; reference passthrough like
    ``quantize_log``.

    PARITY: BITWISE -- reference passthrough.
    """
    return dequantize_blockwise_log(codes, scales, block)


def adamw_update(w, g, m, v, mask, *, lr, b1, b2, eps, wd, c1, c2):
    """Fused AdamW moment + weight update.

    PARITY: BITWISE -- vs the jitted kernels/ref.py composition.
    """
    return _adamw(w, g, m, v, mask, lr, b1, b2, eps, wd, c1, c2,
                  interpret=_interpret())


def adam8bit_update(w, g, m8, v8, ms, vs, mask, *, lr, b1, b2, eps, wd,
                    c1, c2, block: int = 1024):
    """Fused 8-bit Adam update (blockwise-quantized moments; the moment
    (de)quant inside is the BITWISE-class blockwise codec).

    PARITY: ALLCLOSE -- few-ulp vs the jitted kernels/ref.py
    composition: the log-space second-moment decode's ``exp`` compiles
    differently inside the pallas interpreter than in the fused XLA
    reference graph (last-ulp transcendental drift, amplified to at
    most a few representation steps through the update chain).
    """
    return _adam8(w, g, m8, v8, ms, vs, mask, lr, b1, b2, eps, wd, c1, c2,
                  block=block, interpret=_interpret())


def adamw_store_update(w, g, m, v, mask, *, lr, b1, b2, eps, wd, c1, c2,
                       fmt: str = "fp32", block: int = 1024):
    """Fused AdamW step + ParamStore rebuild: moment update, weight
    write, and the storage re-encode (bf16 round / fp8 cast / q8
    blockwise requantize) in one pass -- the optimizer hot path for every
    store format.  Returns ``(core, m2, v2)``; ``core`` mirrors
    ``ParamStore.rebuild``.

    PARITY: BITWISE -- vs the jitted kernels/ref.py composition.
    """
    return _adamw_store(w, g, m, v, mask, lr, b1, b2, eps, wd, c1, c2,
                        fmt=fmt, block=block, interpret=_interpret())


def adam8bit_store_update(w, g, m8, v8, ms, vs, mask, *, lr, b1, b2, eps,
                          wd, c1, c2, fmt: str = "fp32",
                          block: int = 1024):
    """Fused 8-bit Adam step + ParamStore rebuild: blockwise moment
    dequant/requant AND the storage re-encode in one pass.  Returns
    ``(core, m8', v8', ms', vs')``.

    PARITY: ALLCLOSE -- few-ulp vs the jitted kernels/ref.py
    composition, inherited from ``adam8bit_update``'s log-space
    second-moment ``exp`` (compiles differently in the pallas
    interpreter vs the fused reference graph); the tests pin
    integer-view distance <= 4 on every leaf.
    """
    return _adam8_store(w, g, m8, v8, ms, vs, mask, lr, b1, b2, eps, wd,
                        c1, c2, fmt=fmt, block=block,
                        interpret=_interpret())


def q8_slice_cols(qt, start, width: int):
    """Column slice of a gathered q8 ``QuantTensor`` when the scale
    layout permits (serve-path KV head slicing; ``start`` may be traced).
    Returns the sliced QuantTensor, or None when the slice is not
    scale-representable (caller falls back to ``to_dense``).

    PARITY: BITWISE -- pure index/layout transformation; the sliced
    tensor dequantizes to exactly the sliced dequantized original.
    """
    return _q8_slice(qt, start, width)
