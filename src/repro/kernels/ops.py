"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode --
the kernel body runs as traced jnp on the host, which is how we validate
them against ref.py.  On a real TPU backend they compile to Mosaic.
"""
from __future__ import annotations

import jax

from .adam8bit_update import adam8bit_update as _adam8
from .adam_update import adamw_update as _adamw
from .blockwise_quant import dequantize as _deq, quantize as _q


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize(x, block: int = 1024):
    return _q(x, block=block, interpret=_interpret())


def dequantize(codes, scales, block: int = 1024):
    return _deq(codes, scales, block=block, interpret=_interpret())


def adamw_update(w, g, m, v, mask, *, lr, b1, b2, eps, wd, c1, c2):
    return _adamw(w, g, m, v, mask, lr, b1, b2, eps, wd, c1, c2,
                  interpret=_interpret())


def adam8bit_update(w, g, m8, v8, ms, vs, mask, *, lr, b1, b2, eps, wd,
                    c1, c2, block: int = 1024):
    return _adam8(w, g, m8, v8, ms, vs, mask, lr, b1, b2, eps, wd, c1, c2,
                  block=block, interpret=_interpret())
