"""Communication-free sharded checkpointing over RaggedShard DBuffers.

The paper (§4) inherits DTensor-based distributed checkpointing; the JAX
analogue: each group's flat buffer is saved alongside the plan's
``checkpoint_index`` (name -> shape/dtype/granularity/offset).  Save is a
pure local write per shard (no collectives); load can resharded-restore
into a *different* mesh/plan by round-tripping through per-tensor arrays --
that is what RaggedShard's metadata buys.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from ..compat import tree_flatten_with_path, tree_unflatten
from ..core.ragged import checkpoint_index


def save(path, runtime, params, opt_state=None, step: int = 0):
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    meta = {
        "step": int(step),
        "groups": {
            name: {
                "index": checkpoint_index(lo.plan),
                "shard_size": lo.plan.shard_size,
                "num_shards": lo.plan.num_shards,
                "outer_size": lo.outer_size,
                "n_layers": lo.n_layers,
                "mode": lo.plan.mode,
            }
            for name, lo in runtime.layouts.items()
        },
    }
    (path / "meta.json").write_text(json.dumps(meta, indent=1))
    arrays = {f"param__{k}": np.asarray(v) for k, v in params.items()}
    if opt_state is not None:
        flat, _ = tree_flatten_with_path(opt_state)
        for kp, v in flat:
            key = "opt__" + "__".join(
                getattr(p, "key", str(p)) for p in kp)
            arrays[key] = np.asarray(v)
    np.savez(path / "state.npz", **arrays)


def load(path, runtime, opt_state_like=None):
    """Restore params (+ optionally opt state) onto the runtime's mesh.

    If the saved plan matches the runtime's plan, buffers load directly;
    otherwise each tensor is re-extracted via the saved index and re-packed
    with the current plan (resharded restore)."""
    from jax.sharding import NamedSharding

    path = pathlib.Path(path)
    meta = json.loads((path / "meta.json").read_text())
    data = np.load(path / "state.npz")
    params = {}
    for name, lo in runtime.layouts.items():
        saved = meta["groups"][name]
        buf = data[f"param__{name}"]
        same_plan = (
            saved["shard_size"] == lo.plan.shard_size
            and saved["num_shards"] == lo.plan.num_shards
            and saved["outer_size"] == lo.outer_size
            and saved["mode"] == lo.plan.mode
        )
        if not same_plan:
            buf = _repack(buf, saved, lo)
        params[name] = jax.device_put(
            buf, NamedSharding(runtime.mesh, lo.pspec()))
    out = [params, int(meta["step"])]
    if opt_state_like is not None:
        flat, tree = tree_flatten_with_path(opt_state_like)
        restored = []
        for kp, like in flat:
            key = "opt__" + "__".join(getattr(p, "key", str(p)) for p in kp)
            restored.append(jax.device_put(data[key], like.sharding))
        out.append(tree_unflatten(tree, restored))
    return tuple(out)


def _repack(buf: np.ndarray, saved: dict, lo) -> np.ndarray:
    """Cross-plan restore: unpack tensors via the saved index, re-pack with
    the current plan.  Only same outer_size is supported (TP regrouping
    would need the StridedRagged reshuffle)."""
    assert saved["outer_size"] == lo.outer_size, "TP resize not supported"
    idx = saved["index"]
    old_total = saved["shard_size"] * saved["num_shards"]
    layers = buf.reshape((-1, lo.outer_size * old_total))
    out = np.zeros(
        (layers.shape[0], lo.outer_size * lo.plan.total), buf.dtype)
    for li in range(layers.shape[0]):
        for r in range(lo.outer_size):
            old = layers[li, r * old_total:(r + 1) * old_total]
            arrays = {
                name: old[m["offset"]: m["offset"] + int(np.prod(m["shape"]))
                          ].reshape(m["shape"])
                for name, m in idx.items()
            }
            out[li, r * lo.plan.total:(r + 1) * lo.plan.total] = (
                lo.buffer.pack(arrays))
    return out.reshape(buf.shape[:1] + (-1,)) if buf.ndim > 1 else out[0]
