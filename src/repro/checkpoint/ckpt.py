"""Communication-free sharded checkpointing over RaggedShard DBuffers.

The paper (§4) inherits DTensor-based distributed checkpointing; the JAX
analogue: each group's flat buffer is saved alongside the plan's
``checkpoint_index`` (name -> shape/dtype/granularity/offset).  Save is a
pure local write per shard (no collectives); load resharded-restores into a
*different* mesh/plan/TP-degree/store-format by streaming tensors through
the per-tensor shard index (``core.reshard``).

Format v2 (this module writes; both versions load):

  * ``meta.json``   -- {"version": 2, "step", "groups": {...}, "opt": [...]}
                       where each group entry carries the checkpoint index
                       plus layout (shard_size/num_shards/outer_size/
                       outer_dims/n_layers/mode) and store (store/
                       quant_block/ef_m) fields, and "opt" is the optimizer
                       leaf manifest.
  * ``plan.json``   -- the resolved ShardingPlan (exact-restore validation).
  * ``shards/``     -- one ``.npy`` per (group, leaf, uniform shard):
                       ``p__<group>__<leaf>__<j>.npy`` holds shard
                       ``j = part*m + k`` of that leaf, shaped
                       ``(n_layers, S_leaf)`` or ``(S_leaf,)``.  Optimizer
                       leaves save as ``o__<i>__<j>.npy`` (buffer-shaped:
                       moments, 8-bit codes/scales) or ``o__<i>.npy``
                       (dense scalars; Shampoo factors, stored *unpadded*
                       so they are plan-independent).

Save stays a pure local write per shard.  Load addresses individual extents
via ``GroupIndex``, so cross-plan restores never materialize more than one
group buffer (and ``tools/reshard.py``, file-to-file, never more than one
tensor).  Parity classes (DESIGN.md §Resharding): same-plan = bitwise per
leaf; cross-plan = bitwise-on-master; cross-format = master-exact, codes
requantized from the master, EF residuals re-zeroed.

Format v1 (legacy, read-only): one monolithic ``state.npz``.  Restores
same-plan; a cross-plan load with optimizer state raises (the old code
silently device_put stale same-plan arrays).
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from .. import compat
from ..compat import tree_flatten_with_path, tree_unflatten
from ..core.ragged import checkpoint_index
from ..core.reshard import (GroupIndex, buffer_reader, buffer_writer,
                            copy_tensor, stream_tensors)


# --------------------------------------------------------------------------- #
# dtype widening: .npy round-trips numpy-native dtypes only
# --------------------------------------------------------------------------- #

def _nonnative_names() -> set[str]:
    names = {"bfloat16"}
    names.update(str(jnp.dtype(d)) for d in compat.float8_dtypes().values())
    return names


def _savable(a) -> np.ndarray:
    """numpy persists native dtypes only: ml_dtypes bfloat16 (and the fp8
    wire dtypes when present) degrade to raw void arrays on load.  Widen
    them to fp32 on disk (exact; the store format in meta says what to
    narrow back to)."""
    a = np.asarray(a)
    if a.dtype.kind == "V" or str(a.dtype) in _nonnative_names():
        return np.asarray(jnp.asarray(a).astype(jnp.float32))
    return a


def _narrow(a: np.ndarray, dtype) -> np.ndarray:
    """Undo ``_savable``: cast back to the runtime dtype (exact for the
    widened formats: every bf16/fp8 value is fp32-representable)."""
    if np.dtype(a.dtype) == jnp.dtype(dtype):
        return a
    return np.asarray(jnp.asarray(a).astype(dtype))


# --------------------------------------------------------------------------- #
# shard-file naming and access
# --------------------------------------------------------------------------- #

def param_shard_file(group: str, leaf: str, j: int) -> str:
    return f"p__{group}__{leaf}__{j}.npy"


def opt_shard_file(file: str, j: int) -> str:
    return f"{file}__{j}.npy"


def shard_file_reader(shards_dir, name_of_j):
    """A ``core.reshard`` Reader over per-shard ``.npy`` files, memmapped
    so assembling one tensor touches only that tensor's extents."""
    shards_dir = pathlib.Path(shards_dir)
    cache: dict[int, np.ndarray] = {}

    def read(j: int, layer):
        mm = cache.get(j)
        if mm is None:
            f = shards_dir / name_of_j(j)
            if not f.exists():
                raise ValueError(f"checkpoint shard file missing: {f}")
            mm = cache[j] = np.load(f, mmap_mode="r")
        return mm if layer is None else mm[layer]

    return read


def group_master_reader(shards_dir, group: str):
    """Reader over a group's fp32(-widened) master shards (every store
    format saves a ``master`` leaf under v2 -- bare states via
    ``ParamStore.as_leaves``)."""
    return shard_file_reader(
        shards_dir, lambda j: param_shard_file(group, "master", j))


# --------------------------------------------------------------------------- #
# save (format v2)
# --------------------------------------------------------------------------- #

def group_meta(lo) -> dict:
    return {
        "index": checkpoint_index(lo.plan),
        "shard_size": lo.plan.shard_size,
        "num_shards": lo.plan.num_shards,
        "outer_size": lo.outer_size,
        "outer_dims": {n: sd.dim for n, sd in lo.gdef.outer.items()},
        "n_layers": lo.n_layers,
        "mode": lo.plan.mode,
        "store": lo.store.fmt,
        "quant_block": lo.store.block,
        # reduce-wire error-feedback residual chunks (0 = none); the
        # residual checkpoints alongside the weights so EF history
        # survives restarts
        "ef_m": lo.store.ef_m,
    }


def _classify_opt_leaf(runtime, keys: tuple[str, ...],
                       shape: tuple[int, ...]):
    """(kind, group, div) of one optimizer-state leaf.

    ``buffer``: shaped like a group buffer with the last dim divided by
    ``div`` (moments, 8-bit moment codes at div=1, their scales at
    div=quant_block) -- reshards through the extent map.  ``factor``:
    Shampoo/Muon per-layer stats keyed ``<group>/<tensor>/...``, stacked
    over the group's (FSDP-padded) layer dim -- plan-independent once
    unpadded.  ``dense``: everything else, saved whole.
    """
    shape = tuple(shape)
    last = keys[-1]
    lo = runtime.layouts.get(last)
    if lo is not None and shape:
        gs = lo.global_shape()
        if (shape[:-1] == tuple(gs[:-1])
                and shape[-1] and gs[-1] % shape[-1] == 0):
            return "buffer", last, gs[-1] // shape[-1]
    if "/" in last:
        g = last.split("/", 1)[0]
        lo = runtime.layouts.get(g)
        if (lo is not None and lo.n_layers and len(shape) >= 1
                and shape[0] >= lo.n_layers):
            return "factor", g, None
    return "dense", None, None


def save(path, runtime, params, opt_state=None, step: int = 0):
    path = pathlib.Path(path)
    shards = path / "shards"
    shards.mkdir(parents=True, exist_ok=True)
    meta = {
        "version": 2,
        "step": int(step),
        "groups": {name: group_meta(lo)
                   for name, lo in runtime.layouts.items()},
    }
    for name, lo in runtime.layouts.items():
        leaves = lo.store.as_leaves(params[name])
        rows = lo.outer_size * lo.plan.num_shards
        for leaf, arr in leaves.items():
            a = _savable(arr)
            sl = a.shape[-1] // rows
            for j in range(rows):
                np.save(shards / param_shard_file(name, leaf, j),
                        a[..., j * sl: (j + 1) * sl])
    manifest = []
    if opt_state is not None:
        flat, _ = tree_flatten_with_path(opt_state)
        for i, (kp, v) in enumerate(flat):
            keys = tuple(getattr(p, "key", str(p)) for p in kp)
            a = _savable(v)
            kind, g, div = _classify_opt_leaf(runtime, keys, a.shape)
            ent = {"path": list(keys), "kind": kind,
                   "dtype": str(jnp.dtype(np.asarray(v).dtype)),
                   "file": f"o__{i:03d}"}
            if kind == "buffer":
                lo = runtime.layouts[g]
                rows = lo.outer_size * lo.plan.num_shards
                sl = a.shape[-1] // rows
                ent.update(group=g, div=div)
                for j in range(rows):
                    np.save(shards / opt_shard_file(ent["file"], j),
                            a[..., j * sl: (j + 1) * sl])
            elif kind == "factor":
                lo = runtime.layouts[g]
                ent.update(group=g, n_layers=lo.n_layers)
                # strip the FSDP layer padding: padded rows are exactly
                # zero, so the unpadded stat is plan-independent
                np.save(shards / f"{ent['file']}.npy", a[: lo.n_layers])
            else:
                np.save(shards / f"{ent['file']}.npy", a)
            manifest.append(ent)
    meta["opt"] = manifest
    (path / "meta.json").write_text(json.dumps(meta, indent=1))
    # the resolved ShardingPlan rides along for exact-restore validation:
    # load_plan(path).dumps() == runtime.plan.dumps() guarantees the
    # bitwise per-leaf restore path applies to every group
    (path / "plan.json").write_text(
        json.dumps(runtime.plan.to_json(), sort_keys=True, indent=1))


# --------------------------------------------------------------------------- #
# load (v2 streaming; v1 legacy below)
# --------------------------------------------------------------------------- #

def _same_layout(saved: dict, lo) -> bool:
    """Shard bytes are directly reusable iff every layout field AND the
    full placement index match (same shapes in a different packing must
    take the remap path)."""
    return (saved["shard_size"] == lo.plan.shard_size
            and saved["num_shards"] == lo.plan.num_shards
            and saved.get("outer_size", 1) == lo.outer_size
            and {k: int(v) for k, v in saved.get("outer_dims", {}).items()}
            == {n: sd.dim for n, sd in lo.gdef.outer.items()}
            and saved.get("n_layers", 0) == lo.n_layers
            and saved.get("mode", "ragged") == lo.plan.mode
            and saved["index"] == checkpoint_index(lo.plan))


def _same_store(saved: dict, lo) -> bool:
    saved_store = saved.get("store", "fp32")
    return (saved_store == lo.store.fmt
            and saved.get("ef_m", 0) == lo.store.ef_m
            and (not (lo.store.quantized or lo.store.has_ef)
                 or saved.get("quant_block") == lo.store.block))


def load(path, runtime, opt_state_like=None):
    """Restore params (+ optionally opt state) onto the runtime's mesh.

    If a group's saved layout AND store format match the runtime's, its
    shard files concatenate straight back into the buffer (bitwise: a
    q8_block round-trip preserves the master shard and the codes exactly).
    Otherwise the fp32 master is streamed tensor-by-tensor through the
    saved and live shard indices -- any mesh size, plan mode, TP degree
    (tensors are looked up by name, so migrating between groups across a
    TP change is handled), or store format -- and the runtime's store
    re-derives its state (codes requantized from the master, which is
    bitwise-reproducible because align pins tensor starts and S to the
    quant block; EF residuals restart at zero).

    Optimizer state reshards through the same machinery: moment buffers
    follow their parameter's extents (block-granular 8-bit state moves on
    the aligned path and raises on an outer-layout change), Shampoo/Muon
    per-layer factors are re-padded to the new plan, dense leaves load
    verbatim.
    """
    from jax.sharding import NamedSharding

    path = pathlib.Path(path)
    meta = json.loads((path / "meta.json").read_text())
    if int(meta.get("version", 1)) < 2:
        return _load_legacy(path, meta, runtime, opt_state_like)
    shards = path / "shards"
    saved_groups = meta["groups"]
    src_idx = {g: GroupIndex.from_meta(sg) for g, sg in saved_groups.items()}
    tensor_src = {t: g for g, sg in saved_groups.items() for t in sg["index"]}

    params = {}
    for name, lo in runtime.layouts.items():
        sharding = NamedSharding(runtime.mesh, lo.pspec())
        saved = saved_groups.get(name)
        if saved is not None and _same_layout(saved, lo) \
                and _same_store(saved, lo):
            keys = lo.store.state_keys() or ("master",)
            leaves = {}
            for leaf in keys:
                rows = lo.outer_size * lo.plan.num_shards
                parts = [np.load(shards / param_shard_file(name, leaf, j))
                         for j in range(rows)]
                leaves[leaf] = _narrow(np.concatenate(parts, axis=-1),
                                       lo.store.leaf_dtype(leaf))
            state = lo.store.from_leaves(leaves)
        else:
            dst_idx = GroupIndex.from_layout(lo)
            master = np.zeros(lo.global_shape(), np.float32)
            write = buffer_writer(master, dst_idx.num_rows)

            def lookup(tname):
                g = tensor_src.get(tname)
                if g is None:
                    raise ValueError(
                        f"tensor {tname!r} (group {name!r}) not in "
                        f"checkpoint {path}")
                return src_idx[g], group_master_reader(shards, g)

            stream_tensors(dst_idx, write, lookup)
            # cross-plan/format rebuild: EF residuals restart at zero (a
            # fresh error-feedback history is always valid)
            state = lo.store.create(master)
        params[name] = jax.tree.map(
            lambda a: jax.device_put(a, sharding), state)
    out = [params, int(meta["step"])]
    if opt_state_like is not None:
        out.append(_load_opt(shards, meta, runtime, opt_state_like,
                             src_idx, tensor_src))
    return tuple(out)


def _load_opt(shards, meta, runtime, opt_state_like, src_idx, tensor_src):
    man = {tuple(e["path"]): e for e in meta.get("opt", [])}
    flat, tree = tree_flatten_with_path(opt_state_like)
    restored = []
    for kp, like in flat:
        keys = tuple(getattr(p, "key", str(p)) for p in kp)
        leaf = _restore_opt_leaf(shards, man, keys, like, runtime,
                                 src_idx, tensor_src)
        restored.append(jax.device_put(leaf, like.sharding))
    return tree_unflatten(tree, restored)


def _restore_opt_leaf(shards, man, keys, like, runtime, src_idx, tensor_src):
    pathname = "/".join(keys)
    kind, g_new, div = _classify_opt_leaf(runtime, keys, like.shape)
    ent = man.get(keys)
    if kind != "buffer":
        if ent is None:
            raise ValueError(
                f"optimizer state leaf {pathname!r} not in checkpoint "
                f"(saved leaves: {sorted('/'.join(p) for p in man)})")
        a = np.load(shards / f"{ent['file']}.npy")
        if kind == "factor":
            lo = runtime.layouts[g_new]
            if a.shape[1:] != like.shape[1:] or a.shape[0] < lo.n_layers:
                raise ValueError(
                    f"optimizer state {pathname!r}: saved factor shape "
                    f"{a.shape} incompatible with {tuple(like.shape)}")
            out = np.zeros(like.shape, a.dtype)
            out[: lo.n_layers] = a[: lo.n_layers]
            a = out
        elif tuple(a.shape) != tuple(like.shape):
            raise ValueError(
                f"optimizer state {pathname!r}: saved shape {a.shape} != "
                f"expected {tuple(like.shape)}")
        return _narrow(a, like.dtype)

    lo = runtime.layouts[g_new]
    dst_idx = GroupIndex.from_layout(lo)
    if ent is not None and ent["kind"] == "buffer" \
            and ent.get("div") == div \
            and g_new in src_idx and _same_layout_idx(src_idx[g_new], dst_idx):
        read = shard_file_reader(
            shards, lambda j: opt_shard_file(ent["file"], j))
        parts = [np.asarray(read(j, None)) for j in range(dst_idx.num_rows)]
        return _narrow(np.concatenate(parts, axis=-1), like.dtype)

    # cross-plan: each tensor's slice of the moment buffer follows the
    # parameter's extents from its saved owning group
    dest = None
    for name in lo.plan.names:
        g_old = tensor_src.get(name)
        if g_old is None:
            raise ValueError(
                f"optimizer state {pathname!r}: tensor {name!r} not in "
                f"checkpoint")
        src_ent = man.get(keys[:-1] + (g_old,))
        if src_ent is None or src_ent["kind"] != "buffer":
            raise ValueError(
                f"optimizer state {pathname!r}: no saved buffer leaf for "
                f"source group {g_old!r} "
                f"(expected path {'/'.join(keys[:-1] + (g_old,))!r})")
        if src_ent.get("div") != div:
            raise ValueError(
                f"optimizer state {pathname!r}: block granularity changed "
                f"({src_ent.get('div')} -> {div}, e.g. a quant_block "
                f"change); 8-bit optimizer state cannot be resharded "
                f"across it — reinitialize the optimizer instead")
        read = shard_file_reader(
            shards, lambda j, f=src_ent["file"]: opt_shard_file(f, j))
        if dest is None:
            probe = np.asarray(read(0, 0 if lo.n_layers else None))
            dest = np.zeros(like.shape, probe.dtype)
        write = buffer_writer(dest, dst_idx.num_rows)
        s_idx = src_idx[g_old]
        if (s_idx.n_layers or 0) != (lo.n_layers or 0):
            raise ValueError(
                f"optimizer state {pathname!r}: layer count changed for "
                f"{name!r} ({s_idx.n_layers} -> {lo.n_layers})")
        aligned = div > 1 or np.dtype(like.dtype).kind in "iu"
        for li in (range(lo.n_layers) if lo.n_layers else [None]):
            copy_tensor(s_idx, dst_idx, name, read, write,
                        layer=li, div=div, aligned=aligned)
    return _narrow(dest, like.dtype)


def _same_layout_idx(a: GroupIndex, b: GroupIndex) -> bool:
    return (a.plan.shard_size == b.plan.shard_size
            and a.plan.num_shards == b.plan.num_shards
            and a.outer_size == b.outer_size
            and dict(a.outer_dims) == dict(b.outer_dims)
            and (a.n_layers or 0) == (b.n_layers or 0)
            and a.plan.mode == b.plan.mode
            and checkpoint_index(a.plan) == checkpoint_index(b.plan))


def load_plan(path):
    """The ShardingPlan saved with a checkpoint (None for pre-plan
    checkpoints).  Restoring through ``FSDPRuntime(model, mesh,
    plan=load_plan(p))`` reconstructs the saved layout exactly, making the
    bitwise per-leaf restore path apply to every group; comparing against a
    fresh plan's ``dumps()`` (or ``plan.diff``) shows precisely which
    groups will take the rebuild-from-master path instead."""
    from ..core.policy import ShardingPlan

    f = pathlib.Path(path) / "plan.json"
    if not f.exists():
        return None
    return ShardingPlan.from_json(json.loads(f.read_text()))


# --------------------------------------------------------------------------- #
# legacy format v1 (monolithic state.npz) -- read-only
# --------------------------------------------------------------------------- #

def _load_legacy(path, meta, runtime, opt_state_like):
    from jax.sharding import NamedSharding

    data = np.load(path / "state.npz")
    params = {}
    any_cross_plan = None
    for name, lo in runtime.layouts.items():
        saved = meta["groups"][name]
        saved_store = saved.get("store", "fp32")  # pre-store checkpoints
        same_plan = (
            saved["shard_size"] == lo.plan.shard_size
            and saved["num_shards"] == lo.plan.num_shards
            and saved.get("outer_size", 1) == lo.outer_size
            and saved.get("mode", "ragged") == lo.plan.mode
        )
        sharding = NamedSharding(runtime.mesh, lo.pspec())
        same_store = _same_store(saved, lo)
        keys = lo.store.state_keys()
        if same_plan and same_store:
            if keys is not None:
                state = {
                    leaf: np.asarray(
                        jnp.asarray(data[f"param__{name}__{leaf}"])
                        .astype(lo.store.leaf_dtype(leaf)))
                    for leaf in keys}
            else:
                state = np.asarray(
                    jnp.asarray(data[f"param__{name}"])
                    .astype(lo.store.storage_dtype))
        else:
            if not same_plan:
                any_cross_plan = name
            master = _saved_master(data, name, saved_store,
                                   saved.get("ef_m", 0))
            if not same_plan:
                master = _repack(master, saved, lo)
            state = lo.store.create(master)
        params[name] = jax.tree.map(
            lambda a: jax.device_put(a, sharding), state)
    out = [params, int(meta["step"])]
    if opt_state_like is not None:
        if any_cross_plan is not None:
            raise ValueError(
                f"legacy (v1) checkpoint: group {any_cross_plan!r} was "
                f"saved under a different plan; v1 optimizer state is "
                f"same-plan only (the old code silently restored stale "
                f"arrays here).  Re-save under format v2 or load without "
                f"opt_state_like")
        flat, tree = tree_flatten_with_path(opt_state_like)
        restored = []
        for kp, like in flat:
            key = "opt__" + "__".join(getattr(p, "key", str(p)) for p in kp)
            if key not in data:
                raise ValueError(
                    f"optimizer state leaf {key!r} not in legacy "
                    f"checkpoint {path}")
            restored.append(jax.device_put(data[key], like.sharding))
        out.append(tree_unflatten(tree, restored))
    return tuple(out)


def _saved_master(data, name: str, saved_store: str,
                  saved_ef_m: int = 0) -> np.ndarray:
    """fp32 master weights of one group from a saved v1 state of any format
    (dict states -- quantized and/or EF-carrying -- save a master leaf)."""
    if saved_store == "q8_block" or saved_ef_m:
        return np.asarray(data[f"param__{name}__master"], np.float32)
    return np.asarray(data[f"param__{name}"], np.float32)


def _repack(buf: np.ndarray, saved: dict, lo) -> np.ndarray:
    """v1 cross-plan restore: unpack tensors via the saved index, re-pack
    with the current plan.  Only same outer_size is supported here; the v2
    path (``core.reshard``) handles TP regrouping."""
    if saved.get("outer_size", 1) != lo.outer_size:
        raise ValueError(
            f"legacy cross-TP restore not supported: checkpoint outer_size "
            f"{saved.get('outer_size', 1)} != runtime {lo.outer_size}; "
            f"re-save under format v2")
    idx = saved["index"]
    old_total = saved["shard_size"] * saved["num_shards"]
    layers = buf.reshape((-1, lo.outer_size * old_total))
    out = np.zeros(
        (layers.shape[0], lo.outer_size * lo.plan.total), buf.dtype)
    for li in range(layers.shape[0]):
        for r in range(lo.outer_size):
            old = layers[li, r * old_total:(r + 1) * old_total]
            arrays = {
                name: old[m["offset"]: m["offset"] + int(np.prod(m["shape"]))
                          ].reshape(m["shape"])
                for name, m in idx.items()
            }
            out[li, r * lo.plan.total:(r + 1) * lo.plan.total] = (
                lo.buffer.pack(arrays))
    return out.reshape(buf.shape[:1] + (-1,)) if buf.ndim > 1 else out[0]
