"""Communication-free sharded checkpointing over RaggedShard DBuffers.

The paper (§4) inherits DTensor-based distributed checkpointing; the JAX
analogue: each group's flat buffer is saved alongside the plan's
``checkpoint_index`` (name -> shape/dtype/granularity/offset).  Save is a
pure local write per shard (no collectives); load can resharded-restore
into a *different* mesh/plan by round-tripping through per-tensor arrays --
that is what RaggedShard's metadata buys.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import tree_flatten_with_path, tree_unflatten
from ..core.ragged import checkpoint_index


def save(path, runtime, params, opt_state=None, step: int = 0):
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    meta = {
        "step": int(step),
        "groups": {
            name: {
                "index": checkpoint_index(lo.plan),
                "shard_size": lo.plan.shard_size,
                "num_shards": lo.plan.num_shards,
                "outer_size": lo.outer_size,
                "n_layers": lo.n_layers,
                "mode": lo.plan.mode,
                "store": lo.store.fmt,
                "quant_block": lo.store.block,
                # reduce-wire error-feedback residual chunks (0 = none);
                # the residual checkpoints alongside the weights so EF
                # history survives restarts
                "ef_m": lo.store.ef_m,
            }
            for name, lo in runtime.layouts.items()
        },
    }
    (path / "meta.json").write_text(json.dumps(meta, indent=1))
    # the resolved ShardingPlan rides along for exact-restore validation:
    # load_plan(path).dumps() == runtime.plan.dumps() guarantees the
    # bitwise per-leaf restore path applies to every group
    (path / "plan.json").write_text(
        json.dumps(runtime.plan.to_json(), sort_keys=True, indent=1))
    # flat stores save one array per group (the seed's format); dict states
    # (q8_block) save one array per leaf: param__<group>__<leaf>
    arrays = {}
    for k, v in params.items():
        if isinstance(v, dict):
            for leaf, a in v.items():
                arrays[f"param__{k}__{leaf}"] = _savable(a)
        else:
            arrays[f"param__{k}"] = _savable(v)
    if opt_state is not None:
        flat, _ = tree_flatten_with_path(opt_state)
        for kp, v in flat:
            key = "opt__" + "__".join(
                getattr(p, "key", str(p)) for p in kp)
            arrays[key] = _savable(v)
    np.savez(path / "state.npz", **arrays)


def _savable(a) -> np.ndarray:
    """np.savez round-trips numpy-native dtypes only: ml_dtypes bfloat16
    degrades to a raw void ('|V2') array on load.  Widen bf16 to fp32 on
    disk (exact; the store format in meta says what to narrow back to)."""
    a = np.asarray(a)
    if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
        return np.asarray(jnp.asarray(a).astype(jnp.float32))
    return a


def load(path, runtime, opt_state_like=None):
    """Restore params (+ optionally opt state) onto the runtime's mesh.

    If the saved plan AND store format match the runtime's, buffers load
    leaf-by-leaf directly (bitwise: a q8_block round-trip preserves the
    master shard and the codes exactly).  Otherwise the fp32 master is
    reconstructed from the saved state, re-extracted via the saved index
    and re-packed with the current plan if the plans differ, and the
    runtime's store re-derives its state from it (resharded and/or
    re-formatted restore: codes are requantized from the master, which is
    exact because align pins every tensor start to the quant block)."""
    from jax.sharding import NamedSharding

    path = pathlib.Path(path)
    meta = json.loads((path / "meta.json").read_text())
    data = np.load(path / "state.npz")
    params = {}
    for name, lo in runtime.layouts.items():
        saved = meta["groups"][name]
        saved_store = saved.get("store", "fp32")  # pre-store checkpoints
        same_plan = (
            saved["shard_size"] == lo.plan.shard_size
            and saved["num_shards"] == lo.plan.num_shards
            and saved["outer_size"] == lo.outer_size
            and saved["mode"] == lo.plan.mode
        )
        sharding = NamedSharding(runtime.mesh, lo.pspec())
        same_store = (
            saved_store == lo.store.fmt
            and saved.get("ef_m", 0) == lo.store.ef_m
            and (not (lo.store.quantized or lo.store.has_ef)
                 or saved.get("quant_block") == lo.store.block))
        keys = lo.store.state_keys()
        if same_plan and same_store:
            if keys is not None:
                # dict states (q8 and/or EF residual) restore per leaf;
                # bf16 leaves were widened to fp32 on disk (_savable) --
                # narrow back to the leaf dtype, an exact round-trip
                state = {
                    leaf: np.asarray(
                        jnp.asarray(data[f"param__{name}__{leaf}"])
                        .astype(lo.store.leaf_dtype(leaf)))
                    for leaf in keys}
            else:
                state = np.asarray(
                    jnp.asarray(data[f"param__{name}"])
                    .astype(lo.store.storage_dtype))
        else:
            master = _saved_master(data, name, saved_store,
                                   saved.get("ef_m", 0))
            if not same_plan:
                master = _repack(master, saved, lo)
            # cross-plan/format rebuild: EF residuals restart at zero (a
            # fresh error-feedback history is always valid)
            state = lo.store.create(master)
        params[name] = jax.tree.map(
            lambda a: jax.device_put(a, sharding), state)
    out = [params, int(meta["step"])]
    if opt_state_like is not None:
        flat, tree = tree_flatten_with_path(opt_state_like)
        restored = []
        for kp, like in flat:
            key = "opt__" + "__".join(getattr(p, "key", str(p)) for p in kp)
            restored.append(jax.device_put(data[key], like.sharding))
        out.append(tree_unflatten(tree, restored))
    return tuple(out)


def load_plan(path):
    """The ShardingPlan saved with a checkpoint (None for pre-plan
    checkpoints).  Restoring through ``FSDPRuntime(model, mesh,
    plan=load_plan(p))`` reconstructs the saved layout exactly, making the
    bitwise per-leaf restore path apply to every group; comparing against a
    fresh plan's ``dumps()`` (or ``plan.diff``) shows precisely which
    groups will take the rebuild-from-master path instead."""
    from ..core.policy import ShardingPlan

    f = pathlib.Path(path) / "plan.json"
    if not f.exists():
        return None
    return ShardingPlan.from_json(json.loads(f.read_text()))


def _saved_master(data, name: str, saved_store: str,
                  saved_ef_m: int = 0) -> np.ndarray:
    """fp32 master weights of one group from a saved state of any format
    (dict states -- quantized and/or EF-carrying -- save a master leaf)."""
    if saved_store == "q8_block" or saved_ef_m:
        return np.asarray(data[f"param__{name}__master"], np.float32)
    return np.asarray(data[f"param__{name}"], np.float32)


def _repack(buf: np.ndarray, saved: dict, lo) -> np.ndarray:
    """Cross-plan restore: unpack tensors via the saved index, re-pack with
    the current plan.  Only same outer_size is supported (TP regrouping
    would need the StridedRagged reshuffle)."""
    if saved["outer_size"] != lo.outer_size:
        raise ValueError(
            f"cross-TP restore not supported: checkpoint outer_size "
            f"{saved['outer_size']} != runtime {lo.outer_size}")
    idx = saved["index"]
    old_total = saved["shard_size"] * saved["num_shards"]
    layers = buf.reshape((-1, lo.outer_size * old_total))
    out = np.zeros(
        (layers.shape[0], lo.outer_size * lo.plan.total), buf.dtype)
    for li in range(layers.shape[0]):
        for r in range(lo.outer_size):
            old = layers[li, r * old_total:(r + 1) * old_total]
            arrays = {
                name: old[m["offset"]: m["offset"] + int(np.prod(m["shape"]))
                          ].reshape(m["shape"])
                for name, m in idx.items()
            }
            out[li, r * lo.plan.total:(r + 1) * lo.plan.total] = (
                lo.buffer.pack(arrays))
    return out.reshape(buf.shape[:1] + (-1,)) if buf.ndim > 1 else out[0]
