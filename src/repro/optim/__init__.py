from .adamw import AdamW
from .sgd import SGDMomentum
from .adam8bit import Adam8bit
from .muon import Muon
from .shampoo import Shampoo

OPTIMIZERS = {
    "adamw": AdamW,
    "sgd": SGDMomentum,
    "adam8bit": Adam8bit,
    "muon": Muon,
    "shampoo": Shampoo,
}


def make_optimizer(cfg):
    return OPTIMIZERS[cfg.optimizer](cfg)
