"""AdamW on flat DBuffer shards (fp32 master weights, group-fused update).

The whole per-group step -- moment update, weight write, AND the store
re-encode (``ParamStore.rebuild`` semantics: bf16 round / fp8 cast /
q8_block requantize) -- runs as ONE fused kernel through the dispatch
layer (``ops.adamw_store_update``: Pallas on TPU, the same kernel body
interpreted elsewhere).  The jnp composition it replaces lives on as the
parity oracle in ``kernels/ref.py`` (``adamw_store_update_ref``); the
fused path is BITWISE against it, so this module is bit-for-bit the
pre-fusion optimizer."""
from __future__ import annotations

import jax.numpy as jnp

from ..kernels import ops
from .common import OptimizerBase, matrix_mask_local


class AdamW(OptimizerBase):
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.1

    def state_shapes(self, runtime):
        return {"m": self._like_params(runtime),
                "v": self._like_params(runtime)}

    def update(self, runtime, params, grads, state, step):
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t
        new_p, new_m, new_v = {}, {}, {}
        for name, pstate in params.items():
            store = runtime.layouts[name].store
            buf = pstate["master"] if isinstance(pstate, dict) else pstate
            wdm = matrix_mask_local(runtime, runtime.layouts[name],
                                    buf.shape)
            core, m2, v2 = ops.adamw_store_update(
                buf, grads[name], state["m"][name], state["v"][name], wdm,
                lr=lr, b1=self.b1, b2=self.b2, eps=self.eps, wd=self.wd,
                c1=c1, c2=c2, fmt=store.fmt, block=store.block)
            new_p[name] = store.wrap_core(core)
            new_m[name], new_v[name] = m2, v2
        return new_p, {"m": new_m, "v": new_v}
