"""AdamW on flat DBuffer shards (fp32 master weights, group-fused update).

The master weights come from each group's ParamStore (``master_f32`` is the
buffer itself for fp32 stores -- bitwise-identical update graph -- and the
fp32 master shard for q8_block); ``rebuild`` writes the update back in the
group's storage format, requantizing codes/scales in the same fused pass
for quantized stores."""
from __future__ import annotations

import jax.numpy as jnp

from .common import OptimizerBase, matrix_mask_local


class AdamW(OptimizerBase):
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.1

    def state_shapes(self, runtime):
        return {"m": self._like_params(runtime),
                "v": self._like_params(runtime)}

    def update(self, runtime, params, grads, state, step):
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t
        new_p, new_m, new_v = {}, {}, {}
        for name, pstate in params.items():
            store = runtime.layouts[name].store
            w = store.master_f32(pstate)
            g = grads[name].astype(jnp.float32)
            m = self.b1 * state["m"][name] + (1 - self.b1) * g
            v = self.b2 * state["v"][name] + (1 - self.b2) * g * g
            upd = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            wdm = matrix_mask_local(runtime, runtime.layouts[name], w.shape)
            new_p[name] = store.rebuild(w - lr * (upd + self.wd * wdm * w))
            new_m[name], new_v[name] = m, v
        return new_p, {"m": new_m, "v": new_v}
