"""Distributed Shampoo via RaggedShard redistribute (paper §2.1 motivation).

Shampoo preconditions each 2-D parameter with Kronecker factors
L = sum G G^T and R = sum G^T G:  update = L^{-1/4} G R^{-1/4}.
Like Muon (Algorithm 2), this needs whole matrices; we reuse the same
SPMD-clean distribution: the layer dimension of each stacked group is
resharded across the FSDP group (each device preconditions L/m whole
matrices -- row-wise RaggedShard over layers), and the Kronecker factors are
*stored* sharded the same way, so preconditioner updates are local and only
the preconditioned updates are gathered back.

Inverse 4th roots via eigh each step (production systems amortize this over
~100 steps; kept per-step here for simplicity -- noted in DESIGN.md).
Non-2D parameters and unstacked groups fall back to AdamW.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from .common import OptimizerBase, device_linear_index, matrix_mask_local


def _inv_4th_root(M, eps=1e-6):
    """M symmetric PSD (k, k) -> M^{-1/4} via eigendecomposition."""
    w, V = jnp.linalg.eigh(M.astype(jnp.float32))
    w = jnp.maximum(w, eps * jnp.maximum(w.max(), 1.0))
    return (V * (w ** -0.25)) @ V.T


class Shampoo(OptimizerBase):
    b1 = 0.9          # momentum on the preconditioned update
    eps, wd = 1e-6, 0.1

    # ------------------------------------------------------------------ #
    def _factor_specs(self, runtime):
        """{state_key: (global_shape, pspec)} for the Kronecker factors,
        sharded over the padded layer dim across the group's FSDP axes."""
        out = {}
        sizes = dict(zip(runtime.mesh.axis_names,
                         runtime.mesh.devices.shape))
        for gname, lo in runtime.layouts.items():
            if lo.n_layers is None:
                continue
            m = int(np.prod([sizes[a] for a in lo.fsdp_axes])) or 1
            lp = -(-lo.n_layers // m) * m
            axes = lo.fsdp_axes if len(lo.fsdp_axes) > 1 else (
                lo.fsdp_axes[0] if lo.fsdp_axes else None)
            for pl in lo.plan.placements:
                if len(pl.spec.shape) != 2:
                    continue
                a, b = pl.spec.shape
                out[f"{gname}/{pl.spec.name}/L"] = (
                    (lp, a, a), P(axes, None, None))
                out[f"{gname}/{pl.spec.name}/R"] = (
                    (lp, b, b), P(axes, None, None))
        return out

    def state_shapes(self, runtime):
        base = {
            "mom": self._like_params(runtime),
            "m": self._like_params(runtime),
            "v": self._like_params(runtime),
        }
        facs = {}
        for key, (shape, spec) in self._factor_specs(runtime).items():
            facs[key] = jax.ShapeDtypeStruct(
                shape, jnp.float32,
                sharding=NamedSharding(runtime.mesh, spec))
        base["factors"] = facs
        return base

    def pspecs(self, runtime):
        ps = {n: lo.pspec() for n, lo in runtime.layouts.items()}
        out = {k: dict(ps) for k in ("mom", "m", "v")}
        out["factors"] = {
            key: spec for key, (shape, spec) in
            self._factor_specs(runtime).items()
        }
        return out

    # ------------------------------------------------------------------ #
    def _precondition_group(self, runtime, lo, gname, g_local, factors):
        """g_local: (L, S) local grad shard.  Returns ((L, S) preconditioned
        update for 2-D positions, updated factors)."""
        L = lo.n_layers
        S = lo.plan.shard_size
        sizes = dict(zip(runtime.mesh.axis_names,
                         runtime.mesh.devices.shape))
        m = int(np.prod([sizes[a] for a in lo.fsdp_axes])) or 1
        dev = device_linear_index(runtime, lo)
        full = (lax.all_gather(g_local, lo.fsdp_axes, tiled=True, axis=1)
                if lo.fsdp_axes else g_local)
        upd_full = jnp.zeros_like(full)
        l_loc = -(-L // m)
        Lp = l_loc * m
        new_factors = {}
        for pl in lo.plan.placements:
            if len(pl.spec.shape) != 2:
                continue
            a, b = pl.spec.shape
            mats = lax.slice(full, (0, pl.offset), (L, pl.end)).reshape(L, a, b)
            if Lp != L:
                mats = jnp.pad(mats, ((0, Lp - L), (0, 0), (0, 0)))
            mine = lax.dynamic_slice(mats, (dev * l_loc, 0, 0),
                                     (l_loc, a, b)).astype(jnp.float32)
            Lf = factors[f"{gname}/{pl.spec.name}/L"] + jnp.einsum(
                "lab,lcb->lac", mine, mine)
            Rf = factors[f"{gname}/{pl.spec.name}/R"] + jnp.einsum(
                "lab,lac->lbc", mine, mine)
            Li = jax.vmap(_inv_4th_root)(Lf)
            Ri = jax.vmap(_inv_4th_root)(Rf)
            o = jnp.einsum("lac,lcb,lbd->lad", Li, mine, Ri)
            # graft to the gradient's per-matrix RMS (keeps lr comparable)
            gn = jnp.sqrt(jnp.mean(mine ** 2, axis=(1, 2), keepdims=True))
            on = jnp.sqrt(jnp.mean(o ** 2, axis=(1, 2), keepdims=True))
            o = o * (gn / jnp.maximum(on, 1e-12))
            if lo.fsdp_axes:
                o = lax.all_gather(o, lo.fsdp_axes, tiled=True, axis=0)
            upd_full = upd_full.at[:, pl.offset:pl.end].set(
                o[:L].reshape(L, a * b).astype(upd_full.dtype))
            new_factors[f"{gname}/{pl.spec.name}/L"] = Lf
            new_factors[f"{gname}/{pl.spec.name}/R"] = Rf
        local_upd = lax.dynamic_slice(upd_full, (0, dev * S), (L, S))
        return local_upd, new_factors

    # ------------------------------------------------------------------ #
    def update(self, runtime, params, grads, state, step):
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - 0.9 ** t
        c2 = 1.0 - 0.95 ** t
        new_p = {}
        new_s = {"mom": {}, "m": {}, "v": {}, "factors": dict(state["factors"])}
        for name, pstate in params.items():
            lo = runtime.layouts[name]
            w = lo.store.master_f32(pstate)
            g = grads[name].astype(jnp.float32)
            m = 0.9 * state["m"][name] + 0.1 * g
            v = 0.95 * state["v"][name] + 0.05 * g * g
            adam_upd = (m / c1) / (jnp.sqrt(v / c2) + 1e-8)
            mask2d = matrix_mask_local(runtime, lo, w.shape)
            has_mats = lo.n_layers is not None and any(
                len(pl.spec.shape) == 2 for pl in lo.plan.placements)
            if has_mats:
                pre, nf = self._precondition_group(
                    runtime, lo, name, g, state["factors"])
                new_s["factors"].update(nf)
                mom = self.b1 * state["mom"][name] + pre
                upd = mask2d * mom + (1 - mask2d) * adam_upd
            else:
                mom = state["mom"][name]
                upd = adam_upd
            new_p[name] = lo.store.rebuild(
                w - lr * (upd + self.wd * mask2d * w))
            new_s["mom"][name] = mom
            new_s["m"][name], new_s["v"][name] = m, v
        return new_p, new_s
