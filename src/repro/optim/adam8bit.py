"""8-bit Adam (paper §6.3): block-wise INT8-quantized moment states.

Because the planner aligns every tensor start and the shard size to
``cfg.quant_block`` (the `align` option) for adam8bit models, fixed
quant tiles over the *local shard* never straddle a tensor start or a device
boundary -- each device (de)quantizes with zero communication, which is
the paper's central flexibility claim.

States: m, v stored as int8 codes + one f32 absmax scale per block.
The (de)quantize steps run through the kernels dispatch layer
(repro.kernels.ops: fused Pallas on TPU, interpreted elsewhere); the
fully-fused single-kernel update (repro.kernels.adam8bit_update) remains
the opt-in fast path and this jnp composition is its oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..kernels import ops
from .common import OptimizerBase, matrix_mask_local


class Adam8bit(OptimizerBase):
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.1

    def __init__(self, cfg):
        super().__init__(cfg)
        self.block = cfg.quant_block

    def state_shapes(self, runtime):
        bq = self.block
        for lo in runtime.layouts.values():
            if lo.plan.shard_size % bq:
                raise ValueError(
                    f"group {lo.name}: shard {lo.plan.shard_size} not "
                    f"aligned to quant block {bq} -- planner align missing?")
        return {
            "m8": self._like_params(runtime, jnp.int8),
            "v8": self._like_params(runtime, jnp.int8),
            "ms": self._like_params(runtime, jnp.float32, div=bq),
            "vs": self._like_params(runtime, jnp.float32, div=bq),
        }

    def update(self, runtime, params, grads, state, step):
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t
        bq = self.block
        new_p = {}
        new_s = {k: {} for k in ("m8", "v8", "ms", "vs")}
        for name, pstate in params.items():
            store = runtime.layouts[name].store
            w = store.master_f32(pstate)
            g = grads[name].astype(jnp.float32)
            # m: signed linear int8; v: log-space int8 (dynamic range --
            # linear quantization underflows v and explodes the update)
            m = ops.dequantize(state["m8"][name], state["ms"][name], bq)
            v = ops.dequantize_log(state["v8"][name], state["vs"][name], bq)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            upd = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            wdm = matrix_mask_local(runtime, runtime.layouts[name], w.shape)
            new_p[name] = store.rebuild(w - lr * (upd + self.wd * wdm * w))
            m8, ms = ops.quantize(m, bq)
            v8, vs = ops.quantize_log(v, bq)
            new_s["m8"][name], new_s["ms"][name] = m8, ms
            new_s["v8"][name], new_s["vs"][name] = v8, vs
        return new_p, new_s
