"""8-bit Adam (paper §6.3): block-wise INT8-quantized moment states.

Because the planner aligns every tensor start and the shard size to
``cfg.quant_block`` (the `align` option) for adam8bit models, fixed
quant tiles over the *local shard* never straddle a tensor start or a device
boundary -- each device (de)quantizes with zero communication, which is
the paper's central flexibility claim.

States: m, v stored as int8 codes + one f32 absmax scale per block.
The whole step -- moment dequant, update math, moment requant, AND the
store re-encode (bf16 round / fp8 cast / q8_block requantize) -- runs as
ONE fused kernel through the dispatch layer
(``ops.adam8bit_store_update``: Pallas on TPU, the same body interpreted
elsewhere), BITWISE against the jnp composition in ``kernels/ref.py``
(``adam8bit_store_update_ref``).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..kernels import ops
from .common import OptimizerBase, matrix_mask_local


class Adam8bit(OptimizerBase):
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.1

    def __init__(self, cfg):
        super().__init__(cfg)
        self.block = cfg.quant_block

    def state_shapes(self, runtime):
        bq = self.block
        for lo in runtime.layouts.values():
            if lo.plan.shard_size % bq:
                raise ValueError(
                    f"group {lo.name}: shard {lo.plan.shard_size} not "
                    f"aligned to quant block {bq} -- planner align missing?")
        return {
            "m8": self._like_params(runtime, jnp.int8),
            "v8": self._like_params(runtime, jnp.int8),
            "ms": self._like_params(runtime, jnp.float32, div=bq),
            "vs": self._like_params(runtime, jnp.float32, div=bq),
        }

    def update(self, runtime, params, grads, state, step):
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t
        bq = self.block
        new_p = {}
        new_s = {k: {} for k in ("m8", "v8", "ms", "vs")}
        for name, pstate in params.items():
            store = runtime.layouts[name].store
            if store.quantized and store.block != bq:
                raise ValueError(
                    f"group {name}: store quant block {store.block} != "
                    f"optimizer quant block {bq}")
            buf = pstate["master"] if isinstance(pstate, dict) else pstate
            wdm = matrix_mask_local(runtime, runtime.layouts[name],
                                    buf.shape)
            # m: signed linear int8; v: log-space int8 (dynamic range --
            # linear quantization underflows v and explodes the update)
            core, m8, v8, ms, vs = ops.adam8bit_store_update(
                buf, grads[name], state["m8"][name], state["v8"][name],
                state["ms"][name], state["vs"][name], wdm, lr=lr,
                b1=self.b1, b2=self.b2, eps=self.eps, wd=self.wd, c1=c1,
                c2=c2, fmt=store.fmt, block=bq)
            new_p[name] = store.wrap_core(core)
            new_s["m8"][name], new_s["ms"][name] = m8, ms
            new_s["v8"][name], new_s["vs"][name] = v8, vs
        return new_p, new_s
