"""SGD with momentum on flat shards (the paper's OOM-avoidance baseline for
GPT-OSS; we use it analogously for the 340B config without 8-bit Adam)."""
from __future__ import annotations

import jax.numpy as jnp

from .common import OptimizerBase


class SGDMomentum(OptimizerBase):
    mu = 0.9

    def state_shapes(self, runtime):
        return {"m": self._like_params(runtime)}

    def update(self, runtime, params, grads, state, step):
        lr = self.schedule(step)
        new_p, new_m = {}, {}
        for name, pstate in params.items():
            store = runtime.layouts[name].store
            w = store.master_f32(pstate)
            g = grads[name].astype(jnp.float32)
            m = self.mu * state["m"][name] + g
            new_p[name] = store.rebuild(w - lr * m)
            new_m[name] = m
        return new_p, {"m": new_m}
