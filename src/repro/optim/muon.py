"""Distributed Muon via RaggedShard redistribute (paper §6.3, Algorithm 2).

Muon's Newton-Schulz preconditioner needs each 2-D parameter as its full
matrix.  The paper redistributes each tensor to a load-balanced root rank,
runs NS there, and redistributes back.  SPMD/TPU adaptation (DESIGN.md):
the layer dimension of a stacked group plays the role of root selection --
the gathered momentum matrices (L, a, b) are *resharded over layers* across
the FSDP group (each device preconditioning L/m whole matrices: uneven whole-
matrix ownership is exactly a row-wise RaggedShard over the L axis), then
all-gathered back and scattered into the flat update buffer.  Communication
= one extra all-gather of the NS outputs, matching Algorithm 2's
redistribute-back.

Non-2D parameters and unstacked groups (embeddings, head, norms) fall back
to AdamW, as in the Muon reference practice and the paper's experiments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .common import OptimizerBase, device_linear_index, matrix_mask_local

_NS_COEFFS = (3.4445, -4.7750, 2.0315)


def newton_schulz(G, steps: int = 5, eps: float = 1e-7):
    """Matrix-sign iteration; G: (a, b) with any aspect."""
    a, b, c = _NS_COEFFS
    transpose = G.shape[0] > G.shape[1]
    X = G.T if transpose else G
    X = X / (jnp.linalg.norm(X) + eps)
    for _ in range(steps):
        A = X @ X.T
        B = b * A + c * (A @ A)
        X = a * X + B @ X
    return (X.T if transpose else X).astype(G.dtype)


class Muon(OptimizerBase):
    mu = 0.95
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.1

    def state_shapes(self, runtime):
        return {k: self._like_params(runtime) for k in ("mom", "m", "v")}

    # ------------------------------------------------------------------ #
    def _muon_group_update(self, runtime, lo, mom_local):
        """mom_local: (L, S).  Returns (L, S) NS-preconditioned update for
        2-D positions (zeros elsewhere)."""
        L = lo.n_layers
        S = lo.plan.shard_size
        m = int(np.prod([
            dict(zip(runtime.mesh.axis_names,
                     runtime.mesh.devices.shape))[a]
            for a in lo.fsdp_axes
        ])) or 1
        dev = device_linear_index(runtime, lo)

        if lo.fsdp_axes:
            full = lax.all_gather(mom_local, lo.fsdp_axes, tiled=True,
                                  axis=1)  # (L, m*S)
        else:
            full = mom_local
        upd_full = jnp.zeros_like(full)
        l_loc = -(-L // m)
        Lp = l_loc * m
        for pl in lo.plan.placements:
            if len(pl.spec.shape) != 2:
                continue
            a, b = pl.spec.shape
            mats = lax.slice(full, (0, pl.offset), (L, pl.end)).reshape(L, a, b)
            if Lp != L:
                mats = jnp.pad(mats, ((0, Lp - L), (0, 0), (0, 0)))
            mine = lax.dynamic_slice(mats, (dev * l_loc, 0, 0), (l_loc, a, b))
            o = jax.vmap(newton_schulz)(mine.astype(jnp.float32))
            o = o * jnp.sqrt(jnp.maximum(1.0, a / b))
            if lo.fsdp_axes:
                o = lax.all_gather(o, lo.fsdp_axes, tiled=True, axis=0)  # (Lp,a,b)
            # static slice assignment (offsets can exceed int32 as traced
            # starts; as python slices they stay exact)
            upd_full = upd_full.at[:, pl.offset:pl.end].set(
                o[:L].reshape(L, a * b).astype(upd_full.dtype))
        return lax.dynamic_slice(upd_full, (0, dev * S), (L, S))

    # ------------------------------------------------------------------ #
    def update(self, runtime, params, grads, state, step):
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t
        new_p = {}
        new_s = {"mom": {}, "m": {}, "v": {}}
        for name, pstate in params.items():
            lo = runtime.layouts[name]
            w = lo.store.master_f32(pstate)
            g = grads[name].astype(jnp.float32)
            mom = self.mu * state["mom"][name] + g
            m = self.b1 * state["m"][name] + (1 - self.b1) * g
            v = self.b2 * state["v"][name] + (1 - self.b2) * g * g
            adam_upd = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            mask2d = matrix_mask_local(runtime, lo, w.shape)
            use_muon = lo.n_layers is not None and any(
                len(pl.spec.shape) == 2 for pl in lo.plan.placements
            )
            if use_muon:
                muon_upd = self._muon_group_update(
                    runtime, lo, self.mu * mom + g  # nesterov-style
                )
                upd = mask2d * muon_upd + (1 - mask2d) * adam_upd
            else:
                upd = adam_upd
            new_p[name] = lo.store.rebuild(
                w - lr * (upd + self.wd * mask2d * w))
            new_s["mom"][name] = mom
            new_s["m"][name], new_s["v"][name] = m, v
        return new_p, new_s
