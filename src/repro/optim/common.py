"""Optimizer base utilities working on flat DBuffer shards.

Optimizers run *inside* shard_map on the device-local slice of each group
buffer, so every update is one group-fused elementwise pass (the DBuffer
batched-kernel claim of the paper).  Per-tensor behavior (weight decay only
on matrices, Muon only on 2D params) is recovered from the static plan via
position masks computed from the device's linear FSDP index.

Storage formats: ``params[name]`` is a ParamStore *state* (core.store) --
the flat buffer itself for fp32/bf16 stores, a codes/master/scales dict for
q8_block.  Every optimizer reads the fp32 weights through
``layout.store.master_f32`` (identity for fp32: the update graph stays
bitwise-identical to the pre-store runtime) and writes them back through
``layout.store.rebuild``, which requantizes codes/scales inside the same
fused update pass for quantized stores.  Optimizer *state* (m/v/moments) is
always master-shaped, independent of the store format.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P


def device_linear_index(runtime, layout):
    """This device's shard index within the group's FSDP axes (0..m-1)."""
    idx = 0
    sizes = dict(zip(runtime.mesh.axis_names, runtime.mesh.devices.shape))
    for a in layout.fsdp_axes:
        idx = idx * sizes[a] + lax.axis_index(a)
    return idx


def matrix_mask_local(runtime, layout, local_shape):
    """(local_shape) 0/1 mask: 1 where the flat position belongs to a >=2-D
    tensor (weight-decay / Muon eligible).  Computed from plan intervals and
    the device index; O(#tensors) vector ops.

    Global offsets can exceed int32 (multi-billion-element groups), so the
    comparison runs in (128-lane block, within-block) coordinates: block
    indices stay < total/128 < 2^31 for any realistic group."""
    S = layout.plan.shard_size  # multiple of LANE=128 by planner g_coll
    dev = device_linear_index(runtime, layout)
    blk = dev * (S // 128) + jnp.arange(S, dtype=jnp.int32) // 128
    within = jnp.arange(S, dtype=jnp.int32) % 128

    def ge(off: int):  # global_pos >= off
        ob, orem = off // 128, off % 128
        return (blk > ob) | ((blk == ob) & (within >= orem))

    mask = jnp.zeros((S,), jnp.float32)
    for pl in layout.plan.placements:
        if len(pl.spec.shape) >= 2:
            mask = jnp.where(ge(pl.offset) & ~ge(pl.end), 1.0, mask)
    # broadcast to (L, S) etc.
    while mask.ndim < len(local_shape):
        mask = mask[None]
    return jnp.broadcast_to(mask, local_shape)


class OptimizerBase:
    def __init__(self, cfg):
        self.cfg = cfg
        self.lr = cfg.learning_rate

    # state shape helpers ------------------------------------------------
    def _like_params(self, runtime, dtype=jnp.float32, div: int = 1):
        out = {}
        for name, lo in runtime.layouts.items():
            shape = lo.global_shape()
            shape = shape[:-1] + (shape[-1] // div,)
            out[name] = jax.ShapeDtypeStruct(
                shape, dtype, sharding=NamedSharding(runtime.mesh, lo.pspec())
            )
        return out

    def _zeros(self, runtime, dtype=jnp.float32, div: int = 1):
        shapes = self._like_params(runtime, dtype, div)
        return {
            k: jax.device_put(
                np.zeros(v.shape, v.dtype), v.sharding
            )
            for k, v in shapes.items()
        }

    # dry-run support: state as ShapeDtypeStructs (no allocation) ---------
    def state_shapes(self, runtime) -> dict:
        """{state_key: {group_name: ShapeDtypeStruct}}; every leaf is
        sharded with its group's pspec."""
        raise NotImplementedError

    def init(self, runtime):
        return jax.tree.map(
            lambda s: jax.device_put(np.zeros(s.shape, s.dtype), s.sharding),
            self.state_shapes(runtime),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def pspecs(self, runtime):
        return {
            key: {g: runtime.layouts[g].pspec() for g in sub}
            for key, sub in self.state_shapes(runtime).items()
        }

    def _param_pspecs(self, runtime):
        return {n: lo.pspec() for n, lo in runtime.layouts.items()}

    def schedule(self, step):
        warmup = 100.0
        return self.lr * jnp.minimum((step + 1.0) / warmup, 1.0)
