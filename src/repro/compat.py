"""JAX version-abstraction layer: the ONLY module allowed to touch
version-specific JAX symbols.

The runtime targets every JAX from 0.4.3x (installed here: 0.4.37, where
``shard_map`` lives in ``jax.experimental.shard_map`` and takes
``check_rep``) through current releases (``jax.shard_map`` with
``check_vma``, meshes built with ``axis_types``).  Everything else in the
repo imports these wrappers:

  * ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check=False)``
  * ``make_mesh(axis_shapes, axis_names)`` -- tries the ``axis_types``
    (explicit-sharding-era) API first, falls back to plain ``jax.make_mesh``
    and finally to ``mesh_utils`` + ``Mesh``
  * ``tree_flatten_with_path`` / ``tree_unflatten`` -- ``jax.tree`` grew
    ``flatten_with_path`` after 0.4.37; older code spells it
    ``jax.tree_util.tree_flatten_with_path``.  (Plain ``jax.tree.map`` /
    ``leaves`` exist on every supported version and are used directly.)
"""
from __future__ import annotations

from typing import Any, Callable

import jax

# --------------------------------------------------------------------------- #
# shard_map
# --------------------------------------------------------------------------- #
_new_shard_map = getattr(jax, "shard_map", None)
if _new_shard_map is None:
    from jax.experimental.shard_map import shard_map as _impl_shard_map
else:
    _impl_shard_map = _new_shard_map

# the replication-check kwarg was renamed check_rep -> check_vma after the
# top-level jax.shard_map export appeared, so key on the actual signature
# rather than on where the function lives
try:
    import inspect as _inspect

    _CHECK_KW = ("check_vma"
                 if "check_vma" in _inspect.signature(
                     _impl_shard_map).parameters
                 else "check_rep")
except (TypeError, ValueError):  # C-accelerated wrapper: assume current API
    _CHECK_KW = "check_vma"


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check: bool = False) -> Callable:
    """Portable shard_map.  ``check`` maps to ``check_vma`` on new JAX and
    ``check_rep`` on old JAX (both default False here: the runtime uses
    untraceable-replication collectives like psum_scatter)."""
    return _impl_shard_map(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_CHECK_KW: check})


# --------------------------------------------------------------------------- #
# mesh construction
# --------------------------------------------------------------------------- #
def make_mesh(axis_shapes: tuple[int, ...], axis_names: tuple[str, ...],
              *, devices=None):
    """Build a Mesh on any JAX version.

    New JAX wants every axis marked ``AxisType.Auto`` so shard_map +
    NamedSharding keep their classic semantics; old JAX has no axis types
    (everything is implicitly auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names),
                devices=devices,
            )
        except TypeError:  # make_mesh predates axis_types kwarg
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
    from jax.experimental import mesh_utils

    devs = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
    return jax.sharding.Mesh(devs, axis_names)


# --------------------------------------------------------------------------- #
# differentiable optimization barrier
# --------------------------------------------------------------------------- #
# ``lax.optimization_barrier`` exists on every supported JAX but only grew
# autodiff rules after 0.4.37; this wrapper barriers the cotangents itself
# so it differentiates everywhere.  The runtime uses it to force value
# materialization at layer seams inside fused scan bodies (XLA's bf16 pass
# may otherwise keep wider intermediates across the seam, changing bf16
# roundings vs a per-layer scan-iteration boundary).
_lax_barrier = jax.lax.optimization_barrier


def _barrier_inexact(tree):
    """Barrier inexact leaves; pass ints/float0 cotangents through (XLA's
    optimization_barrier rejects float0, and integer leaves don't carry
    numerics worth pinning)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    f0 = jax.dtypes.float0
    keep = [jnp_issubdtype_inexact(l) and getattr(l, "dtype", None) != f0
            for l in leaves]
    picked = [l for l, k in zip(leaves, keep) if k]
    barriered = iter(_lax_barrier(picked) if picked else ())
    out = [next(barriered) if k else l for l, k in zip(leaves, keep)]
    return jax.tree_util.tree_unflatten(treedef, out)


def jnp_issubdtype_inexact(x) -> bool:
    import jax.numpy as _jnp

    dt = getattr(x, "dtype", None)
    return dt is not None and _jnp.issubdtype(dt, _jnp.inexact)


@jax.custom_vjp
def optimization_barrier(tree):
    return _barrier_inexact(tree)


def _ob_fwd(tree):
    return _barrier_inexact(tree), None


def _ob_bwd(_res, ct):
    return (_barrier_inexact(ct),)


optimization_barrier.defvjp(_ob_fwd, _ob_bwd)


# --------------------------------------------------------------------------- #
# float8 dtypes (guarded)
# --------------------------------------------------------------------------- #
def float8_dtypes() -> dict:
    """The float8 dtypes this JAX installation provides, as
    ``{wire-format alias: dtype}`` (``fp8_e4m3`` -> float8_e4m3fn,
    ``fp8_e5m2`` -> float8_e5m2).  Empty on installations without ml_dtypes
    float8 support.  core.wire registers these as legal cast wire formats
    (and, eventually, ParamStore formats) only when present, so call sites
    never need a version check of their own."""
    import jax.numpy as _jnp

    out = {}
    for alias, attr in (("fp8_e4m3", "float8_e4m3fn"),
                        ("fp8_e5m2", "float8_e5m2")):
        dt = getattr(_jnp, attr, None)
        if dt is not None:
            out[alias] = _jnp.dtype(dt)
    return out


HAS_FP8 = bool(float8_dtypes())


# --------------------------------------------------------------------------- #
# compiled-artifact introspection
# --------------------------------------------------------------------------- #
def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a one-element list of dicts on
    JAX 0.4.x and a plain dict on newer releases; normalize to a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


# --------------------------------------------------------------------------- #
# tree utilities
# --------------------------------------------------------------------------- #
def tree_flatten_with_path(tree: Any):
    t = getattr(jax, "tree", None)
    if t is not None and hasattr(t, "flatten_with_path"):
        return t.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def tree_unflatten(treedef, leaves):
    if hasattr(jax, "tree"):
        return jax.tree.unflatten(treedef, leaves)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def tree_map_with_path(f: Callable, tree: Any, *rest: Any):
    """The ``*_with_path`` family migrated from ``jax.tree_util`` to
    ``jax.tree`` across 0.4.x; prefer the new home."""
    t = getattr(jax, "tree", None)
    if t is not None and hasattr(t, "map_with_path"):
        return t.map_with_path(f, tree, *rest)
    return jax.tree_util.tree_map_with_path(f, tree, *rest)
