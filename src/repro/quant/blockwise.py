"""Block-wise quantization (paper §2.1/§6.3; Dettmers et al. 2022).

Symmetric linear INT8 with one absmax scale per block of ``block`` contiguous
elements.  Communication-free under veScale-FSDP: the planner guarantees
(via granularity + align) that quant blocks never straddle device shards, so
each device quantizes its local shard independently -- exactly the paper's
8-bit Adam setup (32x32 blocks == 1024 flat elements).

These are the jnp reference implementations; the Pallas TPU kernels live in
repro.kernels (validated against these in interpret mode).
"""
from __future__ import annotations

import jax.numpy as jnp


def _check_blocking(n: int, block: int, who: str) -> None:
    """Shape validation that survives ``python -O`` (these are API
    contracts, not internal invariants, so no bare asserts).  Shared with
    the Pallas kernel wrappers (repro.kernels) so the kernel and the
    reference raise the identical ValueError instead of the kernel failing
    later with a cryptic reshape error."""
    if block < 1:
        raise ValueError(f"{who}: block must be >= 1, got {block}")
    if n % block != 0:
        raise ValueError(
            f"{who}: last dim {n} not divisible by block {block}")


def _check_scales(n: int, block: int, scales_last: int, who: str) -> None:
    """The dequantize-side half of the contract: one scale per block.
    Shared with the kernel wrappers for identical ValueErrors."""
    if scales_last != n // block:
        raise ValueError(
            f"{who}: scales last dim {scales_last} != "
            f"{n // block} blocks")


def quantize_blockwise(x, block: int):
    """x: (..., n) float, n % block == 0.
    Returns (codes int8 (..., n), scales f32 (..., n // block))."""
    n = x.shape[-1]
    _check_blocking(n, block, "quantize_blockwise")
    xb = x.reshape(x.shape[:-1] + (n // block, block)).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    codes = jnp.clip(jnp.round(xb * inv[..., None]), -127, 127).astype(jnp.int8)
    return codes.reshape(x.shape), scale


def dequantize_blockwise(codes, scales, block: int):
    n = codes.shape[-1]
    _check_blocking(n, block, "dequantize_blockwise")
    _check_scales(n, block, scales.shape[-1], "dequantize_blockwise")
    cb = codes.reshape(codes.shape[:-1] + (n // block, block)).astype(jnp.float32)
    out = cb * scales[..., None]
    return out.reshape(codes.shape)


# ---------------------------------------------------------------------------
# log-space quantization for non-negative, high-dynamic-range states (Adam's
# second moment).  Linear int8 underflows v to 0 inside blocks whose absmax
# is >> the typical entry, which explodes m/(sqrt(v)+eps) -- the reason the
# paper's 8-bit Adam reference [Dettmers et al.] uses *dynamic* quantization.
# codes: 0 == exact zero; 1..127 == absmax * exp((q-127)/127 * RANGE_NATS).
# ---------------------------------------------------------------------------

RANGE_NATS = 24.0  # ~1e-10 relative dynamic range, ~19% relative resolution


def quantize_blockwise_log(x, block: int):
    """x >= 0, (..., n).  Returns (codes int8 in [0,127], scales f32)."""
    n = x.shape[-1]
    _check_blocking(n, block, "quantize_blockwise_log")
    xb = x.reshape(x.shape[:-1] + (n // block, block)).astype(jnp.float32)
    absmax = jnp.max(xb, axis=-1)
    safe = xb / jnp.maximum(absmax[..., None], 1e-38)
    logq = jnp.log(jnp.maximum(safe, 1e-38)) / RANGE_NATS  # [-inf, 0]
    codes = jnp.round(127.0 * (1.0 + logq))
    codes = jnp.where(xb > 0, jnp.clip(codes, 1, 127), 0)
    return codes.astype(jnp.int8).reshape(x.shape), absmax


def dequantize_blockwise_log(codes, scales, block: int):
    n = codes.shape[-1]
    _check_blocking(n, block, "dequantize_blockwise_log")
    cb = codes.reshape(codes.shape[:-1] + (n // block, block)).astype(jnp.float32)
    val = jnp.exp((cb - 127.0) / 127.0 * RANGE_NATS) * scales[..., None]
    out = jnp.where(cb > 0, val, 0.0)
    return out.reshape(codes.shape)
