"""Model-layer primitives shared by every architecture.

Conventions:
  * linear weights are (d_in, d_out); y = x @ w
  * attention tensors are (B, T, H, hd) at rest, (B, H, T, hd) in flight
  * ``tp_axis`` is the mesh axis for tensor parallelism or None (pure FSDP);
    collectives are no-ops when it is None
  * softmax/normalizer math runs in float32 regardless of compute dtype
  * 32k-token prefill never materializes (T x T) logits: attention is chunked
    with an online softmax (lax.scan over KV blocks)
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops

NEG_INF = -1e30


def dense(x, w):
    """``y = x @ w`` with weight-format dispatch: a plain array casts to
    the activation dtype (op-for-op the pre-helper spelling, bitwise
    neutral); a ``QuantTensor`` (gathered-but-still-int8 q8_block weight,
    serve quant mode) routes through the int8 x int8 GEMM so the dense
    weight never materializes."""
    if isinstance(w, ops.QuantTensor):
        return ops.q8_matmul(x, w.codes, w.scales, w.block)
    return x @ w.astype(x.dtype)


def to_dense(w, dtype):
    """Materialize a weight in ``dtype`` -- the fallback for call sites
    that must slice or transpose the weight itself (replicated-KV head
    slicing, tied embeddings): QuantTensors take one fused per-tensor
    dequant, plain arrays just cast."""
    if isinstance(w, ops.QuantTensor):
        k, n = w.shape
        return ops.dequantize_into(
            w.codes.reshape(-1), w.scales, w.block,
            out_dtype=dtype).reshape(k, n)
    return w.astype(dtype)


def psum(x, axis):
    return lax.psum(x, axis) if axis else x


def reduce_out(x, axis, sp: bool):
    """Row-parallel output reduction: plain psum, or (sequence parallelism)
    a fused reduce-scatter over the sequence dim -- activations between
    blocks stay seq-sharded over the TP axis."""
    if not axis:
        return x
    if sp:
        return lax.psum_scatter(x, axis, scatter_dimension=1, tiled=True)
    return lax.psum(x, axis)


def gather_seq(x, axis, sp: bool):
    """Inverse of reduce_out's scatter: all-gather the sequence dim."""
    if axis and sp:
        return lax.all_gather(x, axis, axis=1, tiled=True)
    return x


def pmax(x, axis):
    return lax.pmax(x, axis) if axis else x


def axis_index(axis):
    return lax.axis_index(axis) if axis else 0


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (B, H, T, hd); positions: (B, T) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,T,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (GQA, causal/window/softcap/cross)
# ---------------------------------------------------------------------------

def chunked_attention(
    q,  # (B, Hq, Tq, hd)
    k,  # (B, Hkv, Tk, hd)
    v,  # (B, Hkv, Tk, hd)
    *,
    q_pos=None,       # (B, Tq) int32 positions of queries (None -> non-causal)
    kv_pos=None,      # (B, Tk)
    kv_valid=None,    # (B, Tk) bool (e.g. cache occupancy)
    window=None,      # int | traced scalar | None
    softcap=None,
    chunk: int = 1024,
):
    B, Hq, Tq, hd = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    chunk = min(chunk, Tk)
    nc = -(-Tk // chunk)
    pad = nc * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_valid = (
            jnp.pad(kv_valid, ((0, 0), (0, pad)))
            if kv_valid is not None
            else jnp.pad(jnp.ones((B, Tk), bool), ((0, 0), (0, pad)))
        )
        if kv_pos is not None:
            kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)))
    elif kv_valid is None:
        kv_valid = jnp.ones((B, nc * chunk), bool)

    qg = q.reshape(B, Hkv, group, Tq, hd)
    kc = k.reshape(B, Hkv, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    validc = kv_valid.reshape(B, nc, chunk).transpose(1, 0, 2)
    posc = (
        kv_pos.reshape(B, nc, chunk).transpose(1, 0, 2)
        if kv_pos is not None
        else None
    )

    m0 = jnp.full((B, Hkv, group, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, group, Tq, hd), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        if posc is not None:
            k_i, v_i, ok_i, pos_i = xs
        else:
            k_i, v_i, ok_i = xs
            pos_i = None
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
            k_i.astype(jnp.float32),
        ) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = ok_i[:, None, None, None, :]
        if pos_i is not None and q_pos is not None:
            qp = q_pos[:, None, None, :, None]
            kp = pos_i[:, None, None, None, :]
            mask = mask & (kp <= qp)
            if window is not None:
                mask = mask & (qp - kp < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_i.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    xs = (kc, vc, validc) + ((posc,) if posc is not None else ())
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return out.reshape(B, Hq, Tq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention projection block (GQA + TP over heads)
# ---------------------------------------------------------------------------

def tp_head_counts(n_heads: int, n_kv: int, tp: int) -> tuple[int, int, bool]:
    """Local (q_heads, kv_heads_in_weight, kv_replicated).

    tp <= n_kv: KV projections are Shard(1) like Q (kv_heads_in_weight =
    n_kv/tp).  tp > n_kv: KV weights are replicated over the TP axis (they
    live in the `layers_rep` group); each device *computes* only the single
    KV head its q-group needs by slicing the weight (grads recombine via the
    replicated-group psum over "model")."""
    if n_heads % tp:
        raise ValueError(f"n_heads={n_heads} not divisible by tp={tp}")
    if tp <= n_kv:
        if n_kv % tp:
            raise ValueError(f"n_kv_heads={n_kv} not divisible by tp={tp}")
        return n_heads // tp, n_kv // tp, False
    if tp % n_kv:
        raise ValueError(f"tp={tp} not divisible by n_kv_heads={n_kv}")
    return n_heads // tp, n_kv, True


def attention(
    cfg, p, x, *, q_pos, cache=None, cache_index=None, window=None,
    tp_axis=None, tp=1, prefix="", causal=True, sp=False,
):
    """Self-attention with optional ring-buffer KV cache.

    cache: None (training) or dict(k=(B,Hkv,W,hd), v=..., pos=(B,W) int32,
    init -1).  W may be < seq_len (sliding-window ring buffer -- how the
    long_500k decode shape stays sub-linear in memory).  Writes at
    ``cache_index % W``; validity/causality come from the stored positions.
    Returns (out, new_cache)."""
    B, T, D = x.shape
    hd = cfg.hd
    hq, hkv, kv_rep = tp_head_counts(cfg.n_heads, cfg.n_kv_heads, tp)
    if kv_rep:
        group_size = cfg.n_heads // cfg.n_kv_heads
        kv_head = (lax.axis_index(tp_axis) * hq) // group_size
        hkv = 1

    def proj(name, h, kv=False):
        w = p[prefix + name]
        b = (p[prefix + name + "_b"].astype(x.dtype)
             if cfg.qkv_bias and prefix + name + "_b" in p else None)
        if kv and kv_rep:
            sl = (ops.q8_slice_cols(w, kv_head * hd, hd)
                  if isinstance(w, ops.QuantTensor) else None)
            if sl is not None:
                # scale layout is column-sliceable: stay on the int8 GEMM
                y = dense(x, sl)
            else:
                wd = lax.dynamic_slice(to_dense(w, x.dtype),
                                       (0, kv_head * hd), (w.shape[0], hd))
                y = x @ wd
            if b is not None:
                b = lax.dynamic_slice(b, (kv_head * hd,), (hd,))
        else:
            y = dense(x, w)
        if b is not None:
            y = y + b
        return y.reshape(B, T, h, hd).transpose(0, 2, 1, 3)

    q = proj("wq", hq)
    k = proj("wk", hkv, kv=True)
    v = proj("wv", hkv, kv=True)

    q = rope(q, q_pos, cfg.rope_theta)
    k = rope(k, q_pos, cfg.rope_theta)

    chunk = getattr(cfg, "attn_chunk", 1024)
    if cache is None:
        out = chunked_attention(
            q, k, v, q_pos=q_pos if causal else None,
            kv_pos=q_pos if causal else None, window=window,
            softcap=cfg.attn_softcap, chunk=chunk,
        )
        new_cache = None
    else:
        W = cache["k"].shape[2]
        idx = jnp.asarray(cache_index, jnp.int32)
        if idx.ndim == 1:
            # per-row positions (continuous-batching decode): each batch row
            # writes its own ring slot
            slot = idx % W
            ck = jax.vmap(
                lambda c, kn, s: lax.dynamic_update_slice(c, kn, (0, s, 0))
            )(cache["k"], k.astype(cache["k"].dtype), slot)
            cv = jax.vmap(
                lambda c, vn, s: lax.dynamic_update_slice(c, vn, (0, s, 0))
            )(cache["v"], v.astype(cache["v"].dtype), slot)
            cpos = jax.vmap(
                lambda c, p, s: lax.dynamic_update_slice(c, p, (s,))
            )(cache["pos"], q_pos[:, :T].astype(jnp.int32), slot)
        else:
            slot = idx % W
            # prefill writes assume no wrap (T <= W, index 0); decode is T=1
            ck = lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
            cv = lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
            cpos = lax.dynamic_update_slice(
                cache["pos"], q_pos[:, :T].astype(jnp.int32), (0, slot))
        valid = cpos >= 0
        out = chunked_attention(
            q, ck, cv, q_pos=q_pos, kv_pos=cpos, kv_valid=valid,
            window=window, softcap=cfg.attn_softcap, chunk=chunk,
        )
        new_cache = {"k": ck, "v": cv, "pos": cpos}

    out = out.transpose(0, 2, 1, 3).reshape(B, T, hq * hd)
    out = dense(out, p[prefix + "wo"])
    return reduce_out(out, tp_axis, sp), new_cache


def cross_attention(cfg, p, x, memory, *, tp_axis=None, tp=1, prefix="x_"):
    """Cross-attention onto encoder/vision memory (B, M, D). Non-causal."""
    B, T, D = x.shape
    M = memory.shape[1]
    hd = cfg.hd
    hq, hkv, kv_rep = tp_head_counts(cfg.n_heads, cfg.n_kv_heads, tp)
    if kv_rep:
        raise ValueError("cross-attention with tp > n_kv is not supported")

    q = dense(rms_norm(x, p[prefix + "lnq"], cfg.norm_eps), p[prefix + "wq"]
              ).reshape(B, T, hq, hd).transpose(0, 2, 1, 3)
    k = dense(memory, p[prefix + "wk"]).reshape(B, M, hkv, hd).transpose(0, 2, 1, 3)
    v = dense(memory, p[prefix + "wv"]).reshape(B, M, hkv, hd).transpose(0, 2, 1, 3)
    out = chunked_attention(q, k, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, hq * hd)
    return psum(dense(out, p[prefix + "wo"]), tp_axis)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(cfg, p, x, *, tp_axis=None, prefix="", sp=False):
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(dense(x, p[prefix + "w1"])) * dense(x, p[prefix + "w3"])
    elif cfg.mlp == "geglu":
        h = (jax.nn.gelu(dense(x, p[prefix + "w1"]), approximate=True)
             * dense(x, p[prefix + "w3"]))
    elif cfg.mlp == "squared_relu":  # nemotron-4 [arXiv:2402.16819]
        h = jnp.square(jax.nn.relu(dense(x, p[prefix + "w1"])))
    else:
        raise ValueError(cfg.mlp)
    return reduce_out(dense(h, p[prefix + "w2"]), tp_axis, sp)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding + cross entropy
# ---------------------------------------------------------------------------

def embed(tokens, emb_local, *, tp_axis=None, vocab_start=0):
    """emb_local: (V_local, D).  Vocab-parallel lookup with psum combine."""
    ids = tokens - vocab_start
    ok = (ids >= 0) & (ids < emb_local.shape[0])
    x = jnp.take(emb_local, jnp.clip(ids, 0, emb_local.shape[0] - 1), axis=0)
    x = jnp.where(ok[..., None], x, 0)
    return psum(x, tp_axis)


def lm_logits(x, head_local, *, softcap=None):
    logits = x @ head_local.astype(x.dtype)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def chunked_ce(x, head, labels, mask, *, vocab_chunk: int = 8192,
               softcap=None, tp_axis=None, vocab_start=0):
    """Cross entropy without materializing (B, T, V) logits: scan over vocab
    chunks with an online max/logsumexp (the lm-head analogue of flash
    attention).  Beyond-paper §Perf optimization: the fp32 logits buffer for
    a 152k vocab is ~2.5 GB/device at train_4k; this caps it at
    (B, T, vocab_chunk).  The head matmul is recomputed in backward
    (remat'd scan body) -- bytes traded for ~+1 forward head matmul."""
    B, T, D = x.shape
    V = head.shape[1]
    nc = -(-V // vocab_chunk)
    pad = nc * vocab_chunk - V
    if pad:
        head = jnp.pad(head, ((0, 0), (0, pad)))
    hc = head.reshape(D, nc, vocab_chunk).transpose(1, 0, 2)  # (nc, D, Vc)

    x32 = x
    ids = labels - vocab_start

    def body(carry, xs):
        m, s, picked = carry
        h_i, ci = xs
        lg = (x32 @ h_i.astype(x.dtype)).astype(jnp.float32)
        if softcap is not None:
            lg = softcap * jnp.tanh(lg / softcap)
        base = ci * vocab_chunk
        # mask padded vocab tail
        col = jnp.arange(vocab_chunk)[None, None, :] + base
        lg = jnp.where(col < V, lg, NEG_INF)
        m_new = jnp.maximum(m, lax.stop_gradient(lg.max(-1)))
        s = s * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(-1)
        loc = ids - base
        ok = (loc >= 0) & (loc < vocab_chunk)
        got = jnp.take_along_axis(
            lg, jnp.clip(loc, 0, vocab_chunk - 1)[..., None], axis=-1)[..., 0]
        picked = picked + jnp.where(ok, got, 0.0)
        return (m_new, s, picked), None

    m0 = jnp.full((B, T), NEG_INF, jnp.float32)
    s0 = jnp.zeros((B, T), jnp.float32)
    p0 = jnp.zeros((B, T), jnp.float32)
    (m, s, picked), _ = lax.scan(
        jax.checkpoint(body), (m0, s0, p0),
        (hc, jnp.arange(nc, dtype=jnp.int32)))
    if tp_axis:
        # vocab-parallel composition: each rank covered its vocab shard
        m_glob = pmax(lax.stop_gradient(m), tp_axis)
        s = psum(s * jnp.exp(m - m_glob), tp_axis)
        picked = psum(picked, tp_axis)
        m = m_glob
    nll = jnp.log(s) + m - picked
    return (nll * mask).sum(), mask.sum()


def vocab_parallel_ce(logits_local, labels, mask, *, tp_axis=None,
                      vocab_start=0):
    """Cross entropy over vocab-sharded logits (B, T, V_local).

    mask: (B, T) float weights.  Returns (sum_loss, sum_weight) so the caller
    can reduce across data axes."""
    lg = logits_local.astype(jnp.float32)
    m_local = lg.max(axis=-1)
    # stabilizer only: constant shift; stop_gradient *before* pmax so the
    # JVP machinery never differentiates pmax (it has no rule)
    m_glob = pmax(lax.stop_gradient(m_local), tp_axis)
    sumexp = psum(jnp.exp(lg - m_glob[..., None]).sum(-1), tp_axis)
    ids = labels - vocab_start
    ok = (ids >= 0) & (ids < lg.shape[-1])
    picked = jnp.take_along_axis(
        lg, jnp.clip(ids, 0, lg.shape[-1] - 1)[..., None], axis=-1
    )[..., 0]
    label_logit = psum(jnp.where(ok, picked, 0.0), tp_axis)
    nll = jnp.log(sumexp) + m_glob - label_logit
    return (nll * mask).sum(), mask.sum()
