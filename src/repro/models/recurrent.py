"""Recurrent-family models: xLSTM (sLSTM + mLSTM stacks) and Hymba
(parallel attention + Mamba heads per layer).

Both have O(1)-state decode, which is what makes the ``long_500k`` shape
runnable (see DESIGN.md §Arch-applicability).  The paper's FSDP technique is
fully applicable: their parameter trees are ragged-packed like any other.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ragged import TensorSpec
from . import layers as L
from .ssm import (
    mamba_mix, mamba_param_shapes, mlstm_mix, mlstm_param_shapes,
    slstm_mix, slstm_param_shapes,
)
from .transformer import GroupDef, spec


class XLSTMModel:
    """xLSTM-125m [arXiv:2405.04517]: super-blocks of (slstm_every-1) mLSTM
    blocks followed by one sLSTM block, scanned over.  Stabilized sigmoid
    gating replaces the paper's exponential gating (DESIGN.md)."""

    def __init__(self, cfg):
        self.cfg = cfg
        k = cfg.slstm_every or cfg.n_layers
        if cfg.n_layers % k:
            raise ValueError(
                f"n_layers={cfg.n_layers} not divisible by "
                f"slstm_every={k}")
        self.per_block = k
        self.n_blocks = cfg.n_layers // k
        self.tp = 1

    def groups(self) -> dict[str, GroupDef]:
        cfg = self.cfg
        D = cfg.d_model
        specs = []
        for i in range(self.per_block - 1):
            specs.append(spec(cfg, f"m{i}_ln", (D,)))
            for n, s in mlstm_param_shapes(cfg, D, prefix=f"m{i}_").items():
                specs.append(spec(cfg, n, s))
        specs.append(spec(cfg, "s_ln", (D,)))
        for n, s in slstm_param_shapes(cfg, D, prefix="s_").items():
            specs.append(spec(cfg, n, s))
        g = {
            "layers": GroupDef(tuple(specs), n_layers=self.n_blocks),
            "globals": GroupDef((
                spec(cfg, "emb", (cfg.vocab, D)),
                spec(cfg, "final_ln", (D,)),
                spec(cfg, "head", (D, cfg.vocab)),
            )),
        }
        return g

    # ------------------------------------------------------------------ #
    def _block(self, p, x, states):
        cfg = self.cfg
        new_states = {"m": [], "s": None}
        for i in range(self.per_block - 1):
            st = None if states is None else jax.tree.map(
                lambda t, i=i: t[i], states["m"])
            h = L.rms_norm(x, p[f"m{i}_ln"], cfg.norm_eps)
            out, ns = mlstm_mix(cfg, p, h, state=st, prefix=f"m{i}_")
            x = x + out
            new_states["m"].append(ns)
        st = None if states is None else states["s"]
        h = L.rms_norm(x, p["s_ln"], cfg.norm_eps)
        out, ns = slstm_mix(cfg, p, h, state=st, prefix="s_")
        x = x + out
        new_states["s"] = ns
        new_states["m"] = jax.tree.map(lambda *ts: jnp.stack(ts),
                                       *new_states["m"])
        return x, new_states

    def _backbone(self, pg, x, states=None):
        def body(p, carry, xs):
            x = carry
            x, ns = self._block(p, x, xs)
            return x, ns

        x, new_states = pg.scan(["layers"], body, x, states)
        return x, new_states

    def loss(self, pg, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        g = pg.globals("globals")
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype))
        x, _ = self._backbone(pg, x)
        x = L.rms_norm(x, g["final_ln"], cfg.norm_eps)
        logits = L.lm_logits(x, g["head"])
        nll, w = L.vocab_parallel_ce(
            logits[:, :-1], tokens[:, 1:], jnp.ones((B, T - 1), jnp.float32))
        return nll, w

    def cache_shapes(self, batch: int, seq_len: int) -> dict[str, Any]:
        cfg = self.cfg
        H = cfg.n_heads
        hd = cfg.d_model // H
        nm = self.per_block - 1
        return {
            "m": {
                "C": ((self.n_blocks, nm, batch, H, hd, hd), jnp.float32),
                "n": ((self.n_blocks, nm, batch, H, hd), jnp.float32),
            },
            "s": {
                "c": ((self.n_blocks, batch, H, hd), jnp.float32),
                "n": ((self.n_blocks, batch, H, hd), jnp.float32),
                "m": ((self.n_blocks, batch, H, hd), jnp.float32),
            },
        }

    def cache_batch_dims(self):
        return {"m": {"C": 2, "n": 2},
                "s": {"c": 1, "n": 1, "m": 1}}

    def init_cache(self, batch: int, seq_len: int):
        def mk(path_key, s, d):
            init = -1e30 if path_key == ("s", "m") else 0.0
            return jnp.full(s, init, d)

        shapes = self.cache_shapes(batch, seq_len)
        return {
            grp: {k: mk((grp, k), s, d) for k, (s, d) in sub.items()}
            for grp, sub in shapes.items()
        }

    def prefill(self, pg, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        g = pg.globals("globals")
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype))
        x, new_states = self._backbone(pg, x, cache)
        x = L.rms_norm(x[:, -1:], g["final_ln"], cfg.norm_eps)
        return L.lm_logits(x, g["head"]), new_states

    def decode(self, pg, batch, cache, index):
        return self.prefill(pg, batch, cache)


class HymbaModel:
    """Hymba-1.5B [arXiv:2411.13676]: each layer runs attention and a Mamba
    head in parallel on the same input; outputs are normed and averaged.
    Sliding-window attention everywhere except 3 global layers (first,
    middle, last).  Meta-tokens are omitted (DESIGN.md)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.n_blocks = cfg.n_layers
        self.tp = 1
        self.d_inner = cfg.n_heads * cfg.hd

    def groups(self) -> dict[str, GroupDef]:
        cfg = self.cfg
        D, hd = cfg.d_model, cfg.hd
        Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
        specs = [
            spec(cfg, "ln1", (D,)),
            spec(cfg, "wq", (D, Hq * hd)),
            spec(cfg, "wk", (D, Hkv * hd)),
            spec(cfg, "wv", (D, Hkv * hd)),
            spec(cfg, "wo", (Hq * hd, D)),
            spec(cfg, "attn_n", (Hq * hd,)),
            spec(cfg, "ssm_n", (self.d_inner,)),
            spec(cfg, "ln2", (D,)),
            spec(cfg, "w1", (D, cfg.d_ff)),
            spec(cfg, "w3", (D, cfg.d_ff)),
            spec(cfg, "w2", (cfg.d_ff, D)),
        ]
        for n, s in mamba_param_shapes(cfg, D, d_inner=self.d_inner).items():
            specs.append(spec(cfg, n, s))
        return {
            "layers": GroupDef(tuple(specs), n_layers=self.n_blocks),
            "globals": GroupDef((
                spec(cfg, "emb", (cfg.vocab, D)),
                spec(cfg, "final_ln", (D,)),
                spec(cfg, "head", (D, cfg.vocab)),
            )),
        }

    def _layer_windows(self):
        cfg = self.cfg
        big = np.int32(2**30)
        w = np.full(cfg.n_layers, cfg.sliding_window or big, np.int32)
        for i in (0, cfg.n_layers // 2, cfg.n_layers - 1):
            w[i] = big
        return jnp.asarray(w)

    def _block(self, p, x, q_pos, window, cache, cache_index, pg):
        cfg = self.cfg
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        attn_cache = None if cache is None else cache["attn"]
        ssm_state = None if cache is None else cache["ssm"]
        # attention branch (wo applied after fusing with ssm branch)
        attn_out, new_attn = self._attn_branch(
            p, h, q_pos, window, attn_cache, cache_index)
        ssm_out, new_ssm = mamba_mix(cfg, p, h, state=ssm_state,
                                     d_inner=self.d_inner)
        fused = 0.5 * (
            L.rms_norm(attn_out, p["attn_n"], cfg.norm_eps)
            + L.rms_norm(ssm_out, p["ssm_n"], cfg.norm_eps)
        )
        x = x + fused @ p["wo"].astype(x.dtype)
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        up = jax.nn.silu(h @ p["w1"].astype(x.dtype)) * (h @ p["w3"].astype(x.dtype))
        x = x + up @ p["w2"].astype(x.dtype)
        new_cache = (
            None if cache is None else {"attn": new_attn, "ssm": new_ssm}
        )
        return x, new_cache

    def _attn_branch(self, p, h, q_pos, window, cache, cache_index):
        """Attention without the output projection (fused later); the Mamba
        out_proj is likewise an identity-sized map into the fused space."""
        cfg = self.cfg
        B, T, D = h.shape
        hd = cfg.hd
        Hq, Hkv = cfg.n_heads, cfg.n_kv_heads

        def proj(name, hh):
            return (h @ p[name].astype(h.dtype)).reshape(
                B, T, hh, hd).transpose(0, 2, 1, 3)

        q = L.rope(proj("wq", Hq), q_pos, cfg.rope_theta)
        k = L.rope(proj("wk", Hkv), q_pos, cfg.rope_theta)
        v = proj("wv", Hkv)
        if cache is None:
            out = L.chunked_attention(q, k, v, q_pos=q_pos, kv_pos=q_pos,
                                      window=window)
            new_cache = None
        else:
            W = cache["k"].shape[2]
            idx = jnp.asarray(cache_index, jnp.int32)
            slot = idx % W
            if idx.ndim == 1:  # per-row positions (continuous batching)
                ck = jax.vmap(lambda c, kn, s: jax.lax.dynamic_update_slice(
                    c, kn, (0, s, 0)))(cache["k"], k.astype(cache["k"].dtype),
                                       slot)
                cv = jax.vmap(lambda c, vn, s: jax.lax.dynamic_update_slice(
                    c, vn, (0, s, 0)))(cache["v"], v.astype(cache["v"].dtype),
                                       slot)
                cpos = jax.vmap(lambda c, p, s: jax.lax.dynamic_update_slice(
                    c, p, (s,)))(cache["pos"], q_pos[:, :T].astype(jnp.int32),
                                 slot)
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
                cpos = jax.lax.dynamic_update_slice(
                    cache["pos"], q_pos[:, :T].astype(jnp.int32), (0, slot))
            out = L.chunked_attention(q, ck, cv, q_pos=q_pos, kv_pos=cpos,
                                      kv_valid=cpos >= 0, window=window)
            new_cache = {"k": ck, "v": cv, "pos": cpos}
        return out.transpose(0, 2, 1, 3).reshape(B, T, Hq * hd), new_cache

    def _backbone(self, pg, x, q_pos, caches=None, cache_index=0):
        windows = self._layer_windows()

        def body(p, carry, xs):
            x = carry
            win, cache = xs
            x, nc = self._block(p, x, q_pos, win, cache, cache_index, pg)
            return x, nc

        return pg.scan(["layers"], body, x, (windows, caches))

    def loss(self, pg, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        q_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        g = pg.globals("globals")
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype))
        x, _ = self._backbone(pg, x, q_pos)
        x = L.rms_norm(x, g["final_ln"], cfg.norm_eps)
        logits = L.lm_logits(x, g["head"])
        nll, w = L.vocab_parallel_ce(
            logits[:, :-1], tokens[:, 1:], jnp.ones((B, T - 1), jnp.float32))
        return nll, w

    def cache_window(self, seq_len: int) -> int:
        if self.cfg.sliding_window and seq_len > 65536:
            return self.cfg.sliding_window
        return seq_len

    def cache_shapes(self, batch: int, seq_len: int) -> dict[str, Any]:
        cfg = self.cfg
        W = self.cache_window(seq_len)
        N = cfg.ssm_state
        K = cfg.conv_kernel
        Lb = self.n_blocks
        return {
            "attn": {
                "k": ((Lb, batch, cfg.n_kv_heads, W, cfg.hd), jnp.bfloat16),
                "v": ((Lb, batch, cfg.n_kv_heads, W, cfg.hd), jnp.bfloat16),
                "pos": ((Lb, batch, W), jnp.int32),
            },
            "ssm": {
                "conv": ((Lb, batch, K - 1, self.d_inner), jnp.bfloat16),
                "ssm": ((Lb, batch, self.d_inner, N), jnp.float32),
            },
        }

    def cache_batch_dims(self):
        return {"attn": {"k": 1, "v": 1, "pos": 1},
                "ssm": {"conv": 1, "ssm": 1}}

    def init_cache(self, batch: int, seq_len: int):
        out = {}
        for grp, sub in self.cache_shapes(batch, seq_len).items():
            out[grp] = {
                k: (jnp.full(s, -1, d) if k == "pos" else jnp.zeros(s, d))
                for k, (s, d) in sub.items()
            }
        return out

    def prefill(self, pg, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        q_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        g = pg.globals("globals")
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype))
        x, nc = self._backbone(pg, x, q_pos, caches=cache, cache_index=0)
        x = L.rms_norm(x[:, -1:], g["final_ln"], cfg.norm_eps)
        return L.lm_logits(x, g["head"]), nc

    def decode(self, pg, batch, cache, index):
        cfg = self.cfg
        tokens = batch["tokens"]
        B = tokens.shape[0]
        idx = jnp.asarray(index, jnp.int32)
        q_pos = (idx[:, None] if idx.ndim == 1
                 else jnp.broadcast_to(idx[None, None], (B, 1)))
        g = pg.globals("globals")
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype))
        x, nc = self._backbone(pg, x, q_pos, caches=cache, cache_index=idx)
        x = L.rms_norm(x, g["final_ln"], cfg.norm_eps)
        return L.lm_logits(x, g["head"]), nc
