"""Encoder-decoder backbone for SeamlessM4T-medium [arXiv:2308.11596].

Per the assignment carve-out, the audio frontend (mel-spectrogram + conv
feature extractor) is a stub: ``input_specs`` supplies precomputed frame
embeddings (B, n_frames, d_model).  This module implements the transformer
backbone: a bidirectional encoder over frames and a causal decoder with
cross-attention over encoder output.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import GroupDef, spec


class EncDecModel:
    def __init__(self, cfg):
        self.cfg = cfg
        self.tp = 1
        self.n_enc = cfg.encoder_layers
        self.n_dec = cfg.n_layers

    # ------------------------------------------------------------------ #
    def _enc_layer_specs(self):
        cfg = self.cfg
        D, hd = cfg.d_model, cfg.hd
        Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
        return tuple(
            spec(cfg, n, s) for n, s in [
                ("ln1", (D,)),
                ("wq", (D, Hq * hd)), ("wk", (D, Hkv * hd)),
                ("wv", (D, Hkv * hd)), ("wo", (Hq * hd, D)),
                ("ln2", (D,)),
                ("w1", (D, cfg.d_ff)), ("w3", (D, cfg.d_ff)),
                ("w2", (cfg.d_ff, D)),
            ]
        )

    def _dec_layer_specs(self):
        cfg = self.cfg
        D, hd = cfg.d_model, cfg.hd
        Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
        names = [
            ("ln1", (D,)),
            ("wq", (D, Hq * hd)), ("wk", (D, Hkv * hd)),
            ("wv", (D, Hkv * hd)), ("wo", (Hq * hd, D)),
            ("x_lnq", (D,)),
            ("x_wq", (D, Hq * hd)), ("x_wk", (D, Hkv * hd)),
            ("x_wv", (D, Hkv * hd)), ("x_wo", (Hq * hd, D)),
            ("ln2", (D,)),
            ("w1", (D, cfg.d_ff)), ("w3", (D, cfg.d_ff)),
            ("w2", (cfg.d_ff, D)),
        ]
        return tuple(spec(cfg, n, s) for n, s in names)

    def groups(self) -> dict[str, GroupDef]:
        cfg = self.cfg
        D = cfg.d_model
        return {
            "enc_layers": GroupDef(self._enc_layer_specs(), n_layers=self.n_enc),
            "dec_layers": GroupDef(self._dec_layer_specs(), n_layers=self.n_dec),
            "globals": GroupDef((
                spec(cfg, "frame_proj", (D, D)),
                spec(cfg, "enc_final_ln", (D,)),
                spec(cfg, "emb", (cfg.vocab, D)),
                spec(cfg, "final_ln", (D,)),
                spec(cfg, "head", (D, cfg.vocab)),
            )),
        }

    # ------------------------------------------------------------------ #
    def _encode(self, pg, frames, g):
        cfg = self.cfg
        x = frames.astype(pg.compute_dtype) @ g["frame_proj"].astype(
            pg.compute_dtype)
        B, F, D = x.shape
        pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

        def body(p, carry, _):
            x = carry
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            out, _ = L.attention(cfg, p, h, q_pos=pos, causal=False)
            x = x + out
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + L.mlp(cfg, p, h)
            return x, None

        x, _ = pg.scan(["enc_layers"], body, x, None)
        return L.rms_norm(x, g["enc_final_ln"], cfg.norm_eps)

    def _dec_block(self, p, x, memory, q_pos, cache, cache_index):
        cfg = self.cfg
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        out, new_cache = L.attention(
            cfg, p, h, q_pos=q_pos, cache=cache, cache_index=cache_index)
        x = x + out
        x = x + L.cross_attention(cfg, p, x, memory)
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp(cfg, p, h)
        return x, new_cache

    def _decode_stack(self, pg, x, memory, q_pos, caches=None,
                      cache_index=0):
        def body(p, carry, xs):
            x = carry
            x, nc = self._dec_block(p, x, memory, q_pos, xs, cache_index)
            return x, nc

        return pg.scan(["dec_layers"], body, x, caches)

    # ------------------------------------------------------------------ #
    def loss(self, pg, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        frames = batch["frames"]
        B, T = tokens.shape
        g = pg.globals("globals")
        memory = self._encode(pg, frames, g)
        q_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype))
        x, _ = self._decode_stack(pg, x, memory, q_pos)
        x = L.rms_norm(x, g["final_ln"], cfg.norm_eps)
        logits = L.lm_logits(x, g["head"])
        nll, w = L.vocab_parallel_ce(
            logits[:, :-1], tokens[:, 1:], jnp.ones((B, T - 1), jnp.float32))
        return nll, w

    def cache_shapes(self, batch: int, seq_len: int) -> dict[str, Any]:
        cfg = self.cfg
        return {
            "k": ((self.n_dec, batch, cfg.n_kv_heads, seq_len, cfg.hd),
                  jnp.bfloat16),
            "v": ((self.n_dec, batch, cfg.n_kv_heads, seq_len, cfg.hd),
                  jnp.bfloat16),
            "pos": ((self.n_dec, batch, seq_len), jnp.int32),
            "memory": ((batch, cfg.n_frames, cfg.d_model), jnp.bfloat16),
        }

    def cache_batch_dims(self):
        return {"k": 1, "v": 1, "pos": 1, "memory": 0}

    def init_cache(self, batch: int, seq_len: int):
        out = {}
        for k, (s, d) in self.cache_shapes(batch, seq_len).items():
            out[k] = jnp.full(s, -1, d) if k == "pos" else jnp.zeros(s, d)
        return out

    def prefill(self, pg, batch, cache):
        """Encode frames into the cache memory + prefill decoder tokens."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        g = pg.globals("globals")
        memory = self._encode(pg, batch["frames"], g)
        q_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype))
        kv = {k: cache[k] for k in ("k", "v", "pos")}
        x, nc = self._decode_stack(pg, x, memory, q_pos, caches=kv,
                                   cache_index=0)
        x = L.rms_norm(x[:, -1:], g["final_ln"], cfg.norm_eps)
        nc["memory"] = memory.astype(jnp.bfloat16)
        return L.lm_logits(x, g["head"]), nc

    def decode(self, pg, batch, cache, index):
        cfg = self.cfg
        tokens = batch["tokens"]
        B = tokens.shape[0]
        g = pg.globals("globals")
        memory = cache["memory"].astype(pg.compute_dtype)
        idx = jnp.asarray(index, jnp.int32)
        q_pos = (idx[:, None] if idx.ndim == 1
                 else jnp.broadcast_to(idx[None, None], (B, 1)))
        index = idx
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype))
        kv = {k: cache[k] for k in ("k", "v", "pos")}
        x, nc = self._decode_stack(pg, x, memory, q_pos, caches=kv,
                                   cache_index=index)
        x = L.rms_norm(x, g["final_ln"], cfg.norm_eps)
        nc["memory"] = cache["memory"]
        return L.lm_logits(x, g["head"]), nc
