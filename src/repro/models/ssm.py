"""State-space / recurrent blocks: Mamba-style selective SSM (S6), and the
xLSTM pair (mLSTM: matrix memory, chunkwise-parallel; sLSTM: scalar memory,
sequential scan) [arXiv:2405.04517, arXiv:2312.00752].

All recurrences are O(T) in time and O(chunk) in memory — this is what makes
the ``long_500k`` decode shape (and 32k prefill) viable for the SSM/hybrid
architectures where full attention is skipped.

TPU adaptation: the chunkwise form turns the recurrence into small dense
matmuls (MXU-friendly) with a carried state, instead of the GPU kernels'
warp-level scans.  Gating runs in log-space for stability (ratios <= 1 within
a chunk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# generic diagonal linear recurrence  h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def diagonal_scan(a, b, h0=None, chunk: int = 256):
    """a, b: (B, T, ...) with matching trailing dims.  Returns (h (B,T,...),
    h_last).  Chunked: associative_scan inside a chunk, lax.scan across."""
    B, T = a.shape[:2]
    chunk = min(chunk, T)
    nc = -(-T // chunk)
    pad = nc * chunk - T
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    ac = a.reshape((B, nc, chunk) + a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1)))
    bc = b.reshape((B, nc, chunk) + b.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, b.ndim + 1)))
    if h0 is None:
        h0 = jnp.zeros((B,) + a.shape[2:], a.dtype)

    def combine(x, y):
        (ax, bx), (ay, by) = x, y
        return ax * ay, bx * ay + by

    def body(h, xs):
        a_i, b_i = xs  # (B, chunk, ...)
        pa, pb = lax.associative_scan(combine, (a_i, b_i), axis=1)
        h_i = pa * h[:, None] + pb
        return h_i[:, -1], h_i

    h_last, hs = lax.scan(body, h0, (ac, bc))
    hs = hs.transpose((1, 0, 2) + tuple(range(3, a.ndim + 1)))
    hs = hs.reshape((B, nc * chunk) + a.shape[2:])[:, :T]
    return hs, h_last


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (diagonal A, input-dependent dt/B/C)
# ---------------------------------------------------------------------------

def mamba_mix(cfg, p, x, *, state=None, prefix="ssm_", d_inner=None):
    """x: (B, T, D).  Returns (y (B, T, d_inner_out -> D), new_state).

    state (decode): dict(conv=(B, K-1, d_in), ssm=(B, d_in, N)).
    Parameters: in_proj (D, 2*d_in), conv (K, d_in), dt_proj (d_in,),
    x_bc (d_in, 2N + 1? -> use (d_in, 2N) for B,C and (d_in,) dt bias),
    A_log (d_in, N), out_proj (d_in, D).
    """
    B, T, D = x.shape
    N = cfg.ssm_state
    d_in = d_inner or cfg.ssm_expand * D
    K = cfg.conv_kernel

    xz = x @ p[prefix + "in_proj"].astype(x.dtype)  # (B,T,2*d_in)
    xi, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv1d
    conv_w = p[prefix + "conv"].astype(x.dtype)  # (K, d_in)
    if state is None:
        xpad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
        new_conv = xpad[:, -(K - 1):, :] if K > 1 else jnp.zeros((B, 0, d_in), x.dtype)
    else:
        xpad = jnp.concatenate([state["conv"].astype(x.dtype), xi], axis=1)
        new_conv = xpad[:, -(K - 1):, :] if K > 1 else state["conv"]
    xc = sum(xpad[:, i : i + T, :] * conv_w[i] for i in range(K))
    xc = jax.nn.silu(xc)

    # input-dependent dt, B, C
    dt = jax.nn.softplus(
        xc @ p[prefix + "dt_w"].astype(x.dtype)
        + p[prefix + "dt_b"].astype(x.dtype)
    ).astype(jnp.float32)  # (B,T,d_in)
    bc = xc @ p[prefix + "bc_w"].astype(x.dtype)  # (B,T,2N)
    Bm, Cm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,T,N)
    A = -jnp.exp(p[prefix + "A_log"].astype(jnp.float32))  # (d_in, N)

    a = jnp.exp(dt[..., None] * A)                      # (B,T,d_in,N)
    bterm = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]

    h0 = state["ssm"].astype(jnp.float32) if state is not None else None
    hs, h_last = diagonal_scan(a, bterm, h0)
    y = jnp.einsum("btdn,btn->btd", hs, Cm).astype(x.dtype)
    y = y + xc * p[prefix + "skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p[prefix + "out_proj"].astype(x.dtype)
    new_state = {"conv": new_conv.astype(x.dtype), "ssm": h_last.astype(jnp.float32)}
    return out, new_state


def mamba_param_shapes(cfg, D, prefix="ssm_", d_inner=None):
    N = cfg.ssm_state
    d_in = d_inner or cfg.ssm_expand * D
    K = cfg.conv_kernel
    return {
        prefix + "in_proj": (D, 2 * d_in),
        prefix + "conv": (K, d_in),
        prefix + "dt_w": (d_in, d_in),
        prefix + "dt_b": (d_in,),
        prefix + "bc_w": (d_in, 2 * N),
        prefix + "A_log": (d_in, N),
        prefix + "skip": (d_in,),
        prefix + "out_proj": (d_in, D),
    }


# ---------------------------------------------------------------------------
# mLSTM: matrix-memory LSTM, chunkwise parallel (xLSTM)
# ---------------------------------------------------------------------------

def mlstm_mix(cfg, p, x, *, state=None, prefix="m_"):
    """Matrix-memory cell: C_t = f_t C_{t-1} + i_t k_t v_t^T, h = q C / |q n|.

    Sigmoid gates (stabilized variant; the exponential-gating of the paper is
    replaced by a bounded gate — see DESIGN.md §Arch-applicability).
    Chunkwise-parallel: intra-chunk attention-like matmuls + carried (hd,hd)
    state; O(T) time, MXU-friendly.
    """
    B, T, D = x.shape
    H = cfg.n_heads
    hd = D // H
    chunk = min(128, T)

    def heads(name):
        return (x @ p[prefix + name].astype(x.dtype)).reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    q, k, v = heads("wq"), heads("wk"), heads("wv")
    q = q.astype(jnp.float32) / (hd ** 0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    gates = x @ p[prefix + "wgate"].astype(x.dtype)  # (B,T,2H)
    f = jax.nn.sigmoid(gates[..., :H].astype(jnp.float32) + 4.0)  # bias->remember
    i = jax.nn.sigmoid(gates[..., H:].astype(jnp.float32))
    f = f.transpose(0, 2, 1)  # (B,H,T)
    i = i.transpose(0, 2, 1)

    nc = -(-T // chunk)
    pad = nc * chunk - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        f = jnp.pad(f, ((0, 0), (0, 0), (0, pad)), constant_values=1.0)
        i = jnp.pad(i, ((0, 0), (0, 0), (0, pad)))

    def to_chunks(t):
        return t.reshape((B, H, nc, chunk) + t.shape[3:]).transpose(
            (2, 0, 1, 3) + tuple(range(4, t.ndim + 1)))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    fc, ic = to_chunks(f), to_chunks(i)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    else:
        C0, n0 = state["C"].astype(jnp.float32), state["n"].astype(jnp.float32)

    def body(carry, xs):
        C, n = carry
        q_i, k_i, v_i, f_i, i_i = xs  # (B,H,c,*)
        logf = jnp.log(jnp.maximum(f_i, 1e-8))
        cum = jnp.cumsum(logf, axis=-1)            # (B,H,c) log prod_{s<=t}
        # inter-chunk: h_inter = d_t * (q_t @ C)
        d = jnp.exp(cum)
        h_inter = jnp.einsum("bhtd,bhde->bhte", q_i, C) * d[..., None]
        n_inter = jnp.einsum("bhtd,bhd->bht", q_i, n) * d
        # intra-chunk: A[t,s] = (q_t.k_s) exp(cum_t - cum_s) i_s for s<=t
        ratio = cum[..., :, None] - cum[..., None, :]  # (B,H,c,c)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(tri, jnp.exp(ratio), 0.0) * i_i[..., None, :]
        scores = jnp.einsum("bhtd,bhsd->bhts", q_i, k_i) * w
        h_intra = jnp.einsum("bhts,bhse->bhte", scores, v_i)
        n_intra = jnp.einsum("bhts,bhs->bht", scores, jnp.ones_like(i_i))
        # new carry
        dc = jnp.exp(cum[..., -1])
        rd = jnp.exp(cum[..., -1:] - cum)          # decay from s to end
        kw = k_i * (rd * i_i)[..., None]
        C_new = C * dc[..., None, None] + jnp.einsum("bhsd,bhse->bhde", kw, v_i)
        n_new = n * dc[..., None] + kw.sum(axis=2)
        h = (h_inter + h_intra) / jnp.maximum(
            jnp.abs(n_inter + n_intra), 1.0)[..., None]
        return (C_new, n_new), h

    (C, n), hs = lax.scan(body, (C0, n0), (qc, kc, vc, fc, ic))
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, nc * chunk, hd)[:, :, :T]
    out = hs.transpose(0, 2, 1, 3).reshape(B, T, D).astype(x.dtype)
    out = out * jax.nn.silu(x @ p[prefix + "wog"].astype(x.dtype))
    out = out @ p[prefix + "wo"].astype(x.dtype)
    return out, {"C": C, "n": n}


def mlstm_param_shapes(cfg, D, prefix="m_"):
    H = cfg.n_heads
    return {
        prefix + "wq": (D, D),
        prefix + "wk": (D, D),
        prefix + "wv": (D, D),
        prefix + "wgate": (D, 2 * H),
        prefix + "wog": (D, D),
        prefix + "wo": (D, D),
    }


# ---------------------------------------------------------------------------
# sLSTM: scalar-memory LSTM with exponential gating (sequential)
# ---------------------------------------------------------------------------

def slstm_mix(cfg, p, x, *, state=None, prefix="s_"):
    """Sequential scan over time; per-head scalar memory (c, n, m stabilizer).
    [arXiv:2405.04517 eq. 16-24, simplified: no block-diagonal recurrent R]"""
    B, T, D = x.shape
    H = cfg.n_heads
    hd = D // H

    zifo = x @ p[prefix + "w_zifo"].astype(x.dtype)  # (B,T,4D)
    zifo = zifo.astype(jnp.float32).reshape(B, T, 4, H, hd)
    z, i_g, f_g, o_g = [zifo[:, :, j] for j in range(4)]  # (B,T,H,hd)

    if state is None:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H, hd), -1e30, jnp.float32)
    else:
        c0, n0, m0 = (state[k].astype(jnp.float32) for k in ("c", "n", "m"))

    def step(carry, xs):
        c, n, m = carry
        z_t, i_t, f_t, o_t = xs  # (B,H,hd)
        logf = -jax.nn.softplus(-f_t)  # log sigmoid(f)
        m_new = jnp.maximum(logf + m, i_t)
        ig = jnp.exp(i_t - m_new)
        fg = jnp.exp(logf + m - m_new)
        c_new = fg * c + ig * jnp.tanh(z_t)
        n_new = fg * n + ig
        h = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new), h

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (z, i_g, f_g, o_g))
    (c, n, m), hs = lax.scan(step, (c0, n0, m0), xs)
    hs = hs.transpose(1, 0, 2, 3).reshape(B, T, D).astype(x.dtype)
    out = hs @ p[prefix + "wo"].astype(x.dtype)
    return out, {"c": c, "n": n, "m": m}


def slstm_param_shapes(cfg, D, prefix="s_"):
    return {
        prefix + "w_zifo": (D, 4 * D),
        prefix + "wo": (D, D),
    }
