"""Decoder-only transformer LM family: dense (qwen/gemma/nemotron/llama),
MoE (granite/qwen3), and VLM (llama-3.2-vision cross-attn variant).

The model is written against the ParamGetter protocol (repro.core.fsdp):
``pg.globals(group)`` returns gathered+unpacked tensors of an unstacked
group; ``pg.scan(groups, body, carry, xs)`` runs the FSDP layer scan
(per-layer all-gather -> zero-copy unpack -> body, with remat), which is the
ZeRO-3 schedule.  The same code runs on one CPU device (mesh of size 1) and
on the 512-chip multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.ragged import ShardDim, TensorSpec
from . import layers as L
from .moe import moe_ffn


# ---------------------------------------------------------------------------
# Group definitions (consumed by repro.core.fsdp)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupDef:
    """One communication group: a list of FULL logical tensor specs, stacked
    ``n_layers`` times if part of a layer scan, with optional *outer*
    (TP/EP) sharding applied before RaggedShard (paper Fig. 5)."""

    specs: tuple[TensorSpec, ...]
    n_layers: int | None = None
    outer: dict[str, ShardDim] = dataclasses.field(default_factory=dict)
    # grads of a model-axis-replicated group need a psum over "model"
    replicated_over_model: bool = False


def _gran(cfg, shape) -> int:
    """Granularity policy: block-quantized optimizers get quant_block-sized
    blocks on big tensors (the paper's 32x32 case); else element-wise."""
    size = int(np.prod(shape))
    if (
        cfg.optimizer == "adam8bit"
        and len(shape) >= 2
        and size % cfg.quant_block == 0
    ):
        return cfg.quant_block
    return 1


def spec(cfg, name, shape) -> TensorSpec:
    return TensorSpec(name, tuple(shape), granularity=_gran(cfg, shape))


# ---------------------------------------------------------------------------
# Decoder LM
# ---------------------------------------------------------------------------

class DecoderLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.tp = cfg.parallel.tp
        self.ep = cfg.parallel.ep
        self.is_vlm = cfg.cross_attn_interval > 0
        if self.is_vlm:
            if cfg.n_layers % cfg.cross_attn_interval:
                raise ValueError(
                    f"n_layers={cfg.n_layers} not divisible by "
                    f"cross_attn_interval={cfg.cross_attn_interval}")
            self.n_blocks = cfg.n_layers // cfg.cross_attn_interval
            self.selfs_per_block = cfg.cross_attn_interval - 1
        else:
            self.n_blocks = cfg.n_layers
            self.selfs_per_block = 1

    # ---------------- specs ------------------------------------------------
    def _self_layer_specs(self, prefix=""):
        cfg = self.cfg
        D, hd = cfg.d_model, cfg.hd
        Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
        tp = self.tp
        kv_tp = min(tp, Hkv)
        sharded, replicated = [], []
        out_sh: dict[str, ShardDim] = {}

        def add(name, shape, dim=None):
            s = spec(cfg, prefix + name, shape)
            if dim is not None and self.tp > 1:
                sharded.append(s)
                out_sh[s.name] = ShardDim(dim, "model")
            elif self.tp > 1:
                replicated.append(s)
            else:
                sharded.append(s)

        add("ln1", (D,))
        add("wq", (D, Hq * hd), dim=1)
        add("wk", (D, Hkv * hd), dim=1 if kv_tp == tp else None)
        add("wv", (D, Hkv * hd), dim=1 if kv_tp == tp else None)
        if cfg.qkv_bias:
            add("wq_b", (Hq * hd,), dim=0)
            add("wk_b", (Hkv * hd,), dim=0 if kv_tp == tp else None)
            add("wv_b", (Hkv * hd,), dim=0 if kv_tp == tp else None)
        add("wo", (Hq * hd, D), dim=0)
        if cfg.post_norms:
            add("post_ln1", (D,))
        add("ln2", (D,))
        if cfg.n_experts and not prefix:
            # router lives with the (data x model)-FSDP'd group; experts
            # are a separate EP group (see groups())
            add("moe_router", (D, cfg.n_experts))
        else:
            add("w1", (D, cfg.d_ff), dim=1)
            if cfg.mlp in ("swiglu", "geglu"):
                add("w3", (D, cfg.d_ff), dim=1)
            add("w2", (cfg.d_ff, D), dim=0)
        if cfg.post_norms:
            add("post_ln2", (D,))
        return sharded, replicated, out_sh

    def _cross_layer_specs(self):
        cfg = self.cfg
        D, hd = cfg.d_model, cfg.hd
        Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
        tp = self.tp
        kv_tp = min(tp, Hkv)
        sharded, replicated, out_sh = [], [], {}

        def add(name, shape, dim=None):
            s = spec(cfg, name, shape)
            if dim is not None and tp > 1:
                sharded.append(s)
                out_sh[s.name] = ShardDim(dim, "model")
            elif tp > 1:
                replicated.append(s)
            else:
                sharded.append(s)

        add("x_lnq", (D,))
        add("x_wq", (D, Hq * hd), dim=1)
        add("x_wk", (D, Hkv * hd), dim=1 if kv_tp == tp else None)
        add("x_wv", (D, Hkv * hd), dim=1 if kv_tp == tp else None)
        add("x_wo", (Hq * hd, D), dim=0)
        add("x_gate", (1,))
        add("c_ln2", (D,))
        add("c_w1", (D, cfg.d_ff), dim=1)
        if cfg.mlp in ("swiglu", "geglu"):
            add("c_w3", (D, cfg.d_ff), dim=1)
        add("c_w2", (cfg.d_ff, D), dim=0)
        add("c_gate", (1,))
        return sharded, replicated, out_sh

    def groups(self) -> dict[str, GroupDef]:
        cfg = self.cfg
        D, V = cfg.d_model, cfg.vocab
        g: dict[str, GroupDef] = {}

        # --- layer stack ---------------------------------------------------
        sharded, replicated, out_sh = [], [], {}
        for i in range(self.selfs_per_block):
            pre = f"s{i}_" if self.is_vlm else ""
            s, r, o = self._self_layer_specs(pre)
            sharded += s
            replicated += r
            out_sh.update(o)
        if self.is_vlm:
            s, r, o = self._cross_layer_specs()
            sharded += s
            replicated += r
            out_sh.update(o)
        g["layers"] = GroupDef(tuple(sharded), n_layers=self.n_blocks,
                               outer=out_sh)
        if replicated:
            g["layers_rep"] = GroupDef(tuple(replicated),
                                       n_layers=self.n_blocks,
                                       replicated_over_model=True)

        # --- MoE experts (EP outer sharding over "model") --------------------
        if cfg.n_experts:
            E, F = cfg.n_experts, cfg.d_ff
            especs = [
                spec(cfg, "moe_w1", (E, D, F)),
                spec(cfg, "moe_w3", (E, D, F)),
                spec(cfg, "moe_w2", (E, F, D)),
            ]
            eout = (
                {s.name: ShardDim(0, "model") for s in especs}
                if self.ep > 1
                else {}
            )
            g["layers_experts"] = GroupDef(
                tuple(especs), n_layers=self.n_blocks, outer=eout
            )

        # --- globals ---------------------------------------------------------
        gl = [spec(cfg, "emb", (V, D)), spec(cfg, "final_ln", (D,))]
        gout = {}
        if self.tp > 1:
            gout["emb"] = ShardDim(0, "model")
        if not cfg.tie_embeddings:
            gl.append(spec(cfg, "head", (D, V)))
            if self.tp > 1:
                gout["head"] = ShardDim(1, "model")
        g["globals"] = GroupDef(tuple(gl), outer=gout)
        return g

    # ---------------- forward ------------------------------------------------
    def _layer_windows(self):
        """Per-layer attention window (int32 array, big = global).  gemma2
        alternates local(sliding)/global [arXiv:2408.00118]."""
        cfg = self.cfg
        big = np.int32(2**30)
        if cfg.local_global_alternate and cfg.sliding_window:
            w = [
                cfg.sliding_window if i % 2 == 0 else big
                for i in range(cfg.n_layers)
            ]
        elif cfg.sliding_window:
            w = [cfg.sliding_window] * cfg.n_layers
        else:
            w = [big] * cfg.n_layers
        return jnp.asarray(w, jnp.int32)

    def _self_block(self, p, x, q_pos, window, cache=None, cache_index=0,
                    pg=None, prefix="", sp=False):
        cfg = self.cfg
        tp_axis = pg.tp_axis if self.tp > 1 else None
        h = L.rms_norm(x, p[prefix + "ln1"], cfg.norm_eps)
        h = L.gather_seq(h, tp_axis, sp)  # SP: gather seq for attention
        attn_cfg = _AttnView(cfg, prefix)
        out, new_cache = L.attention(
            attn_cfg, p, h, q_pos=q_pos, cache=cache, cache_index=cache_index,
            window=window, tp_axis=tp_axis, tp=self.tp, prefix=prefix, sp=sp,
        )
        if cfg.post_norms:
            out = L.rms_norm(out, p[prefix + "post_ln1"], cfg.norm_eps)
        x = x + out
        h = L.rms_norm(x, p[prefix + "ln2"], cfg.norm_eps)
        if cfg.n_experts and not prefix:
            moe_out, aux = moe_ffn(
                cfg, p, h,
                ep_axis=pg.ep_axis if self.ep > 1 else None, ep=self.ep,
            )
            if cfg.post_norms:
                moe_out = L.rms_norm(moe_out, p[prefix + "post_ln2"], cfg.norm_eps)
            return x + moe_out, new_cache, aux
        h = L.gather_seq(h, tp_axis, sp)
        out = L.mlp(cfg, p, h, tp_axis=tp_axis, prefix=prefix, sp=sp)
        if cfg.post_norms:
            out = L.rms_norm(out, p[prefix + "post_ln2"], cfg.norm_eps)
        return x + out, new_cache, 0.0

    def _cross_block(self, p, x, memory, pg):
        cfg = self.cfg
        tp_axis = pg.tp_axis if self.tp > 1 else None
        out = L.cross_attention(cfg, p, x, memory, tp_axis=tp_axis, tp=self.tp)
        x = x + jnp.tanh(p["x_gate"].astype(x.dtype)) * out
        h = L.rms_norm(x, p["c_ln2"], cfg.norm_eps)
        out = L.mlp(cfg, p, h, tp_axis=tp_axis, prefix="c_")
        return x + jnp.tanh(p["c_gate"].astype(x.dtype)) * out

    def _scan_groups(self):
        names = ["layers"]
        if self.tp > 1:
            names.append("layers_rep")
        if self.cfg.n_experts:
            names.append("layers_experts")
        return names

    def _backbone(self, pg, x, q_pos, memory=None, caches=None,
                  cache_index=0, sp=False):
        """Run the layer stack.  caches: pytree with leading dim n_blocks."""
        cfg = self.cfg
        windows = self._layer_windows().reshape(
            self.n_blocks, self.selfs_per_block
            if not self.is_vlm else cfg.cross_attn_interval
        )[:, : self.selfs_per_block]

        def body(p, carry, xs):
            x, aux = carry
            win, cache = xs
            new_caches = []
            for i in range(self.selfs_per_block):
                pre = f"s{i}_" if self.is_vlm else ""
                c_i = None if cache is None else jax.tree.map(
                    lambda t, i=i: t[i], cache)
                x, nc, a = self._self_block(
                    p, x, q_pos, win[i], cache=c_i, cache_index=cache_index,
                    pg=pg, prefix=pre, sp=sp,
                )
                aux = aux + a
                if nc is not None:
                    new_caches.append(nc)
            if self.is_vlm and memory is not None:
                x = self._cross_block(p, x, memory, pg)
            y = (
                jax.tree.map(lambda *ts: jnp.stack(ts), *new_caches)
                if new_caches
                else None
            )
            return (x, aux), y

        xs = (windows, caches)
        (x, aux), new_caches = pg.scan(self._scan_groups(), body,
                                       (x, jnp.float32(0)), xs)
        return x, aux, new_caches

    def _sp_active(self, T: int) -> bool:
        """Sequence parallelism: residual stream seq-sharded over the TP
        axis (Megatron-SP); active for multi-token steps that divide."""
        sp = self.cfg.parallel.sequence_parallel and self.tp > 1
        if sp and self.cfg.n_experts:
            raise ValueError("sequence_parallel with MoE is not supported")
        return sp and T > 1 and T % self.tp == 0

    def _embed_in(self, pg, tokens, sp=False):
        cfg = self.cfg
        g = pg.globals("globals")
        vstart = 0
        tp_axis = pg.tp_axis if self.tp > 1 else None
        if self.tp > 1:
            vstart = L.axis_index(pg.tp_axis) * g["emb"].shape[0]
        x = L.embed(tokens, g["emb"].astype(pg.compute_dtype),
                    tp_axis=None, vocab_start=vstart)
        if self.tp > 1:
            x = L.reduce_out(x, tp_axis, sp)  # SP: fused reduce-scatter(seq)
        return x, g, vstart

    def _logits(self, pg, g, x, sp=False):
        cfg = self.cfg
        x = L.gather_seq(x, pg.tp_axis if self.tp > 1 else None, sp)
        x = L.rms_norm(x, g["final_ln"], cfg.norm_eps)
        head = g["emb"].T if cfg.tie_embeddings else g["head"]
        return L.lm_logits(x, head, softcap=cfg.final_softcap)

    # ---------------- public API ----------------------------------------------
    def loss(self, pg, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        sp = self._sp_active(T)
        q_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        x, g, vstart = self._embed_in(pg, tokens, sp=sp)
        memory = batch.get("patches") if self.is_vlm else None
        if memory is not None:
            memory = memory.astype(pg.compute_dtype)
        x, aux, _ = self._backbone(pg, x, q_pos, memory=memory, sp=sp)
        tp_axis = pg.tp_axis if self.tp > 1 else None
        if cfg.ce_chunk:
            # §Perf beyond-paper: vocab-chunked online-logsumexp CE -- never
            # materializes the (B, T, V) fp32 logits buffer
            x = L.gather_seq(x, tp_axis, sp)
            x = L.rms_norm(x, g["final_ln"], cfg.norm_eps)
            head = g["emb"].T if cfg.tie_embeddings else g["head"]
            nll, w = L.chunked_ce(
                x[:, :-1], head.astype(pg.compute_dtype), tokens[:, 1:],
                jnp.ones((B, T - 1), jnp.float32),
                vocab_chunk=cfg.ce_chunk, softcap=cfg.final_softcap,
                tp_axis=tp_axis, vocab_start=vstart,
            )
        else:
            logits = self._logits(pg, g, x, sp=sp)
            nll, w = L.vocab_parallel_ce(
                logits[:, :-1], tokens[:, 1:],
                jnp.ones((B, T - 1), jnp.float32),
                tp_axis=tp_axis, vocab_start=vstart,
            )
        return nll + aux * w / max(cfg.n_layers, 1), w

    def cache_window(self, seq_len: int) -> int:
        """Ring-buffer size.  Long-context decode on a sliding-window arch
        caps the cache at the window (the gemma2 long_500k variant: all
        layers windowed -- see DESIGN.md)."""
        cfg = self.cfg
        if cfg.sliding_window and seq_len > 65536:
            return cfg.sliding_window
        return seq_len

    def cache_shapes(self, batch: int, seq_len: int) -> dict[str, Any]:
        """Full (global) KV cache shapes, leading dim = scan blocks.

        With TP > n_kv (replicated-KV GQA), each model rank caches its one
        sliced head: the global head dim is ``tp`` (sharded over "model",
        pairs duplicated -- noted in EXPERIMENTS)."""
        cfg = self.cfg
        W = self.cache_window(seq_len)
        if self.tp > 1 and self.tp <= cfg.n_kv_heads:
            raise ValueError(
                f"replicated-KV cache layout needs tp > n_kv_heads; got "
                f"tp={self.tp}, n_kv_heads={cfg.n_kv_heads}")
        hkv = self.tp if self.tp > 1 else cfg.n_kv_heads
        shape = (self.n_blocks, self.selfs_per_block, batch, hkv, W, cfg.hd)
        return {
            "k": (shape, jnp.bfloat16),
            "v": (shape, jnp.bfloat16),
            "pos": ((self.n_blocks, self.selfs_per_block, batch, W),
                    jnp.int32),
        }

    def cache_batch_dims(self):
        """Batch-dim index per cache leaf (for runtime cache sharding)."""
        return {"k": 2, "v": 2, "pos": 2}

    def init_cache(self, batch: int, seq_len: int):
        out = {}
        for k, (s, d) in self.cache_shapes(batch, seq_len).items():
            out[k] = (jnp.zeros(s, d) if k != "pos"
                      else jnp.full(s, -1, d))
        return out

    def prefill(self, pg, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        sp = self._sp_active(T)
        q_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        x, g, _ = self._embed_in(pg, tokens, sp=sp)
        memory = batch.get("patches") if self.is_vlm else None
        if memory is not None:
            memory = memory.astype(pg.compute_dtype)
        x, _, new_cache = self._backbone(
            pg, x, q_pos, memory=memory, caches=cache, cache_index=0, sp=sp)
        x = L.gather_seq(x, pg.tp_axis if self.tp > 1 else None, sp)
        logits = self._logits(pg, g, x[:, -1:])
        return logits, new_cache

    def decode(self, pg, batch, cache, index):
        """One token against a filled cache.  index: int32 scalar position,
        or a (B,) vector of per-row positions (continuous batching)."""
        cfg = self.cfg
        tokens = batch["tokens"]  # (B, 1)
        B = tokens.shape[0]
        idx = jnp.asarray(index, jnp.int32)
        q_pos = (idx[:, None] if idx.ndim == 1
                 else jnp.broadcast_to(idx[None, None], (B, 1)))
        index = idx
        x, g, _ = self._embed_in(pg, tokens)
        memory = batch.get("patches") if self.is_vlm else None
        if memory is not None:
            memory = memory.astype(pg.compute_dtype)
        x, _, new_cache = self._backbone(
            pg, x, q_pos, memory=memory, caches=cache, cache_index=index)
        logits = self._logits(pg, g, x)
        return logits, new_cache


class _AttnView:
    """cfg proxy letting prefixed (VLM self-layer) params reuse L.attention."""

    def __init__(self, cfg, prefix):
        self._cfg = cfg
        self._prefix = prefix

    def __getattr__(self, k):
        return getattr(self._cfg, k)
