"""Mixture-of-Experts FFN with top-k routing and expert parallelism.

GShard-style capacity dispatch [Lepikhin et al. 2020] adapted to shard_map:
tokens are ranked within their expert via a sort-based position count (no
(N*k, E, C) one-hot tensors), scattered into an (E, C, D) buffer, exchanged
over the EP mesh axis with two all_to_alls, and combined with router weights.

Composition with the paper (Fig. 5): expert weights are Shard(0) on the
expert dim over the EP axis, *then* RaggedShard-packed over the FSDP axes —
the (RaggedShard, Shard(0)) = StridedRaggedShard case.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import psum


def _positions_within_expert(flat_e, n_experts):
    """rank of each assignment among same-expert assignments (stable)."""
    m = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(m, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0)
    )
    rank_sorted = idx - run_start
    rank = jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted)
    return rank


def moe_ffn(cfg, p, x, *, ep_axis=None, ep=1, prefix="moe_"):
    """x: (B, T, D) local tokens.  Returns (out, aux_loss).

    p[f"{prefix}router"]: (D, E) replicated over EP.
    p[f"{prefix}w1"/"w2"/"w3"]: (E_local, D, F) / (E_local, F, D) / (E_local, D, F).
    """
    B, T, D = x.shape
    N = B * T
    E = cfg.n_experts
    k = cfg.top_k
    e_loc = E // ep

    xf = x.reshape(N, D)
    logits = (xf @ p[prefix + "router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, k)  # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (N * k)
    aux = E * jnp.sum(me * ce) * cfg.moe_aux_coef

    cap = max(1, int(cfg.capacity_factor * N * k / E))
    if T == 1:
        # decode: the per-expert buffer is tiny (N = batch), so run dropless
        # -- capacity-dropping at decode would make generation depend on
        # which other requests share the batch (and diverge from prefill)
        cap = max(cap, N)
    flat_e = top_e.reshape(-1)                    # (N*k,)
    flat_w = top_p.reshape(-1).astype(x.dtype)
    flat_tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    rank = _positions_within_expert(flat_e, E)
    keep = rank < cap
    slot = flat_e * cap + jnp.minimum(rank, cap - 1)  # (N*k,)

    # dispatch: (E*cap, D)
    contrib = jnp.where(keep[:, None], xf[flat_tok], 0).astype(x.dtype)
    buf = jnp.zeros((E * cap, D), x.dtype).at[
        jnp.where(keep, slot, E * cap - 1)
    ].add(jnp.where(keep[:, None], contrib, 0))

    if ep_axis is not None and ep > 1:
        # (ep, e_loc*cap, D) -> exchange -> (e_loc, ep*cap, D)
        buf = buf.reshape(ep, e_loc * cap, D)
        buf = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False)
        # buf now (ep, e_loc*cap, D) where leading dim = source device
        h = buf.reshape(ep, e_loc, cap, D).transpose(1, 0, 2, 3)
        h = h.reshape(e_loc, ep * cap, D)
    else:
        h = buf.reshape(e_loc, cap, D)

    # expert MLP batched over local experts
    w1 = p[prefix + "w1"].astype(x.dtype)
    w2 = p[prefix + "w2"].astype(x.dtype)
    if prefix + "w3" in p:
        g = jnp.einsum("ecd,edf->ecf", h, w1)
        u = jnp.einsum("ecd,edf->ecf", h, p[prefix + "w3"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w1))
    out_e = jnp.einsum("ecf,efd->ecd", h, w2)

    if ep_axis is not None and ep > 1:
        out_e = out_e.reshape(e_loc, ep, cap, D).transpose(1, 0, 2, 3)
        out_e = out_e.reshape(ep, e_loc * cap, D)
        out_e = lax.all_to_all(out_e, ep_axis, split_axis=0, concat_axis=0,
                               tiled=False)
        out_flat = out_e.reshape(E * cap, D)
    else:
        out_flat = out_e.reshape(E * cap, D)

    gathered = out_flat[slot] * (flat_w * keep)[:, None]
    out = jnp.zeros((N, D), x.dtype).at[flat_tok].add(gathered)
    return out.reshape(B, T, D), aux
