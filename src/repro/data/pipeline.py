"""Deterministic synthetic token pipeline.

Production-shaped: an infinite, seekable stream of fixed-length sequences,
sharded by host, with per-step determinism (step -> batch is a pure
function, so restarts resume exactly -- matching the checkpointing story).

The "corpus" is a procedurally generated Zipf-ish token distribution with
Markov structure, so cross-entropy has learnable signal (examples train
against it and the loss visibly drops, e.g. Figure 10 reproductions).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Markov structure: tok_{t+1} ~ mix(unigram-zipf, f(tok_t))
    order_mix: float = 0.7


class SyntheticStream:
    def __init__(self, cfg: DataConfig, model_cfg=None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # deterministic "successor" structure: next ~ (a*tok + b) % v band
        self.a = int(rng.integers(3, 97)) * 2 + 1
        self.b = int(rng.integers(0, v))

    def batch(self, step: int) -> dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng([cfg.seed, step])
        B, T, v = cfg.global_batch, cfg.seq_len, cfg.vocab
        toks = np.empty((B, T), np.int64)
        toks[:, 0] = rng.choice(v, size=B, p=self.unigram)
        mix = rng.random((B, T)) < cfg.order_mix
        iid = rng.choice(v, size=(B, T), p=self.unigram)
        for t in range(1, T):
            succ = (self.a * toks[:, t - 1] + self.b) % v
            toks[:, t] = np.where(mix[:, t], succ, iid[:, t])
        out = {"tokens": jnp.asarray(toks, jnp.int32)}
        mc = self.model_cfg
        if mc is not None and mc.arch_type == "vlm":
            out["patches"] = jnp.asarray(
                rng.normal(0, 1, (B, mc.n_patches, mc.d_model)), jnp.bfloat16)
        if mc is not None and mc.arch_type == "audio":
            out["frames"] = jnp.asarray(
                rng.normal(0, 1, (B, min(mc.n_frames, T), mc.d_model)),
                jnp.bfloat16)
        return out

    def shard(self, batch, runtime):
        """Place a host batch onto the mesh with the runtime's batch specs."""
        from jax.sharding import NamedSharding

        specs = runtime.batch_pspec(batch)
        return {
            k: jax.device_put(v, NamedSharding(runtime.mesh, specs[k]))
            for k, v in batch.items()
        }
