"""Hymba-1.5B [arXiv:2411.13676]: 32L, d_model=1600, 25H (GQA kv=5),
d_ff=5504, vocab=32001, ssm_state=16; parallel attention + Mamba heads,
sliding-window attention except 3 global layers (meta tokens omitted)."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    ssm_state=16, conv_kernel=4, sliding_window=1024,
    source="[arXiv:2411.13676]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model")),
    optimizer="adamw",
)
