"""Qwen3-235B-A22B [hf:Qwen/Qwen3-30B-A3B family scaled per assignment]:
94L, d_model=4096, 64H (GQA kv=4), 128 experts top-8, d_ff=1536/expert,
vocab=151936.  EP=16 over the model axis (experts Shard(0) then
RaggedShard -- the paper's Fig.5 composition); 8-bit Adam to fit optimizer
states on v5e."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128,
    n_experts=128, top_k=8,
    source="[hf:Qwen/Qwen3-30B-A3B]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model"), ep=16),
    optimizer="adam8bit",
)
