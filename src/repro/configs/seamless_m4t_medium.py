"""SeamlessM4T-medium [arXiv:2308.11596]: enc-dec transformer backbone,
12 encoder + 12 decoder layers, d_model=1024, 16H, d_ff=4096, vocab=256206.
Audio frontend stubbed to frame embeddings (assignment carve-out)."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12, encoder_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64, n_frames=4096,
    mlp="swiglu",
    source="[arXiv:2308.11596]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model")),
    optimizer="adamw",
)
