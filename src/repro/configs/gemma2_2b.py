"""Gemma2-2B [arXiv:2408.00118]: 26L, d_model=2304, 8H (GQA kv=4),
d_ff=9216, vocab=256000; alternating local(4096)/global attention, attn +
final logit softcaps, GeGLU, post-norms, tied embeddings."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_ff=9216, vocab=256000, head_dim=256,
    mlp="geglu", attn_softcap=50.0, final_softcap=30.0,
    sliding_window=4096, local_global_alternate=True, post_norms=True,
    tie_embeddings=True, rope_theta=10000.0,
    source="[arXiv:2408.00118]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model")),
    optimizer="adamw",
)
