"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision]: 100L,
d_model=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256; cross-attention
image layers every 5th layer.  Vision frontend is a stub: input_specs
provides patch embeddings (assignment carve-out)."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    mlp="swiglu", cross_attn_interval=5, n_patches=1024, rope_theta=5e5,
    source="[hf:meta-llama/Llama-3.2-11B-Vision]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model")),
    optimizer="adamw",
)
