"""Architecture registry: exact assigned configs, keyed by public id."""
from __future__ import annotations

import importlib

from .base import ModelConfig, ParallelConfig, ShapeConfig, SHAPES

# public arch id -> module name
_MODULES = {
    "qwen2.5-14b": "qwen2_5_14b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "qwen1.5-32b": "qwen1_5_32b",
    "xlstm-125m": "xlstm_125m",
    "hymba-1.5b": "hymba_1_5b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "gemma2-2b": "gemma2_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "nemotron-4-340b": "nemotron_4_340b",
    # the paper's own evaluation models
    "llama3-70b": "llama3_70b",
    "gpt-oss-120b": "gpt_oss_120b",
}

ARCH_IDS = list(_MODULES)
ASSIGNED_ARCH_IDS = ARCH_IDS[:10]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def build_model(cfg: ModelConfig):
    from ..models.encdec import EncDecModel
    from ..models.recurrent import HymbaModel, XLSTMModel
    from ..models.transformer import DecoderLM

    if cfg.arch_type in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.arch_type == "ssm":
        return XLSTMModel(cfg)
    if cfg.arch_type == "hybrid":
        return HymbaModel(cfg)
    if cfg.arch_type == "audio":
        return EncDecModel(cfg)
    raise ValueError(cfg.arch_type)


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Which (arch x shape) pairs run; skips per DESIGN.md."""
    if shape.name == "long_500k":
        if cfg.arch_type in ("ssm", "hybrid"):
            return True, ""
        if cfg.sliding_window:
            return True, "sliding-window cache variant"
        return False, "pure full-attention arch: 500k dense KV out of scope"
    return True, ""
