"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family scaled per assignment]:
48L, d_model=5120, 40 heads (GQA kv=8), d_ff=13824, vocab=152064, QKV bias."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    arch_type="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, head_dim=128,
    qkv_bias=True, mlp="swiglu", rope_theta=1e6,
    source="[hf:Qwen/Qwen2.5-0.5B]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model")),
    optimizer="adamw",
)
