"""LLaMA-3-70B [arXiv:2407.21783] -- the paper's dense evaluation model
(Fig. 8): 80L, d_model=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="llama3-70b",
    arch_type="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    mlp="swiglu", rope_theta=5e5,
    source="[arXiv:2407.21783]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model")),
    optimizer="adamw",
)
