"""GPT-OSS-120B [arXiv:2508.10925] -- the paper's MoE evaluation model
(Fig. 8, Table 1): 36L, d_model=2880, 64H (GQA kv=8), 128 experts top-4,
d_ff=2880/expert, vocab=201088."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="gpt-oss-120b",
    arch_type="moe",
    n_layers=36, d_model=2880, n_heads=64, n_kv_heads=8,
    d_ff=2880, vocab=201088, head_dim=64,
    n_experts=128, top_k=4,
    source="[arXiv:2508.10925]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model"), ep=16),
    optimizer="adamw",
)
