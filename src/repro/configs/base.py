"""Config system: model architecture + parallelism + run shapes.

Every assigned architecture gets one ``<id>.py`` in this package exporting
``CONFIG`` (exact paper/model-card numbers, cited) built on these dataclasses.
``ModelConfig.reduced()`` derives the CPU smoke-test variant (2 layers,
d_model<=512, <=4 experts) required by the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How one architecture maps onto the production mesh.

    The paper's primary axis is FSDP (ZeRO-3).  Small/medium models use
    *pure FSDP* over every non-pod mesh axis (the paper's main mode); very
    large dense models add TP over ``model``; MoE models add EP over
    ``model``.  ``pod`` defaults to HSDP replication (paper §6.1 sweeps
    2x/4x replication); set ``pod_fsdp=True`` to extend ZeRO-3 across pods.

    The flat schedule knobs below are the *legacy* surface: at runtime init
    they are lowered (bitwise-neutrally) onto a typed
    ``core.policy.PolicySet`` -- a default ``ShardingPolicy`` plus one
    exact-name rule per ``group_schedules`` entry -- and resolved into the
    ``ShardingPlan`` the runtime consumes.  New code should prefer
    ``core.policy.plan(model, mesh, policies)`` with explicit policies
    (or ``policies="auto"`` for the cost-model planner); see DESIGN.md
    §Policy API for the lowering table.
    """

    fsdp_axes: tuple[str, ...] = ("data", "model")  # param-shard axes
    batch_axes: tuple[str, ...] = ("data", "model")  # batch-shard axes
    tp: int = 1           # tensor parallel degree over "model"
    ep: int = 1           # expert parallel degree over "model"
    pod_fsdp: bool = False   # multi-pod: extend FSDP over "pod" (else HSDP)
    sequence_parallel: bool = False  # shard activations over "model" (w/ tp)
    microbatches: int = 1    # gradient accumulation chunks

    # --- communication schedule (core.schedule.CommSchedule) ----------------
    # two-slot double-buffered layer all-gathers: the layer scan runs over
    # pairs, slot i%2 gathers layer i, both slots issue before either
    # layer's compute; gathered buffers never ride the scan carry
    prefetch: bool = False
    reshard_after_forward: bool = True  # drop gathered params after fwd (remat)
    keep_last_gathered: bool = False  # last layer's gathered params stay live
    gather_dtype: Optional[str] = None  # all-gather wire dtype (None=compute)
    # grad reduce-scatter dtype (None=wire).  When set, it also pins the
    # accumulate dtype of the replica gradient psums -- notably the HSDP
    # cross-pod psum in FSDPRuntime._reduce_grads ("fp32" buys exact
    # cross-pod accumulation for 2x reduce bandwidth).  Legacy spelling:
    # lowers bitwise-neutrally onto reduce_wire's cast codecs
    reduce_dtype: Optional[str] = None
    # wire FORMAT of the gradient reduce-scatter (core.wire.WireCodec):
    # None derives a cast codec from reduce_dtype / the gather wire dtype
    # (the legacy path, bit for bit); "fp32"/"bf16" name the cast codec;
    # "q8_block" is the QSDP-style quantized gradient wire -- int8 codes +
    # per-block scales (~4x fewer bytes than fp32) with per-shard
    # error-feedback residuals in the param state tree.  Mutually
    # exclusive with reduce_dtype
    reduce_wire: Optional[str] = None
    # "xla" = lax.all_gather/psum_scatter, overlap left to XLA's
    # latency-hiding scheduler; "ring" = explicit lax.ppermute chunk ring
    # (bitwise identical to xla; issue order visible in the HLO)
    gather_mode: str = "xla"
    # gradient reduce-scatter algorithm: "match" mirrors the gather mode
    # (bitwise identical to XLA's linear-order reduction); "ring_acc" is the
    # accumulate-in-flight ring -- n-1 chunk-hops instead of the order-exact
    # ring's n(n-1)/2, trading bitwise-vs-XLA reproducibility for bandwidth
    reduce_mode: str = "match"
    # storage format of the sharded parameter buffers (core.store.ParamStore):
    # "fp32" (master weights, the default), "bf16" (half-size storage),
    # "q8_block" (block-wise int8 codes + scales alongside an fp32 master
    # shard; the all-gather moves the quantized payload, ~4x fewer wire
    # bytes than fp32).  Per-group overrides go through group_schedules,
    # e.g. {"layers": {"param_store": "q8_block"}}
    param_store: str = "fp32"
    # per-group schedule overrides, group name -> dict over
    # schedule.GROUP_OVERRIDE_KEYS (gather_mode/gather_dtype/reduce_dtype/
    # sharded), e.g. {"globals": {"sharded": False},
    #                 "layers": {"reduce_dtype": "fp32"}} keeps the small
    # globals group replicated (no per-step gather) and fp32-reduces only
    # the layer stack
    group_schedules: Optional[Mapping[str, Mapping[str, Any]]] = None

    def __post_init__(self):
        # TP shards activations over "model", so parameters can't also be
        # ZeRO-sharded over it.  EP is fine: the runtime strips "model" from
        # the expert groups' FSDP axes (experts are Shard(0) over "model").
        # ValueError (not assert): config validation must survive python -O.
        if self.tp > 1 and "model" in self.fsdp_axes:
            raise ValueError(
                f"tp={self.tp} shards activations over 'model'; fsdp_axes "
                f"{self.fsdp_axes} must not ZeRO-shard parameters over it "
                f"too")
        if self.microbatches < 1:
            raise ValueError(
                f"microbatches must be >= 1, got {self.microbatches}")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention variants -------------------------------------------------
    qkv_bias: bool = False                 # qwen1.5/2.5
    attn_softcap: Optional[float] = None   # gemma2 attn logit softcap
    final_softcap: Optional[float] = None  # gemma2 final logit softcap
    sliding_window: Optional[int] = None
    local_global_alternate: bool = False   # gemma2: alternate local/global
    post_norms: bool = False               # gemma2 post-attn/post-mlp norms

    # --- mlp ----------------------------------------------------------------
    mlp: str = "swiglu"  # swiglu | geglu | squared_relu

    # --- moe ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # --- vlm (stub vision frontend: input_specs provides patch embeddings) --
    cross_attn_interval: int = 0  # every k-th layer is a cross-attn layer
    n_patches: int = 1024

    # --- audio / enc-dec (stub audio frontend: frame embeddings) ------------
    encoder_layers: int = 0
    n_frames: int = 1024

    # --- ssm / hybrid --------------------------------------------------------
    ssm_state: int = 0
    conv_kernel: int = 4
    slstm_every: int = 0        # xlstm: every k-th block is sLSTM
    ssm_expand: int = 2

    # --- misc ----------------------------------------------------------------
    attn_chunk: int = 1024  # KV-chunk for online-softmax attention (§Perf)
    ce_chunk: int = 0       # vocab-chunked CE (0 = materialize logits)
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    source: str = ""  # citation from the assignment table

    # --- parallel + training defaults ---------------------------------------
    parallel: ParallelConfig = ParallelConfig()
    optimizer: str = "adamw"  # adamw | adam8bit | sgd | muon
    quant_block: int = 1024   # flat elements per quant block (32x32 paper blocks)
    learning_rate: float = 3e-4

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def reduced(self) -> "ModelConfig":
        """CPU smoke variant: same family, 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = max(2, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        hd = d // heads
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            sliding_window=min(self.sliding_window, 16)
            if self.sliding_window
            else None,
            cross_attn_interval=2 if self.cross_attn_interval else 0,
            n_patches=8,
            encoder_layers=2 if self.encoder_layers else 0,
            n_frames=16,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            slstm_every=2 if self.slstm_every else 0,
            parallel=ParallelConfig(
                fsdp_axes=("data",), batch_axes=("data",), microbatches=1
            ),
            quant_block=64,
        )


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
