"""Nemotron-4-340B [arXiv:2402.16819]: 96L, d_model=18432, 96H (GQA kv=8),
d_ff=73728, vocab=256000, squared-ReLU MLP.  TP=16 x FSDP=16 with sequence
parallelism and gradient accumulation; 8-bit Adam (the paper's block-wise
quantized optimizer) is what makes 340B optimizer state fit 256 x 16 GB."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000, head_dim=192,
    mlp="squared_relu",
    source="[arXiv:2402.16819]",
    parallel=ParallelConfig(fsdp_axes=("data",), batch_axes=("data",),
                            tp=16, sequence_parallel=True, microbatches=16),
    optimizer="adam8bit",
)
