"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family scaled per assignment]: 64L,
d_model=5120, 40H (GQA kv=40 = MHA), d_ff=27392, vocab=152064, QKV bias."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab=152064, head_dim=128,
    qkv_bias=True, mlp="swiglu", rope_theta=1e6,
    source="[hf:Qwen/Qwen1.5-0.5B]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model")),
    optimizer="adamw",
)
