"""xLSTM-125M [arXiv:2405.04517]: 12L, d_model=768, 4 heads, vocab=50304;
sLSTM + mLSTM blocks (one sLSTM per 6-block super-block here; stabilized
sigmoid gating -- DESIGN.md)."""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=192,
    slstm_every=6,
    source="[arXiv:2405.04517]",
    parallel=ParallelConfig(fsdp_axes=("data", "model"),
                            batch_axes=("data", "model")),
    optimizer="adamw",
)
