"""Batched serving driver: prefill a batch of prompts, then decode tokens
with ZeRO-3 parameter gathering per layer (params stay sharded at rest).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import build_model, get_config
    from ..core.fsdp import FSDPRuntime
    from .mesh import make_local_mesh

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh(args.data, args.model)
    model = build_model(cfg)
    runtime = FSDPRuntime(model, mesh)
    params = runtime.init_params(args.seed)
    prefill = runtime.make_prefill_step()
    decode = runtime.make_decode_step()

    rng = np.random.default_rng(args.seed)
    B, P = args.batch, args.prompt_len
    total = P + args.gen
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, P)), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frames, cfg.d_model)), jnp.bfloat16)

    cache = model.init_cache(B, total)
    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    print(f"prefill {B}x{P} in {time.time()-t0:.2f}s")

    out_tokens = [np.asarray(nxt)]
    t0 = time.time()
    for i in range(args.gen - 1):
        db = dict(batch)
        db["tokens"] = nxt[:, None]
        logits, cache = decode(params, db, cache, jnp.int32(P + i))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(nxt))
    dt = time.time() - t0
    gen = np.stack(out_tokens, 1)
    print(f"decoded {args.gen-1} steps x batch {B} in {dt:.2f}s "
          f"({B*(args.gen-1)/max(dt,1e-9):.1f} tok/s)")
    print("sample continuations:")
    for b in range(min(B, 4)):
        print(f"  [{b}]", gen[b].tolist())


if __name__ == "__main__":
    main()
