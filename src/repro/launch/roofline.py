"""Roofline analysis from the compiled dry-run artifact (no hardware).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device for SPMD
modules).  Collective bytes are parsed from ``compiled.as_text()``: for each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute we
take the largest operand/result buffer and apply the standard ring-volume
factor (m-1)/m (2x for all-reduce).

CPU-backend caveat (recorded in EXPERIMENTS.md): XLA:CPU upcasts some bf16
collectives to f32; we report bytes as lowered.  MODEL_FLOPS = 6*N*D uses
N_active for MoE.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

from ..compat import cost_analysis
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s*(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    by_kind: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        sizes = []
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            sizes.append(n * _DTYPE_BYTES[dt])
        buf = max(sizes)
        # group size
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm2 = _GROUPS2_RE.search(line)
            if gm2:
                g = int(gm2.group(2))
        ring = (g - 1) / g if g > 1 else 0.0
        vol = buf * ring * (2.0 if kind == "all-reduce" else 1.0)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0.0) + vol
    return CollectiveStats(counts, by_kind)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (training) with N_active for MoE; 2*N*D for a
    forward-only step (prefill/decode)."""
    n_active = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg) -> float:
    """Per-token active parameter count from the config."""
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.hd
    attn = D * (cfg.n_heads * hd) * 2 + D * (cfg.n_kv_heads * hd) * 2
    if cfg.n_experts:
        glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        ffn = cfg.top_k * glu * D * F + D * cfg.n_experts  # + router
    else:
        glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        ffn = glu * D * F
    if cfg.arch_type == "ssm":
        # mLSTM projections dominate
        per_layer = 5 * D * D
    elif cfg.arch_type == "hybrid":
        d_in = cfg.n_heads * hd
        per_layer = attn + ffn + 2 * D * 2 * d_in
    else:
        per_layer = attn + ffn
    n_layers = cfg.n_layers + cfg.encoder_layers
    if cfg.cross_attn_interval:
        # cross layers replace every k-th self layer's attention cost-ish
        pass
    return per_layer * n_layers + 2 * V * D


def total_params(cfg) -> float:
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.hd
    attn = D * (cfg.n_heads * hd) * 2 + D * (cfg.n_kv_heads * hd) * 2
    glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    if cfg.n_experts:
        ffn = cfg.n_experts * glu * D * F + D * cfg.n_experts
    else:
        ffn = glu * D * F
    if cfg.arch_type == "ssm":
        per_layer = 5 * D * D
    elif cfg.arch_type == "hybrid":
        d_in = cfg.n_heads * hd
        per_layer = attn + ffn + 2 * D * 2 * d_in
    else:
        per_layer = attn + ffn
    n_layers = cfg.n_layers + cfg.encoder_layers
    return per_layer * n_layers + 2 * V * D


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compile_ok: bool
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collective_bytes: float = 0.0
    temp_bytes: float = 0.0
    arg_bytes: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)
    model_flops: float = 0.0
    # plan-predicted wire bytes for BOTH comm directions (the resolved
    # ShardingPlan's codec accounting: parameter all-gather payload and
    # gradient reduce-scatter payload), so the dry-run row shows the
    # q8-vs-fp32 wire drops without HLO parsing
    gather_wire_bytes: float = 0.0
    reduce_wire_bytes: float = 0.0
    error: str = ""
    note: str = ""

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "ok": self.compile_ok,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "dominant": self.dominant if self.compile_ok else "-",
            "hlo_gflops_dev": round(self.flops_per_device / 1e9, 3),
            "hbm_gb_dev": round(self.bytes_per_device / 1e9, 3),
            "coll_gb_dev": round(self.collective_bytes / 1e9, 4),
            "temp_gb_dev": round(self.temp_bytes / 1e9, 3),
            "arg_gb_dev": round(self.arg_bytes / 1e9, 3),
            "gather_wire_mb": round(self.gather_wire_bytes / 1e6, 3),
            "reduce_wire_mb": round(self.reduce_wire_bytes / 1e6, 3),
            "model_gflops": round(self.model_flops / 1e9, 1),
            "useful_ratio": round(self.useful_ratio, 4),
            "colls": self.coll_counts,
            "note": self.note or self.error[:200],
        }


def analyze(compiled, *, arch, shape_cfg, mesh_name, chips, cfg,
            note="", plan=None) -> Roofline:
    cost = cost_analysis(compiled)
    mem = compiled.memory_analysis()
    stats = parse_collectives(compiled.as_text())
    return Roofline(
        gather_wire_bytes=float(plan.gather_wire_bytes()) if plan else 0.0,
        reduce_wire_bytes=float(plan.reduce_wire_bytes()) if plan else 0.0,
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        compile_ok=True,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=stats.total_bytes,
        temp_bytes=float(getattr(mem, "temp_size_in_bytes", 0)),
        arg_bytes=float(getattr(mem, "argument_size_in_bytes", 0)),
        coll_counts=stats.counts,
        model_flops=model_flops(cfg, shape_cfg),
        note=note,
    )
