"""Production mesh construction (TPU v5e, 256 chips/pod).

Functions, not module-level constants: importing this module never touches
jax device state (the dry run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from ..compat import make_mesh


def production_axis_sizes(*, multi_pod: bool = False) -> dict[str, int]:
    """Axis-name -> size of the production mesh, as plain metadata --
    enough for core.policy.plan() to resolve a ShardingPlan without
    creating the 256/512 virtual devices (dryrun --plan-only)."""
    if multi_pod:
        return {"pod": 2, "data": 16, "model": 16}
    return {"data": 16, "model": 16}


def make_production_mesh(*, multi_pod: bool = False):
    sizes = production_axis_sizes(multi_pod=multi_pod)
    return make_mesh(tuple(sizes.values()), tuple(sizes))


def make_local_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Mesh over however many (possibly host-platform) devices exist."""
    if pod is not None:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
