"""Production mesh construction (TPU v5e, 256 chips/pod).

Functions, not module-level constants: importing this module never touches
jax device state (the dry run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Mesh over however many (possibly host-platform) devices exist."""
    if pod is not None:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
