import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: prove the distribution config is coherent + extract
roofline terms from the compiled artifacts.

For every (architecture x input shape) pair, lower + compile the step on the
production mesh (single-pod 16x16 = 256 chips; --multi-pod 2x16x16 = 512),
print ``memory_analysis()`` / ``cost_analysis()``, and append a JSON row.

Scan-trip calibration: XLA cost_analysis counts a while-loop body ONCE, so a
rolled layer scan under-reports by ~n_layers.  We therefore compile, per
scanned stack, one extra variant with 2 blocks fully unrolled; the cost
difference is exactly one layer's cost, and

    true = cost(full) + sum_s (L_s - 1) * body_s          (microbatches=1)

For gradient accumulation (micro>1) the optimizer's one-shot cost is
estimated analytically and the inner fwd/bwd scaled by micro (documented in
EXPERIMENTS.md §Dry-run).

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--planner ragged]
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"

_STATE_BYTES = {"adamw": 8, "sgd": 4, "adam8bit": 2.01, "muon": 12}


def _stacks(cfg) -> dict[str, int]:
    """scan-stack name -> true block count for this config."""
    if cfg.arch_type == "audio":
        return {"enc": cfg.encoder_layers, "dec": cfg.n_layers}
    if cfg.arch_type == "vlm":
        return {"layers": cfg.n_layers // cfg.cross_attn_interval}
    if cfg.arch_type == "ssm":
        k = cfg.slstm_every or cfg.n_layers
        return {"layers": cfg.n_layers // k}
    return {"layers": cfg.n_layers}


def _with_blocks(cfg, blocks: dict[str, int]):
    """Return cfg whose stacks scan ``blocks[s]`` times."""
    if cfg.arch_type == "audio":
        return dataclasses.replace(
            cfg, encoder_layers=blocks["enc"], n_layers=blocks["dec"])
    if cfg.arch_type == "vlm":
        return dataclasses.replace(
            cfg, n_layers=blocks["layers"] * cfg.cross_attn_interval)
    if cfg.arch_type == "ssm":
        k = cfg.slstm_every or cfg.n_layers
        return dataclasses.replace(cfg, n_layers=blocks["layers"] * k)
    return dataclasses.replace(cfg, n_layers=blocks["layers"])


def _compile(cfg, shape, mesh, planner, unroll=1, policies=None):
    from ..configs import build_model
    from ..core.fsdp import FSDPRuntime
    from ..optim import make_optimizer
    from .specs import input_specs

    model = build_model(cfg)
    runtime = FSDPRuntime(model, mesh, planner=planner, scan_unroll=unroll,
                          policies=policies)
    optimizer = make_optimizer(cfg)
    if shape.kind == "train":
        step = runtime.make_train_step(optimizer)
        args = input_specs(cfg, shape, runtime, model, optimizer)
    elif shape.kind == "prefill":
        step = runtime.make_prefill_step()
        args = input_specs(cfg, shape, runtime, model)
    else:
        step = runtime.make_decode_step()
        args = input_specs(cfg, shape, runtime, model)
    compiled = step.lower(*args).compile()
    return compiled, runtime


def _costs(compiled):
    from ..compat import cost_analysis
    from .roofline import parse_collectives

    ca = cost_analysis(compiled)
    st = parse_collectives(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            st.total_bytes, st.counts)


def _optimizer_cost(runtime, cfg):
    """Analytic one-shot optimizer cost per device (flops, bytes)."""
    import numpy as np

    local = 0
    for lo in runtime.layouts.values():
        n = lo.plan.shard_size * (lo.n_layers or 1)
        local += n
    state = _STATE_BYTES.get(cfg.optimizer, 8)
    # read w, g, states; write w, states (fp32 master + state bytes)
    return 12.0 * local, local * (4 * 3 + 2 * state)


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            planner: str = "ragged", quiet: bool = False,
            calibrate: bool = True, overrides: dict | None = None,
            policies=None, cost_model=None, verify: bool = False):
    from ..configs import build_model, get_config, supports_shape
    from ..configs.base import SHAPES
    from ..core.policy import make_plan
    from .mesh import make_production_mesh
    from .roofline import Roofline, model_flops

    cfg = get_config(arch)
    if overrides:
        par = dataclasses.replace(cfg.parallel,
                                  **overrides.get("parallel", {}))
        cfg = dataclasses.replace(
            cfg, parallel=par,
            **{k: v for k, v in overrides.items() if k != "parallel"})
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = 512 if multi_pod else 256

    ok, why = supports_shape(cfg, shape)
    if not ok:
        return Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                        chips=chips, compile_ok=False, note=f"SKIP: {why}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    if policies == "auto":
        # resolve the cost model ONCE on the full model, then pin the
        # resulting per-group decisions as an explicit PolicySet so the
        # 1/2-layer calibration variants compile under identical policies
        auto = make_plan(build_model(cfg), mesh, "auto",
                         cost_model=cost_model)
        if not quiet:
            # measured-vs-builtin pricing + profile provenance per group
            print(auto.describe())
        policies = auto.policy_set()

    t0 = time.time()
    compiled, runtime = _compile(cfg, shape, mesh, planner,
                                 policies=policies)
    t_full = time.time() - t0
    if verify:
        # abstract-eval verification on the production mesh: prove the
        # plan's declared comm/memory/dtype invariants against the traced
        # step before trusting any cost numbers from it
        from ..analysis import verify_runtime

        vreport = verify_runtime(runtime)
        if not quiet:
            print(vreport.summary())
        vreport.raise_if_failed()
    mem = compiled.memory_analysis()
    if not quiet:
        from ..compat import cost_analysis

        print(runtime.plan.describe())
        print(mem)
        ca = cost_analysis(compiled)
        print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})

    f_full, b_full, c_full, counts = _costs(compiled)
    # effective accumulation = what the runtime actually ran after clamping
    # to a divisor of the per-device batch
    if shape.kind == "train":
        import numpy as np

        sizes = dict(zip(runtime.mesh.axis_names,
                         runtime.mesh.devices.shape))
        div = int(np.prod([
            sizes[a]
            for a in runtime._usable_batch_axes(shape.global_batch)
        ])) or 1
        b_loc = max(shape.global_batch // div, 1)
        micro = cfg.parallel.microbatches
        while b_loc % micro:
            micro -= 1
    else:
        micro = 1
    stacks = _stacks(cfg)

    if calibrate:
        base_blocks = {s: 1 for s in stacks}
        cal_cfg = _with_blocks(cfg, base_blocks)
        cbase, _ = _compile(cal_cfg, shape, mesh, planner, unroll=1,
                            policies=policies)
        f_b, b_b, c_b, _ = _costs(cbase)
        bodies = {}
        for s in stacks:
            blocks = dict(base_blocks)
            blocks[s] = 2
            cvar, _ = _compile(_with_blocks(cfg, blocks), shape, mesh,
                               planner, unroll=2, policies=policies)
            f_v, b_v, c_v, _ = _costs(cvar)
            bodies[s] = (f_v - f_b, b_v - b_b, c_v - c_b)
        o_f, o_b = (_optimizer_cost(runtime, cfg)
                    if shape.kind == "train" else (0.0, 0.0))
        inner_f = max(f_full - o_f, 0.0)
        inner_b = max(b_full - o_b, 0.0)
        f_true = o_f + micro * (inner_f + sum(
            (stacks[s] - 1) * max(bodies[s][0], 0) for s in stacks))
        b_true = o_b + micro * (inner_b + sum(
            (stacks[s] - 1) * max(bodies[s][1], 0) for s in stacks))
        c_true = micro * (c_full + sum(
            (stacks[s] - 1) * max(bodies[s][2], 0) for s in stacks))
    else:
        f_true, b_true, c_true = f_full, b_full, c_full

    r = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        compile_ok=True,
        flops_per_device=f_true, bytes_per_device=b_true,
        collective_bytes=c_true,
        temp_bytes=float(getattr(mem, "temp_size_in_bytes", 0)),
        arg_bytes=float(getattr(mem, "argument_size_in_bytes", 0)),
        coll_counts=counts,
        model_flops=model_flops(cfg, shape),
        # plan-predicted wire payloads, both directions (gather + reduce)
        gather_wire_bytes=float(runtime.plan.gather_wire_bytes()),
        reduce_wire_bytes=float(runtime.plan.reduce_wire_bytes()),
        note=(why + f" full_compile={t_full:.0f}s").strip(),
    )
    return r


def plan_only(arch: str, *, multi_pod: bool = False, planner: str = "ragged",
              policies=None, cost_model=None) -> str:
    """Resolve and print the ShardingPlan without compiling anything --
    plans are auditable in seconds, not compile-minutes.  Planning is pure
    host-side metadata, so this uses the production mesh's axis *sizes*
    (no 256/512 virtual devices are created).

    With the default (legacy) policies it also cross-checks the lowering:
    the plan produced by the config's flat knobs must be JSON-identical to
    the plan from the explicitly-spelled PolicySet (CI runs this)."""
    from ..configs import build_model, get_config
    from ..core.policy import PolicySet, make_plan
    from .mesh import production_axis_sizes

    cfg = get_config(arch)
    axes = production_axis_sizes(multi_pod=multi_pod)
    model = build_model(cfg)
    p = make_plan(model, axes, policies, planner=planner,
                  cost_model=cost_model)
    out = [p.describe()]
    if policies is None:
        explicit = PolicySet.from_parallel_config(cfg.parallel)
        p2 = make_plan(model, axes, explicit, planner=planner)
        if p.dumps() != p2.dumps():
            raise AssertionError(
                "legacy-config lowering diverged from the explicit "
                f"PolicySet spelling: {p.diff(p2)}")
        out.append("plan lowering parity OK (legacy knobs == PolicySet)")
    return "\n".join(out)


def append_result(row: dict, path: pathlib.Path):
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(row) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--planner", default="ragged")
    ap.add_argument("--policies", default=None,
                    help="'auto' picks per-group store/comm policies from "
                         "the structure-aware cost model (core.policy); "
                         "default lowers the config's legacy knobs")
    ap.add_argument("--plan-only", action="store_true",
                    help="resolve + print the ShardingPlan (and check "
                         "legacy-lowering parity); no compilation")
    ap.add_argument("--profile", default=None,
                    help="measured comm profile JSON (BENCH_comm.json from "
                         "benchmarks.bench_comm); prices --policies auto "
                         "from the calibrated curves instead of the "
                         "builtin roofline constants")
    ap.add_argument("--verify", action="store_true",
                    help="prove the plan's declared comm/memory/dtype "
                         "invariants against the traced step "
                         "(repro.analysis) before reporting costs; abort "
                         "on any violation")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the beyond-paper §Perf winners "
                         "(attn_chunk=512, ce_chunk=8192, capacity 1.0)")
    ap.add_argument("--out", default=str(RESULTS / "dryrun.jsonl"))
    args = ap.parse_args()

    from ..configs import ASSIGNED_ARCH_IDS
    from ..configs.base import SHAPES

    cost_model = None
    if args.profile:
        from ..core.policy import CostModel

        cost_model = CostModel.from_profile(args.profile)

    if args.plan_only:
        archs = ASSIGNED_ARCH_IDS if args.all else [args.arch]
        for arch in archs:
            print(f"== {arch} ==")
            print(plan_only(arch, multi_pod=args.multi_pod,
                            planner=args.planner, policies=args.policies,
                            cost_model=cost_model))
        return

    pairs = (
        [(a, s) for a in ASSIGNED_ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    out = pathlib.Path(args.out)
    # beyond-paper optimized profile (EXPERIMENTS.md §Perf): smaller online-
    # softmax tiles, vocab-chunked CE, tight MoE capacity, tuned microbatches
    OPTIMIZED = {"attn_chunk": 512, "ce_chunk": 8192,
                 "capacity_factor": 1.0}
    OPTIMIZED_PARALLEL = {"nemotron-4-340b": {"microbatches": 4}}
    for arch, shape in pairs:
        try:
            ov = None
            if args.optimized:
                ov = dict(OPTIMIZED)
                if arch in OPTIMIZED_PARALLEL:
                    ov["parallel"] = OPTIMIZED_PARALLEL[arch]
            r = run_one(arch, shape, multi_pod=args.multi_pod,
                        planner=args.planner,
                        calibrate=not args.no_calibrate, overrides=ov,
                        policies=args.policies, cost_model=cost_model,
                        verify=args.verify)
            row = r.row()
        except Exception as e:
            traceback.print_exc()
            row = {"arch": arch, "shape": shape,
                   "mesh": "pod2x16x16" if args.multi_pod else "pod16x16",
                   "ok": False, "note": f"ERROR {type(e).__name__}: {e}"}
        row["planner"] = args.planner
        row["policies"] = args.policies or "legacy"
        row["profile"] = "optimized" if args.optimized else "baseline"
        print(json.dumps(row))
        append_result(row, out)


if __name__ == "__main__":
    main()
