"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --steps 50 --batch 8 --seq 128

On this CPU container, --reduced (smoke-scale) is the realistic mode; the
full configs are exercised by the dry run.  The driver wires data pipeline,
FSDP runtime, optimizer, metrics, and periodic checkpointing.
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--data", type=int, default=1, help="data axis size")
    ap.add_argument("--model", type=int, default=1, help="model axis size")
    ap.add_argument("--planner", default="ragged")
    ap.add_argument("--policies", default=None,
                    help="sharding policies: 'auto' runs the structure-"
                         "aware cost model per group (core.policy); default "
                         "lowers the config's legacy knobs")
    ap.add_argument("--profile", default=None,
                    help="measured comm profile JSON (BENCH_comm.json from "
                         "benchmarks.bench_comm): '--policies auto' prices "
                         "formats and ring chunking from the calibrated "
                         "curves instead of the builtin roofline")
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore params/opt state from --ckpt if it exists "
                         "(any saved plan/mesh/TP degree: cross-plan loads "
                         "stream through the extent map) and continue from "
                         "the saved step")
    ap.add_argument("--tp", type=int, default=0,
                    help="override the arch config's tensor-parallel degree "
                         "(requires --model >= the degree)")
    ap.add_argument("--verify", action="store_true",
                    help="statically verify the plan's declared comm/memory/"
                         "dtype invariants against the traced step "
                         "(repro.analysis) before running; abort on any "
                         "violation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..checkpoint import ckpt
    from ..configs import build_model, get_config
    from ..core.fsdp import FSDPRuntime
    from ..data.pipeline import DataConfig, SyntheticStream
    from ..optim import make_optimizer
    from .mesh import make_local_mesh

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.optimizer:
        cfg = dataclasses.replace(cfg, optimizer=args.optimizer)
    if args.tp:
        par = cfg.parallel
        if args.tp > 1:
            par = dataclasses.replace(
                par, tp=args.tp,
                fsdp_axes=tuple(a for a in par.fsdp_axes if a != "model")
                or ("data",))
        else:
            par = dataclasses.replace(par, tp=1)
        cfg = dataclasses.replace(cfg, parallel=par)
    mesh = make_local_mesh(args.data, args.model)
    model = build_model(cfg)
    cost_model = None
    if args.profile:
        from ..core.policy import CostModel

        cost_model = CostModel.from_profile(args.profile)
    runtime = FSDPRuntime(model, mesh, planner=args.planner,
                          policies=args.policies, cost_model=cost_model)
    print(runtime.plan.describe())
    optimizer = make_optimizer(cfg)
    if args.verify:
        from ..analysis import verify_runtime

        report = verify_runtime(runtime, optimizer,
                                profile_path=args.profile)
        print(report.summary())
        report.raise_if_failed()

    params = runtime.init_params(args.seed)
    opt_state = optimizer.init(runtime)
    start = 0
    if args.resume and args.ckpt:
        import pathlib

        if (pathlib.Path(args.ckpt) / "meta.json").exists():
            params, start, opt_state = ckpt.load(args.ckpt, runtime,
                                                 opt_state)
            print(f"resumed {args.ckpt} @ step {start}")
    step_fn = runtime.make_train_step(optimizer)
    stream = SyntheticStream(
        DataConfig(cfg.vocab, args.seq, args.batch, seed=args.seed), cfg)

    n_params = sum(
        int(lo.plan.payload) * (lo.n_layers or 1) * lo.outer_size
        for lo in runtime.layouts.values()
    )
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"planner={args.planner} optimizer={cfg.optimizer} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    step = jnp.int32(start)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = stream.shard(stream.batch(i), runtime)
        params, opt_state, step, metrics = step_fn(
            params, opt_state, step, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tok_s = (i + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"tok/s {tok_s:,.0f}")
        if args.ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, runtime, params, opt_state, step=i + 1)
            print(f"checkpoint @ step {i+1} -> {args.ckpt}")
    if args.ckpt:
        ckpt.save(args.ckpt, runtime, params, opt_state, step=args.steps)
        print(f"final checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
