"""ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
shardable, zero allocation.  This is what the dry run lowers against."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs.base import ModelConfig, ShapeConfig


def _sds(shape, dtype, mesh, pspec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, pspec))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, runtime):
    """Input batch ShapeDtypeStructs for one (arch, shape) pair."""
    mesh = runtime.mesh
    B = shape.global_batch
    T = shape.seq_len if shape.kind != "decode" else 1
    batch = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, min(cfg.n_frames, shape.seq_len), cfg.d_model), jnp.bfloat16)
    pspecs = runtime.batch_pspec(batch)
    return {
        k: _sds(v.shape, v.dtype, mesh, pspecs[k]) for k, v in batch.items()
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, runtime, model):
    mesh = runtime.mesh
    shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
    proto = jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]), shapes,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )
    pspecs = runtime.cache_pspec(proto, shape.global_batch)
    return jax.tree.map(
        lambda sd, ps: _sds(sd.shape, sd.dtype, mesh, ps), proto, pspecs)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, runtime, model,
                optimizer=None):
    """All lowering inputs for the step implied by ``shape.kind``.

    train   -> (params, opt_state, step, batch)
    prefill -> (params, batch, cache)
    decode  -> (params, batch, cache, index)
    """
    params = runtime.param_shapes()
    if shape.kind == "train":
        return (params, optimizer.state_shapes(runtime),
                jax.ShapeDtypeStruct((), jnp.int32),
                batch_specs(cfg, shape, runtime))
    cache = cache_specs(cfg, shape, runtime, model)
    if shape.kind == "prefill":
        return (params, batch_specs(cfg, shape, runtime), cache)
    return (params, batch_specs(cfg, shape, runtime), cache,
            jax.ShapeDtypeStruct((), jnp.int32))
