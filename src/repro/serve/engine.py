"""Continuous-batching serving engine over ZeRO-3 sharded parameters.

Production-shaped serving loop on top of the FSDP runtime's decode step:
a fixed pool of batch slots, each independently holding one request at its
own sequence position.  Every engine iteration runs ONE decode call for the
whole pool with a per-row position vector — admitted requests stream their
prompt tokens through the same call (chunked prefill degenerate case),
active requests consume their last sampled token, and empty slots are
harmless (their rows are invalidated on admission).

One compiled shape for the entire lifetime of the engine; parameters stay
RaggedShard-sharded at rest (gathered per layer inside the step), so the
engine composes with any mesh the runtime supports.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import compat


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (len,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0        # next position to write in this row
    cursor: int = 0     # prompt tokens already consumed


class ServeEngine:
    def __init__(self, runtime, model, params, *, pool: int = 4,
                 max_len: int = 256, extras: dict | None = None,
                 sample: Callable | None = None):
        self.rt = runtime
        self.model = model
        self.params = params
        self.pool = pool
        self.max_len = max_len
        self.extras = extras or {}
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1))
        self.cache = model.init_cache(pool, max_len)
        self.slots = [_Slot() for _ in range(pool)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._decode = runtime.make_decode_step()

    # ------------------------------------------------------------------ #
    def submit(self, req: Request):
        self.queue.append(req)

    def _reset_row(self, row: int):
        """Invalidate a slot's cache row (pos arrays -> -1) so stale entries
        from a previous occupant can never attend."""
        bdims = self.model.cache_batch_dims()

        def rst(path, leaf, bdim):
            if path and getattr(path[-1], "key", None) == "pos":
                idx = [slice(None)] * leaf.ndim
                idx[bdim] = row
                return leaf.at[tuple(idx)].set(-1)
            return leaf

        self.cache = compat.tree_map_with_path(rst, self.cache, bdims)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                self._reset_row(i)
                self.slots[i] = _Slot(req=self.queue.popleft())

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One engine iteration (one decode call for the whole pool)."""
        self._admit()
        toks = np.zeros((self.pool, 1), np.int32)
        pos = np.zeros((self.pool,), np.int32)
        active = []
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            active.append(i)
            pos[i] = s.pos
            if s.cursor < len(s.req.prompt):
                toks[i, 0] = int(s.req.prompt[s.cursor])
            else:
                toks[i, 0] = s.req.out[-1]
        if not active:
            return 0
        batch = {"tokens": jnp.asarray(toks), **self.extras}
        logits, self.cache = self._decode(
            self.params, batch, self.cache, jnp.asarray(pos, jnp.int32))
        sampled = np.asarray(self.sample(logits))
        for i in active:
            s = self.slots[i]
            s.pos += 1
            if s.cursor < len(s.req.prompt):
                s.cursor += 1
                if s.cursor < len(s.req.prompt):
                    continue  # still streaming the prompt; logits unused
            s.req.out.append(int(sampled[i, 0]))
            if len(s.req.out) >= s.req.max_new or s.pos >= self.max_len - 1:
                s.req.done = True
                self.finished.append(s.req)
                self.slots[i] = _Slot()
        return len(active)

    def run(self, max_steps: int = 100_000):
        steps = 0
        while (self.queue or any(s.req for s in self.slots)) and \
                steps < max_steps:
            self.step()
            steps += 1
        return self.finished
