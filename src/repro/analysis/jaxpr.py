"""Jaxpr-level extraction: CommTrace + BufferTrace from a traced step.

This module is PURE MECHANICS -- walk a closed jaxpr (recursing through
call primitives, scans, custom_vjp bodies; never into ``pallas_call``
bodies, whose values are tile-resident on TPU and not XLA buffers) and
extract:

  * ``CommTrace`` -- every collective equation (all_gather / psum_scatter /
    ppermute / psum / all_to_all) with its payload dtype, element count,
    mesh axes, and the scan-trip multiplier of the scope it sits in, so
    per-step wire bytes are computable without running anything.
  * ``BufferTrace`` -- every equation-output aval (the intermediate
    buffers XLA must materialize), every scan-carry aval, and a per-scope
    liveness peak for avals of a given size class (the gathered-buffer
    peak the two-slot prefetch bounds).

Invariant *checking* against a ShardingPlan lives in
``repro.analysis.verify``; this module knows nothing about plans.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Optional, Sequence

import jax
import numpy as np

_JAXPR_TYPES = (jax.core.ClosedJaxpr, jax.core.Jaxpr)

#: primitives that put payload on the inter-device wire
COLLECTIVE_PRIMS = frozenset(
    {"all_gather", "psum_scatter", "reduce_scatter", "ppermute", "psum",
     "all_to_all"})


def _sub_jaxprs(eqn) -> Iterator[jax.core.Jaxpr]:
    """The sub-jaxprs of one equation's params (scan/cond bodies, pjit /
    remat / custom_vjp calls), as plain Jaxprs."""
    for p in jax.tree.leaves(eqn.params,
                             is_leaf=lambda x: isinstance(x, _JAXPR_TYPES)):
        if isinstance(p, jax.core.ClosedJaxpr):
            yield p.jaxpr
        elif isinstance(p, jax.core.Jaxpr):
            yield p


def _as_jaxpr(jaxpr) -> jax.core.Jaxpr:
    return jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr


def iter_eqns(jaxpr, *, skip_pallas: bool = True,
              _mult: int = 1, _path: str = "") -> Iterator[tuple]:
    """Yield ``(eqn, trips, path)`` for every equation reachable from
    ``jaxpr``.  ``trips`` is how many times the equation executes per call
    of the top-level jaxpr (the product of enclosing scan lengths; while
    loops count as 1 -- the bound is unknowable statically).  ``path`` is
    a ``/``-joined primitive trail for Violation reports."""
    jx = _as_jaxpr(jaxpr)
    for i, eqn in enumerate(jx.eqns):
        name = eqn.primitive.name
        here = f"{_path}/{name}[{i}]"
        yield eqn, _mult, here
        if skip_pallas and "pallas" in name:
            continue
        sub_mult = _mult
        if name == "scan":
            length = eqn.params.get("length")
            if length is not None:
                sub_mult = _mult * int(length)
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, skip_pallas=skip_pallas,
                                 _mult=sub_mult, _path=here)


def intermediate_avals(jaxpr, *, skip_pallas: bool = True) -> list:
    """Every equation-output aval reachable from ``jaxpr`` -- the
    intermediates XLA materializes as buffers.  With ``skip_pallas`` (the
    default) values inside ``pallas_call`` bodies are excluded: the kernel
    body IS the fusion (tile-resident on TPU), so its values are not XLA
    buffers.  Generalizes the walker the fused-kernel jaxpr regressions
    were built on."""
    acc = []
    for eqn, _, _ in iter_eqns(jaxpr, skip_pallas=skip_pallas):
        if skip_pallas and "pallas" in eqn.primitive.name:
            continue
        for v in eqn.outvars:
            av = getattr(v, "aval", None)
            if av is not None and hasattr(av, "shape"):
                acc.append(av)
    return acc


def scan_carry_avals(jaxpr) -> list[tuple[tuple, str]]:
    """``(shape, dtype-name)`` of every scan-carry input across the whole
    program -- what the prefetch retention regression inspects (a gathered
    layer buffer in a carry means backward retains one buffer per layer)."""
    found = []
    for eqn, _, _ in iter_eqns(jaxpr):
        if eqn.primitive.name == "scan":
            nc = eqn.params["num_consts"]
            nk = eqn.params["num_carry"]
            for v in eqn.invars[nc:nc + nk]:
                found.append((tuple(v.aval.shape), str(v.aval.dtype)))
    return found


def has_full_f32(fn: Callable, *args, n: int) -> bool:
    """True if tracing ``fn(*args)`` materializes any fp32 intermediate of
    ``>= n`` elements outside pallas bodies (the gather-path fused-dequant
    regression: the fused kernel must show none, the unfused composition
    must show at least one)."""
    avals = intermediate_avals(jax.make_jaxpr(fn)(*args))
    return any(av.dtype == jax.numpy.float32
               and int(np.prod(av.shape)) >= n for av in avals)


def count_full_f32(fn: Callable, *args, n: int) -> int:
    """Number of fp32 intermediates of ``>= n`` elements outside pallas
    bodies in the trace of ``fn(*args)``."""
    avals = intermediate_avals(jax.make_jaxpr(fn)(*args))
    return sum(1 for av in avals
               if av.dtype == jax.numpy.float32
               and int(np.prod(av.shape)) >= n)


# --------------------------------------------------------------------------- #
# CommTrace
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class CollectiveEvent:
    """One collective equation in the traced program.

    ``trips`` is the scan-trip multiplier (executions per step);
    ``wire_bytes`` is per-device bytes ONE execution puts on the wire:
    an all_gather ships its (m-1) remote shards, a psum_scatter ships
    (m-1)/m of its input, a ppermute hop ships its whole operand, and a
    psum costs a reduce + broadcast (2(m-1)/m)."""

    kind: str                 # primitive name
    axes: tuple[str, ...]     # mesh axis names the collective runs over
    axis_size: int            # product of the named axes' sizes
    dtype: str                # payload dtype name
    elems: int                # payload elements (per-device input)
    trips: int                # executions per step (scan multiplier)
    path: str                 # jaxpr location trail

    @property
    def itemsize(self) -> int:
        return jax.numpy.dtype(self.dtype).itemsize

    @property
    def in_bytes(self) -> int:
        return self.elems * self.itemsize

    @property
    def wire_bytes(self) -> float:
        m = self.axis_size
        if m <= 1:
            return 0.0
        if self.kind == "all_gather":
            return float(self.in_bytes * (m - 1))
        if self.kind in ("psum_scatter", "reduce_scatter"):
            return float(self.in_bytes) * (m - 1) / m
        if self.kind == "ppermute":
            return float(self.in_bytes)
        if self.kind == "psum":
            return 2.0 * self.in_bytes * (m - 1) / m
        if self.kind == "all_to_all":
            return float(self.in_bytes) * (m - 1) / m
        return 0.0


def _axis_tuple(params) -> tuple[str, ...]:
    axes = params.get("axis_name", params.get("axes", ()))
    if isinstance(axes, (list, tuple)):
        return tuple(str(a) for a in axes)
    return (str(axes),)


@dataclasses.dataclass(frozen=True)
class CommTrace:
    """All collective events of one traced step."""

    events: tuple[CollectiveEvent, ...]
    axis_sizes: dict[str, int]

    def filter(self, *, kinds: Optional[Sequence[str]] = None,
               dtype: Optional[str] = None,
               elems: Optional[int] = None) -> "CommTrace":
        ev = self.events
        if kinds is not None:
            ev = tuple(e for e in ev if e.kind in kinds)
        if dtype is not None:
            ev = tuple(e for e in ev if e.dtype == dtype)
        if elems is not None:
            ev = tuple(e for e in ev if e.elems == elems)
        return CommTrace(ev, self.axis_sizes)

    @property
    def total_wire_bytes(self) -> float:
        return sum(e.wire_bytes * e.trips for e in self.events)

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + e.trips
        return out

    def __len__(self) -> int:
        return len(self.events)


def extract_comm(jaxpr, axis_sizes: dict[str, int]) -> CommTrace:
    """Walk ``jaxpr`` and collect every collective equation as a
    CollectiveEvent.  ``axis_sizes`` maps mesh axis names to sizes (psum /
    ppermute params carry only names; all_gather also carries axis_size)."""
    events = []
    for eqn, trips, path in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name not in COLLECTIVE_PRIMS:
            continue
        axes = _axis_tuple(eqn.params)
        m = int(np.prod([axis_sizes.get(a, 1) for a in axes])) or 1
        if name == "ppermute":
            # hop count is encoded in the perm, not the axis: a full ring
            # permutation has m entries but each device sends once
            perm = eqn.params.get("perm", ())
            m = max(m, len(perm))
        for v in eqn.invars:
            av = getattr(v, "aval", None)
            if av is None or not hasattr(av, "shape"):
                continue
            events.append(CollectiveEvent(
                kind=name, axes=axes, axis_size=m,
                dtype=str(av.dtype),
                elems=int(np.prod(av.shape)) if av.shape else 1,
                trips=trips, path=path))
    return CommTrace(tuple(events), dict(axis_sizes))


# --------------------------------------------------------------------------- #
# BufferTrace
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BufferTrace:
    """Materialized-buffer view of one traced step: every intermediate
    aval, every scan-carry aval, and per-scope liveness peaks for a size
    class of interest (gathered layer buffers)."""

    intermediates: tuple          # avals
    scan_carries: tuple[tuple[tuple, str], ...]
    # per-scope max simultaneously-live avals matching the probe class,
    # keyed by scope path -- see ``live_peak``
    _jaxpr: Any = dataclasses.field(repr=False, default=None)

    def full_f32(self, n: int) -> list:
        return [av for av in self.intermediates
                if av.dtype == jax.numpy.float32
                and int(np.prod(av.shape)) >= n]

    def live_peak(self, *, elems: int, dtype) -> int:
        """Max number of simultaneously-live values of exactly ``elems``
        elements in ``dtype`` within any single jaxpr scope -- a
        backward-liveness scan per scope (carries and scope inputs count
        as live throughout).  Gathered layer buffers never cross scope
        boundaries except via carries (which the scan-carry regression
        forbids), so the per-scope max IS the program peak for them."""
        want = (int(elems), str(jax.numpy.dtype(dtype)))

        def matches(v) -> bool:
            av = getattr(v, "aval", None)
            return (av is not None and hasattr(av, "shape")
                    and (int(np.prod(av.shape)) if av.shape else 1,
                         str(av.dtype)) == want)

        peak = 0

        def scan_scope(jx):
            nonlocal peak
            # backward pass: live set after the last eqn = outvars
            live = {id(v) for v in jx.outvars if matches(v)}
            # scope inputs that match are live for the whole scope
            base = {id(v) for v in list(jx.invars) + list(jx.constvars)
                    if matches(v)}
            peak = max(peak, len(live | base))
            for eqn in reversed(jx.eqns):
                produced = {id(v) for v in eqn.outvars if matches(v)}
                live -= produced
                for v in eqn.invars:
                    if matches(v):
                        live.add(id(v))
                peak = max(peak, len(live | base))
                if "pallas" in eqn.primitive.name:
                    continue
                for sub in _sub_jaxprs(eqn):
                    scan_scope(sub)

        if self._jaxpr is not None:
            scan_scope(_as_jaxpr(self._jaxpr))
        return peak


def extract_buffers(jaxpr) -> BufferTrace:
    return BufferTrace(
        intermediates=tuple(intermediate_avals(jaxpr)),
        scan_carries=tuple(scan_carry_avals(jaxpr)),
        _jaxpr=jaxpr,
    )


# --------------------------------------------------------------------------- #
# step tracing
# --------------------------------------------------------------------------- #
def _struct_of(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), a.dtype), tree)


def trace_train_step(runtime, optimizer=None, *, batch=None,
                     batch_size: int = 4, seq: int = 16):
    """``(closed_jaxpr, out_shapes)`` of one train step under the
    runtime's resolved plan -- pure abstract eval: parameters enter as
    ShapeDtypeStructs (``runtime.param_shapes()``), nothing is
    materialized beyond the optimizer's zero-init state, and nothing
    compiles.  ``batch`` defaults to the model's synthetic-pipeline batch
    structure so every arch (dense / MoE / encdec / recurrent) traces with
    the inputs training actually feeds it."""
    import jax.numpy as jnp

    from ..data.pipeline import DataConfig, SyntheticStream

    if optimizer is None:
        from ..optim import make_optimizer

        optimizer = make_optimizer(runtime.cfg)
    if batch is None:
        stream = SyntheticStream(
            DataConfig(runtime.cfg.vocab, seq, batch_size), runtime.cfg)
        batch = stream.batch(0)
    params = runtime.param_shapes()
    opt_state = _struct_of(optimizer.init(runtime))
    step = jax.ShapeDtypeStruct((), jnp.int32)
    fn = runtime.make_train_step(optimizer)
    closed, out_shapes = jax.make_jaxpr(fn, return_shape=True)(
        params, opt_state, step, _struct_of(batch))
    return closed, out_shapes
