"""Source layering linter: AST-based rules that keep the repo's
layering doctrine machine-enforced.

Rules (each independently selectable; ``tools/lint.py`` is the CLI):

  * ``compat-only``   -- version-specific JAX symbols (shard_map,
    mesh_utils, the ``*_with_path`` tree family, optimization_barrier,
    fp8 dtype names) are imported/used ONLY inside ``repro.compat``;
    ``jax.experimental.pallas`` is additionally allowed in the
    ``kernels/`` tier, whose whole job is backend-specific code.
  * ``quant-blockwise`` -- hot paths must go through ``repro.kernels.ops``;
    direct ``quant.blockwise`` imports are allowed only in ``kernels/``
    (built on the reference), ``quant/`` itself, and ``tests/`` (parity
    suites).  Generalizes the retired ``tools/check_quant_imports.py``.
  * ``bare-assert``   -- no ``assert`` statements in non-test source:
    ``python -O`` strips them, so config/validation paths must raise.
  * ``parity-tags``   -- every wire/kernel primitive declares its parity
    class via a ``PARITY: BITWISE|ALLCLOSE`` docstring tag, and any tag
    whose subject DESIGN.md's §Kernels table also names must agree with
    the table (the doctrine artifact and the code can't drift apart).
  * ``tracked-bytecode`` -- no ``*.pyc`` / ``__pycache__`` tracked by
    git (repo-hygiene regression guard).

Each finding is a ``LintError`` (path, line, rule, message).  The rule
set is a registry: new layering rules subclass nothing -- they are
functions registered in ``RULES`` with a name and a docstring.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import subprocess
import sys
from pathlib import Path
from typing import Callable, Iterable, Iterator, Optional

# --------------------------------------------------------------------------- #
# findings
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class LintError:
    path: str   # repo-relative
    line: int
    rule: str
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


# --------------------------------------------------------------------------- #
# shared AST helpers
# --------------------------------------------------------------------------- #
def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` attribute chain as a dotted string (None if the chain
    bottoms out in anything but a Name)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _imported_dotted(tree: ast.AST) -> Iterator[tuple[int, str]]:
    """Every imported dotted name with its line: ``import a.b`` ->
    ``a.b``; ``from a.b import c`` -> ``a.b.c`` (and ``a.b`` itself);
    relative levels are preserved as leading dots so callers can match
    in-package imports."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                yield node.lineno, alias.name
        elif isinstance(node, ast.ImportFrom):
            base = "." * node.level + (node.module or "")
            yield node.lineno, base
            for alias in node.names:
                yield node.lineno, f"{base}.{alias.name}" if base else alias.name


# --------------------------------------------------------------------------- #
# rule: compat-only
# --------------------------------------------------------------------------- #
#: dotted-prefix -> the compat entry point to use instead
_VERSIONED = {
    "jax.experimental.shard_map": "repro.compat.shard_map",
    "jax.experimental.mesh_utils": "repro.compat.make_mesh",
    "jax.experimental.pallas": "the kernels/ tier (backend-specific code)",
    "jax.experimental.maps": "repro.compat",
    "jax.tree_util.tree_map_with_path": "repro.compat.tree_map_with_path",
    "jax.tree_util.tree_flatten_with_path":
        "repro.compat.tree_flatten_with_path",
    "jax.tree.map_with_path": "repro.compat.tree_map_with_path",
    "jax.tree.flatten_with_path": "repro.compat.tree_flatten_with_path",
    "jax.lax.optimization_barrier": "repro.compat.optimization_barrier",
    "jax.numpy.float8_e4m3fn": "repro.compat.float8_dtypes",
    "jax.numpy.float8_e5m2": "repro.compat.float8_dtypes",
    "jnp.float8_e4m3fn": "repro.compat.float8_dtypes",
    "jnp.float8_e5m2": "repro.compat.float8_dtypes",
}
#: path-prefix exemptions per banned prefix (compat.py is globally exempt)
_VERSIONED_ALLOWED = {
    "jax.experimental.pallas": ("src/repro/kernels/",),
}


def check_compat_only(rel: str, tree: ast.AST, src: str) -> list[LintError]:
    """Version-specific JAX symbols only via repro.compat."""
    if rel == "src/repro/compat.py":
        return []
    errs = []
    seen: set[tuple[int, str]] = set()  # one finding per (line, prefix)

    def hit(line: int, name: str) -> None:
        for banned, repl in _VERSIONED.items():
            if name == banned or name.startswith(banned + "."):
                if any(rel.startswith(p) for p in
                       _VERSIONED_ALLOWED.get(banned, ())):
                    return
                if (line, banned) in seen:
                    return
                seen.add((line, banned))
                errs.append(LintError(
                    rel, line, "compat-only",
                    f"version-specific JAX symbol {banned!r}; use {repl}"))
                return

    for line, name in _imported_dotted(tree):
        hit(line, name)
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            name = _dotted(node)
            if name:
                hit(node.lineno, name)
    return errs


# --------------------------------------------------------------------------- #
# rule: quant-blockwise
# --------------------------------------------------------------------------- #
_QUANT_ALLOWED = ("src/repro/kernels/", "src/repro/quant/", "tests/")


def check_quant_blockwise(rel: str, tree: ast.AST, src: str) -> list[LintError]:
    """Hot paths import repro.kernels.ops, never quant.blockwise."""
    if any(rel.startswith(p) for p in _QUANT_ALLOWED):
        return []
    errs = []
    seen: set[int] = set()  # one finding per import line
    for line, name in _imported_dotted(tree):
        bare = name.lstrip(".")
        if (bare in ("quant", "quant.blockwise", "repro.quant",
                     "repro.quant.blockwise")
                or bare.startswith(("quant.blockwise.",
                                    "repro.quant.blockwise."))):
            if line in seen:
                continue
            seen.add(line)
            errs.append(LintError(
                rel, line, "quant-blockwise",
                f"direct reference-oracle import {name!r}; hot paths go "
                f"through repro.kernels.ops (repro.kernels.ref for "
                f"deliberate unfused ablations)"))
    return errs


# --------------------------------------------------------------------------- #
# rule: bare-assert
# --------------------------------------------------------------------------- #
def check_bare_assert(rel: str, tree: ast.AST, src: str) -> list[LintError]:
    """No ``assert`` in non-test source: ``python -O`` strips them."""
    return [LintError(rel, node.lineno, "bare-assert",
                      "bare assert in non-test code (stripped under "
                      "python -O); raise ValueError/RuntimeError")
            for node in ast.walk(tree) if isinstance(node, ast.Assert)]


# --------------------------------------------------------------------------- #
# rule: parity-tags
# --------------------------------------------------------------------------- #
_PARITY_RE = re.compile(r"PARITY:\s*(\w+)")
_PARITY_CLASSES = ("BITWISE", "ALLCLOSE")
#: modules whose comm/codec primitives MUST carry a tag, and which
#: function names count as primitives there
_PARITY_REQUIRED = {
    "src/repro/core/wire.py": re.compile(
        r"^(_ring_(all_gather|reduce_scatter|acc_reduce_scatter)"
        r"|_q8_(route|ring_acc)_reduce_scatter"
        r"|dtype_reduce_scatter|codec_reduce_scatter"
        r"|payload_all_gather|codec_gather(_ef|_defer_ef)?"
        r"|codec_grad_proxy(_ef|_defer_ef)?|sharded_gather)$"),
    "src/repro/kernels/ops.py": re.compile(r"^[a-z]\w*$"),
}
#: DESIGN.md rows: "| ... `ops.<name>` ... | BITWISE/ALLCLOSE |"
_DESIGN_ROW_RE = re.compile(
    r"`ops\.(\w+)`[^|]*\|\s*(BITWISE|ALLCLOSE)\s*\|")


def _design_parity_table(root: Path) -> dict[str, str]:
    doc = root / "DESIGN.md"
    if not doc.exists():
        return {}
    out: dict[str, str] = {}
    for m in _DESIGN_ROW_RE.finditer(doc.read_text()):
        out[m.group(1)] = m.group(2)
    return out


def make_parity_rule(root: Path) -> Callable:
    design = _design_parity_table(root)

    def check_parity_tags(rel: str, tree: ast.AST, src: str) -> list[LintError]:
        """Wire/kernel primitives declare PARITY class; DESIGN.md agrees."""
        required = _PARITY_REQUIRED.get(rel)
        errs = []
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            doc = ast.get_docstring(node) or ""
            m = _PARITY_RE.search(doc)
            if m is None:
                if required is not None and required.match(node.name):
                    errs.append(LintError(
                        rel, node.lineno, "parity-tags",
                        f"comm/codec primitive {node.name!r} has no "
                        f"'PARITY: BITWISE|ALLCLOSE' docstring tag "
                        f"(DESIGN.md §Static analysis)"))
                continue
            cls = m.group(1)
            if cls not in _PARITY_CLASSES:
                errs.append(LintError(
                    rel, node.lineno, "parity-tags",
                    f"{node.name!r} declares unknown parity class {cls!r} "
                    f"(one of {_PARITY_CLASSES})"))
            elif design.get(node.name, cls) != cls:
                errs.append(LintError(
                    rel, node.lineno, "parity-tags",
                    f"{node.name!r} tagged PARITY: {cls} but DESIGN.md's "
                    f"§Kernels table declares {design[node.name]}"))
        return errs

    return check_parity_tags


# --------------------------------------------------------------------------- #
# rule: tracked-bytecode (repo-level)
# --------------------------------------------------------------------------- #
def check_tracked_bytecode(root: Path) -> list[LintError]:
    """No git-tracked *.pyc / __pycache__ entries."""
    try:
        out = subprocess.run(["git", "ls-files"], cwd=root, check=True,
                             capture_output=True, text=True).stdout
    except (OSError, subprocess.CalledProcessError):
        return []  # not a git checkout (sdist, CI artifact dir): nothing to do
    return [LintError(f, 0, "tracked-bytecode",
                      "compiled bytecode tracked by git; `git rm --cached` "
                      "it (covered by .gitignore)")
            for f in out.splitlines()
            if f.endswith((".pyc", ".pyo")) or "__pycache__" in f]


# --------------------------------------------------------------------------- #
# driver
# --------------------------------------------------------------------------- #
#: file-level rules: name -> factory(root) -> check(rel, tree, src)
RULES: dict[str, Callable[[Path], Callable]] = {
    "compat-only": lambda root: check_compat_only,
    "quant-blockwise": lambda root: check_quant_blockwise,
    "bare-assert": lambda root: check_bare_assert,
    "parity-tags": make_parity_rule,
}
#: repo-level rules: name -> check(root)
REPO_RULES: dict[str, Callable[[Path], list]] = {
    "tracked-bytecode": check_tracked_bytecode,
}

#: default scan surface (tests/ keep their asserts and oracle imports)
DEFAULT_SCAN = ("src", "benchmarks", "tools")


def run_lint(root, paths: Optional[Iterable] = None,
             select: Optional[Iterable[str]] = None) -> list[LintError]:
    """Run the selected rules (default: all) over ``paths`` (default:
    ``DEFAULT_SCAN`` under ``root``); returns all findings sorted by
    location."""
    root = Path(root).resolve()
    names = list(select) if select else [*RULES, *REPO_RULES]
    unknown = set(names) - set(RULES) - set(REPO_RULES)
    if unknown:
        raise ValueError(f"unknown lint rules: {sorted(unknown)}; "
                         f"available: {sorted([*RULES, *REPO_RULES])}")
    checks = [RULES[n](root) for n in names if n in RULES]

    if paths is None:
        files = [p for top in DEFAULT_SCAN
                 for p in sorted((root / top).rglob("*.py"))
                 if (root / top).exists()]
    else:
        files = []
        for p in paths:
            p = Path(p)
            p = p if p.is_absolute() else root / p
            files += sorted(p.rglob("*.py")) if p.is_dir() else [p]

    errs: list[LintError] = []
    for py in files:
        rel = py.resolve().relative_to(root).as_posix()
        src = py.read_text()
        try:
            tree = ast.parse(src, filename=str(py))
        except SyntaxError as e:
            errs.append(LintError(rel, e.lineno or 0, "syntax",
                                  f"unparseable: {e.msg}"))
            continue
        for check in checks:
            errs.extend(check(rel, tree, src))
    for n in names:
        if n in REPO_RULES:
            errs.extend(REPO_RULES[n](root))
    return sorted(errs, key=lambda e: (e.path, e.line, e.rule))


def main(argv: Optional[list[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="layering linter (repro.analysis.lint)")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src benchmarks tools)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: two levels above this package)")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE", help="run only these rules")
    args = ap.parse_args(argv)
    # lint.py sits at <root>/src/repro/analysis/lint.py
    root = Path(args.root) if args.root else \
        Path(__file__).resolve().parents[3]
    errs = run_lint(root, paths=args.paths or None, select=args.select)
    for e in errs:
        print(e)
    rules = ", ".join(args.select or [*RULES, *REPO_RULES])
    if errs:
        print(f"lint: {len(errs)} finding(s) [{rules}]")
        return 1
    print(f"lint ok [{rules}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
