"""Plan verification: prove a ShardingPlan's declared invariants against
the traced program before a step ever runs.

The plan *declares* (``GroupPlanEntry.invariants`` /
``ShardingPlan.invariants``) and this module *checks* -- two layers:

  * ``verify_plan_static(plan)`` -- checks that need no trace: schedule
    dtype resolution, ring-chunk / quant-block alignment agreement, and
    pricing-profile freshness.  Runs anywhere (no mesh, no devices).
  * ``verify_runtime(runtime)`` -- abstract-evals one train step under
    the runtime's plan (``repro.analysis.jaxpr.trace_train_step``; no
    compilation, no device buffers) and checks the traced collectives
    and buffers against every declared invariant: wire legs present,
    byte totals fit the plan's ``gather_wire_mb``/``reduce_wire_mb``
    predictions, wire dtypes legal for the resolved codec, ring chunks
    land on the declared snap, gathered-buffer peak within the scan
    structure's slot bound, no full-fp32 dequant intermediates on q8
    paths, and EF residual leaves genuinely computed by the backward.

Failures are structured ``Violation``s (group, invariant,
expected-vs-found, jaxpr location), collected into a
``VerificationReport``; callers decide whether to raise
(``report.raise_if_failed()``) or render (``report.summary()``).
DESIGN.md §Static analysis has the invariant catalog.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


# --------------------------------------------------------------------------- #
# report structure
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Violation:
    """One failed invariant: which group, which declared invariant, what
    the plan promised vs what the trace (or static check) found, and --
    when a jaxpr equation is implicated -- where."""

    group: str
    invariant: str
    expected: str
    found: str
    where: str = ""
    severity: str = "error"  # "error" | "warning"

    def __str__(self) -> str:
        loc = f" at {self.where}" if self.where else ""
        return (f"[{self.severity}] group={self.group} "
                f"invariant={self.invariant}: expected {self.expected}; "
                f"found {self.found}{loc}")


@dataclasses.dataclass(frozen=True)
class VerificationReport:
    """All violations plus the list of ``group:invariant`` labels that
    were actually checked (an invariant that never ran is not a pass)."""

    violations: tuple[Violation, ...]
    checked: tuple[str, ...]

    @property
    def errors(self) -> tuple[Violation, ...]:
        return tuple(v for v in self.violations if v.severity == "error")

    @property
    def warnings(self) -> tuple[Violation, ...]:
        return tuple(v for v in self.violations if v.severity == "warning")

    @property
    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> str:
        head = (f"plan verification: {len(self.checked)} invariants "
                f"checked, {len(self.errors)} violations, "
                f"{len(self.warnings)} warnings")
        lines = [head] + [f"  {v}" for v in self.violations]
        return "\n".join(lines)

    def raise_if_failed(self) -> "VerificationReport":
        if not self.ok:
            raise VerificationError(self)
        return self

    def merged(self, other: "VerificationReport") -> "VerificationReport":
        return VerificationReport(self.violations + other.violations,
                                  self.checked + other.checked)


class VerificationError(RuntimeError):
    """Raised by ``raise_if_failed``; carries the full report."""

    def __init__(self, report: VerificationReport):
        self.report = report
        super().__init__(report.summary())


class _Collector:
    def __init__(self):
        self.violations: list[Violation] = []
        self.checked: list[str] = []

    def check(self, group: str, invariant: str) -> None:
        self.checked.append(f"{group}:{invariant}")

    def fail(self, group: str, invariant: str, expected: str, found: str,
             where: str = "", severity: str = "error") -> None:
        self.violations.append(Violation(group, invariant, expected, found,
                                         where, severity))

    def report(self) -> VerificationReport:
        return VerificationReport(tuple(self.violations),
                                  tuple(self.checked))


# --------------------------------------------------------------------------- #
# static (trace-free) checks
# --------------------------------------------------------------------------- #
def verify_plan_static(plan, *, profile_path=None) -> VerificationReport:
    """Check everything provable from the plan alone: per-group schedule
    dtype resolution (``validate_for``), ring-chunk/quant-block snap
    agreement, and -- when ``profile_path`` is given or ``BENCH_comm.json``
    exists -- that an auto plan's recorded pricing-profile hash still
    matches the profile on disk (mismatch is a *warning*: the plan still
    runs, but its pricing provenance is stale)."""
    import jax.numpy as jnp

    col = _Collector()
    cd = jnp.dtype(plan.compute_dtype)
    for name, entry in plan.groups.items():
        col.check(name, "schedule_valid")
        try:
            entry.schedule().validate_for(cd)
        except ValueError as e:
            col.fail(name, "schedule_valid", "schedule resolves for "
                     f"compute={cd.name}", str(e))
    for inv in plan.invariants():
        if inv["name"] == "ring_chunk":
            col.check(inv["group"], "ring_chunk")
            if inv["snapped"] != inv["wire"]:
                col.fail(
                    inv["group"], "ring_chunk",
                    f"declared ring_chunk_elems={inv['declared']} snapping "
                    f"to a {inv['unit']}-aligned chunk of {inv['snapped']}",
                    f"wire path snaps to {inv['wire']} "
                    f"({inv['wire'] % inv['unit']} elems past a quant-block "
                    f"boundary: blocks would straddle ring messages)")
        elif inv["name"] == "profile_fresh":
            _check_profile_fresh(col, inv, profile_path)
    return col.report()


def _check_profile_fresh(col: _Collector, inv: dict, profile_path) -> None:
    import os

    from ..core.profile import load_profile

    path = profile_path or "BENCH_comm.json"
    if not os.path.exists(path):
        return  # nothing on disk to compare against
    col.check("*", "profile_fresh")
    try:
        prof = load_profile(path)
    except Exception as e:  # malformed profile: report, don't crash
        col.fail("*", "profile_fresh", f"loadable profile at {path}",
                 f"{type(e).__name__}: {e}", severity="warning")
        return
    if prof.content_hash() != inv["hash"]:
        col.fail(
            "*", "profile_fresh",
            f"plan priced with profile {inv['profile']}@{inv['hash']}",
            f"profile on disk ({path}) now hashes "
            f"{prof.content_hash()} -- pricing is stale, re-plan to "
            f"re-price", severity="warning")


# --------------------------------------------------------------------------- #
# trace-backed checks
# --------------------------------------------------------------------------- #
def _axes_of(entry) -> frozenset:
    return frozenset(entry.fsdp_axes)


def _event_matches_group(ev, entry, legs, rdtypes) -> bool:
    """Attribute a collective event to a plan group by signature:
    the event runs over the group's FSDP axes (ppermute rings carry the
    manual ring axis name, so for them only sizes can be compared) and
    its payload is one of the group's wire legs (full leg for one-shot
    collectives, a divisor chunk for ring hops)."""
    shard = entry.plan.shard_size
    total = entry.plan.total
    if ev.kind in ("all_gather",):
        return (_axes_of(entry) == frozenset(ev.axes)
                and any(ev.elems == e for _, e in legs))
    if ev.kind in ("psum_scatter", "reduce_scatter"):
        return (_axes_of(entry) == frozenset(ev.axes)
                and ev.dtype in rdtypes and ev.elems == total)
    if ev.kind == "ppermute":
        # manual rings run over a collapsed axis name; match by world size
        # and divisor-of-leg chunking instead
        if ev.axis_size != entry.fsdp_world:
            return False
        for d, e in legs:
            if ev.dtype == d and e % max(ev.elems, 1) == 0:
                return True
        for d in rdtypes:
            # ring_acc / q8 routes chunk the shard (divisors); the
            # order-exact route concatenates un-reduced chunks, so hop i
            # carries i x chunk (multiples of the shard chunk)
            if ev.dtype == d and (shard % max(ev.elems, 1) == 0
                                  or ev.elems % max(shard, 1) == 0):
                return True
        return False
    return False


def _byte_fit(observed: float, unit_g: float, unit_r: float,
              a_max: int, b_max: int) -> tuple[int, int, float]:
    """Best integer (a, b) with observed ~= a*unit_g + b*unit_r; returns
    (a, b, relative error).  a/b are per-layer copy counts (forward,
    remat re-gathers, prefetch overlap legs), so small integers."""
    best = (0, 0, 1.0 if observed else 0.0)
    for a in range(a_max + 1):
        rem = observed - a * unit_g
        if unit_r > 0:
            b = max(0, min(b_max, int(round(rem / unit_r))))
        else:
            b = 0
        got = a * unit_g + b * unit_r
        err = abs(observed - got) / max(observed, 1.0)
        if err < best[2]:
            best = (a, b, err)
    return best


def verify_trace(plan, comm, buffers, out_shapes=None, *,
                 rtol: float = 0.05) -> VerificationReport:
    """Check a plan's declared invariants against an extracted
    ``CommTrace`` + ``BufferTrace`` (and, for EF threading, the traced
    step's output shape tree).  Pure function of the traces -- callers
    that already hold a jaxpr (tests) use this directly;
    ``verify_runtime`` wraps tracing + this + the static pass."""
    import jax.numpy as jnp

    col = _Collector()
    cd = jnp.dtype(plan.compute_dtype)
    invs = plan.invariants()
    by_group: dict[str, list[dict]] = {}
    for inv in invs:
        by_group.setdefault(inv["group"], []).append(inv)

    for name, entry in plan.groups.items():
        declared = {i["name"]: i for i in by_group.get(name, ())}
        if "comm_bytes" in declared:
            _check_comm(col, entry, declared["comm_bytes"],
                        declared.get("ring_chunk"), comm, rtol)
        if "wire_dtype" in declared:
            _check_wire_dtype(col, entry, declared["wire_dtype"],
                              declared["comm_bytes"], comm)
        if "no_f32_dequant" in declared:
            _check_no_f32_dequant(col, entry, declared["no_f32_dequant"],
                                  buffers)
        if "ef_threading" in declared:
            _check_ef_threading(col, entry, out_shapes)

    for inv in by_group.get("*", ()):
        if inv["name"] == "gathered_peak":
            _check_gathered_peak(col, inv, cd, buffers)
    return col.report()


def _check_comm(col, entry, inv, ring_inv, comm, rtol) -> None:
    """comm_missing + comm_bytes: every declared wire leg must appear in
    the trace with the right collective kind, and the total traced wire
    bytes attributable to the group must fit an integer number of
    plan-predicted copies.  Traced per-device bytes carry the (m-1)/m
    ring/bandwidth discount the plan accounting deliberately leaves out,
    so the per-copy unit is scaled here."""
    name = entry.name
    m = entry.fsdp_world
    legs = tuple((d, int(e)) for d, e in inv["gather_legs"])
    rdtypes = tuple(inv["reduce_dtypes"])
    mine = [e for e in comm.events
            if _event_matches_group(e, entry, legs, rdtypes)]

    col.check(name, "comm_missing")
    ring_gather = ring_inv is not None and entry.schedule().gather_mode == "ring"
    gather_kinds = ("ppermute",) if ring_gather else ("all_gather",)
    for d, e in legs:
        hit = [ev for ev in mine if ev.kind in gather_kinds
               and ev.dtype == d
               and (e % max(ev.elems, 1) == 0 if ring_gather
                    else ev.elems == e)]
        if not hit:
            near = sorted({(ev.kind, ev.dtype, ev.elems) for ev in mine})
            col.fail(name, "comm_missing",
                     f"gather leg {d}[{e}] via {gather_kinds[0]} "
                     f"(codec {entry.policy.store})",
                     f"no matching collective; group-attributed events: "
                     f"{near or 'none'}")
    reduce_kinds = (("ppermute",) if inv["reduce_route"] == "ring"
                    else ("psum_scatter", "reduce_scatter"))
    rhit = [ev for ev in mine if ev.kind in reduce_kinds
            and ev.dtype in rdtypes]
    if not rhit:
        near = sorted({(ev.kind, ev.dtype, ev.elems) for ev in mine})
        col.fail(name, "comm_missing",
                 f"reduce route {inv['reduce_route']} in {rdtypes}",
                 f"no matching collective; group-attributed events: "
                 f"{near or 'none'}")

    col.check(name, "comm_bytes")
    observed = sum(e.wire_bytes * e.trips for e in mine)
    n = entry.n_layers or 1
    disc = (m - 1) / m if m > 1 else 0.0
    unit_g = inv["gather_mb_per_copy"] * 1e6 * disc
    unit_r = inv["reduce_mb_per_copy"] * 1e6 * disc
    # a = per-layer gather copies x layers (fwd + remat re-gathers +
    # prefetch overlap); b = reduce copies x layers
    a, b, err = _byte_fit(observed, unit_g, unit_r,
                          a_max=4 * n + 8, b_max=2 * n + 4)
    if err > rtol:
        col.fail(name, "comm_bytes",
                 f"traced wire bytes = a*{unit_g / 1e6:.4f}MB + "
                 f"b*{unit_r / 1e6:.4f}MB (integer copies of the plan's "
                 f"per-copy predictions)",
                 f"{observed / 1e6:.4f}MB; best fit a={a} b={b} off by "
                 f"{100 * err:.1f}% (> {100 * rtol:.0f}% tolerance)")

    if ring_inv is not None:
        _check_ring_chunk_trace(col, entry, ring_inv, mine, legs)


def _check_ring_chunk_trace(col, entry, inv, mine, legs) -> None:
    """Traced ring hops must land on the declared snap: int8 code chunks
    stay quant-block aligned, and (for ring gathers) the primary code/wire
    leg actually moves in chunks of the declared snapped size."""
    name, unit = entry.name, inv["unit"]
    col.check(name, "ring_chunk")
    code_dtype, code_elems = legs[0]
    hops = [e for e in mine if e.kind == "ppermute" and e.dtype == code_dtype
            and code_elems % max(e.elems, 1) == 0]
    misaligned = sorted({e.elems for e in hops if e.elems % unit})
    if misaligned:
        col.fail(name, "ring_chunk",
                 f"every {code_dtype} ring hop a multiple of the quant "
                 f"block ({unit})",
                 f"hop chunks {misaligned} straddle block boundaries",
                 where=next(e.path for e in hops if e.elems % unit))
    if (entry.schedule().gather_mode == "ring" and hops
            and not any(e.elems == inv["snapped"] for e in hops)):
        col.fail(name, "ring_chunk",
                 f"gather ring hops of the snapped chunk size "
                 f"{inv['snapped']} (declared {inv['declared']})",
                 f"observed hop sizes {sorted({e.elems for e in hops})}")


def _check_wire_dtype(col, entry, inv, comm_inv, comm) -> None:
    """Any collective whose payload is shaped like this group's shard /
    gathered buffer and runs over its axes must ship a dtype the resolved
    codec allows -- the check that catches a plan promising q8 while the
    trace ships bf16 (or the reverse)."""
    name = entry.name
    col.check(name, "wire_dtype")
    legal = set(inv["legal"])
    shard, total = entry.plan.shard_size, entry.plan.total
    for ev in comm.events:
        if ev.kind == "ppermute":
            if ev.axis_size != entry.fsdp_world:
                continue
            sized = (shard % max(ev.elems, 1) == 0
                     or ev.elems % max(shard, 1) == 0)
        else:
            if frozenset(ev.axes) != _axes_of(entry):
                continue
            sized = ev.elems in (shard, total)
        # scales legs ride beside code legs at shard/block granularity
        sized = sized or (entry.store.quantized
                          and (shard // entry.quant_block)
                          % max(ev.elems, 1) == 0)
        if sized and ev.dtype not in legal:
            col.fail(name, "wire_dtype",
                     f"wire dtypes within {sorted(legal)} (resolved codec "
                     f"{entry.policy.store}/"
                     f"{entry.policy.reduce_wire or 'cast'})",
                     f"{ev.kind} ships {ev.dtype}[{ev.elems}]",
                     where=ev.path)


def _check_no_f32_dequant(col, entry, inv, buffers) -> None:
    """Quantized gather paths must decode straight into the compute
    dtype: no full-gathered-size code->float32 convert outside pallas
    bodies (the fused-kernel regression, generalized).  The invariant's
    ``src_dtype`` names the code dtype -- "int8" for q8_block, the
    float8 dtype for fp8 stores (whose decode is ONE cast to the compute
    dtype; a full f32 dequant would betray an unfused two-step decode).
    The EF residual and optimizer masters are legitimately fp32 at
    related sizes, so the check keys on the *conversion*, not on any
    fp32 aval existing.  The one legitimate non-pallas int8->f32 decode
    is the LOG-space moment decode of the 8-bit Adam family (a reference
    passthrough by design, ops.quantize_log docstring) -- recognizable
    because its value flows into an ``exp`` within a few steps;
    linear-space decodes run as pallas kernels and never appear here."""
    from .jaxpr import _as_jaxpr, _sub_jaxprs

    name = entry.name
    col.check(name, "no_f32_dequant")
    gathered = inv["gathered_elems"]
    src_dtype = inv.get("src_dtype", "int8")
    if buffers._jaxpr is None:
        return

    def scan_scope(jx, path):
        consumers: dict[int, list] = {}
        for eqn in jx.eqns:
            for v in eqn.invars:
                consumers.setdefault(id(v), []).append(eqn)

        def feeds_exp(var, depth=4) -> bool:
            if depth <= 0:
                return False
            for c in consumers.get(id(var), ()):
                if c.primitive.name == "exp":
                    return True
                if any(feeds_exp(o, depth - 1) for o in c.outvars):
                    return True
            return False

        for i, eqn in enumerate(jx.eqns):
            pname = eqn.primitive.name
            here = f"{path}/{pname}[{i}]"
            if pname == "convert_element_type":
                src = getattr(eqn.invars[0], "aval", None)
                dst = getattr(eqn.outvars[0], "aval", None)
                if (src is not None and dst is not None
                        and hasattr(src, "shape")
                        and str(src.dtype) == src_dtype
                        and str(dst.dtype) == "float32"):
                    n = int(np.prod(dst.shape)) if dst.shape else 1
                    if n >= gathered and not feeds_exp(eqn.outvars[0]):
                        col.fail(
                            name, "no_f32_dequant",
                            "quantized decode fused into the compute dtype "
                            f"(no full-size {src_dtype}->float32 "
                            "materialization)",
                            f"convert_element_type {src_dtype}->float32 "
                            f"over {n} elems (gathered size {gathered})",
                            where=here)
            if "pallas" in pname:
                continue
            for sub in _sub_jaxprs(eqn):
                scan_scope(sub, here)

    scan_scope(_as_jaxpr(buffers._jaxpr), "")


def _check_ef_threading(col, entry, out_shapes) -> None:
    """The EF residual must come back from the step as a genuinely
    computed fp32 leaf -- present in the new-params tree under the
    group's ``reduce_ef`` key, fp32, sized m shard-lengths.  (The jaxpr
    side -- that the leaf is an equation output, not a passthrough of the
    input -- is implied: ``trace_train_step`` feeds params as
    ShapeDtypeStructs, so an un-updated residual could only appear via
    identity, which the size/dtype check plus the reduce-leg
    comm_missing check above pins.)"""
    from ..core.store import EF_KEY

    name = entry.name
    col.check(name, "ef_threading")
    if out_shapes is None:
        return
    new_params = out_shapes[0]
    g = new_params.get(name) if isinstance(new_params, dict) else None
    leaf = g.get(EF_KEY) if isinstance(g, dict) else None
    # the step's output tree is GLOBAL (outside shard_map): the residual's
    # last dim is ef_m x the buffer's, i.e. m x gathered-total per layer
    # (each device's slice is one full gathered buffer)
    expect = entry.fsdp_world * entry.plan.total
    if leaf is None:
        col.fail(name, "ef_threading",
                 f"'{EF_KEY}' residual leaf in the step's new-params tree",
                 f"group output keys: "
                 f"{sorted(g) if isinstance(g, dict) else type(g).__name__}")
        return
    n = int(np.prod(leaf.shape)) if leaf.shape else 1
    # layered groups carry one residual per layer: elems per layer
    per_layer = n // (entry.n_layers or 1) if entry.n_layers else n
    if str(leaf.dtype) != "float32" or per_layer != expect:
        col.fail(name, "ef_threading",
                 f"fp32 residual of m x gathered-total = {expect} "
                 f"elems/layer",
                 f"{leaf.dtype}[{'x'.join(map(str, leaf.shape))}]")


def _check_gathered_peak(col, inv, cd, buffers) -> None:
    """Two teeth: (1) no scan carry holds a full gathered layer buffer in
    the compute dtype (a carry means backward retains one buffer per
    layer -- the prefetch-retention regression); (2) the per-scope
    liveness peak of gathered-size compute-dtype buffers stays within the
    scan structure's slot bound.  Backward scopes hold a cotangent twin
    per live gathered buffer, so the liveness bound is 2x the forward
    slot count."""
    slots = inv["max_slots"]
    for gname, meta in inv["groups"].items():
        elems = meta["elems"]
        col.check(gname, "gathered_peak")
        carried = [s for s, d in buffers.scan_carries
                   if d == str(cd) and int(np.prod(s)) == elems]
        if carried:
            col.fail(gname, "gathered_peak",
                     f"no {cd.name}[{elems}] gathered buffer in any scan "
                     f"carry (reshard-after-forward frees layers)",
                     f"scan carries hold {carried}")
        peak = buffers.live_peak(elems=elems, dtype=cd)
        # 2x: every live gathered buffer has a cotangent twin in backward
        # scopes; +1: a reshape/unpack view of the buffer is a distinct
        # jaxpr value of the same size class even though XLA aliases it
        bound = 2 * slots + 1
        if peak > bound:
            col.fail(gname, "gathered_peak",
                     f"<= {bound} simultaneously-live {cd.name}[{elems}] "
                     f"buffers (2x {slots} slots for backward cotangents, "
                     f"+1 aliasing view)",
                     f"liveness peak {peak}")


# --------------------------------------------------------------------------- #
# entry point
# --------------------------------------------------------------------------- #
def verify_runtime(runtime, optimizer=None, *, batch=None, plan=None,
                   profile_path=None,
                   rtol: float = 0.05) -> VerificationReport:
    """Trace one train step of ``runtime`` (pure abstract eval) and check
    every invariant its plan declares -- static checks included.  ``plan``
    defaults to the runtime's own resolved plan; passing a different plan
    verifies THAT plan's promises against THIS runtime's program (how the
    broken-plan CLI demo works)."""
    from .jaxpr import extract_buffers, extract_comm, trace_train_step

    plan = plan if plan is not None else runtime.plan
    report = verify_plan_static(plan, profile_path=profile_path)
    closed, out_shapes = trace_train_step(runtime, optimizer, batch=batch)
    axis_sizes = {str(a): int(s) for a, s in
                  zip(runtime.mesh.axis_names,
                      runtime.mesh.devices.shape)}
    comm = extract_comm(closed, axis_sizes)
    buffers = extract_buffers(closed)
    return report.merged(verify_trace(plan, comm, buffers, out_shapes,
                                      rtol=rtol))
