"""Static analysis for the FSDP repro: jaxpr plan verification
(``repro.analysis.verify``) and source layering lint
(``repro.analysis.lint``).

The doctrine (DESIGN.md §Static analysis): every plan guarantee the repo
claims -- comm volume, gathered-buffer peak, wire dtypes, quant-block
alignment, EF threading -- is DECLARED on the plan
(``ShardingPlan.invariants``) and PROVED here against the abstract-eval
trace, before anything compiles or runs.  Tests call into this package
instead of re-implementing jaxpr walkers.
"""
from .jaxpr import (BufferTrace, CollectiveEvent, CommTrace, count_full_f32,
                    extract_buffers, extract_comm, has_full_f32,
                    intermediate_avals, iter_eqns, scan_carry_avals,
                    trace_train_step)
from .verify import (VerificationError, VerificationReport, Violation,
                     verify_plan_static, verify_runtime, verify_trace)

__all__ = [
    "BufferTrace", "CollectiveEvent", "CommTrace", "count_full_f32",
    "extract_buffers", "extract_comm", "has_full_f32",
    "intermediate_avals", "iter_eqns", "scan_carry_avals",
    "trace_train_step", "VerificationError", "VerificationReport",
    "Violation", "verify_plan_static", "verify_runtime", "verify_trace",
]
