"""ShardingPolicy / ShardingPlan API: selector matching, legacy-knob
lowering parity (plan-JSON equality between the flat ParallelConfig
spelling and the explicit PolicySet spelling, on 1- and 8-shard meshes),
JSON round-trips, runtime-from-plan bitwise parity, and the "auto"
cost-model planner (dense + MoE, dryrun-level and train-step smoke)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import build_model, get_config
from repro.configs.base import ParallelConfig
from repro.core.fsdp import FSDPRuntime
from repro.core.policy import (CostModel, GroupInfo, PolicyRule, PolicySet,
                               ShardingPlan, ShardingPolicy, group_tag, plan)
from repro.core.schedule import CommSchedule
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

MESH = make_local_mesh(1, 1)


def _model(arch="qwen2.5-14b", **par_over):
    cfg = get_config(arch).reduced()
    if par_over:
        cfg = dataclasses.replace(
            cfg, parallel=dataclasses.replace(cfg.parallel, **par_over))
    return build_model(cfg)


def _train(rt, cfg, steps=2):
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)
    rng = np.random.default_rng(0)
    out = []
    for _ in range(steps):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        params, state, st, m = fn(params, state, st, batch)
        out.append((float(m["loss"]), float(m["grad_norm"])))
    return out, {k: np.asarray(jax.tree.leaves(v)[0])
                 for k, v in params.items()}


# --------------------------------------------------------------------------- #
# selectors
# --------------------------------------------------------------------------- #

def test_rule_matching_glob_tag_predicate():
    model = _model("granite-moe-1b-a400m")  # layers, layers_experts, globals
    pset = PolicySet(rules=(
        PolicyRule(tag="experts", policy=ShardingPolicy(store="q8_block")),
        PolicyRule(match="glob*", policy=ShardingPolicy(sharded=False)),
        PolicyRule(where=lambda i: i.n_layers is not None,
                   policy=ShardingPolicy(store="bf16")),
    ))
    p = plan(model, {"data": 8}, pset)
    assert p.groups["layers_experts"].policy.store == "q8_block"
    assert p.groups["globals"].policy.sharded is False
    assert p.groups["layers"].policy.store == "bf16"
    # the tags themselves
    assert p.groups["layers_experts"].tag == "experts"
    assert p.groups["layers"].tag == "layers"
    assert p.groups["globals"].tag == "globals"


def test_first_match_wins():
    model = _model()
    pset = PolicySet(rules=(
        PolicyRule(match="layers", policy=ShardingPolicy(store="bf16")),
        PolicyRule(tag="layers", policy=ShardingPolicy(store="q8_block")),
    ))
    p = plan(model, {"data": 1}, pset)
    assert p.groups["layers"].policy.store == "bf16"


def test_selector_validation():
    with pytest.raises(ValueError):
        PolicyRule(policy=ShardingPolicy())  # no selector
    with pytest.raises(ValueError):
        PolicyRule(tag="expert", policy=ShardingPolicy())  # not a TAG
    # scan-structure knobs come from the default, never a rule
    with pytest.raises(ValueError):
        PolicySet(rules=(
            PolicyRule(match="layers", policy=ShardingPolicy(prefetch=True)),
        ))
    # policy knobs are validated by CommSchedule at construction
    with pytest.raises(ValueError):
        ShardingPolicy(store="q4_block")
    with pytest.raises(ValueError):
        ShardingPolicy(gather_mode="nccl")


def test_typoed_rule_raises_instead_of_silently_ignoring():
    model = _model()
    pset = PolicySet(rules=(
        PolicyRule(match="layrs", policy=ShardingPolicy(store="bf16")),))
    with pytest.raises(ValueError, match="matched no communication group"):
        plan(model, {"data": 8}, pset)
    # same protection on the legacy spelling (exact-name rules)
    with pytest.raises(ValueError):
        FSDPRuntime(_model(), MESH,
                    group_schedules={"layrs": {"gather_mode": "ring"}})


def test_legacy_group_schedules_keys_are_exact_names_not_globs():
    """Legacy group_schedules keys were always exact group names; a key
    with glob metacharacters must keep raising (unknown name), never
    silently become a pattern that matches several groups."""
    model = _model("granite-moe-1b-a400m")
    with pytest.raises(ValueError, match="matched no communication group"):
        FSDPRuntime(model, MESH,
                    group_schedules={"layers*": {"sharded": False}})


# --------------------------------------------------------------------------- #
# legacy lowering: plan-JSON equality with the explicit PolicySet spelling
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("axes", [{"data": 1}, {"data": 8}])
def test_legacy_lowering_plan_json_equality(axes):
    par = ParallelConfig(
        ("data",), ("data",), prefetch=True, reduce_dtype="fp32",
        group_schedules={"globals": {"sharded": False},
                         "layers": {"param_store": "q8_block",
                                    "gather_mode": "ring"}})
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              parallel=par)
    model = build_model(cfg)
    legacy = plan(model, axes, None)  # lowers cfg.parallel

    default = ShardingPolicy(prefetch=True, reduce_dtype="fp32")
    explicit = PolicySet(
        rules=(
            PolicyRule(match="globals",
                       policy=dataclasses.replace(default, sharded=False)),
            PolicyRule(match="layers",
                       policy=dataclasses.replace(default, store="q8_block",
                                                  gather_mode="ring")),
        ),
        default=default)
    spelled = plan(model, axes, explicit)
    assert legacy.dumps() == spelled.dumps(), legacy.diff(spelled)


# --------------------------------------------------------------------------- #
# the plan artifact: describe / JSON round-trip / diff
# --------------------------------------------------------------------------- #

def test_plan_json_round_trip_and_describe():
    model = _model()
    p = plan(model, {"data": 8},
             PolicySet(default=ShardingPolicy(store="q8_block")))
    p2 = ShardingPlan.from_json(json.loads(json.dumps(p.to_json())))
    assert p2.dumps() == p.dumps()
    assert p.diff(p2) == []
    txt = p.describe()
    assert "layers" in txt and "globals" in txt and "q8_block" in txt
    assert str(p.groups["layers"].plan.shard_size) in txt


def test_plan_diff_names_the_field():
    model = _model()
    a = plan(model, {"data": 8}, ShardingPolicy())
    b = plan(model, {"data": 8}, ShardingPolicy(store="bf16"))
    d = a.diff(b)
    assert d and any("store" in line for line in d)


# --------------------------------------------------------------------------- #
# runtime consumes a plan (bitwise vs legacy spelling, incl. via JSON)
# --------------------------------------------------------------------------- #

def _assert_bitwise(ref, tst):
    ref_m, ref_p = ref
    tst_m, tst_p = tst
    assert ref_m == tst_m
    for k in ref_p:
        np.testing.assert_array_equal(ref_p[k], tst_p[k])


def test_runtime_from_plan_bitwise_matches_legacy():
    cfg = get_config("qwen2.5-14b").reduced()
    sched = CommSchedule(prefetch=True, reduce_dtype="fp32")
    ref = _train(FSDPRuntime(build_model(cfg), MESH, schedule=sched,
                             donate=False), cfg)

    model = build_model(cfg)
    p = plan(model, MESH, PolicySet(
        default=ShardingPolicy(prefetch=True, reduce_dtype="fp32")))
    tst = _train(FSDPRuntime(model, MESH, plan=p, donate=False), cfg)
    _assert_bitwise(ref, tst)

    # a plan restored from JSON reconstructs the exact layout
    restored = ShardingPlan.from_json(p.to_json())
    tst2 = _train(FSDPRuntime(build_model(cfg), MESH, plan=restored,
                              donate=False), cfg)
    _assert_bitwise(ref, tst2)


def test_runtime_plan_mismatches_raise():
    model = _model()
    p = plan(model, {"data": 8}, ShardingPolicy())
    with pytest.raises(ValueError, match="mesh"):
        FSDPRuntime(model, MESH, plan=p)  # 8-shard plan on a 1-device mesh
    p1 = plan(model, MESH, ShardingPolicy())
    with pytest.raises(ValueError, match="either plan="):
        FSDPRuntime(model, MESH, plan=p1, schedule=CommSchedule())
    with pytest.raises(ValueError, match="compute dtype"):
        FSDPRuntime(model, MESH, plan=p1, compute_dtype=jnp.float32)


# --------------------------------------------------------------------------- #
# the auto planner
# --------------------------------------------------------------------------- #

def test_auto_picks_q8_for_bandwidth_bound_stacks_and_fp32_on_one_device():
    for arch in ("qwen2.5-14b", "granite-moe-1b-a400m"):
        model = _model(arch)
        p8 = plan(model, {"data": 8}, "auto")
        for name, e in p8.groups.items():
            if e.n_layers:  # stacked groups: quantized wire pays at m > 1
                assert e.policy.store == "q8_block", (arch, name)
        # tiny unstacked globals at reduced scale: replicated
        assert p8.groups["globals"].policy.sharded is False
        p1 = plan(model, {"data": 1}, "auto")
        for name, e in p1.groups.items():  # no wire -> stay exact fp32
            assert e.policy.store == "fp32", (arch, name)
            assert e.policy.sharded is True, (arch, name)


def test_auto_respects_replicate_threshold():
    model = _model()
    cm = CostModel.default()
    none = dataclasses.replace(cm, replicate_bytes=0)
    p = plan(model, {"data": 8}, "auto", cost_model=none)
    assert p.groups["globals"].policy.sharded is True


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "granite-moe-1b-a400m"])
def test_auto_train_step_smoke(arch):
    """policies="auto" end-to-end: plan -> runtime -> 2 train steps."""
    cfg = get_config(arch).reduced()
    rt = FSDPRuntime(build_model(cfg), MESH, policies="auto", donate=False)
    metrics, _ = _train(rt, cfg)
    assert all(np.isfinite(l) and np.isfinite(g) for l, g in metrics)


# --------------------------------------------------------------------------- #
# checkpoint integration
# --------------------------------------------------------------------------- #

def test_checkpoint_saves_plan_json(tmp_path):
    cfg = get_config("qwen2.5-14b").reduced()
    rt = FSDPRuntime(build_model(cfg), MESH, donate=False)
    params = rt.init_params(0)
    ckpt.save(tmp_path / "ck", rt, params)
    saved = ckpt.load_plan(tmp_path / "ck")
    assert saved is not None
    assert saved.dumps() == rt.plan.dumps()
    assert ckpt.load_plan(tmp_path) is None  # pre-plan checkpoints


def test_group_info_and_tags():
    model = _model("granite-moe-1b-a400m")
    groups = model.groups()
    tags = {n: group_tag(n, g) for n, g in groups.items()}
    assert tags["layers"] == "layers"
    assert tags["layers_experts"] == "experts"
    assert tags["globals"] == "globals"
    info = GroupInfo("layers", "layers", 2, groups["layers"].specs)
    assert info.payload == 2 * sum(s.size for s in groups["layers"].specs)
