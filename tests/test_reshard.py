"""Elastic resharding: the per-tensor shard index (core.reshard), the
offline tool (tools/reshard.py), and the in-job elastic path
(FSDPRuntime.replan).

Parity classes pinned here (DESIGN.md §Resharding): same-plan moves are
bitwise per leaf; cross-plan (mesh size / planner mode / TP degree) moves
are bitwise on the fp32 master; cross-format rebuilds are master-exact
with codes requantized from the master and EF residuals re-zeroed.
"""
import dataclasses
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.planner import (plan_fsdp2, plan_group, plan_megatron,
                                plan_naive)
from repro.core.ragged import Extent, TensorSpec
from repro.core.reshard import GroupIndex, buffer_reader, copy_tensor
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

MESH = make_local_mesh(1, 1)
REPO = pathlib.Path(__file__).resolve().parent.parent


def _run_driver(driver: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src"), str(REPO)])
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(driver)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out


# --------------------------------------------------------------------------- #
# the extent map itself (pure placement arithmetic)
# --------------------------------------------------------------------------- #

SPECS = [
    TensorSpec("a", (7, 96), granularity=96),
    TensorSpec("b", (384,), granularity=1),
    TensorSpec("c", (13, 64), granularity=64),
    TensorSpec("d", (5,), granularity=1),
]


@pytest.mark.parametrize("planner,kwargs", [
    (plan_group, dict(g_coll=128, align=32)),
    (plan_naive, {}),
    (plan_megatron, {}),
    (plan_fsdp2, {}),
])
def test_extent_map_matches_packing(planner, kwargs):
    """For every plan mode, a tensor's extents address exactly the bytes
    DBuffer.pack put there -- the contract every reshard path rests on."""
    from repro.core.dbuffer import DBuffer

    for m in (1, 2, 4):
        plan = planner(SPECS, m, **kwargs) if kwargs else planner(SPECS, m)
        buf = DBuffer(plan)
        arrays = {s.name: np.arange(s.size, dtype=np.float32).reshape(s.shape)
                  * (i + 1)
                  for i, s in enumerate(SPECS)}
        flat = buf.pack(arrays)
        shards = flat.reshape(m, plan.shard_size)
        for s in SPECS:
            exts = plan.tensor_extents(s.name)
            covered = 0
            got = np.empty(s.size, np.float32)
            for e in exts:
                assert 0 <= e.lo < e.hi <= plan.shard_size
                got[e.tensor_lo: e.tensor_lo + e.size] = \
                    shards[e.shard][e.lo: e.hi]
                covered += e.size
            assert covered == s.size, f"{s.name}: extents must tile exactly"
            np.testing.assert_array_equal(got,
                                          arrays[s.name].reshape(-1))


def test_extent_scaling():
    e = Extent(shard=2, lo=64, hi=160, tensor_lo=128)
    s = e.scaled(32)
    assert (s.shard, s.lo, s.hi, s.tensor_lo) == (2, 2, 5, 4)
    with pytest.raises(ValueError, match="not aligned"):
        Extent(0, 10, 20, 0).scaled(32)


def test_copy_tensor_blocks_cross_outer_blockstate():
    """Block-granular (div>1) and aligned leaves refuse an outer-layout
    change instead of silently reinterpreting quant blocks."""
    spec = TensorSpec("w", (8, 64), granularity=64)
    p1 = plan_group([spec], 2, g_coll=128, align=64)
    a_idx = GroupIndex(plan=p1, outer_size=1)
    b_idx = GroupIndex(plan=p1, outer_size=2, outer_dims={"w": 0})
    src = np.arange(a_idx.num_rows * p1.shard_size, dtype=np.float32)
    dst = np.zeros(b_idx.num_rows * p1.shard_size, np.float32)
    with pytest.raises(ValueError, match="outer"):
        copy_tensor(a_idx, b_idx, "w", buffer_reader(src, a_idx.num_rows),
                    buffer_reader(dst, b_idx.num_rows), div=64)


# --------------------------------------------------------------------------- #
# offline tool: 1-device cross-planner / cross-format
# --------------------------------------------------------------------------- #

def test_tool_reshard_cross_planner_and_format(tmp_path):
    """q8 ragged checkpoint -> naive fp32 plan via tools/reshard.py:
    masters stream bitwise, optimizer moments follow, step survives."""
    from repro.core.policy import make_plan
    from repro.core.schedule import CommSchedule

    sys.path.insert(0, str(REPO))
    from tools.reshard import reshard

    cfg = get_config("gemma2-2b").reduced()
    rt = FSDPRuntime(build_model(cfg), MESH,
                     schedule=CommSchedule(param_store="q8_block"))
    opt = make_optimizer(cfg)
    params = rt.init_params(0)
    state = opt.init(rt)
    ckpt.save(tmp_path / "a", rt, params, state, step=5)

    plan_b = make_plan(build_model(cfg), {"data": 1, "model": 1}, None,
                       planner="naive")
    summary = reshard(tmp_path / "a", tmp_path / "b", plan_b, verbose=False)
    assert summary["streamed"], "cross-planner must take the stream path"

    rt_b = FSDPRuntime(build_model(cfg), MESH, planner="naive")
    p2, step, s2 = ckpt.load(tmp_path / "b", rt_b, opt.init(rt_b))
    assert step == 5
    for name, lo_a in rt.layouts.items():
        lo_b = rt_b.layouts[name]
        a = np.asarray(params[name]["master"])
        b = np.asarray(p2[name])
        for li in (range(lo_a.n_layers) if lo_a.n_layers else [None]):
            ta = lo_a.buffer.unpack_np(a[li] if li is not None else a)
            tb = lo_b.buffer.unpack_np(b[li] if li is not None else b)
            for k in ta:
                np.testing.assert_array_equal(ta[k], tb[k])


def test_tool_reshard_identity_is_bitwise_copy(tmp_path):
    """Same plan in == bytewise file copies, no streaming."""
    from repro.core.policy import make_plan

    sys.path.insert(0, str(REPO))
    from tools.reshard import reshard

    cfg = get_config("gemma2-2b").reduced()
    rt = FSDPRuntime(build_model(cfg), MESH)
    params = rt.init_params(1)
    ckpt.save(tmp_path / "a", rt, params, step=2)
    plan_same = make_plan(build_model(cfg), {"data": 1, "model": 1}, None)
    summary = reshard(tmp_path / "a", tmp_path / "b", plan_same,
                      verbose=False)
    assert not summary["streamed"]
    assert sorted(summary["copied"]) == sorted(rt.layouts)
    for f in sorted((tmp_path / "a" / "shards").glob("p__*.npy")):
        assert (tmp_path / "b" / "shards" / f.name).read_bytes() \
            == f.read_bytes()


# --------------------------------------------------------------------------- #
# 8-device subprocess suites (virtual CPU mesh)
# --------------------------------------------------------------------------- #

def test_tool_reshard_8_to_4_resume(tmp_path):
    """The ROADMAP #4 acceptance: train on an 8-way mesh, tool-reshard the
    checkpoint to 4-way, resume -- master weights bitwise, optimizer
    moments bitwise, training continues."""
    driver = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, numpy as np, jax.numpy as jnp
        from repro.configs import get_config, build_model
        from repro.configs.base import ParallelConfig
        from repro.core.fsdp import FSDPRuntime
        from repro.checkpoint import ckpt
        from repro.launch.mesh import make_local_mesh
        from repro.optim import make_optimizer
        from repro.data.pipeline import DataConfig, SyntheticStream
        from repro.compat import tree_flatten_with_path
        from repro.core.policy import make_plan
        from tools.reshard import reshard

        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(
            cfg, parallel=ParallelConfig(("data",), ("data",)))
        model = build_model(cfg)
        rt8 = FSDPRuntime(model, make_local_mesh(8, 1))
        opt = make_optimizer(cfg)
        params = rt8.init_params(0)
        state = opt.init(rt8)
        fn = rt8.make_train_step(opt)
        stream = SyntheticStream(DataConfig(cfg.vocab, 16, 8, seed=0), cfg)
        st = jnp.int32(0)
        for i in range(3):
            b = stream.shard(stream.batch(i), rt8)
            params, state, st, m = fn(params, state, st, b)
        ckpt.save({str(tmp_path / 'c8')!r}, rt8, params, state, step=3)

        plan4 = make_plan(build_model(cfg), {{"data": 4, "model": 1}}, None)
        reshard({str(tmp_path / 'c8')!r}, {str(tmp_path / 'c4')!r}, plan4,
                verbose=False)

        rt4 = FSDPRuntime(build_model(cfg), make_local_mesh(4, 1))
        p4, step, s4 = ckpt.load({str(tmp_path / 'c4')!r}, rt4,
                                 opt.init(rt4))
        assert step == 3
        def per_tensor(rt, arrs):
            out = {{}}
            for name, lo in rt.layouts.items():
                a = np.asarray(arrs[name])
                a = a if isinstance(arrs[name], np.ndarray) else a
                if isinstance(arrs[name], dict):
                    a = np.asarray(arrs[name]["master"])
                Ls = range(lo.n_layers) if lo.n_layers else [None]
                for li in Ls:
                    t = lo.buffer.unpack_np(a[li] if li is not None else a)
                    for k, v in t.items():
                        out[(k, li)] = v
            return out
        want = per_tensor(rt8, params)
        got = per_tensor(rt4, p4)
        assert set(want) == set(got)
        for k in want:
            np.testing.assert_array_equal(want[k], got[k])
        # optimizer moments bitwise per tensor
        fa, _ = tree_flatten_with_path(state)
        fb, _ = tree_flatten_with_path(s4)
        da = {{tuple(getattr(p, "key", str(p)) for p in kp): v
              for kp, v in fa}}
        for kp, vb in fb:
            keys = tuple(getattr(p, "key", str(p)) for p in kp)
            g = keys[-1]
            lo8, lo4 = rt8.layouts[g], rt4.layouts[g]
            a, b = np.asarray(da[keys]), np.asarray(vb)
            Ls = range(lo8.n_layers) if lo8.n_layers else [None]
            for li in Ls:
                ta = lo8.buffer.unpack_np(a[li] if li is not None else a)
                tb = lo4.buffer.unpack_np(b[li] if li is not None else b)
                for k in ta:
                    np.testing.assert_array_equal(ta[k], tb[k])
        # training continues on the 4-way mesh
        fn4 = rt4.make_train_step(opt)
        st4 = jnp.int32(3)
        b = stream.shard(stream.batch(3), rt4)
        p4, s4, st4, m4 = fn4(p4, s4, st4, b)
        assert np.isfinite(float(m4["loss"]))
        print("RESHARD_8TO4_OK")
    """
    out = _run_driver(driver)
    assert "RESHARD_8TO4_OK" in out.stdout


def test_tool_reshard_cross_tp(tmp_path):
    """TP 2 -> 1 and TP 1 -> 2 through the tool, judged against the
    deterministic TP-invariant init as an independent oracle (tensors
    migrate between the layers and layers_rep groups across the change)."""
    driver = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, numpy as np
        from repro.configs import get_config, build_model
        from repro.configs.base import ParallelConfig
        from repro.core.fsdp import FSDPRuntime
        from repro.checkpoint import ckpt
        from repro.launch.mesh import make_local_mesh
        from repro.optim import make_optimizer
        from repro.core.policy import make_plan
        from tools.reshard import reshard

        base = get_config("qwen2.5-14b").reduced()
        def cfg_tp(tp):
            return dataclasses.replace(
                base, parallel=ParallelConfig(("data",), ("data",), tp=tp))

        # --- TP 2 -> 1 -------------------------------------------------
        rt2 = FSDPRuntime(build_model(cfg_tp(2)), make_local_mesh(4, 2))
        assert "layers_rep" in rt2.layouts
        opt2 = make_optimizer(cfg_tp(2))
        ckpt.save({str(tmp_path / 'tp2')!r}, rt2, rt2.init_params(3),
                  opt2.init(rt2), step=9)
        plan1 = make_plan(build_model(cfg_tp(1)), {{"data": 8, "model": 1}},
                          None)
        reshard({str(tmp_path / 'tp2')!r}, {str(tmp_path / 'tp1')!r},
                plan1, verbose=False)
        rt1 = FSDPRuntime(build_model(cfg_tp(1)), make_local_mesh(8, 1))
        opt1 = make_optimizer(cfg_tp(1))
        p1, step, s1 = ckpt.load({str(tmp_path / 'tp1')!r}, rt1,
                                 opt1.init(rt1))
        assert step == 9
        want = rt1.init_params(3)
        for name in want:
            np.testing.assert_array_equal(np.asarray(want[name]),
                                          np.asarray(p1[name]))
        print("TP2_TO_TP1_OK")

        # --- TP 1 -> 2 (replicated tensors fan out into every part) ----
        ckpt.save({str(tmp_path / 'a1')!r}, rt1, want, step=4)
        plan2 = make_plan(build_model(cfg_tp(2)), {{"data": 4, "model": 2}},
                          None)
        reshard({str(tmp_path / 'a1')!r}, {str(tmp_path / 'a2')!r},
                plan2, verbose=False)
        p2, step = ckpt.load({str(tmp_path / 'a2')!r}, rt2)
        assert step == 4
        want2 = rt2.init_params(3)
        for name in want2:
            np.testing.assert_array_equal(np.asarray(want2[name]),
                                          np.asarray(p2[name]))
        print("TP1_TO_TP2_OK")
    """
    out = _run_driver(driver)
    assert "TP2_TO_TP1_OK" in out.stdout
    assert "TP1_TO_TP2_OK" in out.stdout


def test_replan_in_job(tmp_path):
    """FSDPRuntime.replan: 8 -> 4 way in-process (no save/load), master
    and moment bitwise, training resumes; then a same-mesh store-format
    replan (fp32 -> q8_block) whose codes equal a fresh quantization of
    the bitwise-preserved master."""
    driver = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, numpy as np, jax.numpy as jnp
        from repro.configs import get_config, build_model
        from repro.configs.base import ParallelConfig
        from repro.core.fsdp import FSDPRuntime
        from repro.core.schedule import CommSchedule
        from repro.launch.mesh import make_local_mesh
        from repro.optim import make_optimizer
        from repro.data.pipeline import DataConfig, SyntheticStream
        from repro.compat import tree_flatten_with_path
        from repro.kernels import ops

        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(
            cfg, parallel=ParallelConfig(("data",), ("data",)))
        model = build_model(cfg)
        rt8 = FSDPRuntime(model, make_local_mesh(8, 1))
        opt = make_optimizer(cfg)
        params = rt8.init_params(0)
        state = opt.init(rt8)
        fn = rt8.make_train_step(opt)
        stream = SyntheticStream(DataConfig(cfg.vocab, 16, 8, seed=0), cfg)
        st = jnp.int32(0)
        for i in range(2):
            b = stream.shard(stream.batch(i), rt8)
            params, state, st, m = fn(params, state, st, b)

        rt4, p4, s4 = rt8.replan(params, state,
                                 mesh=make_local_mesh(4, 1), optimizer=opt)
        for name, lo8 in rt8.layouts.items():
            lo4 = rt4.layouts[name]
            a, b = np.asarray(params[name]), np.asarray(p4[name])
            Ls = range(lo8.n_layers) if lo8.n_layers else [None]
            for li in Ls:
                ta = lo8.buffer.unpack_np(a[li] if li is not None else a)
                tb = lo4.buffer.unpack_np(b[li] if li is not None else b)
                for k in ta:
                    np.testing.assert_array_equal(ta[k], tb[k])
        fa, _ = tree_flatten_with_path(state)
        fb, _ = tree_flatten_with_path(s4)
        da = {tuple(getattr(p, "key", str(p)) for p in kp): v
              for kp, v in fa}
        for kp, vb in fb:
            keys = tuple(getattr(p, "key", str(p)) for p in kp)
            g = keys[-1]
            lo8, lo4 = rt8.layouts[g], rt4.layouts[g]
            a, b = np.asarray(da[keys]), np.asarray(vb)
            Ls = range(lo8.n_layers) if lo8.n_layers else [None]
            for li in Ls:
                ta = lo8.buffer.unpack_np(a[li] if li is not None else a)
                tb = lo4.buffer.unpack_np(b[li] if li is not None else b)
                for k in ta:
                    np.testing.assert_array_equal(ta[k], tb[k])
        # same mesh, store-format change: fp32 -> q8_block (before the
        # train step below donates and deletes the p4 buffers)
        rtq, pq, _ = rt4.replan(p4, schedule=CommSchedule(
            param_store="q8_block"))
        for name, lo in rtq.layouts.items():
            np.testing.assert_array_equal(
                np.asarray(p4[name]), np.asarray(pq[name]["master"]))
            want, _ = ops.quantize(jnp.asarray(pq[name]["master"]),
                                   lo.store.block)
            np.testing.assert_array_equal(
                np.asarray(want), np.asarray(pq[name]["codes"]))
        print("REPLAN_STORE_OK")

        # resume training in-job on the new mesh (fresh uncommitted step)
        fn4 = rt4.make_train_step(opt)
        st4 = jnp.int32(int(st))
        b = stream.shard(stream.batch(2), rt4)
        p4b, s4b, st4, m4 = fn4(p4, s4, st4, b)
        assert np.isfinite(float(m4["loss"]))
        print("REPLAN_MESH_OK")
    """
    out = _run_driver(driver)
    assert "REPLAN_MESH_OK" in out.stdout
    assert "REPLAN_STORE_OK" in out.stdout


def test_adam8bit_state_reshards(tmp_path):
    """8-bit optimizer state (int8 moment codes + block scales) moves on
    the aligned extent path across an FSDP mesh-size change."""
    driver = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, numpy as np, jax.numpy as jnp
        from repro.configs import get_config, build_model
        from repro.configs.base import ParallelConfig
        from repro.core.fsdp import FSDPRuntime
        from repro.checkpoint import ckpt
        from repro.launch.mesh import make_local_mesh
        from repro.optim import make_optimizer
        from repro.data.pipeline import DataConfig, SyntheticStream
        from repro.compat import tree_flatten_with_path
        from repro.core.policy import make_plan
        from tools.reshard import reshard

        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(
            cfg, optimizer="adam8bit",
            parallel=ParallelConfig(("data",), ("data",)))
        model = build_model(cfg)
        rt8 = FSDPRuntime(model, make_local_mesh(8, 1))
        opt = make_optimizer(cfg)
        params = rt8.init_params(0)
        state = opt.init(rt8)
        fn = rt8.make_train_step(opt)
        stream = SyntheticStream(DataConfig(cfg.vocab, 16, 8, seed=0), cfg)
        st = jnp.int32(0)
        for i in range(2):
            b = stream.shard(stream.batch(i), rt8)
            params, state, st, m = fn(params, state, st, b)
        ckpt.save({str(tmp_path / 'c8')!r}, rt8, params, state, step=2)
        plan4 = make_plan(build_model(cfg), {{"data": 4, "model": 1}}, None)
        reshard({str(tmp_path / 'c8')!r}, {str(tmp_path / 'c4')!r}, plan4,
                verbose=False)
        rt4 = FSDPRuntime(build_model(cfg), make_local_mesh(4, 1))
        p4, step, s4 = ckpt.load({str(tmp_path / 'c4')!r}, rt4,
                                 opt.init(rt4))
        fa, _ = tree_flatten_with_path(state)
        fb, _ = tree_flatten_with_path(s4)
        da = {{tuple(getattr(p, "key", str(p)) for p in kp): v
              for kp, v in fa}}
        checked = 0
        for kp, vb in fb:
            keys = tuple(getattr(p, "key", str(p)) for p in kp)
            g = keys[-1]
            lo8, lo4 = rt8.layouts[g], rt4.layouts[g]
            a, b = np.asarray(da[keys]), np.asarray(vb)
            div = lo8.global_shape()[-1] // a.shape[-1]
            # compare per-tensor through the extent map (int8 codes and
            # scales are layout-dependent but extent-exact)
            from repro.core.reshard import GroupIndex, buffer_reader
            i8 = GroupIndex.from_layout(lo8)
            i4 = GroupIndex.from_layout(lo4)
            r8 = buffer_reader(a, i8.num_rows)
            r4 = buffer_reader(b, i4.num_rows)
            for name in lo8.plan.names:
                Ls = range(lo8.n_layers) if lo8.n_layers else [None]
                for li in Ls:
                    e8 = [x.scaled(div) for x in
                          lo8.plan.tensor_extents(name)] if div > 1 \
                        else lo8.plan.tensor_extents(name)
                    e4 = [x.scaled(div) for x in
                          lo4.plan.tensor_extents(name)] if div > 1 \
                        else lo4.plan.tensor_extents(name)
                    n = sum(x.size for x in e8)
                    fa8 = np.empty(n, a.dtype)
                    for x in e8:
                        fa8[x.tensor_lo: x.tensor_lo + x.size] = \
                            r8(x.shard, li)[x.lo: x.hi]
                    fb4 = np.empty(n, b.dtype)
                    for x in e4:
                        fb4[x.tensor_lo: x.tensor_lo + x.size] = \
                            r4(x.shard, li)[x.lo: x.hi]
                    np.testing.assert_array_equal(fa8, fb4)
                    checked += 1
        assert checked
        print("ADAM8BIT_RESHARD_OK")
    """
    out = _run_driver(driver)
    assert "ADAM8BIT_RESHARD_OK" in out.stdout
