"""Fused optimizer-update + store-rebuild kernels
(``ops.adamw_store_update`` / ``ops.adam8bit_store_update``).

Parity doctrine (DESIGN.md §Kernels): adamw is BITWISE against the
jitted unfused reference (``ref.adamw_store_update_ref``) for every
store format; adam8bit is ALLCLOSE at few-ulp integer-view distance (<= 4) --
the log-space second-moment decode's ``exp`` compiles differently
inside the pallas interpreter than in the fused reference graph
(verified: 40/40 random seeds drift by a last-ulp step or two on the
weight, 0/40 for adamw).  The scalars (lr, betas, eps, wd,
bias-correction terms) ride as TRACED f32 arguments on BOTH sides --
closing the reference over python floats would fold ``1 - b1`` in f64
and shift the coefficients by ulps, which is exactly the class of
drift the contract exists to catch.

The jaxpr regressions prove the fusion claim structurally: the fused q8
path shows strictly fewer full-size f32 intermediates than the unfused
update-then-requantize composition (the ``store.rebuild`` second pass is
gone), using the same ``repro.analysis`` walker the plan verifier runs.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.analysis import count_full_f32
from repro.compat import float8_dtypes
from repro.kernels import ops, ref


def rnd(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


def special_blocks(nblocks, block, seed, dtype=jnp.float32):
    """Random data with the adversarial blocks the sweeps require: block 0
    all zeros, block 1 denormal absmax (the requantize epilogue's
    1/max(scale, eps) guard)."""
    x = np.array(rnd((nblocks * block,), seed=seed))
    x[:block] = 0.0
    if nblocks > 1:
        x[block:2 * block] *= 1e-42
    return jnp.asarray(x).astype(dtype)


ALL_FMTS = ["fp32", "bf16", "q8_block"] + sorted(float8_dtypes())
FLAT_FMTS = [f for f in ALL_FMTS if f != "q8_block"]

# traced-f32 hyperparameters: lr, b1, b2, eps, wd, c1, c2
SCALARS = tuple(jnp.float32(x)
                for x in (1e-3, 0.9, 0.95, 1e-8, 0.1, 0.5, 0.25))


def _adamw_inputs(n, seed=0, w_dtype=jnp.float32, block=1024):
    nb = -(-n // block)
    w = special_blocks(nb, block, seed=seed)[:n].astype(w_dtype)
    g = rnd((n,), seed=seed + 1)
    m = rnd((n,), seed=seed + 2) * 0.1
    v = jnp.abs(rnd((n,), seed=seed + 3)) * 0.01
    rng = np.random.default_rng(seed + 4)
    mask = jnp.asarray(rng.integers(0, 2, (n,)).astype(np.float32))
    return w, g, m, v, mask


def _assert_bitwise(got, want, msg=""):
    ga = jax.tree_util.tree_leaves(got)
    wa = jax.tree_util.tree_leaves(want)
    assert len(ga) == len(wa)
    for a, b in zip(ga, wa):
        assert a.dtype == b.dtype, (msg, a.dtype, b.dtype)
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8),
            err_msg=msg)


_INT_VIEW = {1: np.int8, 2: np.int16, 4: np.int32}


def _assert_ulp(got, want, msg="", max_ulp=4):
    """Integer-representation distance <= max_ulp on every leaf
    (subsumes bitwise; the adam8bit contract -- see module docstring)."""
    ga = jax.tree_util.tree_leaves(got)
    wa = jax.tree_util.tree_leaves(want)
    assert len(ga) == len(wa)
    for a, b in zip(ga, wa):
        assert a.dtype == b.dtype, (msg, a.dtype, b.dtype)
        ai = np.asarray(a).view(_INT_VIEW[jnp.dtype(a.dtype).itemsize])
        bi = np.asarray(b).view(_INT_VIEW[jnp.dtype(b.dtype).itemsize])
        d = np.abs(ai.astype(np.int64) - bi.astype(np.int64))
        assert d.max(initial=0) <= max_ulp, (msg, a.dtype, int(d.max()),
                                             int((d > 0).sum()))


# --------------------------------------------------------------------------- #
# adamw: bitwise parity across every store format
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_adamw_store_update_bitwise(fmt):
    n, block = 8 * 1024, 1024
    w_dtype = jnp.bfloat16 if fmt == "bf16" else jnp.float32
    w, g, m, v, mask = _adamw_inputs(n, w_dtype=w_dtype, block=block)
    got = ops.adamw_store_update(
        w, g, m, v, mask, lr=SCALARS[0], b1=SCALARS[1], b2=SCALARS[2],
        eps=SCALARS[3], wd=SCALARS[4], c1=SCALARS[5], c2=SCALARS[6],
        fmt=fmt, block=block)
    want = jax.jit(ref.adamw_store_update_ref, static_argnums=(12, 13))(
        w, g, m, v, mask, *SCALARS, fmt, block)
    _assert_bitwise(got, want, f"adamw fmt={fmt}")


@pytest.mark.parametrize("fmt", FLAT_FMTS)
def test_adamw_flat_overhang(fmt):
    """Flat formats take the (rows, 128)-tile path with inert zero pad --
    an n that is a multiple of neither the lane width nor the quant block
    must still match the reference exactly."""
    n = 100
    w_dtype = jnp.bfloat16 if fmt == "bf16" else jnp.float32
    w, g, m, v, mask = _adamw_inputs(n, w_dtype=w_dtype)
    got = ops.adamw_store_update(
        w, g, m, v, mask, lr=SCALARS[0], b1=SCALARS[1], b2=SCALARS[2],
        eps=SCALARS[3], wd=SCALARS[4], c1=SCALARS[5], c2=SCALARS[6],
        fmt=fmt, block=1024)
    want = jax.jit(ref.adamw_store_update_ref, static_argnums=(12, 13))(
        w, g, m, v, mask, *SCALARS, fmt, 1024)
    _assert_bitwise(got, want, f"adamw overhang fmt={fmt}")
    leaves = jax.tree_util.tree_leaves(got)
    assert all(a.shape == (n,) for a in leaves if a.ndim == 1)


def test_adamw_q8_misaligned_raises():
    w, g, m, v, mask = _adamw_inputs(100)
    with pytest.raises(ValueError, match="align"):
        ops.adamw_store_update(
            w, g, m, v, mask, lr=SCALARS[0], b1=SCALARS[1], b2=SCALARS[2],
            eps=SCALARS[3], wd=SCALARS[4], c1=SCALARS[5], c2=SCALARS[6],
            fmt="q8_block", block=1024)


def test_adamw_unknown_fmt_raises():
    w, g, m, v, mask = _adamw_inputs(1024)
    with pytest.raises(ValueError, match="fmt"):
        ops.adamw_store_update(
            w, g, m, v, mask, lr=SCALARS[0], b1=SCALARS[1], b2=SCALARS[2],
            eps=SCALARS[3], wd=SCALARS[4], c1=SCALARS[5], c2=SCALARS[6],
            fmt="int4", block=1024)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(ALL_FMTS), st.sampled_from([128, 1024]),
       st.integers(1, 8), st.integers(0, 10_000))
def test_adamw_store_update_property(fmt, block, nblocks, seed):
    n = nblocks * block
    w_dtype = jnp.bfloat16 if fmt == "bf16" else jnp.float32
    w, g, m, v, mask = _adamw_inputs(n, seed=seed, w_dtype=w_dtype,
                                     block=block)
    got = ops.adamw_store_update(
        w, g, m, v, mask, lr=SCALARS[0], b1=SCALARS[1], b2=SCALARS[2],
        eps=SCALARS[3], wd=SCALARS[4], c1=SCALARS[5], c2=SCALARS[6],
        fmt=fmt, block=block)
    want = jax.jit(ref.adamw_store_update_ref, static_argnums=(12, 13))(
        w, g, m, v, mask, *SCALARS, fmt, block)
    _assert_bitwise(got, want, f"property fmt={fmt} block={block} "
                               f"nblocks={nblocks} seed={seed}")


# --------------------------------------------------------------------------- #
# adam8bit: few-ulp parity (block layout pinned by the quantized moments)
# --------------------------------------------------------------------------- #

def _adam8_inputs(n, seed=0, w_dtype=jnp.float32, block=1024):
    w, g, m, v, mask = _adamw_inputs(n, seed=seed, w_dtype=w_dtype,
                                     block=block)
    m8, ms = ref.quantize_ref(np.asarray(m, np.float32), block)
    v8, vs = ref.quantize_ref(np.abs(np.asarray(v, np.float32)), block)
    return w, g, m8, v8, ms, vs, mask


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_adam8bit_store_update_ulp(fmt):
    n, block = 8 * 1024, 1024
    w_dtype = jnp.bfloat16 if fmt == "bf16" else jnp.float32
    w, g, m8, v8, ms, vs, mask = _adam8_inputs(n, w_dtype=w_dtype,
                                               block=block)
    got = ops.adam8bit_store_update(
        w, g, m8, v8, ms, vs, mask, lr=SCALARS[0], b1=SCALARS[1],
        b2=SCALARS[2], eps=SCALARS[3], wd=SCALARS[4], c1=SCALARS[5],
        c2=SCALARS[6], fmt=fmt, block=block)
    want = jax.jit(ref.adam8bit_store_update_ref, static_argnums=(14, 15))(
        w, g, m8, v8, ms, vs, mask, *SCALARS, fmt, block)
    _assert_ulp(got, want, f"adam8bit fmt={fmt}")


def test_adam8bit_misaligned_raises():
    w, g, m8, v8, ms, vs, mask = _adam8_inputs(1024)
    with pytest.raises(ValueError, match="align"):
        ops.adam8bit_store_update(
            w[:100], g[:100], m8, v8, ms, vs, mask[:100], lr=SCALARS[0],
            b1=SCALARS[1], b2=SCALARS[2], eps=SCALARS[3], wd=SCALARS[4],
            c1=SCALARS[5], c2=SCALARS[6], fmt="fp32", block=1024)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(ALL_FMTS), st.sampled_from([128, 1024]),
       st.integers(1, 8), st.integers(0, 10_000))
def test_adam8bit_store_update_property(fmt, block, nblocks, seed):
    n = nblocks * block
    w_dtype = jnp.bfloat16 if fmt == "bf16" else jnp.float32
    w, g, m8, v8, ms, vs, mask = _adam8_inputs(n, seed=seed,
                                               w_dtype=w_dtype, block=block)
    got = ops.adam8bit_store_update(
        w, g, m8, v8, ms, vs, mask, lr=SCALARS[0], b1=SCALARS[1],
        b2=SCALARS[2], eps=SCALARS[3], wd=SCALARS[4], c1=SCALARS[5],
        c2=SCALARS[6], fmt=fmt, block=block)
    want = jax.jit(ref.adam8bit_store_update_ref, static_argnums=(14, 15))(
        w, g, m8, v8, ms, vs, mask, *SCALARS, fmt, block)
    _assert_ulp(got, want, f"property fmt={fmt} block={block} "
                            f"nblocks={nblocks} seed={seed}")


# --------------------------------------------------------------------------- #
# jaxpr regression: the fusion claim, structurally
# --------------------------------------------------------------------------- #

def test_fused_q8_update_fewer_f32_streams():
    """The unfused composition runs the update (w2 materialized f32) and
    then store.rebuild as a second full-size pass; the fused kernel's
    requantize epilogue writes codes/scales from registers.  Count the
    full-size f32 intermediates outside pallas bodies -- fused must be
    strictly lower."""
    n, block = 8 * 1024, 1024
    w, g, m, v, mask = _adamw_inputs(n, block=block)

    def fused(w, g, m, v, mask, *sc):
        return ops.adamw_store_update(
            w, g, m, v, mask, lr=sc[0], b1=sc[1], b2=sc[2], eps=sc[3],
            wd=sc[4], c1=sc[5], c2=sc[6], fmt="q8_block", block=block)

    def unfused(w, g, m, v, mask, *sc):
        return ref.adamw_store_update_ref(w, g, m, v, mask, *sc,
                                          "q8_block", block)

    cf = count_full_f32(fused, w, g, m, v, mask, *SCALARS, n=n)
    cu = count_full_f32(unfused, w, g, m, v, mask, *SCALARS, n=n)
    assert cf < cu, (cf, cu)


def test_fused_fp8_update_fewer_f32_streams():
    if not float8_dtypes():
        pytest.skip("installed JAX has no float8 dtypes")
    n = 8 * 1024
    w, g, m, v, mask = _adamw_inputs(n)

    def fused(w, g, m, v, mask, *sc):
        return ops.adamw_store_update(
            w, g, m, v, mask, lr=sc[0], b1=sc[1], b2=sc[2], eps=sc[3],
            wd=sc[4], c1=sc[5], c2=sc[6], fmt="fp8_e4m3", block=1024)

    def unfused(w, g, m, v, mask, *sc):
        return ref.adamw_store_update_ref(w, g, m, v, mask, *sc,
                                          "fp8_e4m3", 1024)

    cf = count_full_f32(fused, w, g, m, v, mask, *SCALARS, n=n)
    cu = count_full_f32(unfused, w, g, m, v, mask, *SCALARS, n=n)
    assert cf < cu, (cf, cu)


# --------------------------------------------------------------------------- #
# 8-device: the kernel under shard_map, per-shard bitwise vs the reference
# --------------------------------------------------------------------------- #

_DRIVER_8DEV = textwrap.dedent("""
    import os, json, functools
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.kernels import ops, ref
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(8, 1)
    axis = mesh.axis_names[0]
    block, shard = 1024, 4 * 1024
    n = 8 * shard
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    g = jnp.asarray(rng.normal(size=n).astype(np.float32))
    m = jnp.asarray(rng.normal(size=n).astype(np.float32)) * 0.1
    v = jnp.abs(jnp.asarray(rng.normal(size=n).astype(np.float32))) * 0.01
    mask = jnp.asarray(rng.integers(0, 2, (n,)).astype(np.float32))
    sc = tuple(jnp.float32(x) for x in (1e-3, 0.9, 0.95, 1e-8, 0.1,
                                        0.5, 0.25))

    def upd(w, g, m, v, mask, *sc):
        return ops.adamw_store_update(
            w, g, m, v, mask, lr=sc[0], b1=sc[1], b2=sc[2], eps=sc[3],
            wd=sc[4], c1=sc[5], c2=sc[6], fmt="q8_block", block=block)

    sharded = shard_map(
        upd, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis),
                  *([P()] * 7)),
        out_specs=({"codes": P(axis), "master": P(axis),
                    "scales": P(axis)}, P(axis), P(axis)))
    store8, m8, v8 = jax.jit(sharded)(w, g, m, v, mask, *sc)

    r = jax.jit(ref.adamw_store_update_ref, static_argnums=(12, 13))
    ok = True
    for i in range(8):
        s = slice(i * shard, (i + 1) * shard)
        want_store, wm, wv = r(w[s], g[s], m[s], v[s], mask[s], *sc,
                               "q8_block", block)
        sb = slice(i * (shard // block), (i + 1) * (shard // block))
        for leaf, wl in (("codes", want_store["codes"]),
                         ("master", want_store["master"]),
                         ("scales", want_store["scales"])):
            gl = store8[leaf][sb if leaf == "scales" else s]
            ok &= bool(np.array_equal(
                np.asarray(gl).view(np.uint8),
                np.asarray(wl).view(np.uint8)))
        ok &= bool(np.array_equal(np.asarray(m8[s]), np.asarray(wm)))
        ok &= bool(np.array_equal(np.asarray(v8[s]), np.asarray(wv)))
    print(json.dumps({"bitwise": ok}))
""")


@pytest.mark.slow
def test_adamw_store_update_shard_map_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DRIVER_8DEV],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["bitwise"], data
