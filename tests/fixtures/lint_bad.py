"""Deliberately non-conforming file: the negative fixture the CI
static-analysis job lints to prove a lint failure blocks the job.  It is
NOT on the linter's default scan surface (tests/ is excluded) so the real
tree stays green; the job (and tests/test_analysis.py) point the linter at
this file explicitly and demand a nonzero exit.

Expected findings: compat-only (versioned shard_map import + *_with_path
attribute use) and bare-assert.
"""
from jax.experimental.shard_map import shard_map  # noqa: F401


def scatter(tree, f):
    import jax

    assert tree is not None
    return jax.tree_util.tree_map_with_path(f, tree)
