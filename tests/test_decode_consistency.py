"""Serving-path correctness: incrementally decoding token-by-token must
produce the same logits as prefilling the whole prefix at once -- this pins
down cache semantics (RoPE positions, ring slots, causal masks, SSM state
carry) across architecture families."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.launch.mesh import make_local_mesh

MESH = make_local_mesh(1, 1)
ARCHS = ["qwen2.5-14b", "gemma2-2b", "xlstm-125m", "hymba-1.5b",
         "seamless-m4t-medium", "granite-moe-1b-a400m"]


def _batch(cfg, tokens):
    rng = np.random.default_rng(7)
    b = {"tokens": tokens}
    if cfg.arch_type == "vlm":
        b["patches"] = jnp.asarray(
            rng.normal(size=(tokens.shape[0], cfg.n_patches, cfg.d_model)),
            jnp.bfloat16)
    if cfg.arch_type == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(size=(tokens.shape[0], cfg.n_frames, cfg.d_model)),
            jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_incremental_decode_matches_prefill(arch):
    import dataclasses

    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # parity requires no capacity drops in EITHER path: decode is
        # dropless by construction (moe_ffn), the reference prefill needs
        # headroom (capacity-MoE outputs are batch-composition-dependent)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH)
    params = rt.init_params(0)
    prefill = rt.make_prefill_step()
    decode = rt.make_decode_step()

    B, P, K, S = 2, 6, 4, 32
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, P + K)), jnp.int32)

    # incremental: prefill P tokens, then decode K teacher-forced tokens
    cache = model.init_cache(B, S)
    b = _batch(cfg, tokens[:, :P])
    logits_inc, cache = prefill(params, b, cache)
    inc = [np.asarray(logits_inc, np.float32)]
    for t in range(P, P + K - 1):
        db = _batch(cfg, tokens[:, t:t + 1])
        lg, cache = decode(params, db, cache, jnp.int32(t))
        inc.append(np.asarray(lg, np.float32))

    # reference: fresh prefill of each longer prefix
    for j, t in enumerate(range(P, P + K)):
        cache2 = model.init_cache(B, S)
        lg_ref, _ = prefill(params, _batch(cfg, tokens[:, :t]), cache2)
        np.testing.assert_allclose(
            inc[j], np.asarray(lg_ref, np.float32), rtol=3e-2, atol=3e-2,
            err_msg=f"{arch} step {j}")
