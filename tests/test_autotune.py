"""The measured-cost autotuner (ISSUE 8): comm-profile schema round-trips,
``CostModel.from_profile`` pricing, per-mode latency crossover, ring-chunk
selection, plan provenance (profile name + content hash) and plan-JSON
reproducibility from the recorded profile, builtin-vs-measured decision
divergence, and the bitwise neutrality of ``ring_chunk_elems`` on real
8-device shards (subprocess twin, CI's chunking parity suite)."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.policy import CostModel, make_plan
from repro.core.profile import (BUILTIN_NAME, CommProfile, CommSample,
                                SCHEMA, builtin_profile, load_profile)
from repro.core.schedule import GROUP_OVERRIDE_KEYS, CommSchedule
from repro.core.wire import _snap_chunk


def _model(arch="qwen2.5-14b"):
    return build_model(get_config(arch).reduced())


def _samples(direction, fmt, mode, per_elem_ns, sizes=(1 << 16, 1 << 20)):
    return [CommSample(direction=direction, fmt=fmt, mode=mode,
                       elems=e, chunk_elems=e, time_us=e * per_elem_ns * 1e-3)
            for e in sizes]


def _measured_profile(name="measured-test", world=8, sweep=()):
    """A deterministic 'measured' profile with the OPPOSITE economics of
    the builtin roofline: cast wires cheap (bf16 ring cheapest), q8 wires
    expensive (this backend's quant kernels are slow) -- the CPU truth the
    calibrated BENCH_comm.json also reports."""
    ns = {("gather", "fp32", "xla"): 4.0, ("gather", "fp32", "ring"): 4.0,
          ("gather", "bf16", "xla"): 2.0, ("gather", "bf16", "ring"): 0.5,
          ("gather", "q8_block", "xla"): 50.0,
          ("gather", "q8_block", "ring"): 50.0,
          ("reduce", "fp32", "xla"): 4.0, ("reduce", "fp32", "ring"): 4.0,
          ("reduce", "fp32", "ring_acc"): 4.0,
          ("reduce", "bf16", "xla"): 2.0, ("reduce", "bf16", "ring"): 0.5,
          ("reduce", "bf16", "ring_acc"): 0.5,
          ("reduce", "q8_block", "xla"): 100.0,
          ("reduce", "q8_block", "ring"): 100.0,
          ("reduce", "q8_block", "ring_acc"): 100.0}
    entries = []
    for (d, f, m), v in ns.items():
        entries.extend(_samples(d, f, m, v))
    entries.extend(sweep)
    return CommProfile(name=name, entries=tuple(entries), backend="cpu",
                       world=world, builtin=False, end_to_end=True,
                       quick=True)


_SWEEP = (
    # gather bf16 ring chunk sweep at 1<<20 (shard 131072 at world 8):
    # 16384-elem messages beat the shard-sized default (0.5 ns/elem)
    CommSample("gather", "bf16", "ring", 1 << 20, 65536,
               (1 << 20) * 0.45e-3),
    CommSample("gather", "bf16", "ring", 1 << 20, 16384,
               (1 << 20) * 0.4e-3),
)


# --------------------------------------------------------------------------- #
# schema + fitted curves
# --------------------------------------------------------------------------- #

def test_profile_round_trip_and_hash_stability():
    prof = _measured_profile(sweep=_SWEEP)
    again = CommProfile.from_json(json.loads(json.dumps(prof.to_json())))
    assert again == prof
    assert again.content_hash() == prof.content_hash()
    # the hash covers content: any entry change changes it
    other = _measured_profile(name="measured-test-2", sweep=_SWEEP)
    assert other.content_hash() != prof.content_hash()


def test_profile_schema_rejects_malformed():
    with pytest.raises(ValueError, match="ring_acc is a reduce-only"):
        CommProfile(name="x", entries=(CommSample(
            "gather", "fp32", "ring_acc", 8, 8, 1.0),))
    with pytest.raises(ValueError, match="chunk_elems"):
        CommProfile(name="x", entries=(CommSample(
            "gather", "fp32", "ring", 8, 16, 1.0),))
    with pytest.raises(ValueError, match="direction"):
        CommProfile(name="x", entries=(CommSample(
            "sideways", "fp32", "ring", 8, 8, 1.0),))
    with pytest.raises(ValueError, match="schema"):
        CommProfile.from_json({"schema": "comm-profile/v0", "name": "x",
                               "entries": []})


def test_profile_validator_cli(tmp_path):
    from repro.core import profile as profile_mod

    good = tmp_path / "ok.json"
    _measured_profile().save(good)
    assert profile_mod.main([str(good)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": SCHEMA, "name": "x",
                               "entries": [{"direction": "gather"}]}))
    assert profile_mod.main([str(bad)]) == 1


def test_linear_fit_recovers_latency_and_slope():
    lat_s, ns = 2e-5, 3.0
    entries = tuple(CommSample("gather", "fp32", "xla", e, e,
                               (lat_s + e * ns * 1e-9) * 1e6)
                    for e in (1 << 14, 1 << 18, 1 << 20))
    prof = CommProfile(name="fit", entries=entries, world=4)
    lat, slope = prof.linear("gather", "fp32", "xla")
    assert lat == pytest.approx(lat_s, rel=1e-6)
    assert slope == pytest.approx(ns * 1e-9, rel=1e-6)
    # one point degenerates to pure slope; missing key raises
    one = CommProfile(name="one", entries=entries[:1])
    assert one.linear("gather", "fp32", "xla")[0] == 0.0
    with pytest.raises(KeyError):
        one.linear("reduce", "fp32", "xla")


def test_best_ring_chunk_search():
    prof = _measured_profile(sweep=_SWEEP)
    assert prof.best_ring_chunk("gather", "bf16") == 16384
    # no sweep for this key -> None; default-wins sweep -> None
    assert prof.best_ring_chunk("gather", "fp32") is None
    losing = (CommSample("gather", "fp32", "ring", 1 << 20, 16384,
                         (1 << 20) * 9.0e-3),)
    assert _measured_profile(sweep=losing).best_ring_chunk(
        "gather", "fp32") is None


def test_builtin_profile_fit_recovers_roofline_constants():
    prof = builtin_profile(ici_bw=50e9, latency_s=5e-6)
    assert prof.name == BUILTIN_NAME and prof.builtin
    lat, slope = prof.linear("gather", "fp32", "xla")
    assert lat == pytest.approx(5e-6, rel=1e-9)
    assert slope == pytest.approx(4.0 / 50e9, rel=1e-9)


# --------------------------------------------------------------------------- #
# CostModel: per-mode latency (satellite) + measured pricing
# --------------------------------------------------------------------------- #

def test_per_mode_latency_crossover():
    cm = CostModel(ici_bw=1e11, hbm_bw=1e12, peak_flops=1e15,
                   xla_latency_s=1e-3, ring_hop_latency_s=1e-3)
    # no collective at m=1: modes price identically
    assert cm._latency("xla", 1) == cm._latency("ring", 1) == 0.0
    assert cm.gather_time("fp32", 1 << 10, 1, 1, 1024, 4, mode="xla") == \
        cm.gather_time("fp32", 1 << 10, 1, 1, 1024, 4, mode="ring")
    # at m>=2 the ring pays m-1 hops vs one xla issue: same wire volume,
    # so the builtin roofline never picks ring
    assert cm._latency("ring", 8) == 7 * cm._latency("xla", 8)
    assert cm.choose_gather(1 << 20, 4, 8, 1024, 2)[1] == "xla"
    # measured curves CAN cross: a high-latency/low-slope xla curve vs a
    # low-latency/high-slope ring curve -- latency dominates tiny buffers
    # (ring wins), bandwidth dominates big ones (xla wins back)
    def pts(mode, lat_s, ns):
        return tuple(CommSample("gather", "fp32", mode, e, e,
                                (lat_s + e * ns * 1e-9) * 1e6)
                     for e in (1 << 16, 1 << 20))
    prof = CommProfile(name="xover", world=8,
                       entries=pts("xla", 1e-3, 1.0) + pts("ring", 1e-5, 4.0))
    mcm = CostModel.from_profile(prof)

    def t(mode, elems):
        return mcm.gather_time("fp32", elems, 1, 8, 1024, 4, mode=mode)
    assert t("ring", 1 << 14) < t("xla", 1 << 14)
    assert t("xla", 1 << 22) < t("ring", 1 << 22)


def test_auto_latency_dominated_group_replicates():
    # the replicate threshold is the planner-level expression of the
    # latency crossover: a tiny unstacked group's per-step gather latency
    # outweighs the shard's memory win, so auto keeps it replicated
    model = _model()
    p = make_plan(model, {"data": 8}, "auto")
    assert not p.groups["globals"].policy.sharded
    cm0 = dataclasses.replace(CostModel.default(), replicate_bytes=0)
    p0 = make_plan(model, {"data": 8}, "auto", cost_model=cm0)
    assert p0.groups["globals"].policy.sharded


def test_measured_time_rescales_ring_volume():
    prof = _measured_profile(world=8)
    cm = CostModel.from_profile(prof)
    t8 = cm._measured_time("gather", "fp32", "xla", 1 << 20, 8)
    t2 = cm._measured_time("gather", "fp32", "xla", 1 << 20, 2)
    # (m-1)/m volume: m=2 ships (1/2)/(7/8) of the world-8 measurement
    assert t2 == pytest.approx(t8 * (1 / 2) / (7 / 8), rel=1e-9)
    assert cm._measured_time("gather", "fp32", "xla", 1 << 20, 1) == \
        pytest.approx(0.0, abs=1e-12)
    # keys the profile lacks fall back to the builtin roofline (None)
    assert cm._measured_time("gather", "missing", "xla", 1 << 20, 8) is None


def test_from_profile_back_derives_bandwidth():
    cm = CostModel.from_profile(_measured_profile())
    # fp32 gather xla curve: 4 ns/elem = 4 B / 1e9 B/s
    assert cm.ici_bw == pytest.approx(1e9, rel=1e-6)
    assert cm.measured
    assert not CostModel.default().measured
    assert CostModel.default().provenance_profile().name == BUILTIN_NAME


# --------------------------------------------------------------------------- #
# the tentpole: measured profile drives planning + ring chunking
# --------------------------------------------------------------------------- #

def test_auto_decision_diverges_builtin_vs_measured():
    model = _model()
    mesh = {"data": 8}
    p_b = make_plan(model, mesh, "auto")
    prof = _measured_profile(sweep=_SWEEP)
    p_m = make_plan(model, mesh, "auto",
                    cost_model=CostModel.from_profile(prof))

    pol_b = p_b.groups["layers"].policy
    pol_m = p_m.groups["layers"].policy
    # builtin roofline: bandwidth-bound stack -> q8_block over xla
    assert (pol_b.store, pol_b.gather_mode) == ("q8_block", "xla")
    assert pol_b.ring_chunk_elems is None
    # measured (q8 codecs expensive, bf16 ring cheap, chunk sweep winner):
    # format AND route AND chunking all flip
    assert (pol_m.store, pol_m.gather_mode) == ("bf16", "ring")
    assert pol_m.ring_chunk_elems == 16384

    # the decision is visible: provenance + both pricings in describe()
    d_b, d_m = p_b.describe(), p_m.describe()
    assert f"profile={BUILTIN_NAME}@{p_b.profile_hash}" in d_b
    assert f"profile=measured-test@{prof.content_hash()}" in d_m
    for d in (d_b, d_m):
        assert "auto_ms" in d and "builtin_ms" in d
    assert "chunk=16384" in d_m
    # measured plan prices its own choice below the builtin roofline's
    # pricing of it; the builtin plan agrees with itself
    pr = p_m.pricing["layers"]
    assert pr["auto_ms"] != pr["builtin_ms"]
    assert p_b.pricing["layers"]["auto_ms"] == \
        p_b.pricing["layers"]["builtin_ms"]


@pytest.mark.parametrize("axes", [{"data": 1}, {"data": 8}])
def test_plan_reproducible_from_recorded_profile(axes, tmp_path):
    model = _model()
    path = tmp_path / "BENCH_comm.json"
    _measured_profile(sweep=_SWEEP).save(path)
    prof = load_profile(path)
    p1 = make_plan(model, axes, "auto",
                   cost_model=CostModel.from_profile(prof))
    assert p1.profile_name == prof.name
    assert p1.profile_hash == prof.content_hash()
    # re-planning from the recorded profile is plan-JSON-equal
    p2 = make_plan(model, axes, "auto",
                   cost_model=CostModel.from_profile(load_profile(path)))
    assert p1.dumps() == p2.dumps()
    # ... and a builtin re-plan records ITS provenance, distinct hash
    p3 = make_plan(model, axes, "auto")
    assert p3.profile_name == BUILTIN_NAME
    assert p3.profile_hash != p1.profile_hash
    # round-trip preserves provenance, pricing, and the chunk knob
    from repro.core.policy import ShardingPlan

    back = ShardingPlan.from_json(json.loads(p1.dumps()))
    assert back.dumps() == p1.dumps()
    assert back.profile_hash == p1.profile_hash
    assert back.groups["layers"].policy.ring_chunk_elems == \
        p1.groups["layers"].policy.ring_chunk_elems


def test_checkpointed_profile_artifact_prices_plan(tmp_path):
    # the calibrated-artifact workflow end to end: save a profile, load it
    # from an arbitrary path, plan, and confirm the plan says so
    path = tmp_path / "anywhere" / "profile.json"
    path.parent.mkdir()
    prof = _measured_profile()
    prof.save(path)
    cm = CostModel.from_profile(str(path))
    p = make_plan(_model(), {"data": 8}, "auto", cost_model=cm)
    assert p.profile_name == "measured-test"
    assert p.profile_hash == prof.content_hash()


# --------------------------------------------------------------------------- #
# the ring_chunk_elems knob (schedule-level)
# --------------------------------------------------------------------------- #

def test_ring_chunk_schedule_validation():
    s = CommSchedule(gather_mode="ring", ring_chunk_elems=4096)
    assert "chunk=4096" in s.describe()
    assert "ring_chunk_elems" in GROUP_OVERRIDE_KEYS
    with pytest.raises(ValueError, match="ring_chunk_elems"):
        CommSchedule(gather_mode="ring", ring_chunk_elems=0)
    with pytest.raises(ValueError, match="ring_chunk_elems"):
        CommSchedule(gather_mode="ring", ring_chunk_elems=True)
    with pytest.raises(ValueError, match="manual ring"):
        CommSchedule(ring_chunk_elems=4096)  # xla/match: knob is inert
    # legal wherever a manual ring actually runs
    CommSchedule(reduce_mode="ring_acc", ring_chunk_elems=64)
    CommSchedule(reduce_wire="q8_block", ring_chunk_elems=1024)


def test_snap_chunk_divisor_rule():
    assert _snap_chunk(1024, None) == 1024
    assert _snap_chunk(1024, 2048) == 1024      # >= rows: no split
    assert _snap_chunk(1024, 256) == 256        # exact divisor
    assert _snap_chunk(1024, 300) == 256        # snaps down to a divisor
    assert _snap_chunk(1000, 300) == 250
    assert _snap_chunk(1024, 1) == 1
    # unit alignment (q8 codes: chunk must hold whole quant blocks)
    assert _snap_chunk(4096, 1500, unit=1024) == 1024
    assert _snap_chunk(4096, 5, unit=1024) == 1024
    assert _snap_chunk(4100, 1024, unit=1024) == 4100  # rows not aligned


# --------------------------------------------------------------------------- #
# 8-device: chunked rings are bitwise-neutral at the wire layer on real
# meshes -- every route, forward and VJP, including non-divisor snaps --
# and a chunked train step keeps the loss stream (the CI chunking parity
# suite; subprocess so the device count is per-test).  DESIGN.md
# SS Autotuning documents why the e2e pin is loss parity rather than
# end-state bit equality: enabling chunking recompiles the whole-step
# program and XLA:CPU drifts a few ULPs in gradients even though every
# wire call is bitwise in isolation.
# --------------------------------------------------------------------------- #

_DRIVER_CHUNK_8DEV = textwrap.dedent("""
    import os, sys, json, dataclasses
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.configs import get_config, build_model
    from repro.configs.base import ParallelConfig
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import CommSchedule
    from repro.core.wire import (WireCodec, _snap_chunk, codec_gather,
                                 codec_reduce_scatter)
    from repro.launch.mesh import make_local_mesh
    from repro.optim import make_optimizer

    MESH8 = make_local_mesh(8, 1)
    AXES, SIZES = ("data",), (8,)
    # 32768 divides neither tested shard-row count, so every route also
    # exercises the snap-to-divisor path (82176 -> 27392, 32896 -> 16448,
    # both whole multiples of the quant block)
    CHUNK = 32768
    out = {}

    # ---- wire layer: chunked == unchunked, bit for bit, per route ---- #
    rng = np.random.default_rng(0)
    bf16 = jnp.dtype(jnp.bfloat16)

    def gather_pair(shard, gc, rcc, mode, rmode, chunk):
        x = jnp.asarray(rng.standard_normal(shard * 8), jnp.float32)
        ct = jnp.asarray(rng.standard_normal(shard * 8),
                         jnp.float32).astype(bf16)
        def body(xs, c):
            y, vjp = jax.vjp(lambda v: codec_gather(
                v, AXES, SIZES, gc, rcc, bf16, jnp.float32, mode, rmode,
                chunk), xs)
            (g,) = vjp(c)
            return y, g
        f = shard_map(body, mesh=MESH8, in_specs=(P("data"), P(None)),
                      out_specs=(P(None), P("data")), check_rep=False)
        y, g = jax.jit(f)(x, ct)
        return np.asarray(y), np.asarray(g)

    def reduce_pair(shard, codec, mode, rmode, chunk, with_ef=False):
        ct = jnp.asarray(rng.standard_normal(shard * 8),
                         jnp.float32).astype(bf16)
        ef = (jnp.asarray(rng.standard_normal(shard * 8), jnp.float32)
              if with_ef else None)
        def body(c, *e):
            g, nef = codec_reduce_scatter(c, e[0] if e else None, codec,
                                          AXES, SIZES, mode, rmode,
                                          jnp.float32, chunk)
            return (g, nef) if e else (g,)
        ins = (P(None), P(None)) if with_ef else (P(None),)
        outs = (P("data"), P(None)) if with_ef else (P("data"),)
        f = shard_map(body, mesh=MESH8, in_specs=ins, out_specs=outs,
                      check_rep=False)
        args = (ct, ef) if with_ef else (ct,)
        return tuple(np.asarray(a) for a in jax.jit(f)(*args))

    for shard in (82176, 32896):
        tag = f"{shard}"
        snapped = _snap_chunk(shard, CHUNK)
        out[f"snap_{tag}"] = bool(0 < snapped < shard and snapped != CHUNK)
        gc = rcc = WireCodec("bf16")
        seed = rng.bit_generator.state
        a = gather_pair(shard, gc, rcc, "ring", "match", None)
        rng.bit_generator.state = seed
        b = gather_pair(shard, gc, rcc, "ring", "match", CHUNK)
        out[f"gather_vjp_bitwise_{tag}"] = bool(
            np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1]))
        for name, codec, rmode, ef in (
                ("reduce_ring", WireCodec("fp32"), "match", False),
                ("reduce_ring_acc", WireCodec("fp32"), "ring_acc", False),
                ("q8_route", WireCodec("q8_block", 64), "match", True),
                ("q8_ring_acc", WireCodec("q8_block", 64), "ring_acc",
                 True)):
            seed = rng.bit_generator.state
            a = reduce_pair(shard, codec, "ring", rmode, None, ef)
            rng.bit_generator.state = seed
            b = reduce_pair(shard, codec, "ring", rmode, CHUNK, ef)
            out[f"{name}_bitwise_{tag}"] = bool(all(
                np.array_equal(x, y) for x, y in zip(a, b)))

    # ---- e2e: a fully chunked train step keeps the loss stream ---- #
    def train(schedule, steps=2):
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(cfg, n_layers=2,
                                  parallel=ParallelConfig(("data",), ("data",)))
        model = build_model(cfg)
        rt = FSDPRuntime(model, MESH8, schedule=schedule, donate=False)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)
        r = np.random.default_rng(0)
        losses = []
        for i in range(steps):
            batch = {"tokens": jnp.asarray(
                r.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
            params, state, st, m = fn(params, state, st, batch)
            losses.append(float(m["loss"]))
        return losses, {k: jax.tree.map(np.asarray, v)
                        for k, v in params.items()}

    base = CommSchedule(gather_mode="ring")
    rl, rp = train(base)
    cl, cp = train(dataclasses.replace(base, ring_chunk_elems=CHUNK))
    out["e2e_loss_close"] = bool(all(
        abs(a - b) <= 1e-3 * max(1.0, abs(a)) for a, b in zip(rl, cl)))
    out["e2e_params_allclose"] = bool(jax.tree.all(jax.tree.map(
        lambda a, b: np.allclose(np.asarray(a, np.float32),
                                 np.asarray(b, np.float32),
                                 rtol=2e-2, atol=1e-4), rp, cp)))
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_ring_chunk_bitwise_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DRIVER_CHUNK_8DEV],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    bad = {k: v for k, v in data.items() if not v}
    assert not bad, (bad, data)
