"""Block-wise quantization properties (linear + log-space variants)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.quant.blockwise import (
    RANGE_NATS, dequantize_blockwise, dequantize_blockwise_log,
    quantize_blockwise, quantize_blockwise_log,
)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.sampled_from([64, 128, 1024]),
       st.integers(0, 999))
def test_linear_roundtrip_bounded(nb, block, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 2, nb * block).astype(np.float32))
    c, s = quantize_blockwise(x, block)
    back = dequantize_blockwise(c, s, block)
    err = np.abs(np.asarray(back) - np.asarray(x)).reshape(nb, block)
    # half a code step, plus fp32 rounding of the quant/dequant arithmetic
    # (proportional to |x|: x/s*127 and code*s each round once)
    fp32_slack = 4 * np.finfo(np.float32).eps * np.abs(
        np.asarray(x)).reshape(nb, block)
    bound = np.asarray(s)[:, None] / 2 + fp32_slack + 1e-7
    assert (err <= bound).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.sampled_from([64, 1024]), st.integers(0, 999),
       st.floats(1e-8, 1e4))
def test_log_roundtrip_relative_error(nb, block, seed, scale):
    """Log-space: *relative* error bounded across ~10 decades -- the property
    linear int8 lacks (and why un-fixed 8-bit Adam diverged)."""
    rng = np.random.default_rng(seed)
    # v-like: non-negative, huge dynamic range within a block
    x = np.abs(rng.normal(0, 1, nb * block)) ** 4 * scale
    x = jnp.asarray(x.astype(np.float32))
    c, s = quantize_blockwise_log(x, block)
    back = np.asarray(dequantize_blockwise_log(c, s, block))
    xs = np.asarray(x)
    # exclude values within one code step of the range floor (clipped to
    # code 1, where the error exceeds the half-step bound by construction)
    nz = xs > np.asarray(s).repeat(block) * np.exp(
        -RANGE_NATS + RANGE_NATS / 127)
    rel = np.abs(back[nz] - xs[nz]) / xs[nz]
    # resolution: half a code step = RANGE_NATS/254 nats ~ 9.9% relative
    assert rel.max() <= np.expm1(RANGE_NATS / 254) * 1.05 + 1e-6
    # zeros stay exactly zero
    assert (back[xs == 0] == 0).all()


def test_log_quant_no_underflow_to_zero():
    """The divergence scenario: one big entry + many tiny ones per block.
    Linear quant zeroes the tiny ones; log quant preserves their scale."""
    block = 1024
    x = np.full(block, 1e-6, np.float32)
    x[0] = 1.0
    xj = jnp.asarray(x)
    cl, sl = quantize_blockwise(xj, block)
    linear_back = np.asarray(dequantize_blockwise(cl, sl, block))
    assert (linear_back[1:] == 0).all()  # the failure mode
    cg, sg = quantize_blockwise_log(xj, block)
    log_back = np.asarray(dequantize_blockwise_log(cg, sg, block))
    assert (log_back[1:] > 0).all()
    rel = np.abs(log_back[1:] - 1e-6) / 1e-6
    assert rel.max() < 0.15


def test_shape_checks_raise_value_error():
    """API-contract checks must be ValueErrors (they survive ``python -O``,
    where bare asserts vanish), for quantize and dequantize, linear and
    log-space alike."""
    x = jnp.zeros(96, jnp.float32)
    with pytest.raises(ValueError):
        quantize_blockwise(x, 64)  # 96 % 64 != 0
    with pytest.raises(ValueError):
        quantize_blockwise_log(jnp.abs(x), 64)
    with pytest.raises(ValueError):
        quantize_blockwise(x, 0)  # block < 1
    codes, scales = quantize_blockwise(jnp.zeros(128, jnp.float32), 64)
    with pytest.raises(ValueError):
        dequantize_blockwise(codes, scales, 48)  # 128 % 48 != 0
    with pytest.raises(ValueError):
        # scales count inconsistent with codes/block
        dequantize_blockwise(codes, scales[:1], 64)
    with pytest.raises(ValueError):
        dequantize_blockwise_log(codes, scales, 48)
