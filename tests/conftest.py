# NOTE: no XLA_FLAGS here on purpose -- smoke tests and benches must see 1
# device; multi-device tests spawn subprocesses that set the flag themselves.
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (still run by default)")
