"""Fused quant hot-path kernels: the contracts the dispatch layer
(repro.kernels.ops) guarantees to its call sites.

  * jaxpr regression: the gather-path fused decode (``dequantize_into``)
    never materializes a full-size fp32 buffer OUTSIDE the kernel body --
    the unfused reference provably does, so the test has teeth.
  * the reduce-path fused encode + error feedback is BITWISE against the
    JITTED reference composition (the regime training actually runs: XLA
    contracts ``comp - codes*scale`` into an FMA under jit on every
    backend, so the eager two-step composition differs sub-ulp and is NOT
    the contract).
  * the serve-path int8 GEMM is ALLCLOSE against the dense semantic
    oracle (activation row-quantization is new error by design) and
    BITWISE against its own jnp op-sequence equivalent.
  * partial tiles: explicit ``tile_blocks`` overrides that leave a cdiv
    overhang (grid padding on the last tile) change nothing.
  * kernel wrappers raise the reference's ValueError contract
    (_check_blocking/_check_scales), differing only in the callee name.
  * property sweeps (hypothesis when installed, fixed-seed otherwise):
    fp32/bf16 cotangents, all-zero blocks, denormal-absmax blocks,
    block in {128, 1024}.

The 8-device subprocess scenario at the bottom drives the two new wired
paths on real shards: deferred-EF microbatch accumulation and the serve
quant-matmul schedule.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.analysis import count_full_f32, has_full_f32
from repro.kernels import ops, ref
from repro.kernels.blockwise_quant import dequantize_into as deq_into_raw
from repro.kernels.blockwise_quant import quantize as quantize_raw
from repro.kernels.encode_ef import encode_ef as encode_ef_raw
from repro.quant.blockwise import dequantize_blockwise, quantize_blockwise


def rnd(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


def special_blocks(nblocks, block, seed, dtype=jnp.float32):
    """Random data with the adversarial blocks the sweeps require: block 0
    all zeros (scale == 0 -> inv == 0 path), block 1 denormal absmax
    (exercises the 1e-30 guard in 1/max(scale, 1e-30))."""
    x = np.array(rnd((nblocks * block,), seed=seed))
    x[:block] = 0.0
    if nblocks > 1:
        x[block:2 * block] *= 1e-42
    return jnp.asarray(x).astype(dtype)


# ---------------------------------------------------------------------------
# jaxpr regression: no full-size fp32 materialization on the gather path
# (the walker lives in repro.analysis -- the same machinery the plan
# verifier's no_f32_dequant invariant runs on full train steps)
# ---------------------------------------------------------------------------

def test_dequantize_into_no_f32_materialization():
    n, block = 8 * 1024, 1024
    codes = jnp.zeros((n,), jnp.int8)
    scales = jnp.ones((n // block,), jnp.float32)

    fused = lambda c, s: ops.dequantize_into(c, s, block,
                                             out_dtype=jnp.bfloat16)
    assert not has_full_f32(fused, codes, scales, n=n), (
        "fused gather decode materialized a full-size fp32 buffer")

    # the unfused composition DOES materialize one -- proves the walker
    # actually sees full-size f32 intermediates when they exist
    unfused = lambda c, s: ref.dequantize_into_ref(c, s, block, jnp.bfloat16)
    assert has_full_f32(unfused, codes, scales, n=n)


def test_encode_ef_no_extra_f32_buffers():
    """The fused encode+EF's only full-size fp32 values outside the kernel
    body are the ef input's reshape view and the new_ef output (3 avals:
    the pjit result, one reshape in, one reshape out); the unfused
    composition threads a dozen-plus full-size fp32 temporaries (comp,
    blocked views, products, the dequant buffer) through XLA."""
    n, block = 8 * 1024, 1024
    ct = jnp.zeros((n,), jnp.bfloat16)
    ef = jnp.zeros((n,), jnp.float32)

    fused = lambda c, e: ops.encode_ef(c, e, block)
    unfused = lambda c, e: ref.encode_ef_ref(c, e, block)
    assert count_full_f32(fused, ct, ef, n=n) <= 3
    assert count_full_f32(unfused, ct, ef, n=n) >= 10


# ---------------------------------------------------------------------------
# fused encode + error feedback: bitwise vs the JITTED reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("block", [128, 1024])
def test_encode_ef_bitwise_vs_jitted_ref(dtype, block):
    ct = special_blocks(6, block, seed=11, dtype=dtype)
    ef = rnd((6 * block,), seed=12, scale=1e-3)
    codes, scales, new_ef = ops.encode_ef(ct, ef, block)
    wc, ws, we = jax.jit(ref.encode_ef_ref, static_argnums=2)(ct, ef, block)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(wc))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(ws))
    np.testing.assert_array_equal(np.asarray(new_ef), np.asarray(we))
    assert new_ef.dtype == jnp.float32


def test_encode_ef_residual_is_quantization_error():
    """Semantics, not just parity: new_ef == comp - decode(encode(comp))
    computed within the jitted regime."""
    block = 64
    ct = rnd((512,), seed=1)
    ef = rnd((512,), seed=2, scale=1e-2)
    codes, scales, new_ef = ops.encode_ef(ct, ef, block)

    @jax.jit
    def expect(ct, ef):
        comp = ct.astype(jnp.float32) + ef
        return comp - dequantize_blockwise(
            *quantize_blockwise(comp, block), block)

    np.testing.assert_array_equal(np.asarray(new_ef),
                                  np.asarray(expect(ct, ef)))


# ---------------------------------------------------------------------------
# serve-path int8 GEMM
# ---------------------------------------------------------------------------

def _q8mm_jnp(x, codes, scales, block):
    """Op-for-op jnp spelling of the kernel (per output-column group):
    the bitwise twin, not the semantic oracle."""
    k, n = codes.shape
    s2 = ops.fold_scales(scales, k, n, block)
    nj = s2.shape[0]
    ncols = n // nj
    outs = []
    for j in range(nj):
        a = x.astype(jnp.float32) * s2[j][None, :]
        rmax = jnp.max(jnp.abs(a), axis=1)
        rs = rmax / 127.0
        inv = jnp.where(rs > 0, 1.0 / jnp.maximum(rs, 1e-30), 0.0)
        a8 = jnp.clip(jnp.round(a * inv[:, None]), -127, 127).astype(jnp.int8)
        acc = jax.lax.dot_general(
            a8, codes[:, j * ncols:(j + 1) * ncols],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        outs.append((acc.astype(jnp.float32) * rs[:, None]).astype(x.dtype))
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("k,n,block", [
    (128, 512, 128),   # case A: N % block == 0 (nj = 4)
    (256, 64, 128),    # case B: block % N == 0 (one block spans 2 rows)
    (64, 64, 64),      # both cases degenerate to nj = 1
])
def test_q8_matmul_matches_oracle_and_jnp_twin(k, n, block):
    w = rnd((k, n), seed=k + n, scale=0.05)
    codes, scales = ops.quantize(w.reshape(-1), block)
    codes = codes.reshape(k, n)
    x = rnd((8, k), seed=3)

    got = ops.q8_matmul(x, codes, scales, block)
    # ALLCLOSE class vs the dense semantic oracle: activation row
    # quantization adds bounded new error
    want = ref.q8_matmul_ref(x, codes, scales, block)
    denom = max(np.abs(np.asarray(want)).mean(), 1e-6)
    assert np.abs(np.asarray(got) - np.asarray(want)).max() / denom < 0.05
    # BITWISE vs the jitted jnp op-sequence twin
    twin = jax.jit(_q8mm_jnp, static_argnums=3)(x, codes, scales, block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(twin))


def test_q8_matmul_leading_dims_and_out_dtype():
    k, n, block = 64, 128, 64
    w = rnd((k, n), seed=5, scale=0.05)
    codes, scales = ops.quantize(w.reshape(-1), block)
    codes = codes.reshape(k, n)
    x = rnd((2, 3, k), seed=6).astype(jnp.bfloat16)
    y = ops.q8_matmul(x, codes, scales, block)
    assert y.shape == (2, 3, n) and y.dtype == jnp.bfloat16
    y32 = ops.q8_matmul(x, codes, scales, block, out_dtype=jnp.float32)
    assert y32.dtype == jnp.float32


def test_quant_eligible_contract():
    assert ops.quant_eligible((128, 512), 128)       # case A
    assert ops.quant_eligible((256, 64), 128)        # case B
    assert not ops.quant_eligible((256,), 128)       # 1-D
    assert not ops.quant_eligible((100, 96), 128)    # partial blocks
    assert not ops.quant_eligible((128, 192), 128)   # inseparable scales


# ---------------------------------------------------------------------------
# partial tiles: cdiv overhang on explicit tile overrides
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nblocks,tile", [(5, 2), (7, 4), (3, 8)])
@pytest.mark.parametrize("block", [128, 1024])
def test_partial_tile_parity(nblocks, tile, block):
    """grid = cdiv(nblocks, tile) leaves an overhang tile; Pallas pads
    reads and clips writes, and per-row absmax makes padding inert -- the
    overhang result is bitwise the single-tile result for every kernel."""
    x = special_blocks(nblocks, block, seed=nblocks * 31 + tile)
    ck, cs = quantize_raw(x, block=block, interpret=True, tile_blocks=tile)
    wk, ws = ops.quantize(x, block)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(cs), np.asarray(ws))

    back = deq_into_raw(ck, cs, block=block, out_dtype=jnp.bfloat16,
                        interpret=True, tile_blocks=tile)
    wback = ops.dequantize_into(wk, ws, block, out_dtype=jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(wback))

    ef = rnd((nblocks * block,), seed=9, scale=1e-3)
    got = encode_ef_raw(x, ef, block=block, interpret=True, tile_blocks=tile)
    want = ops.encode_ef(x, ef, block)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# ValueError contract: kernel == reference, modulo the callee name
# ---------------------------------------------------------------------------

def _msg_body(err, who):
    s = str(err.value)
    assert s.startswith(who + ": "), s
    return s[len(who) + 2:]


def test_shape_errors_match_reference():
    x = rnd((100,), seed=0)  # 100 % 64 != 0
    with pytest.raises(ValueError) as k:
        ops.quantize(x, 64)
    with pytest.raises(ValueError) as r:
        quantize_blockwise(x, 64)
    assert _msg_body(k, "quantize") == _msg_body(r, "quantize_blockwise")

    codes = jnp.zeros((128,), jnp.int8)
    bad_scales = jnp.ones((3,), jnp.float32)  # want 2 blocks
    with pytest.raises(ValueError) as k:
        ops.dequantize_into(codes, bad_scales, 64, out_dtype=jnp.bfloat16)
    with pytest.raises(ValueError) as r:
        dequantize_blockwise(codes, bad_scales, 64)
    assert _msg_body(k, "dequantize") == _msg_body(
        r, "dequantize_blockwise")

    with pytest.raises(ValueError) as k:
        ops.quantize(x, 0)
    with pytest.raises(ValueError) as r:
        quantize_blockwise(x, 0)
    assert _msg_body(k, "quantize") == _msg_body(r, "quantize_blockwise")

    # encode_ef adds one contract of its own: ef must be ct-shaped f32
    ct = rnd((128,), seed=1)
    with pytest.raises(ValueError):
        ops.encode_ef(ct, rnd((64,), seed=2), 64)
    # q8_matmul shares both checks
    with pytest.raises(ValueError):
        ops.q8_matmul(rnd((4, 100), seed=3), jnp.zeros((100, 3), jnp.int8),
                      jnp.ones((1,)), 64)


# ---------------------------------------------------------------------------
# property sweeps
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from([jnp.float32, jnp.bfloat16]),
       st.sampled_from([128, 1024]), st.integers(1, 8),
       st.integers(0, 10_000))
def test_encode_ef_property(dtype, block, nblocks, seed):
    ct = special_blocks(nblocks, block, seed=seed, dtype=dtype)
    ef = rnd((nblocks * block,), seed=seed + 1, scale=1e-3)
    got = ops.encode_ef(ct, ef, block)
    want = jax.jit(ref.encode_ef_ref, static_argnums=2)(ct, ef, block)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # the zero block's compensated signal is just ef: residual must be
    # ef - decode(encode(ef)), finite either way
    assert np.isfinite(np.asarray(got[2])).all()


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([jnp.float32, jnp.bfloat16]),
       st.sampled_from([128, 1024]), st.integers(1, 8),
       st.integers(0, 10_000))
def test_dequantize_into_property(out_dtype, block, nblocks, seed):
    x = special_blocks(nblocks, block, seed=seed)
    codes, scales = ops.quantize(x, block)
    got = ops.dequantize_into(codes, scales, block, out_dtype=out_dtype)
    want = jax.jit(ref.dequantize_into_ref,
                   static_argnums=(2, 3))(codes, scales, block, out_dtype)
    assert got.dtype == jnp.dtype(out_dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# 8-device: deferred-EF microbatch + serve quant matmul on real shards
# ---------------------------------------------------------------------------

_DRIVER_8DEV = textwrap.dedent("""
    import os, json, dataclasses
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, build_model
    from repro.configs.base import ParallelConfig
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import APPROX_VARIANTS, CommSchedule
    from repro.optim import make_optimizer
    from repro.launch.mesh import make_local_mesh

    MESH8 = make_local_mesh(8, 1)
    out = {}

    # deferred-EF microbatch accumulation vs single-batch on 8-way shards
    def train(micro, steps=2):
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(cfg, n_layers=3, parallel=ParallelConfig(
            ("data",), ("data",), microbatches=micro))
        rt = FSDPRuntime(build_model(cfg), MESH8,
                         schedule=CommSchedule(reduce_wire="q8_block"),
                         donate=False)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)
        rng = np.random.default_rng(0)
        losses = []
        for _ in range(steps):
            batch = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
            params, state, st, m = fn(params, state, st, batch)
            losses.append(float(m["loss"]))
        return losses

    ref_l, acc_l = train(1), train(2)
    out["defer_finite"] = bool(np.isfinite(acc_l).all())
    out["defer_rel"] = max(abs(a - b) / max(1.0, abs(a))
                           for a, b in zip(ref_l, acc_l))

    # serve quant matmul vs dense-dequant q8 serve on 8-way shards
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)

    def prefill(sched):
        rt = FSDPRuntime(model, MESH8, schedule=sched)
        params = rt.init_params(0)
        cache = model.init_cache(8, 32)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (8, 8)), jnp.int32)}
        logits, _ = rt.make_prefill_step()(params, batch, cache)
        return np.asarray(logits, np.float32)

    ld = prefill(CommSchedule(param_store="q8_block"))
    lq = prefill(APPROX_VARIANTS["q8_serve_matmul"])
    out["serve_rel"] = float(np.linalg.norm(lq - ld) / np.linalg.norm(ld))
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_fused_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DRIVER_8DEV],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["defer_finite"], data
    assert data["defer_rel"] < 0.02, data
    assert data["serve_rel"] < 0.15, data
