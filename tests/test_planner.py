"""Planner (Algorithm 1) correctness: constraints, optimality vs brute force,
baseline planners, and the paper's qualitative claims (padding < few %)."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.planner import (
    check_valid_shard,
    plan_exact,
    plan_fsdp2,
    plan_group,
    plan_megatron,
    plan_naive,
    straddled_blocks,
)
from repro.core.ragged import GroupPlan, TensorSpec, row_granularity


def specs(*sized):
    """sized: list of (size, granularity)"""
    return [
        TensorSpec(f"t{i}", (s,), granularity=g) for i, (s, g) in enumerate(sized)
    ]


# ---------------------------------------------------------------------------
# basic feasibility + constraint validation
# ---------------------------------------------------------------------------

def test_single_tensor_even():
    plan = plan_group(specs((1024, 1)), 4, g_coll=1)
    assert plan.shard_size == 256
    assert plan.padding == 0
    plan.validate()


def test_block_alignment_forces_padding():
    # 3 blocks of 100 over 2 devices: S=150 would split a block; S=200 works.
    plan = plan_group(specs((300, 100)), 2, g_coll=1)
    plan.validate()
    assert plan.shard_size in (200, 300)
    assert straddled_blocks(plan) == 0


def test_ragged_distribution_is_uneven():
    # one tensor of 3 blocks x 100 over 2 devices at S=200: dev0 gets 2 blocks,
    # dev1 gets 1 -- the ragged distribution of the paper's Fig. 4.
    plan = plan_group(specs((300, 100)), 2, g_coll=1)
    counts = plan.blocks_per_device()
    per_dev = [c.get("t0", 0) for c in counts]
    assert sum(per_dev) == 3
    assert max(per_dev) != min(per_dev)  # genuinely ragged


def test_padding_between_not_within():
    plan = plan_group(specs((96, 32), (96, 32), (64, 1)), 2, g_coll=1)
    plan.validate()  # contiguity is asserted inside validate()
    assert straddled_blocks(plan) == 0


def test_lane_alignment_default():
    plan = plan_group(specs((1000, 1), (777, 1)), 4)
    assert plan.shard_size % 128 == 0  # g_coll = LANE


def test_align_option_aligns_starts():
    plan = plan_group(
        specs((1024, 256), (100, 1), (512, 256)), 2, g_coll=1, align=256
    )
    plan.validate()
    for p in plan.placements:
        assert p.offset % 256 == 0
    assert plan.shard_size % 256 == 0


def test_infeasible_block_bigger_than_everything_is_still_planned():
    # single block of 1000 on 4 devices: S must be >= 1000 (block can't split)
    plan = plan_group(specs((1000, 1000)), 4, g_coll=1)
    assert plan.shard_size >= 1000


# ---------------------------------------------------------------------------
# exactness vs brute force (Hypothesis property tests)
# ---------------------------------------------------------------------------

@st.composite
def small_instances(draw):
    m = draw(st.integers(2, 4))
    n = draw(st.integers(1, 4))
    ts = []
    for i in range(n):
        g = draw(st.sampled_from([1, 2, 3, 4, 5, 8]))
        blocks = draw(st.integers(1, 6))
        ts.append(TensorSpec(f"t{i}", (g * blocks,), granularity=g))
    return ts, m


@settings(max_examples=120, deadline=None)
@given(small_instances())
def test_heuristic_vs_exact(inst):
    ts, m = inst
    heur = plan_group(ts, m, g_coll=1)
    heur.validate()
    exact = plan_exact(ts, m, g_coll=1, max_S=heur.shard_size)
    # heuristic is feasible and within 2x of the true optimum (paper: 2-approx;
    # in practice near-optimal). exact may beat it via permutations we fix.
    assert heur.shard_size >= exact.shard_size
    assert heur.shard_size <= 2 * exact.shard_size + max(t.granularity for t in ts)


@settings(max_examples=100, deadline=None)
@given(small_instances(), st.integers(1, 64))
def test_greedy_placement_matches_dfs_feasibility(inst, S):
    """For fixed order+S, earliest-feasible greedy == exhaustive placement."""
    ts, m = inst
    greedy_ok = check_valid_shard(ts, S, m)

    def dfs(i, pos):
        if i == len(ts):
            return True
        t = ts[i]
        for l in range(pos, m * S - t.size + 1):
            ok = all(
                (k * S - l) % t.granularity == 0
                for k in range(l // S + 1, (l + t.size - 1) // S + 1)
            )
            if ok and dfs(i + 1, l + t.size):
                return True
        return False

    assert greedy_ok == dfs(0, 0)


@settings(max_examples=60, deadline=None)
@given(small_instances())
def test_feasibility_monotone_in_S(inst):
    ts, m = inst
    plan = plan_group(ts, m, g_coll=1)
    S = plan.shard_size
    g = math.lcm(*[t.granularity for t in ts])
    # paper's monotonicity claim over multiples of the LCM
    assert check_valid_shard(ts, S + g, m)


# ---------------------------------------------------------------------------
# baseline planners reproduce the systems' pathologies
# ---------------------------------------------------------------------------

def test_fsdp2_pads_small_tensors():
    # 100 tiny biases on 256 devices: FSDP2 pads each to 256 -> huge inflation
    ts = [TensorSpec(f"b{i}", (8,)) for i in range(100)]
    f2 = plan_fsdp2(ts, 256)
    rg = plan_group(ts, 256, g_coll=1)
    assert f2.padding_ratio > 10  # catastrophic
    assert rg.padding_ratio < 1.0


def test_megatron_row_padding_inflation():
    # odd expert matrices: row padding to device count inflates the buffer
    ts = [TensorSpec(f"w{i}", (3, 1000), granularity=1) for i in range(4)]
    mg = plan_megatron(ts, 8)
    rg = plan_group(ts, 8, g_coll=1)
    assert mg.padding > rg.padding


def test_naive_straddles_blocks():
    ts = specs((300, 100), (500, 100))
    nv = plan_naive(ts, 3, g_coll=1)
    rg = plan_group(ts, 3, g_coll=1)
    assert straddled_blocks(nv) > 0
    assert straddled_blocks(rg) == 0


# ---------------------------------------------------------------------------
# transformer-shaped workload: padding stays small (paper Fig. 11: <3%)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [8, 64, 256])
@pytest.mark.parametrize("rows", [1, 16, 128])
def test_padding_small_on_transformer_layer(m, rows):
    d, f = 2048, 8192
    ts = []
    for name, shape in [
        ("wq", (d, d)), ("wk", (d, d // 4)), ("wv", (d, d // 4)), ("wo", (d, d)),
        ("w1", (f, d)), ("w2", (d, f)), ("w3", (f, d)),
        ("ln1", (d,)), ("ln2", (d,)),
    ]:
        g = row_granularity(shape, rows) if len(shape) == 2 else 1
        g = min(g, int(np.prod(shape)))
        if int(np.prod(shape)) % g:
            g = 1
        ts.append(TensorSpec(name, shape, granularity=g))
    plan = plan_group(ts, m)
    plan.validate()
    assert straddled_blocks(plan) == 0
    # Paper Fig. 11: mostly <~3%, with LCM-rounding spikes at coarse
    # granularity x large device counts.  When the number of blocks
    # approaches the device count the paper's §6.4 guideline kicks in
    # (cap the FSDP group size, scale by HSDP) -- padding blows up by design.
    max_g = max(t.granularity for t in ts)
    if plan.payload / m < 2 * max_g:
        # ideal shard barely holds a couple of blocks: the blow-up regime the
        # paper's guideline avoids via HSDP; feasible + intact is enough.
        assert plan.padding_ratio >= 0.0
    elif rows == 1:
        assert plan.padding_ratio < 0.05, plan.padding_ratio
    else:
        assert plan.padding_ratio < 0.20, plan.padding_ratio


def test_order_variants_run():
    ts = specs((300, 100), (500, 100), (64, 1))
    for order in ("default", "by_granularity", "by_size"):
        p = plan_group(ts, 4, g_coll=1, order=order)
        p.validate()


def test_planner_runtime_at_scale():
    """Paper §6.4: planning is sub-second even for hundreds of tensors and
    hundreds of shards."""
    rng = np.random.default_rng(0)
    ts = []
    for i in range(300):
        rows = int(rng.integers(1, 64)) * 16
        cols = int(rng.integers(1, 64)) * 128
        ts.append(TensorSpec(f"w{i}", (rows, cols), granularity=cols * 16))
    plan = plan_group(ts, 512)
    plan.validate()
    assert plan.stats.plan_seconds < 5.0
