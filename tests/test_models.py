"""Model-layer correctness: chunked attention vs naive softmax oracle,
sliding windows, ring cache, SSM scans vs sequential reference, MoE
dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers as L
from repro.models.moe import _positions_within_expert, moe_ffn
from repro.models.ssm import diagonal_scan


def naive_attention(q, k, v, q_pos, kv_pos, kv_valid=None, window=None,
                    softcap=None):
    B, Hq, Tq, hd = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(hd)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones(s.shape, bool)
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, :]
    if q_pos is not None:
        qp = q_pos[:, None, :, None]
        kp = kv_pos[:, None, None, :]
        mask &= kp <= qp
        if window is not None:
            mask &= qp - kp < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))


def rnd(shape, seed):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("Tq,Tk,chunk", [(16, 16, 4), (8, 24, 5), (1, 32, 8),
                                         (32, 32, 32)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_chunked_attention_matches_naive(Tq, Tk, chunk, hq, hkv):
    B, hd = 2, 16
    q, k, v = rnd((B, hq, Tq, hd), 1), rnd((B, hkv, Tk, hd), 2), rnd(
        (B, hkv, Tk, hd), 3)
    q_pos = jnp.broadcast_to(
        jnp.arange(Tk - Tq, Tk, dtype=jnp.int32)[None], (B, Tq))
    kv_pos = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32)[None], (B, Tk))
    got = L.chunked_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                              chunk=chunk)
    want = naive_attention(q, k, v, q_pos, kv_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [1, 3, 8])
def test_sliding_window(window):
    B, H, T, hd = 1, 2, 24, 8
    q, k, v = rnd((B, H, T, hd), 4), rnd((B, H, T, hd), 5), rnd(
        (B, H, T, hd), 6)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    got = L.chunked_attention(q, k, v, q_pos=pos, kv_pos=pos, window=window,
                              chunk=7)
    want = naive_attention(q, k, v, pos, pos, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_softcap():
    B, H, T, hd = 1, 1, 12, 8
    q, k, v = rnd((B, H, T, hd), 7), rnd((B, H, T, hd), 8), rnd(
        (B, H, T, hd), 9)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    got = L.chunked_attention(q, k, v, q_pos=pos, kv_pos=pos, softcap=5.0,
                              chunk=4)
    want = naive_attention(q, k, v, pos, pos, softcap=5.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_ring_cache_decode_equals_full_context():
    """Decoding with a wrap-around ring cache == attention over the last W
    positions of the full sequence (the long_500k mechanism)."""
    B, H, hd, W, T = 1, 2, 8, 8, 20

    class Cfg:
        hd = 8
        n_heads = 2
        n_kv_heads = 2
        qkv_bias = False
        rope_theta = 1e4
        attn_softcap = None

    p = {
        "wq": rnd((H * hd, H * hd), 11) * 0.2,
        "wk": rnd((H * hd, H * hd), 12) * 0.2,
        "wv": rnd((H * hd, H * hd), 13) * 0.2,
        "wo": rnd((H * hd, H * hd), 14) * 0.2,
    }
    xs = rnd((B, T, H * hd), 15)
    cache = {
        "k": jnp.zeros((B, H, W, hd)), "v": jnp.zeros((B, H, W, hd)),
        "pos": jnp.full((B, W), -1, jnp.int32),
    }
    outs = []
    for t in range(T):
        q_pos = jnp.full((B, 1), t, jnp.int32)
        o, cache = L.attention(Cfg, p, xs[:, t:t + 1], q_pos=q_pos,
                               cache=cache, cache_index=t, window=W)
        outs.append(o)
    # reference: full K/V, window-masked
    ref_cache = {
        "k": jnp.zeros((B, H, T, hd)), "v": jnp.zeros((B, H, T, hd)),
        "pos": jnp.full((B, T), -1, jnp.int32),
    }
    refs = []
    for t in range(T):
        q_pos = jnp.full((B, 1), t, jnp.int32)
        o, ref_cache = L.attention(Cfg, p, xs[:, t:t + 1], q_pos=q_pos,
                                   cache=ref_cache, cache_index=t, window=W)
        refs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)),
        np.asarray(jnp.concatenate(refs, 1)), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# SSM scans
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 7), st.integers(0, 1000))
def test_diagonal_scan_matches_sequential(T, chunk, seed):
    B, D = 2, 3
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (B, T, D)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(B, T, D)).astype(np.float32))
    hs, h_last = diagonal_scan(a, b, chunk=chunk)
    h = np.zeros((B, D), np.float32)
    seq = []
    for t in range(T):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        seq.append(h.copy())
    np.testing.assert_allclose(np.asarray(hs), np.stack(seq, 1), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), seq[-1], rtol=1e-4,
                               atol=1e-5)


def test_diagonal_scan_carry_composes():
    """prefill(T) then decode(1) == prefill(T+1) -- the serve-path contract."""
    B, T, D = 1, 9, 4
    a = jnp.asarray(np.random.default_rng(0).uniform(0.6, 1, (B, T + 1, D)),
                    jnp.float32)
    b = rnd((B, T + 1, D), 1)
    full, _ = diagonal_scan(a, b, chunk=4)
    part, h = diagonal_scan(a[:, :T], b[:, :T], chunk=4)
    step, _ = diagonal_scan(a[:, T:], b[:, T:], h0=h, chunk=4)
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, T]), rtol=1e-4, atol=1e-5)


def test_mlstm_chunk_invariance():
    """mLSTM chunked form must not depend on the chunk size."""
    from repro.models.ssm import mlstm_mix

    class Cfg:
        n_heads = 2
        norm_eps = 1e-6

    B, T, D = 1, 33, 16
    p = {f"m_{n}": rnd((D, sz), i) * 0.3 for i, (n, sz) in enumerate(
        [("wq", D), ("wk", D), ("wv", D), ("wog", D), ("wo", D)])}
    p["m_wgate"] = rnd((D, 4), 9) * 0.3
    x = rnd((B, T, D), 10)
    # monkey-run with different chunk sizes by slicing T
    out1, st1 = mlstm_mix(Cfg, p, x)
    # sequential: feed one token at a time carrying state
    st = None
    outs = []
    for t in range(T):
        o, st = mlstm_mix(Cfg, p, x[:, t:t + 1], state=st)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(out1), np.asarray(jnp.concatenate(outs, 1)),
        rtol=5e-3, atol=5e-3)


def test_slstm_sequential_state():
    from repro.models.ssm import slstm_mix

    class Cfg:
        n_heads = 2
        norm_eps = 1e-6

    B, T, D = 2, 11, 8
    p = {"s_w_zifo": rnd((D, 4 * D), 1) * 0.4, "s_wo": rnd((D, D), 2) * 0.4}
    x = rnd((B, T, D), 3)
    full, stf = slstm_mix(Cfg, p, x)
    st = None
    outs = []
    for t in range(T):
        o, st = slstm_mix(Cfg, p, x[:, t:t + 1], state=st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(stf["c"]), np.asarray(st["c"]),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 40), st.integers(2, 8), st.integers(0, 500))
def test_positions_within_expert(n, E, seed):
    rng = np.random.default_rng(seed)
    fe = jnp.asarray(rng.integers(0, E, (n,)), jnp.int32)
    rank = np.asarray(_positions_within_expert(fe, E))
    fe_np = np.asarray(fe)
    for e in range(E):
        idx = np.nonzero(fe_np == e)[0]
        assert (np.sort(rank[idx]) == np.arange(len(idx))).all()


def test_moe_capacity_drops_and_combines():
    class Cfg:
        n_experts = 4
        top_k = 2
        capacity_factor = 1.0
        moe_aux_coef = 0.01
        mlp = "swiglu"

    B, T, D, F, E = 2, 8, 16, 32, 4
    p = {
        "moe_router": rnd((D, E), 1) * 0.3,
        "moe_w1": rnd((E, D, F), 2) * 0.2,
        "moe_w3": rnd((E, D, F), 3) * 0.2,
        "moe_w2": rnd((E, F, D), 4) * 0.2,
    }
    x = rnd((B, T, D), 5)
    out, aux = moe_ffn(Cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0
    # zero capacity factor edge: everything dropped -> output ~0
    Cfg.capacity_factor = 1e-9
    out0, _ = moe_ffn(Cfg, p, x)
    # cap >= 1 always, so at most E tokens survive; most are dropped
    assert np.abs(np.asarray(out0)).mean() < np.abs(np.asarray(out)).mean()


# ---------------------------------------------------------------------------
# vocab-parallel CE vs direct
# ---------------------------------------------------------------------------

def test_ce_matches_direct():
    B, T, V = 2, 6, 37
    logits = rnd((B, T, V), 1)
    labels = jnp.asarray(np.random.default_rng(2).integers(0, V, (B, T)),
                         jnp.int32)
    mask = jnp.ones((B, T), jnp.float32)
    nll, w = L.vocab_parallel_ce(logits, labels, mask)
    lp = jax.nn.log_softmax(np.asarray(logits, np.float32), axis=-1)
    want = -np.take_along_axis(np.asarray(lp), np.asarray(labels)[..., None],
                               -1).sum()
    np.testing.assert_allclose(float(nll), float(want), rtol=1e-5)
    assert float(w) == B * T


def test_optimized_profile_training_parity():
    """The beyond-paper optimized profile (attn_chunk 512 + chunked CE) must
    be a pure performance change: losses match the baseline path closely."""
    import dataclasses

    import jax.numpy as jnp

    from repro.configs import build_model, get_config
    from repro.core.fsdp import FSDPRuntime
    from repro.launch.mesh import make_local_mesh
    from repro.optim import make_optimizer

    mesh = make_local_mesh(1, 1)

    def run(cfg):
        model = build_model(cfg)
        rt = FSDPRuntime(model, mesh)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)
        b = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (4, 32)),
            jnp.int32)}
        losses = []
        for _ in range(3):
            params, state, st, m = fn(params, state, st, b)
            losses.append(float(m["loss"]))
        return losses

    base_cfg = get_config("gemma2-2b").reduced()  # exercises final_softcap too
    opt_cfg = dataclasses.replace(base_cfg, attn_chunk=8, ce_chunk=64)
    base, opt = run(base_cfg), run(opt_cfg)
    for a, b in zip(base, opt):
        assert abs(a - b) < 2e-2, (base, opt)
