"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def rnd(shape, seed=0, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(dtype))


# ---------------------------------------------------------------------------
# blockwise quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block", [
    (1024, 128), (4096, 1024), (8192, 1024), (2048, 256), (512, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(n, block, dtype):
    x = rnd((n,), seed=n, dtype=np.float32).astype(dtype)
    ck, cs = ops.quantize(x, block=block)
    rk, rs = ref.quantize_ref(x, block)
    diff = np.abs(np.asarray(ck, np.int32) - np.asarray(rk, np.int32))
    if dtype == jnp.float32:
        assert (diff == 0).all()
    else:
        # bf16 inputs: scale-path rounding can flip a .5 tie by one code
        assert diff.max() <= 1 and (diff != 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(cs), np.asarray(rs), rtol=1e-6)


@pytest.mark.parametrize("lead", [(), (3,), (2, 5)])
def test_quantize_leading_dims(lead):
    x = rnd(lead + (2048,), seed=7)
    ck, cs = ops.quantize(x, block=256)
    rk, rs = ref.quantize_ref(x, 256)
    assert ck.shape == x.shape and cs.shape == lead + (8,)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(rk))


def test_quant_dequant_roundtrip_error_bounded():
    x = rnd((8192,), seed=3, scale=2.0)
    ck, cs = ops.quantize(x, block=1024)
    back = ops.dequantize(ck, cs, block=1024)
    # int8 symmetric: error <= scale/2 = absmax/254 per block
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.repeat(np.asarray(cs), 1024) / 2 + 1e-7
    assert (err <= bound).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 16), st.sampled_from([128, 256, 1024]),
       st.integers(0, 10_000))
def test_quantize_property(nblocks, block, seed):
    x = rnd((nblocks * block,), seed=seed)
    ck, cs = ops.quantize(x, block=block)
    rk, rs = ref.quantize_ref(x, block)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(cs), np.asarray(rs), rtol=1e-6)
    # invariant: dequantized absmax reproduces the original per block
    back = ops.dequantize(ck, cs, block=block).reshape(nblocks, block)
    orig = np.asarray(x).reshape(nblocks, block)
    np.testing.assert_allclose(
        np.abs(back).max(1), np.abs(orig).max(1), rtol=1e-2, atol=1e-6)


# ---------------------------------------------------------------------------
# fused adamw
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 8192, 128 * 65])
def test_adamw_kernel_matches_ref(n):
    n = (n // 128) * 128
    w, g = rnd((n,), 1), rnd((n,), 2)
    m, v = rnd((n,), 3, scale=0.1), jnp.abs(rnd((n,), 4, scale=0.01))
    mask = (rnd((n,), 5) > 0).astype(jnp.float32)
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, c1=0.5, c2=0.25)
    w2, m2, v2 = ops.adamw_update(w, g, m, v, mask, **kw)
    rw, rm, rv = ref.adamw_update_ref(w, g, m, v, mask, *kw.values())
    np.testing.assert_allclose(np.asarray(w2), np.asarray(rw), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(rm), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(rv), rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# fused 8-bit adam
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block", [(4096, 1024), (2048, 256), (1024, 128)])
def test_adam8bit_kernel_matches_ref(n, block):
    nb = n // block
    w, g = rnd((n,), 1), rnd((n,), 2)
    m0 = rnd((n,), 3, scale=0.1)
    v0 = jnp.abs(rnd((n,), 4, scale=0.01))
    m8, ms = ops.quantize(m0, block=block)
    v8, vs = ops.quantize(v0, block=block)
    mask = jnp.ones((n,), jnp.float32)
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, c1=0.5, c2=0.25)
    outs = ops.adam8bit_update(w, g, m8, v8, ms, vs, mask, block=block, **kw)
    refs = ref.adam8bit_update_ref(w, g, m8, v8, ms, vs, mask,
                                   *kw.values(), block)
    for o, r, name in zip(outs, refs, ["w", "m8", "v8", "ms", "vs"]):
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(r, np.float32),
            rtol=1e-5, atol=1e-6, err_msg=name)


def test_adam8bit_zero_state_bootstraps():
    n, block = 2048, 1024
    w, g = rnd((n,), 1), rnd((n,), 2)
    z8 = jnp.zeros((n,), jnp.int8)
    zs = jnp.zeros((n // block,), jnp.float32)
    mask = jnp.zeros((n,), jnp.float32)
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, c1=0.1, c2=0.05)
    w2, m8, v8, ms, vs = ops.adam8bit_update(
        w, g, z8, v8=z8, ms=zs, vs=zs, mask=mask, block=block, **kw)
    assert np.isfinite(np.asarray(w2)).all()
    assert (np.asarray(ms) > 0).all()  # moments materialized
