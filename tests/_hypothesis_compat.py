"""Property-testing shim: real hypothesis when installed, fixed-seed
example sampling otherwise.

Tests import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly.  With hypothesis installed the tests stay fully
property-based (shrinking, database, the works); without it, ``@given``
replays ``max_examples`` deterministic samples drawn from a seed derived
from the test name -- no shrinking, but the same strategy surface, so the
suite runs on a bare ``pip install jax pytest`` image.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import math
    import random
    import types
    import zlib

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A sampler: ``sample(rng) -> value``."""

        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._sample(rng)))

        def filter(self, pred):
            def sample(rng):
                for _ in range(10_000):
                    v = self._sample(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate never satisfied")

            return _Strategy(sample)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **_kw):
        # log-uniform over positive ranges (hypothesis explores magnitudes,
        # plain uniform over e.g. (1e-8, 1e4) would never sample small)
        if min_value > 0 and max_value / min_value > 1e3:
            lo, hi = math.log(min_value), math.log(max_value)
            return _Strategy(lambda rng: math.exp(rng.uniform(lo, hi)))
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _just(value):
        return _Strategy(lambda rng: value)

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _one_of(*strats):
        return _Strategy(lambda rng: rng.choice(strats).sample(rng))

    def _lists(elements, min_size=0, max_size=10, **_kw):
        def sample(rng):
            n = rng.randint(min_size, max_size)
            return [elements.sample(rng) for _ in range(n)]

        return _Strategy(sample)

    def _tuples(*strats):
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))

    def _composite(f):
        @functools.wraps(f)
        def builder(*args, **kwargs):
            def sample(rng):
                return f(lambda s: s.sample(rng), *args, **kwargs)

            return _Strategy(sample)

        return builder

    strategies = types.SimpleNamespace(
        integers=_integers,
        floats=_floats,
        booleans=_booleans,
        just=_just,
        sampled_from=_sampled_from,
        one_of=_one_of,
        lists=_lists,
        tuples=_tuples,
        composite=_composite,
    )
    st = strategies

    class settings:  # noqa: N801 -- mirrors hypothesis' decorator name
        def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None,
                     **_kw):
            self.max_examples = max_examples

        def __call__(self, f):
            f._compat_settings = self
            return f

    def given(*strats, **kwstrats):
        def decorator(f):
            # like hypothesis, drawn args fill the rightmost positional
            # parameters; anything left of them is a pytest fixture
            params = list(inspect.signature(f).parameters.values())
            drawn_names = [p.name for p in params[len(params) - len(strats):]]

            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_compat_settings", None)
                n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
                rng = random.Random(zlib.crc32(f.__qualname__.encode()))
                for _ in range(n):
                    # bind drawn values by name: fixtures arrive in kwargs,
                    # so positional splicing would mis-assign them
                    kdrawn = {k: s.sample(rng)
                              for k, s in zip(drawn_names, strats)}
                    kdrawn.update(
                        (k, s.sample(rng)) for k, s in kwstrats.items())
                    f(*args, **kwargs, **kdrawn)

            # hide the drawn parameters from pytest's fixture resolution
            keep = params[: len(params) - len(strats)]
            keep = [p for p in keep if p.name not in kwstrats]
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature(keep)
            return wrapper

        return decorator
