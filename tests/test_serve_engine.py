"""Continuous-batching engine: requests complete, outputs match a
straight-line (single-request) decode of the same prompts."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.launch.mesh import make_local_mesh
from repro.serve.engine import Request, ServeEngine

MESH = make_local_mesh(1, 1)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH)
    params = rt.init_params(0)
    return cfg, model, rt, params


def _straightline(cfg, model, rt, params, prompt, max_new, pool=1,
                  max_len=64):
    """Reference: single-slot engine (no batching interference)."""
    eng = ServeEngine(rt, model, params, pool=pool, max_len=max_len)
    req = Request(uid=0, prompt=prompt, max_new=max_new)
    eng.submit(req)
    eng.run()
    return req.out


def test_engine_completes_all_requests(setup):
    cfg, model, rt, params = setup
    rng = np.random.default_rng(0)
    eng = ServeEngine(rt, model, params, pool=2, max_len=64)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, (4 + i,)).astype(
            np.int32), max_new=5)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    finished = eng.run()
    assert len(finished) == 5
    for r in reqs:
        assert r.done and len(r.out) == 5
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_engine_matches_straightline(setup):
    """Continuous batching must not change any request's tokens (slots are
    independent cache rows)."""
    cfg, model, rt, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (3, 5)]
    want = [_straightline(cfg, model, rt, params, p, 4, pool=2)
            for p in prompts]
    eng = ServeEngine(rt, model, params, pool=2, max_len=64)
    reqs = [Request(uid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, w in zip(reqs, want):
        assert r.out == w, (r.out, w)
