"""Continuous-batching engine: requests complete, outputs match a
straight-line (single-request) decode of the same prompts."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.schedule import APPROX_VARIANTS, CommSchedule
from repro.launch.mesh import make_local_mesh
from repro.serve.engine import Request, ServeEngine

MESH = make_local_mesh(1, 1)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH)
    params = rt.init_params(0)
    return cfg, model, rt, params


def _straightline(cfg, model, rt, params, prompt, max_new, pool=1,
                  max_len=64):
    """Reference: single-slot engine (no batching interference)."""
    eng = ServeEngine(rt, model, params, pool=pool, max_len=max_len)
    req = Request(uid=0, prompt=prompt, max_new=max_new)
    eng.submit(req)
    eng.run()
    return req.out


def test_engine_completes_all_requests(setup):
    cfg, model, rt, params = setup
    rng = np.random.default_rng(0)
    eng = ServeEngine(rt, model, params, pool=2, max_len=64)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, (4 + i,)).astype(
            np.int32), max_new=5)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    finished = eng.run()
    assert len(finished) == 5
    for r in reqs:
        assert r.done and len(r.out) == 5
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_engine_matches_straightline(setup):
    """Continuous batching must not change any request's tokens (slots are
    independent cache rows)."""
    cfg, model, rt, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (3, 5)]
    want = [_straightline(cfg, model, rt, params, p, 4, pool=2)
            for p in prompts]
    eng = ServeEngine(rt, model, params, pool=2, max_len=64)
    reqs = [Request(uid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, w in zip(reqs, want):
        assert r.out == w, (r.out, w)


def test_quant_matmul_serve_tracks_dense_q8():
    """serve_quant_matmul keeps eligible q8_block weights as int8 through
    the matmuls (ops.q8_matmul) instead of dequantizing every gather.  The
    only new error vs the dense-dequant q8 serve is the per-row activation
    quantization, so prefill logits must stay close (ALLCLOSE parity
    class) and the engine must still complete requests."""
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)

    def prefill_logits(sched):
        rt = FSDPRuntime(model, MESH, schedule=sched)
        params = rt.init_params(0)
        cache = model.init_cache(2, 32)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)}
        logits, _ = rt.make_prefill_step()(params, batch, cache)
        return rt, params, np.asarray(logits, np.float32)

    _, _, dense = prefill_logits(CommSchedule(param_store="q8_block"))
    rt, params, quant = prefill_logits(APPROX_VARIANTS["q8_serve_matmul"])
    err = np.linalg.norm(quant - dense) / np.linalg.norm(dense)
    assert err < 0.15, err
    # the schedule knob survives the policy/plan round-trip
    assert rt.schedule.serve_quant_matmul

    eng = ServeEngine(rt, model, params, pool=2, max_len=64)
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, (3 + i,)).astype(
        np.int32), max_new=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done and len(r.out) == 4
        assert all(0 <= t < cfg.vocab for t in r.out)
