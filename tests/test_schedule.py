"""CommSchedule correctness: every schedule variant is a pure reordering /
re-materialization of the same collectives, so on one device all variants
must produce bitwise-identical training trajectories; the manual ring
(ppermute) gather mode must match the xla collectives bitwise on any device
count; and prefetch's two-slot double buffer must never place a gathered
layer buffer in a scan carry (the per-layer retention bug)."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.schedule import (GROUP_OVERRIDE_KEYS, VARIANTS, CommSchedule,
                                 resolve_group_schedules, sharded_gather)
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

MESH = make_local_mesh(1, 1)


def _build_runtime(schedule, arch="qwen2.5-14b", planner="ragged",
                   n_layers=None, group_schedules=None):
    cfg = get_config(arch).reduced()  # 2 layers: exercises keep_last split
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH, planner=planner, schedule=schedule,
                     donate=False, group_schedules=group_schedules)
    return cfg, rt


def _train(schedule, steps=3, arch="qwen2.5-14b", planner="ragged",
           n_layers=None, group_schedules=None):
    cfg, rt = _build_runtime(schedule, arch=arch, planner=planner,
                             n_layers=n_layers,
                             group_schedules=group_schedules)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)
    rng = np.random.default_rng(0)
    out = []
    for i in range(steps):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        params, state, st, m = fn(params, state, st, batch)
        out.append((float(m["loss"]), float(m["grad_norm"])))
    finals = {k: np.asarray(v) for k, v in params.items()}
    return out, finals


def _assert_same(ref, tst, msg):
    ref_metrics, ref_params = ref
    metrics, params = tst
    for (rl, rg), (tl, tg) in zip(ref_metrics, metrics):
        assert np.float32(rl).tobytes() == np.float32(tl).tobytes(), (
            msg, ref_metrics, metrics)
        assert np.float32(rg).tobytes() == np.float32(tg).tobytes(), (
            msg, ref_metrics, metrics)
    for k in ref_params:
        np.testing.assert_array_equal(ref_params[k], params[k], err_msg=(
            f"{msg}: params[{k}] diverged"))


@pytest.fixture(scope="module")
def reference():
    return _train(CommSchedule.default())


@pytest.mark.parametrize("name", [k for k in VARIANTS if k != "default"])
def test_schedule_parity_bitwise(name, reference):
    """Prefetch / reshard / keep-last / dtype / ring variants:
    bitwise-identical loss, grad-norm, and final params vs. the default
    schedule."""
    _assert_same(reference, _train(VARIANTS[name]), name)


@pytest.mark.parametrize("name", [k for k in VARIANTS
                                  if VARIANTS[k].gather_mode == "xla"])
def test_ring_twin_parity_bitwise(name, reference):
    """The ring twin of every xla variant stays bitwise-identical."""
    ring = dataclasses.replace(VARIANTS[name], gather_mode="ring")
    _assert_same(reference, _train(ring), f"ring:{name}")


@pytest.mark.parametrize("n", [1, 2, 3, 5])
def test_prefetch_keep_last_edge_layer_counts(n):
    """Small-n fallbacks (LayerPlan): n=1 runs keep_last's un-rematted
    path with an empty main scan; n=2+keep_last leaves one main layer (no
    pairing); n=3 pairs without a tail; n=5 pairs with a tail.  All must
    stay bitwise-identical to the sequential default."""
    ref = _train(CommSchedule.default(), steps=2, n_layers=n)
    tst = _train(VARIANTS["overlap_all"], steps=2, n_layers=n)
    _assert_same(ref, tst, f"overlap_all n={n}")


def test_schedule_parity_fsdp2_planner():
    """Schedule variants stay exact under the FSDP2 (interleaved) layout."""
    ref = _train(CommSchedule.default(), planner="fsdp2")
    tst = _train(VARIANTS["ring_overlap"], planner="fsdp2")
    _assert_same(ref, tst, "fsdp2:ring_overlap")


def test_group_schedule_overrides_parity(reference):
    """Per-group overrides (unsharded globals, fp32-reduce + ring layers)
    are pure comm-path changes: bitwise-identical on one device."""
    tst = _train(CommSchedule.default(), group_schedules={
        "globals": {"sharded": False},
        "layers": {"reduce_dtype": "fp32", "gather_mode": "ring"},
    })
    _assert_same(reference, tst, "group_overrides")


def test_layer_plan_edges():
    s = CommSchedule(prefetch=True, keep_last_gathered=True)
    p = s.plan_layers(1)
    assert (p.main, p.split_last, p.prefetch) == (0, True, False)
    p = s.plan_layers(2)
    assert (p.main, p.split_last, p.prefetch) == (1, True, False)
    p = s.plan_layers(3)
    assert (p.main, p.pairs, p.tail, p.split_last) == (2, 1, 0, True)
    p = s.plan_layers(6)
    assert (p.main, p.pairs, p.tail) == (5, 2, 1)
    # keep_last needs remat (+reshard): without it the main scan keeps all
    p = s.plan_layers(4, remat=False)
    assert (p.main, p.split_last, p.pairs) == (4, False, 2)
    p = CommSchedule(prefetch=True).plan_layers(2)
    assert (p.main, p.pairs, p.tail, p.split_last) == (2, 1, 0, False)
    p = CommSchedule(prefetch=True,
                     reshard_after_forward=False).plan_layers(3)
    assert (p.split_last, p.prefetch) == (False, True)


def test_schedule_validation():
    with pytest.raises(ValueError):
        CommSchedule(gather_mode="nccl")
    with pytest.raises(ValueError):
        CommSchedule(gather_dtype="fp16")
    base = CommSchedule.default()
    with pytest.raises(ValueError):
        resolve_group_schedules(base, {"globals": {"prefetch": True}})
    assert "prefetch" not in GROUP_OVERRIDE_KEYS
    # whole CommSchedule instances would smuggle structure knobs through
    with pytest.raises(ValueError):
        resolve_group_schedules(base, {"globals": CommSchedule(prefetch=True)})
    got = resolve_group_schedules(base, {"globals": {"sharded": False}})
    assert got["globals"].sharded is False and got["globals"].prefetch is False
    # overrides naming groups the model doesn't have fail at runtime init
    cfg = get_config("qwen2.5-14b").reduced()
    with pytest.raises(ValueError):
        FSDPRuntime(build_model(cfg), MESH,
                    group_schedules={"global": {"sharded": False}})


def test_validate_for_compute_dtype():
    """A None gather_dtype inherits the compute dtype; an unsupported
    compute dtype must fail at runtime construction, not at trace time."""
    with pytest.raises(ValueError):
        CommSchedule().validate_for(jnp.float16)
    CommSchedule().validate_for(jnp.bfloat16)
    CommSchedule(gather_dtype="bf16").validate_for(jnp.float16)  # pinned: ok
    cfg = get_config("qwen2.5-14b").reduced()
    with pytest.raises(ValueError):
        FSDPRuntime(build_model(cfg), MESH, compute_dtype=jnp.float16)


def test_default_schedule_from_config():
    cfg = get_config("qwen2.5-14b").reduced()
    assert CommSchedule.from_config(cfg) == CommSchedule.default()
    par = dataclasses.replace(cfg.parallel, prefetch=True,
                              reduce_dtype="fp32", gather_mode="ring")
    cfg = dataclasses.replace(cfg, parallel=par)
    sched = CommSchedule.from_config(cfg)
    assert sched.prefetch and sched.reduce_dtype == "fp32"
    assert sched.gather_mode == "ring"


def test_wire_and_accum_dtype_resolution():
    cd = jnp.dtype(jnp.bfloat16)
    s = CommSchedule()
    assert s.wire_dtype(cd) == jnp.bfloat16
    assert s.accum_dtype(cd) == jnp.bfloat16
    s = CommSchedule(gather_dtype="fp32")
    assert s.wire_dtype(cd) == jnp.float32
    assert s.accum_dtype(cd) == jnp.float32  # reduce follows wire
    s = CommSchedule(reduce_dtype="fp32")
    assert s.wire_dtype(cd) == jnp.bfloat16
    assert s.accum_dtype(cd) == jnp.float32
    with pytest.raises(ValueError):
        CommSchedule(gather_dtype="fp16")


def test_sharded_gather_identity_without_axes():
    x = jnp.arange(8, dtype=jnp.float32)
    args = ((), (), jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32),
            jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32), "xla", "match")
    y = sharded_gather(x, *args)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(x.astype(jnp.bfloat16)))
    g = jax.grad(lambda v: sharded_gather(v, *args).sum())(x)
    assert g.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(g), np.ones(8, np.float32))


def test_gathered_peak_bytes_accounting():
    """The analytic gathered-buffer peak the two-slot prefetch bounds:
    1 slot sequential, 2 with prefetch (+1 split-out last layer), n_layers
    with resharding off -- independent of depth when prefetching."""
    def peak(schedule, n_layers):
        _, rt = _build_runtime(schedule, n_layers=n_layers)
        return rt.gathered_peak_bytes()

    per_layer = peak(CommSchedule(), 4)
    assert per_layer > 0
    assert peak(CommSchedule(prefetch=True), 4) == 2 * per_layer
    assert peak(CommSchedule(prefetch=True), 32) == 2 * per_layer
    assert peak(CommSchedule(prefetch=True, keep_last_gathered=True),
                4) == 3 * per_layer
    assert peak(CommSchedule(reshard_after_forward=False), 4) == 4 * per_layer
    # n=1 + keep_last: empty main scan, only the split-out layer is live
    assert peak(CommSchedule(keep_last_gathered=True), 1) == per_layer


# --------------------------------------------------------------------------- #
# regression: prefetch must not store gathered layer buffers in scan carries
# --------------------------------------------------------------------------- #

from repro.analysis import iter_eqns, scan_carry_avals


def _step_jaxpr(schedule, n_layers=5):
    cfg, rt = _build_runtime(schedule, n_layers=n_layers)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    batch = {"tokens": jnp.zeros((4, 16), jnp.int32)}
    return rt, jax.make_jaxpr(fn)(params, state, jnp.int32(0), batch)


def test_prefetch_scan_carry_has_no_gathered_buffers():
    """The retention bug regression: the first prefetch cut threaded the
    next layer's gathered buffer through the checkpointed scan carry, so
    backward retained one gathered buffer per layer.  The two-slot pair
    scan must keep every scan carry free of gathered-layer-sized arrays --
    its carry signature is a subset of the sequential schedule's."""
    rt, pre = _step_jaxpr(VARIANTS["overlap_all"])
    _, ref = _step_jaxpr(CommSchedule.default())
    pre_carries = set(scan_carry_avals(pre))
    ref_carries = set(scan_carry_avals(ref))
    assert pre_carries <= ref_carries, (
        "prefetch added scan carry entries", pre_carries - ref_carries)
    # and explicitly: no carry anywhere is a gathered layer flat buffer
    gathered = {((lo.sharded_dim,), str(jnp.dtype(rt.compute_dtype)))
                for lo in rt.layouts.values() if lo.n_layers}
    assert not (gathered & (pre_carries | ref_carries)), (
        "gathered layer buffer rides a scan carry", gathered)


def _pair_barrier_eqns(closed_jaxpr, gathered_avals):
    """optimization_barrier eqns whose operands include >= 2 gathered layer
    buffers -- the explicit two-slot issue-order pin in the pair scan."""
    found = []
    for eqn, _, _ in iter_eqns(closed_jaxpr):
        if eqn.primitive.name != "optimization_barrier":
            continue
        hits = sum(
            (tuple(v.aval.shape), str(v.aval.dtype)) in gathered_avals
            for v in eqn.invars)
        if hits >= 2:
            found.append(eqn)
    return found


def test_pair_prefetch_issue_order_is_explicit_in_backward():
    """ROADMAP "schedule work remaining": the backward re-gather issue
    order of the pair scan must be explicit, mirroring the forward's
    two-slot order, instead of left to XLA's scheduler.  The pin is an
    optimization_barrier over BOTH slots' gathered buffers; because remat
    replays it, it must appear at least twice in the full train-step jaxpr
    (the forward pair scan and the backward scan's recompute).  The default
    sequential schedule has no such pair barrier."""
    rt, pre = _step_jaxpr(VARIANTS["overlap_all"], n_layers=6)
    gathered = {((lo.sharded_dim,), str(jnp.dtype(rt.compute_dtype)))
                for lo in rt.layouts.values() if lo.n_layers}
    pins = _pair_barrier_eqns(pre, gathered)
    assert len(pins) >= 2, (
        "pair scan's two-slot gather issue order is not pinned in both "
        f"forward and backward (found {len(pins)} pair barriers)")
    _, ref = _step_jaxpr(CommSchedule.default(), n_layers=6)
    assert not _pair_barrier_eqns(ref, gathered), (
        "sequential schedule unexpectedly contains a pair gather barrier")


# --------------------------------------------------------------------------- #
# 8-device ring parity (subprocess: jax fixes the device count at first init)
# --------------------------------------------------------------------------- #

_RING_DRIVER = textwrap.dedent("""
    import os, sys, json, dataclasses
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, build_model
    from repro.configs.base import ParallelConfig
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import VARIANTS
    from repro.optim import make_optimizer
    from repro.launch.mesh import make_local_mesh

    MESH = make_local_mesh(8, 1)

    def train(schedule, steps=2):
        cfg = get_config("qwen2.5-14b").reduced()
        # 3 layers: prefetch pair + keep_last split both active
        cfg = dataclasses.replace(cfg, n_layers=3,
                                  parallel=ParallelConfig(("data",), ("data",)))
        model = build_model(cfg)
        rt = FSDPRuntime(model, MESH, schedule=schedule, donate=False)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)
        rng = np.random.default_rng(0)
        ms = []
        for i in range(steps):
            batch = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
            params, state, st, m = fn(params, state, st, batch)
            ms.append((np.float32(m["loss"]).tobytes().hex(),
                       np.float32(m["grad_norm"]).tobytes().hex()))
        return ms, {k: np.asarray(v) for k, v in params.items()}

    bad = []
    for name, sched in VARIANTS.items():
        if sched.gather_mode != "xla":
            continue
        xm, xp = train(sched)
        rm, rp = train(dataclasses.replace(sched, gather_mode="ring"))
        if xm != rm or any(not np.array_equal(xp[k], rp[k]) for k in xp):
            bad.append(name)
    print(json.dumps({"bad": bad}))
""")


@pytest.mark.slow
def test_ring_matches_xla_bitwise_8dev():
    """Every xla variant and its ring twin produce bitwise-identical
    2-step trajectories over 8-way FSDP: the ring all-gather is pure data
    movement and the ring reduce-scatter reduces in XLA's own
    (linear-device-order, fp32-accumulate) order."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _RING_DRIVER],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["bad"] == [], f"ring != xla for variants: {data['bad']}"
