"""CommSchedule correctness: every schedule variant is a pure reordering /
re-materialization of the same collectives, so on one device all variants
must produce bitwise-identical training trajectories."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.schedule import VARIANTS, CommSchedule, sharded_gather
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

MESH = make_local_mesh(1, 1)


def _train(schedule, steps=3, arch="qwen2.5-14b", planner="ragged"):
    cfg = get_config(arch).reduced()  # 2 layers: exercises keep_last split
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH, planner=planner, schedule=schedule,
                     donate=False)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)
    rng = np.random.default_rng(0)
    out = []
    for i in range(steps):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        params, state, st, m = fn(params, state, st, batch)
        out.append((float(m["loss"]), float(m["grad_norm"])))
    finals = {k: np.asarray(v) for k, v in params.items()}
    return out, finals


@pytest.fixture(scope="module")
def reference():
    return _train(CommSchedule.default())


@pytest.mark.parametrize("name", [k for k in VARIANTS if k != "default"])
def test_schedule_parity_bitwise(name, reference):
    """Prefetch / reshard / keep-last / dtype variants: bitwise-identical
    loss, grad-norm, and final params vs. the default schedule."""
    ref_metrics, ref_params = reference
    metrics, params = _train(VARIANTS[name])
    for (rl, rg), (tl, tg) in zip(ref_metrics, metrics):
        assert np.float32(rl).tobytes() == np.float32(tl).tobytes(), (
            name, ref_metrics, metrics)
        assert np.float32(rg).tobytes() == np.float32(tg).tobytes(), (
            name, ref_metrics, metrics)
    for k in ref_params:
        np.testing.assert_array_equal(ref_params[k], params[k], err_msg=(
            f"{name}: params[{k}] diverged"))


def test_schedule_parity_fsdp2_planner():
    """Schedule variants stay exact under the FSDP2 (interleaved) layout."""
    ref, refp = _train(CommSchedule.default(), planner="fsdp2")
    tst, tstp = _train(VARIANTS["overlap_all"], planner="fsdp2")
    assert ref == tst
    for k in refp:
        np.testing.assert_array_equal(refp[k], tstp[k])


def test_default_schedule_from_config():
    cfg = get_config("qwen2.5-14b").reduced()
    assert CommSchedule.from_config(cfg) == CommSchedule.default()
    par = dataclasses.replace(cfg.parallel, prefetch=True,
                              reduce_dtype="fp32")
    cfg = dataclasses.replace(cfg, parallel=par)
    sched = CommSchedule.from_config(cfg)
    assert sched.prefetch and sched.reduce_dtype == "fp32"


def test_wire_and_accum_dtype_resolution():
    cd = jnp.dtype(jnp.bfloat16)
    s = CommSchedule()
    assert s.wire_dtype(cd) == jnp.bfloat16
    assert s.accum_dtype(cd) == jnp.bfloat16
    s = CommSchedule(gather_dtype="fp32")
    assert s.wire_dtype(cd) == jnp.float32
    assert s.accum_dtype(cd) == jnp.float32  # reduce follows wire
    s = CommSchedule(reduce_dtype="fp32")
    assert s.wire_dtype(cd) == jnp.bfloat16
    assert s.accum_dtype(cd) == jnp.float32
    with pytest.raises(ValueError):
        CommSchedule(gather_dtype="fp16").wire_dtype(cd)


def test_sharded_gather_identity_without_axes():
    import jax

    x = jnp.arange(8, dtype=jnp.float32)
    y = sharded_gather(x, (), jnp.dtype(jnp.bfloat16),
                       jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16),
                       jnp.dtype(jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(x.astype(jnp.bfloat16)))
    g = jax.grad(lambda v: sharded_gather(
        v, (), jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32),
        jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32)).sum())(x)
    assert g.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(g), np.ones(8, np.float32))
