"""Optimizer correctness on flat DBuffer shards: AdamW math, 8-bit Adam
tracks fp32 Adam, Muon Newton-Schulz orthogonalization, wd masks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map
from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer
from repro.optim.muon import newton_schulz

MESH = make_local_mesh(1, 1)


def _setup(arch="qwen2.5-14b", optimizer=None):
    cfg = get_config(arch).reduced()
    if optimizer:
        cfg = dataclasses.replace(cfg, optimizer=optimizer)
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH)
    return cfg, model, rt


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                  jnp.int32)}


@pytest.mark.parametrize("optname", ["adamw", "sgd", "adam8bit", "muon", "shampoo"])
def test_optimizers_reduce_loss(optname):
    cfg, model, rt = _setup(optimizer=optname)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)
    losses = []
    b = _batch(cfg)
    for i in range(8):
        params, state, st, m = fn(params, state, st, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, (optname, losses)
    assert all(np.isfinite(l) for l in losses)


def test_adam8bit_tracks_adamw():
    """Quantized moments track fp32 Adam closely over a few steps (same
    data, same init)."""
    cfg8, model8, rt8 = _setup(optimizer="adam8bit")
    cfg32, model32, rt32 = _setup(optimizer="adamw")
    p8, p32 = rt8.init_params(0), rt32.init_params(0)
    o8 = make_optimizer(cfg8)
    o32 = make_optimizer(cfg32)
    s8, s32 = o8.init(rt8), o32.init(rt32)
    f8, f32 = rt8.make_train_step(o8), rt32.make_train_step(o32)
    st8 = st32 = jnp.int32(0)
    for i in range(5):
        b = _batch(cfg8, seed=i)
        p8, s8, st8, m8 = f8(p8, s8, st8, b)
        p32, s32, st32, m32 = f32(p32, s32, st32, b)
    assert abs(float(m8["loss"]) - float(m32["loss"])) < 0.1
    for name in p8:
        a, b_ = np.asarray(p8[name]), np.asarray(p32[name])
        # parameters stay close elementwise; int8 moment noise is largest on
        # the sparse-gradient embedding rows (paper Fig. 10: loss curves
        # "track closely, with occasional spikes")
        assert np.max(np.abs(a - b_)) < 2e-2, name
        assert np.mean(np.abs(a - b_)) < 1e-3, name


def test_newton_schulz_orthogonalizes():
    rng = np.random.default_rng(0)
    for shape in [(16, 16), (8, 32), (48, 12)]:
        G = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        X = newton_schulz(G)
        a, b = shape
        k = min(a, b)
        M = np.asarray(X @ X.T if a <= b else X.T @ X)
        # singular values pushed toward 1: X X^T ~ I
        err = np.abs(M - np.eye(k)).max()
        assert err < 0.35, (shape, err)
        # sign agreement with G's polar factor: <X, G> > 0
        assert float(jnp.sum(X * G)) > 0


def test_muon_applies_ns_only_to_matrices():
    cfg, model, rt = _setup(optimizer="muon")
    opt = make_optimizer(cfg)
    lo = rt.layouts["layers"]
    assert any(len(p.spec.shape) == 2 for p in lo.plan.placements)
    # globals (embed) fall back to adamw: no NS path for unstacked groups
    assert rt.layouts["globals"].n_layers is None


def test_wd_mask_matches_plan():
    from repro.optim.common import matrix_mask_local

    cfg, model, rt = _setup()
    lo = rt.layouts["layers"]

    def get_mask():
        return matrix_mask_local(rt, lo, (lo.plan.shard_size,))

    mask = np.asarray(
        shard_map(get_mask, mesh=rt.mesh, in_specs=(),
                  out_specs=jax.sharding.PartitionSpec(None))())
    # host oracle
    want = np.zeros(lo.plan.shard_size, np.float32)
    for p in lo.plan.placements:
        if len(p.spec.shape) >= 2:
            want[p.offset:p.end] = 1.0  # single device: shard == global
    np.testing.assert_array_equal(mask, want[:lo.plan.shard_size])
