"""fp8 ParamStore formats (fp8_e4m3 / fp8_e5m2): float8 codes + fp32
master shard.

Guarantees under test (all guarded on ``compat.float8_dtypes()`` being
non-empty -- the whole module skips on a JAX without float8):

  * state structure: ``{"codes", "master"}``, codes always the exact fp8
    cast of the master (create, rebuild, and through real training);
    scale-free, so no planner alignment requirement (``align() == 1``).
  * schedule plumbing: ``gather_dtype`` is rejected alongside an fp8
    ``param_store`` (the codes ARE the wire payload); the fp8
    APPROX_VARIANTS exist; wire_bytes is 1 B/element.
  * training: an fp8 group trains end to end on 1 device (loss
    decreases, codes track the master bitwise) and under the ring+
    prefetch schedule (same payload, reordered comm -- bitwise equal).
  * checkpoints: a same-layout restore is bitwise on codes AND master
    (codes round-trip through the fp32-widened .npy via _savable);
    cross-format restores re-derive the codes from the master.
  * policy: the builtin roofline never nominates fp8 (its analytic
    fp8-over-q8 gap is pure scales overhead -- 4/quant_block B/elem --
    not evidence of a faster fused cast), so historical auto decisions
    are pinned at every block size; only a *measured* profile with a
    genuinely faster fp8 gather curve, clearing FP8_NEAR_TIE_RTOL,
    flips the choice.  Plans over fp8 groups declare fp8 wire legs
    and the src_dtype-carrying no_f32_dequant invariant, and pass the
    static verifier.

The 8-device subprocess at the bottom drives the acceptance scenario:
an fp8 group trains on 8-way shards, checkpoints, and restores onto a
4-way mesh (elastic reshard) with the master bit-preserved.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.checkpoint import ckpt
from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.policy import CostModel, make_plan
from repro.core.schedule import APPROX_VARIANTS, CommSchedule
from repro.core.store import ParamStore
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

pytestmark = pytest.mark.skipif(
    not compat.HAS_FP8, reason="installed JAX has no float8 dtypes")

MESH = make_local_mesh(1, 1)

FP8_FMTS = sorted(compat.float8_dtypes())


def _u8(a):
    """Bitpattern view -- fp8 NaN-safe equality."""
    return np.asarray(a).view(np.uint8)


def _build(schedule, arch="qwen2.5-14b", optimizer=None):
    cfg = get_config(arch).reduced()
    if optimizer is not None:
        cfg = dataclasses.replace(cfg, optimizer=optimizer)
    rt = FSDPRuntime(build_model(cfg), MESH, schedule=schedule, donate=False)
    return cfg, rt


def _train(schedule, steps=3, **kw):
    cfg, rt = _build(schedule, **kw)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(steps):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        params, state, st, m = fn(params, state, st, batch)
        losses.append(float(m["loss"]))
    finals = {k: jax.tree.map(np.asarray, v) for k, v in params.items()}
    return losses, finals, rt


# --------------------------------------------------------------------------- #
# store structure
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("fmt", FP8_FMTS)
def test_fp8_state_structure(fmt):
    s = ParamStore(fmt)
    assert s.fp8 and not s.quantized
    assert s.state_keys() == ("codes", "master")
    assert s.leaf_dtype("codes") == s.fp8_dtype
    assert s.leaf_dtype("master") == jnp.float32
    assert s.align() == 1           # scale-free: no block requirement
    assert s.wire_bytes(1000, np.float32) == 1000  # 1 B/element

    master = np.linspace(-2, 2, 640, dtype=np.float32)
    state = s.create(master)
    assert set(state) == {"codes", "master"}
    np.testing.assert_array_equal(state["master"], master)
    np.testing.assert_array_equal(
        _u8(state["codes"]),
        _u8(jnp.asarray(master).astype(s.fp8_dtype)))

    # trainable/frozen/combine round-trip
    tr, fz = s.trainable(state), s.frozen(state)
    np.testing.assert_array_equal(np.asarray(tr), master)
    assert set(fz) == {"codes"}
    back = s.combine(tr, fz)
    np.testing.assert_array_equal(_u8(back["codes"]), _u8(state["codes"]))

    # rebuild re-derives the codes from the new master in the same pass
    new = jnp.asarray(master * 0.5)
    reb = s.rebuild(new)
    np.testing.assert_array_equal(
        _u8(reb["codes"]), _u8(new.astype(s.fp8_dtype)))
    np.testing.assert_array_equal(np.asarray(reb["master"]), np.asarray(new))


def test_fp8_dtype_guarded():
    with pytest.raises(ValueError):
        ParamStore("fp32").fp8_dtype


def test_fp8_schedule_validation():
    cfg = get_config("qwen2.5-14b").reduced()
    model = build_model(cfg)
    with pytest.raises(ValueError, match="gather_dtype"):
        FSDPRuntime(model, MESH, schedule=CommSchedule(
            param_store="fp8_e4m3", gather_dtype="bf16"), donate=False)
    for name in ("fp8_store", "fp8_e5m2_store", "fp8_ring_prefetch"):
        assert name in APPROX_VARIANTS, name


# --------------------------------------------------------------------------- #
# training end to end (1 device)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("fmt", FP8_FMTS)
def test_fp8_trains_and_codes_track_master(fmt):
    ref, _, _ = _train(CommSchedule(), steps=4)
    losses, finals, rt = _train(CommSchedule(param_store=fmt), steps=4)
    assert all(np.isfinite(losses))
    # the fp8 forward tracks the fp32 run (measured: e4m3 ~0.5%, e5m2
    # ~2.5% max step deviation); a broken grad proxy diverges by whole
    # units within a step or two
    for a, b in zip(losses, ref):
        assert abs(a - b) < 0.10 * max(1.0, abs(b)), (losses, ref)
    dt = jnp.dtype(compat.float8_dtypes()[fmt])
    for name, state in finals.items():
        assert set(state) == {"codes", "master"}
        assert state["master"].dtype == np.float32
        np.testing.assert_array_equal(
            _u8(state["codes"]),
            _u8(jnp.asarray(state["master"]).astype(dt)),
            err_msg=f"{name}: codes are not the exact fp8 cast")


def test_fp8_ring_prefetch_bitwise_matches_xla():
    """Comm-path reorderings of the same fp8 payload are bitwise equal."""
    _, a, _ = _train(CommSchedule(param_store="fp8_e4m3"), steps=2)
    _, b, _ = _train(APPROX_VARIANTS["fp8_ring_prefetch"], steps=2)
    for name in a:
        for leaf in a[name]:
            np.testing.assert_array_equal(
                _u8(a[name][leaf]), _u8(b[name][leaf]),
                err_msg=f"{name}/{leaf}")


def test_fp8_with_adam8bit():
    losses, finals, _ = _train(CommSchedule(param_store="fp8_e4m3"),
                               steps=4, optimizer="adam8bit")
    assert all(np.isfinite(losses)), losses
    for state in finals.values():
        assert set(state) == {"codes", "master"}


# --------------------------------------------------------------------------- #
# checkpoints
# --------------------------------------------------------------------------- #

def test_fp8_checkpoint_roundtrip_bitwise(tmp_path):
    sched = CommSchedule(param_store="fp8_e4m3")
    cfg, rt = _build(sched)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
    params, state, _, _ = fn(params, state, jnp.int32(0), batch)

    ckpt.save(tmp_path / "c", rt, params, state, step=1)
    _, rt2 = _build(sched)
    p2, step, s2 = ckpt.load(tmp_path / "c", rt2, opt.init(rt2))
    assert step == 1
    for name in params:
        np.testing.assert_array_equal(
            _u8(params[name]["codes"]), _u8(p2[name]["codes"]),
            err_msg=f"{name}: codes not bitwise through save/load")
        np.testing.assert_array_equal(
            np.asarray(params[name]["master"]),
            np.asarray(p2[name]["master"]))


def test_fp8_cross_format_restore(tmp_path):
    """fp32 ckpt -> fp8 runtime re-derives codes from the master; fp8
    ckpt -> fp32 runtime keeps the master bit for bit."""
    _, rt32 = _build(CommSchedule())
    params = rt32.init_params(0)
    ckpt.save(tmp_path / "a", rt32, params, step=1)

    _, rt8 = _build(CommSchedule(param_store="fp8_e4m3"))
    p8, _ = ckpt.load(tmp_path / "a", rt8)
    dt = jnp.dtype(compat.float8_dtypes()["fp8_e4m3"])
    for name in p8:
        np.testing.assert_array_equal(
            np.asarray(p8[name]["master"]), np.asarray(params[name]))
        np.testing.assert_array_equal(
            _u8(p8[name]["codes"]),
            _u8(jnp.asarray(p8[name]["master"]).astype(dt)))

    ckpt.save(tmp_path / "b", rt8, p8, step=2)
    back, _ = ckpt.load(tmp_path / "b", rt32)
    for name in back:
        np.testing.assert_array_equal(
            np.asarray(back[name]), np.asarray(params[name]))


# --------------------------------------------------------------------------- #
# policy: near-tie pricing + invariants + static verify
# --------------------------------------------------------------------------- #

def test_fp8_builtin_pricing_is_pinned_by_near_tie_band():
    """The builtin roofline never nominates fp8: its analytic fp8-over-q8
    "win" is just the per-block scales overhead (4/quant_block B/elem),
    not measured evidence of a faster fused cast -- so auto keeps its
    historical q8_block/fp32 decisions at every block size, even block 64
    where the apparent gap (~4%) exceeds FP8_NEAR_TIE_RTOL."""
    cm = CostModel.default()
    kw = dict(elems_per_layer=1 << 20, n_layers=3, m=8, quant_block=1024,
              compute_itemsize=2)
    assert cm.choose_store(**kw) == "q8_block"
    t_q8 = cm.gather_time("q8_block", **kw)
    t_f8 = cm.gather_time("fp8_e4m3", **kw)
    assert t_f8 < t_q8          # fp8's analytic time is genuinely smaller...
    # ...by exactly the scales overhead, within the band at block 1024
    assert t_f8 > t_q8 * (1 - cm.FP8_NEAR_TIE_RTOL)
    # at block 64 the apparent gap exceeds the band, yet without a
    # measured fp8 curve the incumbent still holds (the PR-10 regression:
    # the reduced qwen2.5-14b config quantizes at block 64)
    kw64 = {**kw, "quant_block": 64}
    t_q8_64 = cm.gather_time("q8_block", **kw64)
    t_f8_64 = cm.gather_time("fp8_e4m3", **kw64)
    assert t_f8_64 < t_q8_64 * (1 - cm.FP8_NEAR_TIE_RTOL)
    assert cm.choose_store(**kw64) == "q8_block"
    assert cm.choose_store(**{**kw, "m": 1}) == "fp32"


def test_fp8_measured_profile_flips_choice():
    """A measured profile whose fp8 gather curve beats every incumbent by
    more than the near-tie band selects the fp8 store."""
    from test_autotune import _measured_profile, _samples

    sweep = tuple(_samples("gather", "fp8_e4m3", "xla", 0.05))
    cm = CostModel.from_profile(_measured_profile(sweep=sweep))
    got = cm.choose_store(elems_per_layer=1 << 20, n_layers=3, m=8,
                          quant_block=1024, compute_itemsize=2)
    assert got == "fp8_e4m3", got


def test_fp8_plan_invariants_and_static_verify():
    from repro.analysis.verify import verify_plan_static

    model = build_model(get_config("qwen2.5-14b").reduced())
    plan = make_plan(model, {"data": 8}, CommSchedule(param_store="fp8_e4m3"))
    invs = plan.invariants()
    legs = [i for i in invs if i["name"] == "wire_dtype"]
    assert legs, invs
    fp8_name = str(jnp.dtype(compat.float8_dtypes()["fp8_e4m3"]))
    assert any(fp8_name in json.dumps(i) for i in legs), legs
    nd = [i for i in invs if i["name"] == "no_f32_dequant"]
    assert nd and all(i.get("src_dtype") == fp8_name for i in nd), nd
    assert verify_plan_static(plan).ok


# --------------------------------------------------------------------------- #
# 8-device acceptance: train + checkpoint + elastic reshard
# --------------------------------------------------------------------------- #

_DRIVER_8DEV = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import ckpt
    from repro.configs import get_config, build_model
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import CommSchedule
    from repro.optim import make_optimizer
    from repro.launch.mesh import make_local_mesh

    cfg = get_config("qwen2.5-14b").reduced()
    model = build_model(cfg)
    sched = CommSchedule(param_store="fp8_e4m3")
    out = {}

    rt8 = FSDPRuntime(model, make_local_mesh(8, 1), schedule=sched,
                      donate=False)
    params = rt8.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt8)
    fn = rt8.make_train_step(opt)
    st = jnp.int32(0)
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(3):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
        params, state, st, m = fn(params, state, st, batch)
        losses.append(float(m["loss"]))
    out["finite"] = bool(np.isfinite(losses).all())

    ckpt.save("/tmp/fp8_ck", rt8, params, state, step=3)

    # elastic: restore the 8-way checkpoint onto a 4-way mesh, re-save,
    # and restore THAT back onto an 8-way runtime -- if the 4-way hop
    # lost a bit anywhere, the same-layout comparison at the end shows it
    rt4 = FSDPRuntime(model, make_local_mesh(4, 1), schedule=sched,
                      donate=False)
    p4, step, s4 = ckpt.load("/tmp/fp8_ck", rt4, opt.init(rt4))
    ckpt.save("/tmp/fp8_ck2", rt4, p4, s4, step=step)
    rt8b = FSDPRuntime(model, make_local_mesh(8, 1), schedule=sched,
                       donate=False)
    p8b, _, _ = ckpt.load("/tmp/fp8_ck2", rt8b, opt.init(rt8b))
    ok = True
    for name in params:
        for leaf in ("codes", "master"):
            ok &= bool(np.array_equal(
                np.asarray(params[name][leaf]).view(np.uint8),
                np.asarray(p8b[name][leaf]).view(np.uint8)))
    out["reshard_bitwise"] = ok

    # and training continues on the resharded params
    fn4 = rt4.make_train_step(opt)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
    _, _, _, m4 = fn4(p4, s4, jnp.int32(step), batch)
    out["resumed_finite"] = bool(np.isfinite(float(m4["loss"])))
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_fp8_8dev_train_ckpt_reshard_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DRIVER_8DEV],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["finite"], data
    assert data["reshard_bitwise"], data
    assert data["resumed_finite"], data
