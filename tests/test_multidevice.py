"""Multi-device numerical equivalence, run in subprocesses (jax locks the
device count at first init, so each scenario gets its own interpreter with
XLA_FLAGS=--xla_force_host_platform_device_count=8).

Scenarios:
  * FSDP over 8 devices == 1-device reference (loss + params after 2 steps)
  * HSDP (2 pods x 4) == flat 8-way FSDP
  * TP=4 x FSDP=2 (sequence-parallel on/off) == 1-device reference
  * EP=4 MoE == 1-device reference
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_DRIVER = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import dataclasses
    from repro.configs import get_config, build_model
    from repro.configs.base import ParallelConfig
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import VARIANTS
    from repro.optim import make_optimizer
    from repro.launch.mesh import make_local_mesh

    scenario = sys.argv[1]

    def batch_for(cfg, B, T, seed=0):
        rng = np.random.default_rng(seed)
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)}
        if cfg.arch_type == "vlm":
            b["patches"] = jnp.asarray(rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
        if cfg.arch_type == "audio":
            b["frames"] = jnp.asarray(rng.normal(size=(B, cfg.n_frames, cfg.d_model)), jnp.bfloat16)
        return b

    def run(cfg, mesh, steps=2, planner="ragged", schedule=None,
            group_schedules=None):
        model = build_model(cfg)
        rt = FSDPRuntime(model, mesh, planner=planner, schedule=schedule,
                         group_schedules=group_schedules)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        ostate = opt.init(rt)
        fn = rt.make_train_step(opt)
        losses = []
        st = jnp.int32(0)
        for i in range(steps):
            params, ostate, st, m = fn(params, ostate, st, batch_for(cfg, 8, 32, seed=i))
            losses.append(float(m["loss"]))
        # gather params back to host, unpacked per tensor for comparison
        out = {}
        for name, lo in rt.layouts.items():
            flat = np.asarray(jax.device_put(params[name], jax.devices("cpu")[0]) if False else params[name])
            if lo.n_layers:
                out[name] = float(np.square(flat.astype(np.float64)).sum())
            else:
                out[name] = float(np.square(flat.astype(np.float64)).sum())
        return losses, out

    if scenario == "fsdp8":
        cfg = get_config("qwen2.5-14b").reduced()
        base = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(base, make_local_mesh(1, 1))
        tst_losses, _ = run(base, make_local_mesh(8, 1))
    elif scenario == "hsdp":
        cfg = get_config("gemma2-2b").reduced()
        flat = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(flat, make_local_mesh(8, 1))
        hs = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        tst_losses, _ = run(hs, make_local_mesh(4, 1, pod=2))
    elif scenario in ("tp", "tp_sp"):
        cfg = get_config("nemotron-4-340b").reduced()
        cfg = dataclasses.replace(cfg, n_heads=4, n_kv_heads=2, head_dim=64,
                                  d_model=256, d_ff=512, optimizer="adamw")
        base = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(base, make_local_mesh(1, 1))
        par = ParallelConfig(("data",), ("data",), tp=4,
                             sequence_parallel=(scenario == "tp_sp"))
        tst = dataclasses.replace(cfg, parallel=par)
        tst_losses, _ = run(tst, make_local_mesh(2, 4))
    elif scenario == "ep":
        cfg = get_config("qwen3-moe-235b-a22b").reduced()
        cfg = dataclasses.replace(cfg, optimizer="adamw")
        base = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(base, make_local_mesh(1, 1))
        par = ParallelConfig(("data", "model"), ("data",), ep=4)
        # batch over data only so routing sees identical tokens per EP group
        tst = dataclasses.replace(cfg, parallel=par)
        tst_losses, _ = run(tst, make_local_mesh(2, 4))
    elif scenario == "shampoo":
        # distributed (layer-resharded) Shampoo == single-device Shampoo
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(cfg, optimizer="shampoo")
        base = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(base, make_local_mesh(1, 1), steps=3)
        tst_losses, _ = run(base, make_local_mesh(8, 1), steps=3)
    elif scenario == "micro":
        cfg = get_config("qwen2.5-14b").reduced()
        base = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(base, make_local_mesh(2, 1))
        tst = dataclasses.replace(cfg, parallel=ParallelConfig(
            ("data",), ("data",), microbatches=4))
        tst_losses, _ = run(tst, make_local_mesh(2, 1))
    elif scenario == "hsdp_groups":
        # schedule-unsharded globals on a pod_fsdp (2 pods x 4) mesh ==
        # flat 8-way FSDP: grad_sync_axes covers ("pod", "data"), so this
        # guards the cross-pod psum against double-reducing such groups
        cfg = get_config("gemma2-2b").reduced()
        base = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(base, make_local_mesh(8, 1))
        tst = dataclasses.replace(cfg, parallel=ParallelConfig(
            ("data",), ("data",), pod_fsdp=True))
        tst_losses, _ = run(tst, make_local_mesh(4, 1, pod=2),
                            group_schedules={"globals": {"sharded": False}})
    elif scenario == "sched_groups":
        # per-group schedule overrides over 8-way FSDP: globals kept
        # replicated (grads psum'd instead of reduce-scattered), layers
        # ring-gathered with fp32 reduce == uniform default schedule
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(cfg, n_layers=4)
        base = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(base, make_local_mesh(8, 1))
        tst_losses, _ = run(base, make_local_mesh(8, 1), group_schedules={
            "globals": {"sharded": False},
            "layers": {"gather_mode": "ring", "reduce_dtype": "fp32"}})
    elif scenario.startswith("sched_"):
        # overlap schedule (prefetch + keep-last + fp32 reduce) over 8-way
        # FSDP == default schedule, per planner layout; only the wire/reduce
        # precision differs across devices.  4 layers so the prefetch path
        # (scan length >= 2 after the keep-last split) really runs
        planner = scenario.removeprefix("sched_")
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(cfg, n_layers=4)
        base = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        ref_losses, _ = run(base, make_local_mesh(8, 1), planner=planner,
                            schedule=VARIANTS["default"])
        tst_losses, _ = run(base, make_local_mesh(8, 1), planner=planner,
                            schedule=VARIANTS["overlap_all"])
    else:
        raise SystemExit(f"unknown scenario {scenario}")

    print(json.dumps({"ref": ref_losses, "tst": tst_losses}))
""")


def _run(scenario: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _DRIVER, scenario],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    return data["ref"], data["tst"]


@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["fsdp8", "hsdp", "tp", "tp_sp", "ep",
                                      "micro", "shampoo", "sched_ragged",
                                      "sched_fsdp2", "sched_groups",
                                      "hsdp_groups"])
def test_parallel_equivalence(scenario):
    ref, tst = _run(scenario)
    for r, t in zip(ref, tst):
        # bf16 compute: collective orders differ slightly between layouts
        assert abs(r - t) < 0.05 * max(1.0, abs(r)), (scenario, ref, tst)
