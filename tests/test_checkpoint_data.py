"""Checkpoint save/restore (incl. cross-planner resharded restore) and the
deterministic data pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

MESH = make_local_mesh(1, 1)


def _train(rt, cfg, params, state, steps=3, seed=0):
    opt = make_optimizer(cfg)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)
    stream = SyntheticStream(DataConfig(cfg.vocab, 16, 4, seed=seed), cfg)
    for i in range(steps):
        b = stream.shard(stream.batch(i), rt)
        params, state, st, m = fn(params, state, st, b)
    return params, state, float(m["loss"])


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH)
    opt = make_optimizer(cfg)
    params = rt.init_params(0)
    state = opt.init(rt)
    params, state, _ = _train(rt, cfg, params, state)
    ckpt.save(tmp_path / "c", rt, params, state, step=3)
    p2, step, s2 = ckpt.load(tmp_path / "c", rt, opt.init(rt))
    assert step == 3
    for name in params:
        np.testing.assert_array_equal(np.asarray(params[name]),
                                      np.asarray(p2[name]))
    # training continues identically from the restore
    a1, _, l1 = _train(rt, cfg, params, state, steps=2, seed=7)
    a2, _, l2 = _train(rt, cfg, p2, s2, steps=2, seed=7)
    assert l1 == l2


def test_cross_planner_restore(tmp_path):
    """Save under the ragged plan, restore into a naive-planner runtime:
    RaggedShard's checkpoint index makes plans interchangeable."""
    cfg = get_config("qwen2.5-14b").reduced()
    model = build_model(cfg)
    rt_a = FSDPRuntime(model, MESH, planner="ragged")
    params = rt_a.init_params(0)
    ckpt.save(tmp_path / "c", rt_a, params, step=1)

    rt_b = FSDPRuntime(build_model(cfg), MESH, planner="naive")
    p2, step = ckpt.load(tmp_path / "c", rt_b)
    # same tensors, different packing: compare per-tensor contents
    for name, lo_a in rt_a.layouts.items():
        lo_b = rt_b.layouts[name]
        a = np.asarray(params[name])
        b = np.asarray(p2[name])
        if lo_a.n_layers:
            for li in range(lo_a.n_layers):
                ta = lo_a.buffer.unpack_np(a[li])
                tb = lo_b.buffer.unpack_np(b[li])
                for k in ta:
                    np.testing.assert_array_equal(ta[k], tb[k])
        else:
            ta = lo_a.buffer.unpack_np(a)
            tb = lo_b.buffer.unpack_np(b)
            for k in ta:
                np.testing.assert_array_equal(ta[k], tb[k])


def test_q8_checkpoint_roundtrip_bitwise(tmp_path):
    """Quantized store round-trip: master shard, codes, and scales are all
    bitwise-preserved, and training continues identically (the 8-device
    twin lives in tests/test_store.py's subprocess driver)."""
    from repro.core.schedule import CommSchedule

    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH,
                     schedule=CommSchedule(param_store="q8_block"))
    opt = make_optimizer(cfg)
    params = rt.init_params(0)
    state = opt.init(rt)
    params, state, _ = _train(rt, cfg, params, state)
    ckpt.save(tmp_path / "c", rt, params, state, step=3)
    p2, step, s2 = ckpt.load(tmp_path / "c", rt, opt.init(rt))
    assert step == 3
    for name in params:
        for leaf in ("codes", "master", "scales"):
            np.testing.assert_array_equal(
                np.asarray(params[name][leaf]), np.asarray(p2[name][leaf]),
                err_msg=f"{name}.{leaf} not bitwise across q8 round-trip")
    a1, _, l1 = _train(rt, cfg, params, state, steps=2, seed=7)
    a2, _, l2 = _train(rt, cfg, p2, s2, steps=2, seed=7)
    assert l1 == l2


def test_cross_format_restore(tmp_path):
    """A pre-store (fp32) checkpoint loads into a q8_block runtime (codes
    derived from the master) and a q8 checkpoint loads back into an fp32
    runtime (master extracted) -- the storage format is a property of the
    runtime, not of the checkpoint."""
    from repro.core.schedule import CommSchedule
    from repro.quant.blockwise import quantize_blockwise

    cfg = get_config("gemma2-2b").reduced()
    rt32 = FSDPRuntime(build_model(cfg), MESH)
    params = rt32.init_params(1)
    ckpt.save(tmp_path / "a", rt32, params, step=1)

    rtq8 = FSDPRuntime(build_model(cfg), MESH,
                       schedule=CommSchedule(param_store="q8_block"))
    pq, _ = ckpt.load(tmp_path / "a", rtq8)
    for name, lo in rtq8.layouts.items():
        np.testing.assert_array_equal(np.asarray(params[name]),
                                      np.asarray(pq[name]["master"]))
        want_codes, _ = quantize_blockwise(
            jnp.asarray(pq[name]["master"]), lo.store.block)
        np.testing.assert_array_equal(np.asarray(want_codes),
                                      np.asarray(pq[name]["codes"]))
    ckpt.save(tmp_path / "b", rtq8, pq, step=2)
    p32, _ = ckpt.load(tmp_path / "b", rt32)
    for name in params:
        np.testing.assert_array_equal(np.asarray(params[name]),
                                      np.asarray(p32[name]))


def test_bf16_checkpoint_roundtrip(tmp_path):
    """bf16 buffers are widened to fp32 on disk (np.savez degrades
    ml_dtypes bfloat16 to raw void arrays) and narrowed back on load:
    the round-trip is exact."""
    from repro.core.schedule import CommSchedule

    cfg = get_config("gemma2-2b").reduced()
    rt = FSDPRuntime(build_model(cfg), MESH,
                     schedule=CommSchedule(param_store="bf16"))
    params = rt.init_params(0)
    ckpt.save(tmp_path / "c", rt, params, step=1)
    p2, step = ckpt.load(tmp_path / "c", rt)
    assert step == 1
    for name in params:
        assert np.asarray(p2[name]).dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(params[name]),
                                      np.asarray(p2[name]))


def test_q8_quant_block_change_requantizes(tmp_path):
    """A q8 checkpoint loaded into a runtime with a different quant_block
    must NOT take the direct leaf path (the scale count would be wrong):
    it rebuilds from the master and requantizes at the new block size."""
    import dataclasses as dc

    from repro.core.schedule import CommSchedule
    from repro.kernels import ops

    sched = CommSchedule(param_store="q8_block")
    cfg = get_config("gemma2-2b").reduced()  # quant_block=64
    rt_a = FSDPRuntime(build_model(cfg), MESH, schedule=sched)
    params = rt_a.init_params(0)
    ckpt.save(tmp_path / "c", rt_a, params, step=1)

    cfg_b = dc.replace(cfg, quant_block=32)  # 64-aligned plans stay valid
    rt_b = FSDPRuntime(build_model(cfg_b), MESH, schedule=sched)
    p2, _ = ckpt.load(tmp_path / "c", rt_b)
    for name, lo in rt_b.layouts.items():
        np.testing.assert_array_equal(np.asarray(params[name]["master"]),
                                      np.asarray(p2[name]["master"]))
        assert (p2[name]["scales"].shape[-1]
                == lo.global_shape()[-1] // 32)
        # compare through the execution engine: rebuild requantizes via
        # ops.quantize, whose jit-regime scale (reciprocal-multiply) can
        # differ from the eager reference by 1 ulp on absmax elements
        want, _ = ops.quantize(jnp.asarray(p2[name]["master"]), 32)
        np.testing.assert_array_equal(np.asarray(want),
                                      np.asarray(p2[name]["codes"]))


def test_data_deterministic_and_learnable():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=4, seed=3)
    s1, s2 = SyntheticStream(cfg), SyntheticStream(cfg)
    b1, b2 = s1.batch(5), s2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(s1.batch(6)["tokens"]),
                              np.asarray(b1["tokens"]))
    # Markov structure: successor correlation is above chance
    t = np.asarray(s1.batch(0)["tokens"])
    succ = (s1.a * t[:, :-1] + s1.b) % cfg.vocab
    frac = (t[:, 1:] == succ).mean()
    assert frac > 0.4  # order_mix=0.7 with zipf collisions


def test_cross_mesh_restore(tmp_path):
    """Save on 1 device, restore onto an 8-device mesh (different plan m):
    the RaggedShard checkpoint index makes shards portable -- the paper's
    communication-free resharded restore."""
    import os
    import subprocess
    import sys
    import textwrap

    cfg_arch = "qwen2.5-14b"
    # save in-process (1 device)
    cfg = get_config(cfg_arch).reduced()
    model = build_model(cfg)
    rt = FSDPRuntime(model, MESH)
    params = rt.init_params(3)
    ckpt.save(tmp_path / "c", rt, params, step=7)
    want = {}
    for name, lo in rt.layouts.items():
        a = np.asarray(params[name])
        if lo.n_layers:
            want[name] = lo.buffer.unpack_np(a[0])
        else:
            want[name] = lo.buffer.unpack_np(a)
    np.savez(tmp_path / "want.npz",
             **{f"{g}__{t}": v for g, ts in want.items()
                for t, v in ts.items()})

    driver = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        from repro.configs import get_config, build_model
        from repro.core.fsdp import FSDPRuntime
        from repro.checkpoint import ckpt
        from repro.launch.mesh import make_local_mesh
        cfg = get_config({cfg_arch!r}).reduced()
        import dataclasses
        from repro.configs.base import ParallelConfig
        cfg = dataclasses.replace(cfg, parallel=ParallelConfig(("data",), ("data",)))
        rt = FSDPRuntime(build_model(cfg), make_local_mesh(8, 1))
        params, step = ckpt.load({str(tmp_path / 'c')!r}, rt)
        assert step == 7
        want = np.load({str(tmp_path / 'want.npz')!r})
        for name, lo in rt.layouts.items():
            a = np.asarray(params[name])
            flat = a[0] if lo.n_layers else a
            got = lo.buffer.unpack_np(flat)
            for t, v in got.items():
                np.testing.assert_array_equal(v, want[f"{{name}}__{{t}}"])
        print("RESTORE_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", driver],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RESTORE_OK" in out.stdout


def test_fp8_wire_dtypes_widen_exact():
    """S2: _savable widens every fp8 wire dtype (not just bf16) to fp32 and
    _narrow restores it bitwise."""
    from repro import compat
    from repro.checkpoint.ckpt import _narrow, _savable

    for name, dt in compat.float8_dtypes().items():
        x = jnp.asarray(np.linspace(-3, 3, 64, dtype=np.float32)).astype(dt)
        wide = _savable(x)
        assert wide.dtype == np.float32
        back = _narrow(wide, dt)
        np.testing.assert_array_equal(
            np.asarray(x).view(np.uint8), np.asarray(back).view(np.uint8),
            err_msg=f"{name} not exact through widen/narrow")


def _write_v1_checkpoint(path, rt, params, opt_state=None, step=1):
    """Hand-write a pre-plan legacy (v1) checkpoint: monolithic state.npz
    plus a meta.json with no "version", "store", or "ef_m" keys and no
    plan.json -- the format the earliest sessions of this repo produced."""
    import json

    from repro.compat import tree_flatten_with_path
    from repro.core.ragged import checkpoint_index

    path.mkdir(parents=True, exist_ok=True)
    arrays, groups = {}, {}
    for name, lo in rt.layouts.items():
        arrays[f"param__{name}"] = np.asarray(params[name])
        groups[name] = {
            "shard_size": lo.plan.shard_size,
            "num_shards": lo.plan.num_shards,
            "outer_size": lo.outer_size,
            "mode": lo.plan.mode,
            "index": checkpoint_index(lo.plan),
        }
    if opt_state is not None:
        flat, _ = tree_flatten_with_path(opt_state)
        for kp, v in flat:
            key = "opt__" + "__".join(getattr(p, "key", str(p)) for p in kp)
            arrays[key] = np.asarray(v)
    np.savez(path / "state.npz", **arrays)
    (path / "meta.json").write_text(
        json.dumps({"step": step, "groups": groups}))


def test_legacy_v1_restore(tmp_path):
    """S3: a pre-plan v1 checkpoint (no version/store/ef_m in meta.json, no
    plan.json) still loads -- params and same-plan optimizer state bitwise,
    load_plan -> None."""
    import pytest

    cfg = get_config("gemma2-2b").reduced()
    rt = FSDPRuntime(build_model(cfg), MESH)
    opt = make_optimizer(cfg)
    params = rt.init_params(2)
    state = opt.init(rt)
    params, state, _ = _train(rt, cfg, params, state, steps=2)
    _write_v1_checkpoint(tmp_path / "v1", rt, params, state, step=2)

    assert ckpt.load_plan(tmp_path / "v1") is None
    p2, step, s2 = ckpt.load(tmp_path / "v1", rt, opt.init(rt))
    assert step == 2
    for name in params:
        np.testing.assert_array_equal(np.asarray(params[name]),
                                      np.asarray(p2[name]))
    from repro.compat import tree_flatten_with_path
    fa, _ = tree_flatten_with_path(state)
    fb, _ = tree_flatten_with_path(s2)
    for (ka, va), (kb, vb) in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))

    # S1: v1 cross-plan + optimizer state must refuse loudly (the old code
    # silently restored stale arrays shaped for the wrong plan)
    rt_naive = FSDPRuntime(build_model(cfg), MESH, planner="naive")
    with pytest.raises(ValueError, match="same-plan only"):
        ckpt.load(tmp_path / "v1", rt_naive, make_optimizer(cfg).init(rt_naive))
    # ...but params alone still cross-plan restore via _repack
    p3, _ = ckpt.load(tmp_path / "v1", rt_naive)
    for name, lo_a in rt.layouts.items():
        lo_b = rt_naive.layouts[name]
        a, b = np.asarray(params[name]), np.asarray(p3[name])
        for li in (range(lo_a.n_layers) if lo_a.n_layers else [None]):
            ta = lo_a.buffer.unpack_np(a[li] if li is not None else a)
            tb = lo_b.buffer.unpack_np(b[li] if li is not None else b)
            for k in ta:
                np.testing.assert_array_equal(ta[k], tb[k])


def test_legacy_v1_restore_8dev(tmp_path):
    """S3 (8-device): the same hand-written v1 checkpoint restores onto an
    8-way mesh (cross-plan m=1 -> m=8, params only)."""
    import os
    import subprocess
    import sys
    import textwrap

    cfg = get_config("qwen2.5-14b").reduced()
    rt = FSDPRuntime(build_model(cfg), MESH)
    params = rt.init_params(5)
    _write_v1_checkpoint(tmp_path / "v1", rt, params, step=4)
    want = {}
    for name, lo in rt.layouts.items():
        a = np.asarray(params[name])
        want.update({f"{name}__{t}": v for t, v in lo.buffer.unpack_np(
            a[0] if lo.n_layers else a).items()})
    np.savez(tmp_path / "want.npz", **want)

    driver = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import numpy as np
        from repro.configs import get_config, build_model
        from repro.configs.base import ParallelConfig
        from repro.core.fsdp import FSDPRuntime
        from repro.checkpoint import ckpt
        from repro.launch.mesh import make_local_mesh
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(
            cfg, parallel=ParallelConfig(("data",), ("data",)))
        rt = FSDPRuntime(build_model(cfg), make_local_mesh(8, 1))
        assert ckpt.load_plan({str(tmp_path / 'v1')!r}) is None
        params, step = ckpt.load({str(tmp_path / 'v1')!r}, rt)
        assert step == 4
        want = np.load({str(tmp_path / 'want.npz')!r})
        for name, lo in rt.layouts.items():
            a = np.asarray(params[name])
            got = lo.buffer.unpack_np(a[0] if lo.n_layers else a)
            for t, v in got.items():
                np.testing.assert_array_equal(v, want[f"{{name}}__{{t}}"])
        print("LEGACY_8DEV_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", driver],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "LEGACY_8DEV_OK" in out.stdout
