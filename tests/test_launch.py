"""Launch-layer tests: roofline HLO parsing, collective accounting,
composition granularity, and a small-mesh dry-run in a subprocess."""
import json
import math
import os
import subprocess
import sys
import textwrap

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.ragged import ShardDim, TensorSpec, compose_granularity
from repro.launch.roofline import parse_collectives


def test_parse_collectives_kinds_and_groups():
    hlo = """
  %ag = f32[1024]{0} all-gather(%x), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[64,128]{1,0} all-reduce(%y), replica_groups=[4,2]<=[8], to_apply=%add
  %rs = f32[256]{0} reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
  %a2a = bf16[16,32]{1,0} all-to-all(%w), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = f32[128]{0} collective-permute(%v), source_target_pairs={{0,1}}
"""
    st_ = parse_collectives(hlo)
    assert st_.counts == {"all-gather": 1, "all-reduce": 1,
                          "reduce-scatter": 1, "all-to-all": 1,
                          "collective-permute": 1}
    # all-gather: 1024*4 bytes * 3/4 ring factor
    assert abs(st_.bytes_by_kind["all-gather"] - 1024 * 4 * 0.75) < 1
    # all-reduce: 2x ring volume, group size 2 -> factor 2*(1/2)=1
    assert abs(st_.bytes_by_kind["all-reduce"] - 64 * 128 * 2 * 1.0) < 1


def test_parse_collectives_ignores_noise():
    st_ = parse_collectives("%x = f32[8]{0} add(%a, %b)\n%all_gatherish = foo")
    assert st_.total_bytes == 0


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(1, 6),
       st.sampled_from([1, 2, 4]), st.integers(1, 3))
def test_compose_granularity_shard_dim(rows, cols, depth, axis_size, g_rows):
    """Shard(dim>0) composition: granularity never cuts into the sharded
    dim (LCM rule from paper §4)."""
    shape = (rows * axis_size, cols * axis_size, depth * axis_size)
    spec = TensorSpec("w", shape, granularity=1)
    for dim in (1, 2):
        out = compose_granularity(spec, ShardDim(dim, "model"), axis_size)
        local_shape = list(shape)
        local_shape[dim] //= axis_size
        assert out.shape == tuple(local_shape)
        stride = math.prod(local_shape[dim:])
        assert out.granularity % math.gcd(out.granularity, stride) == 0
        assert out.size % out.granularity == 0


def test_compose_granularity_shard0_passthrough():
    spec = TensorSpec("w", (8, 6), granularity=6)
    out = compose_granularity(spec, ShardDim(0, "model"), 4)
    assert out.shape == (2, 6)
    assert out.granularity == 6  # StridedRagged: row ranges stay contiguous


DRYRUN_SMALL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get_config, build_model
    from repro.core.fsdp import FSDPRuntime
    from repro.optim import make_optimizer
    from repro.launch.mesh import make_local_mesh
    from repro.launch.specs import input_specs
    from repro.launch.roofline import analyze
    from repro.configs.base import SHAPES
    import dataclasses

    cfg = get_config("gemma2-2b").reduced()
    cfg = dataclasses.replace(cfg, parallel=dataclasses.replace(
        cfg.parallel, fsdp_axes=("data", "model"),
        batch_axes=("data", "model")))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                global_batch=8)
    mesh = make_local_mesh(4, 2)
    model = build_model(cfg)
    rt = FSDPRuntime(model, mesh)
    opt = make_optimizer(cfg)
    step = rt.make_train_step(opt)
    args = input_specs(cfg, shape, rt, model, opt)
    compiled = step.lower(*args).compile()
    r = analyze(compiled, arch=cfg.name, shape_cfg=shape,
                mesh_name="4x2", chips=8, cfg=cfg)
    print(json.dumps({"ok": True, "flops": r.flops_per_device,
                      "coll": r.collective_bytes,
                      "counts": r.coll_counts}))
""")


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    """End-to-end dry-run machinery on an 8-device mesh: lower, compile,
    cost/memory analysis, collective parsing."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", DRYRUN_SMALL],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["ok"] and data["flops"] > 0
    assert data["counts"].get("all-gather", 0) >= 1
    assert data["counts"].get("reduce-scatter", 0) >= 1
    assert data["coll"] > 0
