"""WireCodec layer (core.wire) and the quantized gradient reduce-scatter.

Guarantees under test:
  * codec units: cast codecs are pure ``astype`` round-trips; the q8_block
    codec's decode error is within the per-block int8 bound; wire-byte
    accounting matches the codec formulas.
  * lowering: ``reduce_wire="fp32"/"bf16"`` is bitwise-identical to the
    legacy ``reduce_dtype`` spelling (cast codecs ARE the legacy path) --
    on top of the unchanged test_schedule parity suite, which pins the
    whole refactor to the pre-codec trajectories.
  * q8_block reduce wire (QSDP): training stays finite and tracks the
    fp32-wire trajectory within 2%; the error-feedback residual lives in
    the param state tree, is nonzero after a step, updates exactly to
    ``compensated - decode(encode(compensated))``, and checkpoints /
    restores bitwise; xla and ring gather modes move the same quantized
    payload (bitwise-identical trajectories); ring_acc composes.
  * per-group ``reduce_wire`` overrides through group_schedules and
    PolicyRule; accounting: the q8 reduce wire is >= 3x smaller than an
    fp32 reduce wire.
  * validation: reduce_wire + reduce_dtype is rejected; q8 reduce on an
    unsharded group is rejected; unknown formats are rejected.  Microbatch
    accumulation with EF runs the DEFERRED path (one encode + reduce-
    scatter at the accumulation boundary) and tracks the microbatches=1
    trajectory.
  * fp8 plumbing (satellite): when the installed JAX has float8 dtypes,
    they are legal wire formats end to end without call-site changes.

The 8-device twin of this file is the subprocess scenario at the bottom
(slow marker), mirroring test_store's driver.
"""
import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.checkpoint import ckpt
from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.policy import (CostModel, PolicyRule, PolicySet,
                               ShardingPolicy, make_plan)
from repro.core.schedule import (APPROX_VARIANTS, GROUP_OVERRIDE_KEYS,
                                 CommSchedule, resolve_group_schedules)
from repro.core.store import EF_KEY, ParamStore
from repro.core.wire import (CAST_FORMATS, WIRE_FORMATS, WireCodec,
                             fmt_of_dtype)
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer
from repro.quant.blockwise import dequantize_blockwise, quantize_blockwise

MESH = make_local_mesh(1, 1)

Q8R = CommSchedule(reduce_wire="q8_block")


def _build(schedule, arch="qwen2.5-14b", n_layers=None, optimizer=None,
           group_schedules=None, policies=None):
    cfg = get_config(arch).reduced()
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    if optimizer is not None:
        cfg = dataclasses.replace(cfg, optimizer=optimizer)
    rt = FSDPRuntime(build_model(cfg), MESH, schedule=schedule, donate=False,
                     group_schedules=group_schedules, policies=policies)
    return cfg, rt


def _train(schedule, steps=3, **kw):
    cfg, rt = _build(schedule, **kw)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(steps):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        params, state, st, m = fn(params, state, st, batch)
        losses.append(float(m["loss"]))
    finals = {k: jax.tree.map(np.asarray, v) for k, v in params.items()}
    return losses, finals, rt


def _assert_trees_equal(a, b, msg):
    eq = jax.tree.map(np.array_equal, a, b)
    assert jax.tree.all(eq), (msg, eq)


# --------------------------------------------------------------------------- #
# codec units
# --------------------------------------------------------------------------- #

def test_cast_codec_roundtrip_and_bytes():
    x = jnp.asarray(np.random.default_rng(0).normal(size=256), jnp.float32)
    for fmt in ("fp32", "bf16"):
        c = WireCodec(fmt)
        y = c.decode(c.encode(x), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(x.astype(c.dtype).astype(jnp.float32)))
        assert c.wire_bytes(256) == 256 * c.dtype.itemsize
    assert fmt_of_dtype(jnp.bfloat16) == "bf16"
    assert fmt_of_dtype(jnp.float32) == "fp32"


def test_q8_codec_error_bound_and_bytes():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=512) * 10, jnp.float32)
    c = WireCodec("q8_block", 64)
    payload = c.encode(x)
    assert set(payload) == {"codes", "scales"}
    assert payload["codes"].dtype == jnp.int8
    y = np.asarray(c.decode(payload, jnp.float32))
    err = np.abs(y - np.asarray(x)).reshape(-1, 64)
    sc = np.asarray(payload["scales"]).reshape(-1, 1)
    assert (err <= sc / 2 + 1e-6).all()
    assert c.wire_bytes(512) == 512 + (512 // 64) * 4
    # q8 vs fp32: >= 3x fewer bytes even at the reduced block size of 64
    assert WireCodec("fp32").wire_bytes(512) / c.wire_bytes(512) >= 3.0
    with pytest.raises(ValueError):
        WireCodec("int4")
    with pytest.raises(ValueError):
        WireCodec("q8_block").dtype


# --------------------------------------------------------------------------- #
# lowering: cast reduce wires == legacy reduce_dtype, bitwise
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("fmt", ["fp32", "bf16"])
def test_cast_reduce_wire_is_reduce_dtype_bitwise(fmt):
    ref = _train(CommSchedule(reduce_dtype=fmt), steps=2)
    tst = _train(CommSchedule(reduce_wire=fmt), steps=2)
    assert ref[0] == tst[0], (fmt, ref[0], tst[0])
    _assert_trees_equal(ref[1], tst[1], f"reduce_wire={fmt}")


def test_reduce_wire_resolution():
    cd = jnp.dtype(jnp.bfloat16)
    s = CommSchedule(reduce_wire="fp32")
    assert s.accum_dtype(cd) == jnp.float32
    assert s.reduce_codec(cd).fmt == "fp32"
    s = CommSchedule(reduce_wire="q8_block")
    assert s.accum_dtype(cd) == jnp.float32  # dequant-accumulate in fp32
    assert s.reduce_codec(cd, 64) == WireCodec("q8_block", 64)
    assert s.ef_enabled
    # legacy default: reduce codec is the accum dtype's cast codec
    s = CommSchedule()
    assert s.reduce_codec(cd).fmt == "bf16"
    assert not s.ef_enabled


def test_reduce_wire_validation():
    with pytest.raises(ValueError):
        CommSchedule(reduce_wire="int4")
    with pytest.raises(ValueError):  # legacy + new spelling conflict
        CommSchedule(reduce_wire="fp32", reduce_dtype="fp32")
    with pytest.raises(ValueError):  # nothing to quantize when replicated
        CommSchedule(reduce_wire="q8_block",
                     sharded=False).validate_for(jnp.bfloat16)
    CommSchedule(reduce_wire="q8_block").validate_for(jnp.bfloat16)
    assert "reduce_wire" in GROUP_OVERRIDE_KEYS
    got = resolve_group_schedules(
        CommSchedule.default(), {"layers": {"reduce_wire": "q8_block"}})
    assert got["layers"].reduce_wire == "q8_block"
    # the two reduce spellings are one knob: a per-group override of one
    # displaces the base's other (no spurious both-set error)
    got = resolve_group_schedules(
        CommSchedule(reduce_dtype="fp32"),
        {"layers": {"reduce_wire": "q8_block"}})
    assert (got["layers"].reduce_wire == "q8_block"
            and got["layers"].reduce_dtype is None)
    got = resolve_group_schedules(
        CommSchedule(reduce_wire="q8_block"),
        {"globals": {"reduce_dtype": "fp32"}})
    assert (got["globals"].reduce_dtype == "fp32"
            and got["globals"].reduce_wire is None)


def test_microbatch_accumulation_with_ef_matches_single_batch():
    """Deferred EF: with microbatches > 1 the runtime accumulates fp32
    cotangents across micro-steps and runs ONE quantized reduce-scatter +
    error-feedback update at the accumulation boundary.  Because the mean
    over micro-slices of per-slice cotangents equals the full-batch
    cotangent, the deferred path must produce the same loss trajectory as
    microbatches=1 on the same global batch (up to bf16 activation
    accumulation order)."""
    from repro.configs.base import ParallelConfig

    def run(micro, steps=3):
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(cfg, parallel=ParallelConfig(
            ("data",), ("data",), microbatches=micro))
        rt = FSDPRuntime(build_model(cfg), MESH, schedule=Q8R, donate=False)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)
        rng = np.random.default_rng(0)
        losses = []
        for _ in range(steps):
            batch = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
            params, state, st, m = fn(params, state, st, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses))
        return losses

    ref, acc = run(1), run(2)
    np.testing.assert_allclose(acc, ref, rtol=2e-2)


def test_replica_grad_axes_rejected_with_ef():
    """HSDP (pod replica) grads are psum'd across replicas AFTER the
    reduce-scatter, so each replica would compute a different EF residual
    under a state pspec that claims replication -- the runtime must
    reject the combination (quantized replica reductions are future
    work), and the auto planner must never emit it."""
    from repro.compat import make_mesh
    from repro.configs.base import ParallelConfig
    from repro.core.policy import auto_policies

    mesh = make_mesh((1, 1), ("pod", "data"))
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, parallel=ParallelConfig(
        ("data",), ("data",)))
    rt = FSDPRuntime(build_model(cfg), mesh, schedule=Q8R, donate=False)
    with pytest.raises(ValueError, match="replica"):
        rt.make_train_step(make_optimizer(cfg))
    # pod_fsdp extends ZeRO-3 over pods: no replica axis, EF is legal
    cfg_pf = dataclasses.replace(cfg, parallel=ParallelConfig(
        ("data",), ("data",), pod_fsdp=True))
    rt2 = FSDPRuntime(build_model(cfg_pf), mesh, schedule=Q8R, donate=False)
    rt2.make_train_step(make_optimizer(cfg_pf))
    # auto on an HSDP mesh keeps the exact wire
    pset = auto_policies(build_model(cfg), {"pod": 2, "data": 64})
    assert pset.default.reduce_wire is None
    assert all(r.policy.reduce_wire is None for r in pset.rules)


# --------------------------------------------------------------------------- #
# q8 gradient wire: training, EF residual semantics, state structure
# --------------------------------------------------------------------------- #

def test_q8_reduce_state_structure_and_align():
    _, rt = _build(Q8R)
    params = rt.init_params(0)
    shapes = rt.param_shapes()
    for name, lo in rt.layouts.items():
        st = params[name]
        assert lo.store.has_ef and lo.store.ef_m >= 1
        assert set(st) >= {"master", EF_KEY}
        assert st[EF_KEY].dtype == jnp.float32
        # the residual is m shard-lengths: the local gradient contribution
        assert (st[EF_KEY].shape[-1]
                == lo.global_shape()[-1] * lo.store.ef_m)
        assert np.all(np.asarray(st[EF_KEY]) == 0.0)  # fresh history
        assert {k: v.shape for k, v in shapes[name].items()} == {
            k: v.shape for k, v in st.items()}
        # the planner align guarantee, extended to the reduce wire:
        # reduce-scatter chunks (= shards) are block multiples
        assert lo.plan.shard_size % lo.store.block == 0


def test_q8_reduce_tracks_fp32_wire_loss():
    """The acceptance smoke: q8 gradient wire + error feedback reaches
    every step's loss within 2% of the fp32-wire trajectory."""
    ref, _, _ = _train(CommSchedule(), steps=5)
    q8, finals, _ = _train(Q8R, steps=5)
    assert all(np.isfinite(q8))
    for r, q in zip(ref, q8):
        assert abs(r - q) < 0.02 * max(1.0, abs(r)), (ref, q8)
    # EF is live: residuals are nonzero after training steps
    assert any(np.abs(finals[n][EF_KEY]).max() > 0 for n in finals)


def test_ef_residual_is_exact_quantization_error():
    """The reduce-combine rule's EF contract, checked on the codec
    directly: the new residual is exactly ``comp - decode(encode(comp))``
    for the compensated cotangent, and the shard is the decoded payload
    (m == 1 degenerates to the local quantize/dequantize round-trip).

    The expectation is composed UNDER JIT (kernels.ref.encode_ef_ref is
    the op-for-op unfused sequence): XLA contracts ``comp - codes*scale``
    into an fma on every backend, so a jitted residual differs from the
    eagerly-composed one by the fma's single rounding -- sub-ulp, and
    identical between the fused kernel and the jitted unfused path, which
    is the regime every training step runs in (DESIGN.md, parity-class
    convention)."""
    rng = np.random.default_rng(3)
    ct = jnp.asarray(rng.normal(size=256), jnp.float32)
    ef0 = jnp.asarray(rng.normal(size=256) * 0.01, jnp.float32)
    codec = WireCodec("q8_block", 64)
    from repro.core.wire import codec_reduce_scatter
    from repro.kernels.ref import encode_ef_ref

    want_codes, want_scales, want_ef = jax.jit(
        lambda c, e: encode_ef_ref(c, e, 64))(ct, ef0)
    shard, new_ef = codec_reduce_scatter(
        ct, ef0, codec, (), (), "xla", "match", jnp.dtype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(new_ef), np.asarray(want_ef))
    np.testing.assert_array_equal(
        np.asarray(shard),
        np.asarray(codec.decode(
            {"codes": want_codes, "scales": want_scales}, jnp.float32)))


@pytest.mark.parametrize("name,sched", [
    ("ring", dataclasses.replace(Q8R, gather_mode="ring")),
    ("prefetch", dataclasses.replace(Q8R, prefetch=True)),
    ("keep_last", dataclasses.replace(Q8R, prefetch=True,
                                      keep_last_gathered=True)),
    ("q8_both", APPROX_VARIANTS["q8_both_wires"]),
])
def test_q8_reduce_comm_variants_consistent(name, sched):
    """Comm-path reorderings of the same quantized gradient payload are
    bitwise-identical at a fixed device count (q8_both additionally
    quantizes the store -- compared against its own xla/sequential
    twin)."""
    base = (APPROX_VARIANTS["q8_both_wires"] if name == "q8_both"
            else Q8R)
    tw = (dataclasses.replace(base, gather_mode="ring", prefetch=True)
          if name == "q8_both" else sched)
    ref = _train(base, n_layers=3, steps=2)
    tst = _train(tw, n_layers=3, steps=2)
    assert ref[0] == tst[0], (name, ref[0], tst[0])
    _assert_trees_equal(ref[1], tst[1], f"q8_reduce:{name}")


def test_q8_reduce_ring_acc_allclose():
    """ring_acc + q8 reduce wire (per-hop requantizing ring) on one device
    degenerates to the same quantize/dequantize round-trip -- bitwise here;
    the 8-device scenario asserts allclose."""
    ref = _train(Q8R, steps=2)
    tst = _train(APPROX_VARIANTS["q8_reduce_ring_acc"], steps=2)
    assert ref[0] == tst[0]
    _assert_trees_equal(ref[1], tst[1], "q8_reduce_ring_acc@1dev")


def test_q8_reduce_group_override_and_policy_rule():
    """Per-group reduce_wire: only the layer stack quantizes its gradient
    wire; globals keep the legacy dtype wire (bare-array state).  The
    PolicyRule spelling resolves to the same plan JSON."""
    losses, finals, rt = _train(
        CommSchedule.default(), steps=2,
        group_schedules={"layers": {"reduce_wire": "q8_block"}})
    assert all(np.isfinite(losses))
    assert isinstance(finals["layers"], dict) and EF_KEY in finals["layers"]
    assert isinstance(finals["globals"], np.ndarray)
    assert rt.layouts["layers"].store.has_ef
    assert not rt.layouts["globals"].store.has_ef

    pset = PolicySet(
        rules=(PolicyRule(match="layers",
                          policy=ShardingPolicy(reduce_wire="q8_block")),))
    cfg = get_config("qwen2.5-14b").reduced()
    p1 = make_plan(build_model(cfg), MESH, pset)
    assert p1.dumps() == rt.plan.dumps(), p1.diff(rt.plan)


def test_q8_reduce_with_optimizers_and_stores():
    """EF composes with the quantized store + int8 optimizer state (every
    block-quantized pipeline in one step) and with the bf16 store."""
    for kw in ({"optimizer": "adam8bit"},):
        losses, _, _ = _train(APPROX_VARIANTS["q8_both_wires"], steps=2,
                              **kw)
        assert all(np.isfinite(losses))
    losses, finals, _ = _train(
        CommSchedule(param_store="bf16", reduce_wire="q8_block"), steps=2)
    assert all(np.isfinite(losses))
    assert finals["layers"]["master"].dtype == jnp.bfloat16


# --------------------------------------------------------------------------- #
# checkpoints: EF residual round-trip
# --------------------------------------------------------------------------- #

def test_ef_checkpoint_roundtrip_and_cross_format():
    cfg, rt = _build(Q8R)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    rng = np.random.default_rng(0)
    st = jnp.int32(0)
    for _ in range(2):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        params, state, st, _ = fn(params, state, st, batch)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, rt, params, state, step=2)
        p2, step, s2 = ckpt.load(d, rt, opt.init(rt))
        assert step == 2
        for name in params:
            for leaf in ("master", EF_KEY):
                np.testing.assert_array_equal(
                    np.asarray(params[name][leaf]),
                    np.asarray(p2[name][leaf]),
                    err_msg=f"{name}.{leaf} not bitwise through ckpt")
        # cross-format restore: an fp32-wire runtime reads the EF
        # checkpoint through the master rebuild path (no residual leaf)
        _, rt32 = _build(CommSchedule())
        p3, _ = ckpt.load(d, rt32)
        for name in p3:
            assert not isinstance(p3[name], dict)
            np.testing.assert_array_equal(
                np.asarray(p3[name]),
                np.asarray(params[name]["master"]),
                err_msg=f"{name}: master lost in cross-format restore")
        # and the reverse: the EF runtime restores a plain checkpoint with
        # a fresh zero residual
        with tempfile.TemporaryDirectory() as d2:
            params32 = rt32.init_params(0)
            ckpt.save(d2, rt32, params32, step=0)
            p4, _ = ckpt.load(d2, rt)
            for name in p4:
                assert np.all(np.asarray(p4[name][EF_KEY]) == 0.0)


# --------------------------------------------------------------------------- #
# accounting + auto planner reduce pricing
# --------------------------------------------------------------------------- #

def test_reduce_wire_accounting():
    _, rt32 = _build(CommSchedule(reduce_dtype="fp32"))
    _, rtq8 = _build(Q8R)
    w32, wq8 = rt32.reduce_wire_bytes(), rtq8.reduce_wire_bytes()
    expected = sum(
        (lo.plan.total + lo.plan.total // lo.store.block * 4)
        * (lo.n_layers or 1)
        for lo in rtq8.layouts.values() if lo.fsdp_axes)
    assert wq8 == expected
    assert w32 / wq8 >= 3.0, f"q8 reduce wire only {w32 / wq8:.2f}x smaller"
    # default (bf16 accum) sits in between
    _, rtbf = _build(CommSchedule.default())
    assert wq8 < rtbf.reduce_wire_bytes() < w32
    # the plan json and describe() carry the reduce wire
    j = rtq8.plan.to_json()
    assert all(g["reduce_wire_mb"] > 0 for g in j["groups"].values()
               if g["fsdp_axes"])
    assert "reduce_wire_mb" in rtq8.plan.describe()
    assert "q8_block" in rtq8.plan.groups["layers"].policy.describe()


def test_cost_model_prices_reduce_direction():
    cm = CostModel(ici_bw=1e11, hbm_bw=1e12, peak_flops=1e15)
    # m=1: no wire at all -> the exact dtype wire wins (ties break exact)
    assert cm.choose_reduce_wire(1 << 20, 32, 1, 1024, 2) is None
    # bandwidth-bound stack at scale: the q8 gradient wire wins
    slow = CostModel(ici_bw=1e9, hbm_bw=1e12, peak_flops=1e15)
    assert slow.choose_reduce_wire(1 << 22, 32, 64, 1024, 2) == "q8_block"
    # and the auto planner threads it into policies on a big mesh
    cfg = get_config("qwen2.5-14b").reduced()
    pset = make_plan(build_model(cfg), {"data": 64}, "auto",
                     cost_model=slow).policy_set()
    pols = list({r.match: r.policy for r in pset.rules}.values()) + [
        pset.default]
    q8r = [p for p in pols if p.reduce_wire == "q8_block"]
    assert q8r
    # auto pairs the q8 gradient wire with the accumulate-in-flight ring
    # (the route the cost model's (m-1)/m volume is true of; match-mode
    # q8 ships (m-1)/2 x the payload)
    assert all(p.reduce_mode == "ring_acc" for p in q8r)
    # ...but never for an accumulating config: the EF wire does not
    # compose with microbatches, so auto must only score legal candidates
    from repro.configs.base import ParallelConfig

    cfg_mb = dataclasses.replace(cfg, parallel=ParallelConfig(
        ("data",), ("data",), microbatches=2))
    pset_mb = make_plan(build_model(cfg_mb), {"data": 64}, "auto",
                        cost_model=slow).policy_set()
    assert pset_mb.default.reduce_wire is None
    assert all(r.policy.reduce_wire is None for r in pset_mb.rules)


# --------------------------------------------------------------------------- #
# fp8 plumbing (guarded satellite)
# --------------------------------------------------------------------------- #

def test_fp8_dtypes_guarded():
    fp8 = compat.float8_dtypes()
    if not compat.HAS_FP8:
        assert fp8 == {}
        assert not any(f.startswith("fp8_") for f in WIRE_FORMATS)
        return
    # present-on-installed-JAX: fp8 names are legal cast wire formats end
    # to end without call-site changes
    assert set(fp8) == {"fp8_e4m3", "fp8_e5m2"}
    for name, dt in fp8.items():
        assert name in CAST_FORMATS and name in WIRE_FORMATS
        c = WireCodec(name)
        assert c.dtype == dt
        assert c.wire_bytes(128) == 128  # 1 byte/element
        assert fmt_of_dtype(dt) == name
        x = jnp.asarray([0.5, -1.0, 2.0], jnp.float32)
        y = c.decode(c.encode(x), jnp.float32)
        assert np.isfinite(np.asarray(y)).all()
    # schedule-level: fp8 is a legal gather wire dtype name...
    CommSchedule(gather_dtype="fp8_e4m3").validate_for(jnp.bfloat16)
    # ...and a legal cast reduce wire
    s = CommSchedule(reduce_wire="fp8_e5m2")
    assert s.reduce_codec(jnp.dtype(jnp.bfloat16)).fmt == "fp8_e5m2"
    # ...and, since the fused update kernels landed, a ParamStore format
    # too (fp8 codes + fp32 master; tests/test_fp8_store.py owns it)
    st = ParamStore("fp8_e4m3")
    assert st.fp8 and st.align() == 1


def test_fp8_gather_wire_train_smoke():
    if not compat.HAS_FP8:
        pytest.skip("installed JAX has no float8 dtypes")
    losses, _, _ = _train(CommSchedule(gather_dtype="fp8_e4m3",
                                       reduce_dtype="fp32"), steps=2)
    assert all(np.isfinite(losses))


# --------------------------------------------------------------------------- #
# 8-device: q8 reduce over real shards (xla==ring bitwise, ring_acc
# allclose, fp32-wire tracking, EF checkpoint round-trip)
# --------------------------------------------------------------------------- #

_DRIVER_8DEV = textwrap.dedent("""
    import os, sys, json, dataclasses, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, build_model
    from repro.configs.base import ParallelConfig
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import CommSchedule
    from repro.core.store import EF_KEY
    from repro.checkpoint import ckpt
    from repro.optim import make_optimizer
    from repro.launch.mesh import make_local_mesh

    MESH8 = make_local_mesh(8, 1)
    Q8R = CommSchedule(reduce_wire="q8_block")

    def train(schedule, steps=2, mesh=MESH8, group_schedules=None):
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(cfg, n_layers=3,
                                  parallel=ParallelConfig(("data",), ("data",)))
        model = build_model(cfg)
        rt = FSDPRuntime(model, mesh, schedule=schedule, donate=False,
                         group_schedules=group_schedules)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)
        rng = np.random.default_rng(0)
        losses = []
        for i in range(steps):
            batch = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
            params, state, st, m = fn(params, state, st, batch)
            losses.append(float(m["loss"]))
        finals = {k: jax.tree.map(np.asarray, v) for k, v in params.items()}
        return losses, finals, (rt, params, state, opt)

    out = {}

    # q8 gradient wire over real 8-way FSDP
    ref_l, ref_p, (rt, live_params, live_state, opt) = train(Q8R)
    out["finite"] = bool(np.isfinite(ref_l).all())
    out["ef_nonzero"] = bool(
        max(np.abs(p[EF_KEY]).max() for p in ref_p.values()) > 0)

    # xla vs ring gather modes move the same once-encoded payload and
    # accumulate in absolute device order: bitwise-identical
    bad = []
    for name, sched in {
        "ring": dataclasses.replace(Q8R, gather_mode="ring"),
        "prefetch": dataclasses.replace(Q8R, prefetch=True),
        "ring_prefetch": dataclasses.replace(Q8R, gather_mode="ring",
                                             prefetch=True),
    }.items():
        l, p, _ = train(sched)
        if l != ref_l or not jax.tree.all(
                jax.tree.map(np.array_equal, ref_p, p)):
            bad.append(name)
    out["bad_variants"] = bad

    # allclose tracking vs the fp32 reduce wire (QSDP's convergence claim)
    f32_l, _, _ = train(CommSchedule(reduce_dtype="fp32"))
    out["vs_fp32_wire"] = max(abs(a - b) / max(1.0, abs(a))
                              for a, b in zip(f32_l, ref_l))

    # ring_acc (per-hop requantizing accumulate-in-flight ring): allclose
    a_l, a_p, _ = train(CommSchedule(gather_mode="ring",
                                     reduce_mode="ring_acc",
                                     reduce_wire="q8_block"))
    out["ring_acc_rel"] = max(abs(a - b) / max(1.0, abs(a))
                              for a, b in zip(ref_l, a_l))
    out["ring_acc_allclose"] = bool(all(
        np.allclose(np.asarray(ref_p[n]["master"], np.float32),
                    np.asarray(a_p[n]["master"], np.float32),
                    rtol=2e-2, atol=1e-3)
        for n in ref_p))

    # per-group override on real shards: layers quantized, globals legacy
    g_l, g_p, _ = train(CommSchedule(),
                        group_schedules={"layers":
                                         {"reduce_wire": "q8_block"}})
    out["override_finite"] = bool(np.isfinite(g_l).all())
    out["override_shapes_ok"] = bool(
        isinstance(g_p["layers"], dict) and EF_KEY in g_p["layers"]
        and not isinstance(g_p["globals"], dict))

    # EF residual checkpoint round-trip on real 8-way shards
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, rt, live_params, live_state, step=2)
        p2, step, s2 = ckpt.load(d, rt, opt.init(rt))
        ok = step == 2
        for name in ref_p:
            for leaf in ("master", EF_KEY):
                ok = ok and np.array_equal(
                    np.asarray(live_params[name][leaf]),
                    np.asarray(p2[name][leaf]))
        out["ckpt_bitwise"] = bool(ok)

    # reduce-wire accounting on the 8-way plan: the >=3x q8 win holds on
    # the bandwidth-optimal (ring_acc) route; the order-exact match-mode
    # q8 route honestly reports its m/2 un-reduced-chunk multiplier
    cfg32 = dataclasses.replace(
        get_config("qwen2.5-14b").reduced(), n_layers=3,
        parallel=ParallelConfig(("data",), ("data",), reduce_dtype="fp32"))
    rt32 = FSDPRuntime(build_model(cfg32), MESH8, donate=False)
    cfg_acc = dataclasses.replace(
        get_config("qwen2.5-14b").reduced(), n_layers=3,
        parallel=ParallelConfig(("data",), ("data",),
                                reduce_wire="q8_block",
                                reduce_mode="ring_acc"))
    rt_acc = FSDPRuntime(build_model(cfg_acc), MESH8, donate=False)
    out["wire_ratio"] = rt32.reduce_wire_bytes() / rt_acc.reduce_wire_bytes()
    out["match_q8_times_m_over_2"] = (
        rt.reduce_wire_bytes() == rt_acc.reduce_wire_bytes() * 8 // 2)

    print(json.dumps(out))
""")


@pytest.mark.slow
def test_wire_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DRIVER_8DEV],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["finite"] and data["ef_nonzero"]
    assert data["bad_variants"] == [], data
    assert data["vs_fp32_wire"] < 0.02, data
    assert data["ring_acc_rel"] < 0.05, data
    assert data["ring_acc_allclose"], data
    assert data["override_finite"] and data["override_shapes_ok"], data
    assert data["ckpt_bitwise"], "EF residual not bitwise through ckpt"
    assert data["wire_ratio"] >= 3.0, data
    assert data["match_q8_times_m_over_2"], data
