"""ParamStore storage formats (core.store) and reduce modes.

Guarantees under test:
  * fp32 store: explicit ``param_store="fp32"`` is bitwise-identical to the
    default schedule (the pre-store runtime's format).
  * q8_block store: training runs on 1 and 8 devices, for xla and ring
    gather modes with and without prefetch, and all four are bitwise-
    identical to each other at a fixed device count (pure comm-path
    reorderings of the same quantized payload); the dequantized weights
    stay within the per-block int8 bound of the fp32 master; the codes are
    always the exact requantization of the master.
  * ring_acc reduce-scatter: allclose (not bitwise) parity with the
    order-exact reduce over 8-way FSDP, at n-1 chunk-hops wire cost.
  * gather_wire_bytes: the q8 wire is ~4x smaller than an fp32 wire.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.core.schedule import (APPROX_VARIANTS, GROUP_OVERRIDE_KEYS,
                                 CommSchedule, resolve_group_schedules)
from repro.core.store import ParamStore
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer
from repro.quant.blockwise import dequantize_blockwise, quantize_blockwise

MESH = make_local_mesh(1, 1)

Q8 = CommSchedule(param_store="q8_block")


def _build(schedule, arch="qwen2.5-14b", n_layers=None, optimizer=None,
           group_schedules=None):
    cfg = get_config(arch).reduced()
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    if optimizer is not None:
        cfg = dataclasses.replace(cfg, optimizer=optimizer)
    rt = FSDPRuntime(build_model(cfg), MESH, schedule=schedule, donate=False,
                     group_schedules=group_schedules)
    return cfg, rt


def _train(schedule, steps=3, **kw):
    cfg, rt = _build(schedule, **kw)
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    state = opt.init(rt)
    fn = rt.make_train_step(opt)
    st = jnp.int32(0)
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(steps):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        params, state, st, m = fn(params, state, st, batch)
        losses.append(float(m["loss"]))
    finals = {k: jax.tree.map(np.asarray, v) for k, v in params.items()}
    return losses, finals, rt


def _assert_trees_equal(a, b, msg):
    eq = jax.tree.map(np.array_equal, a, b)
    assert jax.tree.all(eq), (msg, eq)


# --------------------------------------------------------------------------- #
# fp32 regression + structure
# --------------------------------------------------------------------------- #

def test_fp32_store_explicit_is_default_bitwise():
    ref = _train(CommSchedule.default())
    tst = _train(CommSchedule(param_store="fp32"))
    assert ref[0] == tst[0]
    _assert_trees_equal(ref[1], tst[1], "fp32 store != default")
    # fp32 states are bare arrays: the seed's param format, unchanged
    assert all(isinstance(v, np.ndarray) for v in ref[1].values())


def test_q8_state_structure():
    _, rt = _build(Q8)
    params = rt.init_params(0)
    shapes = rt.param_shapes()
    for name, lo in rt.layouts.items():
        st = params[name]
        assert set(st) == {"codes", "master", "scales"}
        assert st["codes"].dtype == jnp.int8
        assert st["master"].dtype == jnp.float32
        assert st["master"].shape == lo.global_shape()
        assert st["scales"].shape[-1] * lo.store.block == lo.global_shape()[-1]
        assert {k: v.shape for k, v in shapes[name].items()} == {
            k: v.shape for k, v in st.items()}
        # the planner's align guarantee, extended to quantized stores:
        # shard size a multiple of the quant block, tensor starts aligned
        assert lo.plan.shard_size % lo.store.block == 0
        for pl in lo.plan.placements:
            assert pl.offset % lo.store.block == 0


def test_q8_codes_track_master_through_training():
    """After any number of fused update+requantize passes, the stored codes
    must equal the exact requantization of the stored master, and the
    dequantized weights must sit within the per-block int8 bound."""
    cfg, rt = _build(Q8)
    _, finals, _ = _train(Q8, steps=3)
    for name, st in finals.items():
        block = rt.layouts[name].store.block
        codes, scales = quantize_blockwise(
            jnp.asarray(st["master"]), block)
        np.testing.assert_array_equal(np.asarray(codes), st["codes"],
                                      err_msg=f"{name}: stale codes")
        deq = np.asarray(dequantize_blockwise(
            jnp.asarray(st["codes"]), jnp.asarray(st["scales"]), block))
        err = np.abs(deq - st["master"]).reshape(-1, block)
        sc = st["scales"].reshape(-1, 1)
        slack = 4 * np.finfo(np.float32).eps * np.abs(
            st["master"]).reshape(-1, block)
        assert (err <= sc / 2 + slack + 1e-7).all(), name


@pytest.mark.parametrize("name,sched", [
    ("ring", dataclasses.replace(Q8, gather_mode="ring")),
    ("prefetch", dataclasses.replace(Q8, prefetch=True)),
    ("ring_prefetch", APPROX_VARIANTS["q8_ring_prefetch"]),
    ("keep_last", dataclasses.replace(Q8, keep_last_gathered=True,
                                      prefetch=True)),
])
def test_q8_comm_variants_bitwise_consistent(name, sched):
    """xla/ring x prefetch/sequential move the same quantized payload in a
    different order: trajectories must agree bitwise at a fixed device
    count (the q8 twin of the fp32 parity suite)."""
    ref = _train(Q8, n_layers=3)
    tst = _train(sched, n_layers=3)
    assert ref[0] == tst[0], (name, ref[0], tst[0])
    _assert_trees_equal(ref[1], tst[1], f"q8:{name}")


def test_q8_tracks_fp32_loss():
    """Quantized-weight training follows the fp32 trajectory at int8
    resolution (QSDP's convergence claim at repro scale)."""
    ref, _, _ = _train(CommSchedule.default())
    q8, _, _ = _train(Q8)
    for r, q in zip(ref, q8):
        assert abs(r - q) < 0.05 * max(1.0, abs(r)), (ref, q8)
    assert all(np.isfinite(q8))


def test_q8_with_adam8bit_and_bf16_store():
    """q8 weights compose with int8 optimizer state (both block-quantized
    pipelines in one step); bf16 store trains and halves storage."""
    q8, _, _ = _train(Q8, optimizer="adam8bit", steps=2)
    assert all(np.isfinite(q8))
    ref, _, _ = _train(CommSchedule.default(), steps=2)
    bf, finals, rt = _train(CommSchedule(param_store="bf16"), steps=2)
    assert all(isinstance(v, np.ndarray) and v.dtype == jnp.bfloat16
               for v in finals.values())
    for r, b in zip(ref, bf):
        assert abs(r - b) < 0.05 * max(1.0, abs(r)), (ref, bf)


def test_q8_group_override_mixed_stores():
    """Per-group param_store: only the layer stack quantized, globals stay
    fp32 flat buffers."""
    losses, finals, rt = _train(
        CommSchedule.default(), steps=2,
        group_schedules={"layers": {"param_store": "q8_block"}})
    assert all(np.isfinite(losses))
    assert isinstance(finals["layers"], dict)
    assert isinstance(finals["globals"], np.ndarray)
    assert rt.layouts["layers"].store.quantized
    assert not rt.layouts["globals"].store.quantized


def test_q8_prefill_smoke():
    """The serve path gathers through the same store layer: prefill on a
    quantized store produces finite logits."""
    cfg, rt = _build(Q8)
    params = rt.init_params(0)
    cache = rt.model.init_cache(2, 16)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    logits, cache = rt.make_prefill_step()(params, {"tokens": tokens}, cache)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# --------------------------------------------------------------------------- #
# wire accounting
# --------------------------------------------------------------------------- #

def test_gather_wire_bytes_q8_vs_fp32():
    _, rt32 = _build(CommSchedule(gather_dtype="fp32"))
    _, rtq8 = _build(Q8)
    w32, wq8 = rt32.gather_wire_bytes(), rtq8.gather_wire_bytes()
    # exact formula: 4 B/elt fp32 vs 1 B/elt of codes + 4 B/block of scales
    expected = sum(
        (lo.plan.total + lo.plan.total // lo.store.block * 4)
        * (lo.n_layers or 1)
        for lo in rtq8.layouts.values() if lo.fsdp_axes)
    assert wq8 == expected
    ratio = w32 / wq8
    assert ratio > 3.5, f"q8 wire only {ratio:.2f}x smaller than fp32"
    # default (bf16 wire) sits in between
    _, rtbf = _build(CommSchedule.default())
    assert wq8 < rtbf.gather_wire_bytes() < w32


# --------------------------------------------------------------------------- #
# validation
# --------------------------------------------------------------------------- #

def test_store_validation():
    with pytest.raises(ValueError):
        CommSchedule(param_store="int4")
    with pytest.raises(ValueError):
        ParamStore("int4")
    with pytest.raises(ValueError):
        ParamStore("q8_block", 0)
    # q8 fixes the wire payload: a gather_dtype is contradictory
    with pytest.raises(ValueError):
        CommSchedule(param_store="q8_block",
                     gather_dtype="fp32").validate_for(jnp.bfloat16)
    CommSchedule(param_store="q8_block").validate_for(jnp.bfloat16)
    with pytest.raises(ValueError):
        CommSchedule(reduce_mode="tree")
    # param_store and reduce_mode are per-group overridable
    assert {"param_store", "reduce_mode"} <= GROUP_OVERRIDE_KEYS
    got = resolve_group_schedules(
        CommSchedule.default(), {"layers": {"param_store": "q8_block"}})
    assert got["layers"].param_store == "q8_block"


def test_q8_rejects_unaligned_baseline_planner():
    """Baseline planners don't honor align; quantized stores must fail
    loudly instead of producing straddling blocks."""
    cfg = get_config("qwen2.5-14b").reduced()
    try:
        rt = FSDPRuntime(build_model(cfg), MESH, planner="fsdp2",
                         schedule=Q8, donate=False)
    except ValueError:
        return  # unaligned shard size rejected at init: the guarantee
    # if the shard size happened to align, the plan must actually be valid
    for lo in rt.layouts.values():
        assert lo.plan.shard_size % lo.store.block == 0


# --------------------------------------------------------------------------- #
# 8-device: q8 over real shards, ring_acc parity, q8 checkpoint round-trip
# --------------------------------------------------------------------------- #

_DRIVER_8DEV = textwrap.dedent("""
    import os, sys, json, dataclasses, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, build_model
    from repro.configs.base import ParallelConfig
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import CommSchedule
    from repro.checkpoint import ckpt
    from repro.optim import make_optimizer
    from repro.launch.mesh import make_local_mesh

    MESH8 = make_local_mesh(8, 1)
    Q8 = CommSchedule(param_store="q8_block")

    def train(schedule, steps=2, mesh=MESH8):
        cfg = get_config("qwen2.5-14b").reduced()
        cfg = dataclasses.replace(cfg, n_layers=3,
                                  parallel=ParallelConfig(("data",), ("data",)))
        model = build_model(cfg)
        rt = FSDPRuntime(model, mesh, schedule=schedule, donate=False)
        params = rt.init_params(0)
        opt = make_optimizer(cfg)
        state = opt.init(rt)
        fn = rt.make_train_step(opt)
        st = jnp.int32(0)
        rng = np.random.default_rng(0)
        losses = []
        for i in range(steps):
            batch = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
            params, state, st, m = fn(params, state, st, batch)
            losses.append(float(m["loss"]))
        finals = {k: jax.tree.map(np.asarray, v) for k, v in params.items()}
        return losses, finals, (rt, params, state, opt)

    out = {}

    # q8 comm variants over 8-way FSDP: all bitwise-identical
    ref_l, ref_p, (rt, live_params, live_state, opt) = train(Q8)
    out["q8_finite"] = bool(np.isfinite(ref_l).all())
    bad = []
    for name, sched in {
        "ring": dataclasses.replace(Q8, gather_mode="ring"),
        "prefetch": dataclasses.replace(Q8, prefetch=True),
        "ring_prefetch": dataclasses.replace(Q8, gather_mode="ring",
                                             prefetch=True),
    }.items():
        l, p, _ = train(sched)
        if l != ref_l or not jax.tree.all(
                jax.tree.map(np.array_equal, ref_p, p)):
            bad.append(name)
    out["q8_bad_variants"] = bad

    # vs 1 device: same tolerance as the rest of the multidevice suite
    one_l, _, _ = train(Q8, mesh=make_local_mesh(1, 1))
    out["q8_vs_1dev"] = max(abs(a - b) / max(1.0, abs(a))
                            for a, b in zip(one_l, ref_l))

    # q8 checkpoint round-trip on real 8-way shards: master and codes
    # bitwise-preserved
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, rt, live_params, live_state, step=2)
        p2, step, s2 = ckpt.load(d, rt, opt.init(rt))
        rt2 = None
        ok = step == 2
        for name in ref_p:
            for leaf in ("codes", "master", "scales"):
                ok = ok and np.array_equal(
                    np.asarray(live_params[name][leaf]),
                    np.asarray(p2[name][leaf]))
        out["ckpt_bitwise"] = bool(ok)

    # ring_acc reduce-scatter: allclose parity with the order-exact reduce
    d_l, d_p, _ = train(CommSchedule(reduce_dtype="fp32"))
    a_l, a_p, _ = train(CommSchedule(gather_mode="ring",
                                     reduce_mode="ring_acc",
                                     reduce_dtype="fp32"))
    close = jax.tree.all(jax.tree.map(
        lambda a, b: np.allclose(np.asarray(a, np.float32),
                                 np.asarray(b, np.float32),
                                 rtol=2e-2, atol=1e-4), d_p, a_p))
    out["ring_acc_losses"] = [d_l, a_l]
    out["ring_acc_allclose"] = bool(close)

    print(json.dumps(out))
""")


@pytest.mark.slow
def test_store_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DRIVER_8DEV],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["q8_finite"]
    assert data["q8_bad_variants"] == [], data
    assert data["q8_vs_1dev"] < 0.05, data
    assert data["ckpt_bitwise"], "q8 checkpoint not bitwise on 8 devices"
    assert data["ring_acc_allclose"], data["ring_acc_losses"]
    da, aa = data["ring_acc_losses"]
    for r, t in zip(da, aa):
        assert abs(r - t) < 0.05 * max(1.0, abs(r)), data["ring_acc_losses"]
