"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each family (2 layers, d_model<=256, <=4 experts), run
one forward/train step and one decode step on CPU, assert output shapes and
no NaNs.  The FULL configs are exercised only via the dry run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, build_model, get_config
from repro.core.fsdp import FSDPRuntime
from repro.launch.mesh import make_local_mesh
from repro.optim import make_optimizer

_MESH = None


def mesh():
    global _MESH
    if _MESH is None:
        _MESH = make_local_mesh(1, 1)
    return _MESH


def batch_for(cfg, B, T, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)}
    if cfg.arch_type == "vlm":
        b["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
    if cfg.arch_type == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frames, cfg.d_model)), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = build_model(cfg)
    rt = FSDPRuntime(model, mesh())
    params = rt.init_params(0)
    opt = make_optimizer(cfg)
    ostate = opt.init(rt)
    fn = rt.make_train_step(opt)
    b = batch_for(cfg, 4, 16)
    p2, o2, st, metrics = fn(params, ostate, jnp.int32(0), b)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 20
    # params actually changed and stayed finite
    for name in p2:
        a = np.asarray(p2[name])
        assert np.isfinite(a).all(), name
    # second step decreases-or-similar (sanity, not convergence)
    p3, o3, st, m2 = fn(p2, o2, st, batch_for(cfg, 4, 16, seed=0))
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rt = FSDPRuntime(model, mesh())
    params = rt.init_params(1)
    B, P, S = 2, 8, 32
    cache = model.init_cache(B, S)
    prefill = rt.make_prefill_step()
    decode = rt.make_decode_step()
    b = batch_for(cfg, B, P, seed=1)
    logits, cache = prefill(params, b, cache)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    db = dict(b)
    db["tokens"] = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache = decode(params, db, cache, jnp.int32(P))
    assert logits2.shape == logits.shape
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (nl, d, h, kv, ff, v), arch
        assert cfg.source
    assert get_config("granite-moe-1b-a400m").n_experts == 32
    assert get_config("granite-moe-1b-a400m").top_k == 8
    assert get_config("qwen3-moe-235b-a22b").n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").top_k == 8
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("gemma2-2b").local_global_alternate
    assert get_config("nemotron-4-340b").mlp == "squared_relu"
    assert get_config("qwen2.5-14b").qkv_bias


@pytest.mark.parametrize("arch", ["gemma2-2b", "xlstm-125m", "hymba-1.5b"])
def test_long_context_cache_is_windowed(arch):
    """long_500k viability: cache memory must not scale with 500k for the
    sliding-window/recurrent archs."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = model.cache_shapes(1, 524_288)

    def max_elems(tree):
        leaves = jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, tuple) and x and
            isinstance(x[0], tuple))
        return max(int(np.prod(s)) for s, _ in leaves)

    if arch == "xlstm-125m":
        assert max_elems(shapes) < 10_000_000  # pure state, no KV at all
    else:
        # ring buffer capped at the sliding window, not seq_len
        w = cfg.sliding_window
        for s, _ in jax.tree.leaves(
                shapes, is_leaf=lambda x: isinstance(x, tuple) and x and
                isinstance(x[0], tuple)):
            assert 524_288 not in s
