"""The static-analysis subsystem (repro.analysis): plan verifier +
layering linter.

  * linter rules unit-tested on synthetic trees (compat-only,
    quant-blockwise, bare-assert, parity-tags incl. the DESIGN.md
    cross-check), the real tree proven clean, and the deliberately-bad
    fixture proven to FAIL -- the CI-blocking path without breaking src/.
  * plan-side declarations: every policy combination declares its
    invariant set; the static pass catches a ring chunk whose unit-1 wire
    snap disagrees with the quant-block snap.
  * stale-profile drift: an auto plan records its pricing profile's
    content hash; mutating the profile on disk turns verify_plan_static
    into a warning, re-pricing shows the drift in diff(), and describe()
    carries the provenance.
  * the 8-device subprocess drives the full verifier on real plans:
    q8/ring passes, a tampered plan (bf16 promise vs q8 wire) names
    group+invariant, FSDPRuntime(verify=True) gates construction, and the
    EF-threading regression fires when a plan that declares error
    feedback is verified against a step that computes none.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest
from test_autotune import _measured_profile

from repro.analysis.lint import LintError, run_lint
from repro.analysis.lint import main as lint_main
from repro.analysis.verify import verify_plan_static
from repro.configs import build_model, get_config
from repro.core.policy import CostModel, make_plan
from repro.core.profile import CommSample
from repro.core.schedule import CommSchedule
from repro.core.wire import _snap_chunk

REPO_ROOT = Path(__file__).resolve().parents[1]


def _model(arch="qwen2.5-14b"):
    return build_model(get_config(arch).reduced())


# --------------------------------------------------------------------------- #
# linter rules on synthetic trees
# --------------------------------------------------------------------------- #

def _lint_tree(tmp_path, files, select=None, paths=None):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return run_lint(tmp_path, select=select, paths=paths)


def _rules(errs):
    return {e.rule for e in errs}


def test_compat_only_flags_versioned_symbols(tmp_path):
    errs = _lint_tree(tmp_path, {
        "src/repro/core/x.py": """
            from jax.experimental.shard_map import shard_map
            import jax

            def f(t, g):
                return jax.tree_util.tree_map_with_path(g, t)
        """,
    }, select=["compat-only"])
    assert len(errs) == 2 and _rules(errs) == {"compat-only"}
    assert "repro.compat" in errs[0].msg


def test_compat_only_exemptions(tmp_path):
    # compat.py itself and pallas-in-kernels are the two legal homes
    errs = _lint_tree(tmp_path, {
        "src/repro/compat.py": """
            from jax.experimental.shard_map import shard_map
        """,
        "src/repro/kernels/k.py": """
            import jax.experimental.pallas as pl
        """,
        "src/repro/core/y.py": """
            import jax.experimental.pallas as pl
        """,
    }, select=["compat-only"])
    assert [e.path for e in errs] == ["src/repro/core/y.py"]


def test_quant_blockwise_and_bare_assert(tmp_path):
    errs = _lint_tree(tmp_path, {
        "src/repro/core/hot.py": """
            from ..quant.blockwise import quantize_blockwise

            def f(x):
                assert x is not None
                return quantize_blockwise(x, 64)
        """,
        # quant/ and tests/ keep their oracle imports and asserts
        "src/repro/quant/ref2.py": """
            from .blockwise import quantize_blockwise
        """,
    }, select=["quant-blockwise", "bare-assert"])
    assert [e.path for e in errs] == ["src/repro/core/hot.py"] * 2
    assert _rules(errs) == {"quant-blockwise", "bare-assert"}


def test_parity_tags_and_design_cross_check(tmp_path):
    (tmp_path / "DESIGN.md").write_text(
        "| `ops.foo` fused decode | BITWISE |\n")
    errs = _lint_tree(tmp_path, {
        "src/repro/kernels/ops.py": '''
            def foo(x):
                """Decode.

                PARITY: ALLCLOSE -- disagrees with DESIGN.md on purpose.
                """
                return x

            def bar(x):
                """No tag at all."""
                return x

            def baz(x):
                """Bad class.

                PARITY: SORTA -- not a class.
                """
                return x

            def _helper(x):
                return x
        ''',
    }, select=["parity-tags"])
    by_msg = sorted((e.rule, e.msg.split("'")[1]) for e in errs)
    assert by_msg == [("parity-tags", "bar"), ("parity-tags", "baz"),
                      ("parity-tags", "foo")]


def test_unknown_rule_rejected(tmp_path):
    with pytest.raises(ValueError, match="unknown lint rules"):
        run_lint(tmp_path, select=["no-such-rule"])


def test_repo_tree_is_lint_clean():
    """The shipped tree passes every rule -- what the CI job enforces."""
    assert run_lint(REPO_ROOT) == []


def test_bad_fixture_blocks_ci():
    """The negative path: a lint failure exits nonzero (blocking CI)
    without any bad code living on the default scan surface."""
    fixture = "tests/fixtures/lint_bad.py"
    errs = run_lint(REPO_ROOT, paths=[fixture])
    assert {"compat-only", "bare-assert"} <= _rules(errs)
    assert all(isinstance(e, LintError) and e.path == fixture for e in errs)
    assert lint_main([fixture, "--root", str(REPO_ROOT)]) == 1
    assert lint_main(["--root", str(REPO_ROOT)]) == 0


# --------------------------------------------------------------------------- #
# plan-side declarations + the static (trace-free) pass
# --------------------------------------------------------------------------- #

Q8_RING = CommSchedule(param_store="q8_block", reduce_wire="q8_block",
                       reduce_mode="ring_acc", gather_mode="ring",
                       prefetch=True)


def test_plan_declares_invariants():
    plain = make_plan(_model(), {"data": 8})
    names = {i["name"] for i in plain.invariants()}
    assert {"comm_bytes", "wire_dtype", "gathered_peak"} <= names
    assert "profile_fresh" not in names  # not an auto plan

    q8 = make_plan(_model(), {"data": 8}, Q8_RING)
    qnames = {i["name"] for i in q8.invariants()}
    assert {"ring_chunk", "no_f32_dequant", "ef_threading"} <= qnames
    # every declaration names its group and parity class
    assert all(i.get("group") and i.get("class")
               for i in q8.invariants())


def test_static_pass_catches_misaligned_ring_chunk():
    import dataclasses

    plan = make_plan(_model(), {"data": 8}, Q8_RING)
    assert verify_plan_static(plan).ok
    gname = max(plan.groups, key=lambda n: plan.groups[n].plan.total)
    e = plan.groups[gname]
    shard, block = e.plan.shard_size, e.quant_block
    # a declared chunk whose unit-1 wire snap lands off the block grid
    bad_chunk = next((c for c in range(block + 1, 32 * block)
                      if _snap_chunk(shard, c, block) != _snap_chunk(shard, c)),
                     None)
    assert bad_chunk is not None, (shard, block)
    pol = dataclasses.replace(e.policy, ring_chunk_elems=bad_chunk)
    bad = dataclasses.replace(
        plan, groups={**dict(plan.groups),
                      gname: dataclasses.replace(e, policy=pol)})
    rep = verify_plan_static(bad)
    assert not rep.ok
    (v,) = [v for v in rep.errors if v.invariant == "ring_chunk"]
    assert v.group == gname and str(bad_chunk) in v.expected
    assert "straddle" in v.found


def test_stale_profile_drift(tmp_path):
    """Satellite: an auto plan's pricing provenance is checkable.  The
    plan records name@content-hash (visible in describe()); a mutated
    profile on disk makes verify_plan_static warn (not fail); re-pricing
    against the mutated profile surfaces the drift in diff()."""
    prof_a = _measured_profile(name="drift-test")
    plan = make_plan(_model("gemma2-2b"), {"data": 8}, "auto",
                     cost_model=CostModel.from_profile(prof_a))
    assert plan.profile_name == "drift-test"
    assert plan.profile_hash == prof_a.content_hash()
    assert plan.profile_hash in plan.describe()
    assert any(i["name"] == "profile_fresh" for i in plan.invariants())

    # fresh profile on disk: the freshness check runs and stays quiet
    path_a = tmp_path / "fresh.json"
    prof_a.save(path_a)
    rep = verify_plan_static(plan, profile_path=str(path_a))
    assert rep.ok and not rep.warnings
    assert "*:profile_fresh" in rep.checked

    # mutated profile (an extra calibration sample changes the content
    # hash): stale pricing is a warning -- the plan still runs
    prof_b = _measured_profile(name="drift-test", sweep=(
        CommSample("gather", "bf16", "ring", 1 << 20, 16384,
                   (1 << 20) * 0.3e-3),))
    assert prof_b.content_hash() != prof_a.content_hash()
    path_b = tmp_path / "mutated.json"
    prof_b.save(path_b)
    rep = verify_plan_static(plan, profile_path=str(path_b))
    assert rep.ok
    (w,) = rep.warnings
    assert w.invariant == "profile_fresh" and "stale" in w.found
    assert plan.profile_hash in w.expected

    # re-pricing against the mutated profile: the drift is a first-class
    # plan difference, not a silent re-decision
    replan = make_plan(_model("gemma2-2b"), {"data": 8}, "auto",
                       cost_model=CostModel.from_profile(prof_b))
    assert replan.profile_hash == prof_b.content_hash()
    assert any("profile.hash" in line for line in plan.diff(replan))


# --------------------------------------------------------------------------- #
# 8-device: the full verifier on real traced plans (subprocess -- jax
# fixes the device count at first init)
# --------------------------------------------------------------------------- #

_VERIFY_DRIVER = textwrap.dedent("""
    import os, json, dataclasses
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax.numpy as jnp
    from repro.analysis import (extract_buffers, extract_comm,
                                trace_train_step, verify_runtime,
                                verify_trace)
    from repro.configs import get_config, build_model
    from repro.core.fsdp import FSDPRuntime
    from repro.core.schedule import VARIANTS
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(8, 1)
    model = build_model(get_config("qwen2.5-14b").reduced())
    out = {}

    q8 = dataclasses.replace(
        VARIANTS["overlap_all"], param_store="q8_block",
        reduce_wire="q8_block", reduce_dtype=None, reduce_mode="ring_acc",
        gather_mode="ring")
    rt = FSDPRuntime(model, mesh, schedule=q8, compute_dtype=jnp.bfloat16)
    rep = verify_runtime(rt)
    out["q8_ok"] = rep.ok
    out["q8_violations"] = [str(v) for v in rep.errors]
    out["q8_checked"] = sorted({c.split(":")[1] for c in rep.checked})

    # the runtime constructor gate is the same machinery
    FSDPRuntime(model, mesh, compute_dtype=jnp.bfloat16, verify=True)
    out["ctor_verify"] = True

    # tampered plan: promises a bf16 cast wire, the runtime ships q8
    gname = max(rt.plan.groups, key=lambda n: rt.plan.groups[n].plan.total)
    e = rt.plan.groups[gname]
    pol = dataclasses.replace(e.policy, store="bf16", reduce_wire=None)
    bad = dataclasses.replace(
        rt.plan, groups={**dict(rt.plan.groups),
                         gname: dataclasses.replace(e, policy=pol)})
    brep = verify_runtime(rt, plan=bad)
    out["tampered_ok"] = brep.ok
    out["tampered"] = sorted({(v.group, v.invariant) for v in brep.errors})

    # EF-threading regression, via the analyzer: verify the EF-declaring
    # q8 plan against a step that computes NO residual
    rt_noef = FSDPRuntime(model, mesh, compute_dtype=jnp.bfloat16)
    closed, shapes = trace_train_step(rt_noef)
    axis_sizes = {str(a): int(s) for a, s in zip(
        rt_noef.mesh.axis_names, rt_noef.mesh.devices.shape)}
    vrep = verify_trace(rt.plan, extract_comm(closed, axis_sizes),
                        extract_buffers(closed), shapes)
    out["ef_flagged"] = sorted({v.group for v in vrep.errors
                                if v.invariant == "ef_threading"})
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_verifier_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _VERIFY_DRIVER],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    # the real q8/ring plan proves out, with the full invariant surface
    assert data["q8_ok"], data["q8_violations"]
    assert {"comm_bytes", "comm_missing", "wire_dtype", "ring_chunk",
            "no_f32_dequant", "ef_threading",
            "gathered_peak"} <= set(data["q8_checked"])
    assert data["ctor_verify"]
    # the tampered plan fails, naming group + invariant
    assert not data["tampered_ok"]
    tampered = {tuple(t) for t in data["tampered"]}
    assert any(inv == "comm_missing" for _, inv in tampered)
    assert any(inv == "wire_dtype" for _, inv in tampered)
    # EF declared but never computed -> exactly the ef_threading invariant
    assert data["ef_flagged"], "missing EF residual went undetected"
